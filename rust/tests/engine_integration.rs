//! Integration tests over the real-execution engine: full BSP training
//! rounds (PJRT train steps → λ-weighted aggregation → optimizer →
//! controller) on heterogeneous simulated clusters.

use hetero_batch::cluster::cpu_cluster;
use hetero_batch::config::{ExperimentCfg, Policy};
use hetero_batch::data;
use hetero_batch::engine::{Engine, Slowdowns, TrainOpts};
use hetero_batch::runtime::Runtime;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn run(model: &str, policy: Policy, steps: u64, cores: &[usize]) -> hetero_batch::metrics::RunReport {
    let mut runtime = Runtime::open(artifacts_dir()).expect("make artifacts");
    let mut cfg = ExperimentCfg::default();
    cfg.workers = cpu_cluster(cores);
    cfg.policy = policy;
    // Real engine: executable swaps are cheap (pre-compiled), act fast.
    cfg.controller.min_obs = 3;
    let opts = TrainOpts {
        model: model.into(),
        policy,
        steps,
        seed: 1,
        ..TrainOpts::default()
    };
    let slow = Slowdowns::from_cores(cores);
    let mut ds = data::for_model(model, cores.len(), 1);
    let mut engine = Engine::new(&mut runtime, cfg, opts, slow).unwrap();
    engine.run(ds.as_mut()).unwrap()
}

#[test]
fn mlp_trains_and_loss_decreases() {
    let r = run("mlp", Policy::Uniform, 40, &[8, 8]);
    assert_eq!(r.total_iters, 40);
    let first = r.losses.first().unwrap().2;
    let last = r.losses.last().unwrap().2;
    assert!(
        last < first * 0.8,
        "loss barely moved: {first} -> {last}"
    );
    // Two workers × 40 iterations of records.
    assert_eq!(r.iters.len(), 80);
}

#[test]
fn dynamic_rebuckets_toward_fast_worker() {
    // Worker 1 has 4x the capacity of worker 0; the dynamic controller
    // must move batch share toward it.
    let r = run("mlp", Policy::Dynamic, 40, &[4, 16]);
    assert!(
        !r.adjustments.is_empty(),
        "controller never adjusted under 4x imbalance"
    );
    let final_b = r.final_batches().unwrap();
    assert!(
        final_b[1] > final_b[0],
        "fast worker should get the bigger bucket: {final_b:?}"
    );
}

#[test]
fn uniform_policy_never_adjusts() {
    let r = run("mlp", Policy::Uniform, 15, &[4, 16]);
    assert!(r.adjustments.is_empty());
    // All records share one batch size.
    let b0 = r.iters[0].batch;
    assert!(r.iters.iter().all(|i| i.batch == b0));
}

#[test]
fn static_policy_splits_by_flops_estimate() {
    let r = run("mlp", Policy::Static, 10, &[4, 16]);
    assert!(r.adjustments.is_empty(), "static is open-loop");
    let b: Vec<f64> = (0..2)
        .map(|w| r.iters.iter().find(|i| i.worker == w).unwrap().batch)
        .collect();
    // 4:16 cores ⇒ roughly 1:4 batch split (bucket-quantized).
    assert!(b[1] >= 3.0 * b[0], "split {b:?}");
}

#[test]
fn variable_batching_reduces_iteration_gap_in_real_engine() {
    let uni = run("mlp", Policy::Uniform, 30, &[4, 16]);
    let dyn_ = run("mlp", Policy::Dynamic, 30, &[4, 16]);
    let gap_u = uni.iteration_gap(2);
    // Skip the controller's warm-up iterations when judging the dynamic
    // run: look at the last 10 iterations only.
    let tail: Vec<_> = dyn_
        .iters
        .iter()
        .filter(|i| i.iter >= 20)
        .cloned()
        .collect();
    let mut tail_report = hetero_batch::metrics::RunReport::new("tail");
    tail_report.iters = tail
        .into_iter()
        .map(|mut i| {
            i.iter -= 20;
            i
        })
        .collect();
    let gap_d = tail_report.iteration_gap(2);
    // The bucket floor limits equalization (the 4-core worker's smallest
    // bucket still carries the fixed dispatch cost x4 virtual slowdown),
    // and wall-clock noise is real here — require a solid reduction, not
    // the simulator-grade 2x.
    assert!(
        gap_d < gap_u * 0.85,
        "dynamic gap {gap_d} not below uniform {gap_u}"
    );
}

fn run_mlp_eval(eval_every: u64, steps: u64) -> hetero_batch::metrics::RunReport {
    let mut runtime = Runtime::open(artifacts_dir()).expect("make artifacts");
    let mut cfg = ExperimentCfg::default();
    cfg.workers = cpu_cluster(&[8, 8]);
    cfg.policy = Policy::Uniform;
    let opts = TrainOpts {
        model: "mlp".into(),
        policy: Policy::Uniform,
        steps,
        eval_every,
        seed: 1,
        ..TrainOpts::default()
    };
    // Shard 2 (= k) is the dedicated eval stream; shards 0..2 train.
    let mut ds = data::for_model("mlp", 3, 1);
    let mut engine = Engine::new(&mut runtime, cfg, opts, Slowdowns::none(2)).unwrap();
    engine.run(ds.as_mut()).unwrap()
}

#[test]
fn eval_every_records_periodic_evals() {
    let r = run_mlp_eval(4, 10);
    // Evals after steps 4 and 8.
    assert_eq!(r.evals.len(), 2, "expected 2 evals, got {:?}", r.evals);
    assert_eq!(r.evals[0].iter, 4);
    assert_eq!(r.evals[1].iter, 8);
    for e in &r.evals {
        assert!(e.loss.is_finite());
        assert!(e.metric.is_finite());
    }
    // Classification metric is accuracy in [0, 1].
    assert!(r.evals.iter().all(|e| (0.0..=1.0).contains(&e.metric)));
}

#[test]
fn eval_is_observation_only() {
    // Evals draw from the dedicated shard, so enabling them must not
    // change the training trajectory at all.
    let with = run_mlp_eval(3, 9);
    let without = run_mlp_eval(0, 9);
    assert_eq!(with.evals.len(), 3);
    assert!(without.evals.is_empty());
    for (a, b) in with.losses.iter().zip(&without.losses) {
        assert_eq!(a.2, b.2, "eval perturbed training at step {}", a.1);
    }
}

fn run_with(prefetch: bool, pool_threads: usize, steps: u64) -> (hetero_batch::metrics::RunReport, f64) {
    let cores = [4usize, 16];
    let mut runtime = Runtime::open(artifacts_dir()).expect("make artifacts");
    let mut cfg = ExperimentCfg::default();
    cfg.workers = cpu_cluster(&cores);
    cfg.policy = Policy::Uniform;
    let opts = TrainOpts {
        model: "mlp".into(),
        policy: Policy::Uniform,
        steps,
        seed: 1,
        prefetch,
        pool_threads,
        ..TrainOpts::default()
    };
    let mut ds = data::for_model("mlp", cores.len(), 1);
    let mut engine =
        Engine::new(&mut runtime, cfg, opts, Slowdowns::from_cores(&cores)).unwrap();
    let t0 = std::time::Instant::now();
    let r = engine.run(ds.as_mut()).unwrap();
    (r, t0.elapsed().as_secs_f64())
}

#[test]
fn prefetch_is_bit_identical_and_not_slower() {
    // Batch generation order is unchanged by prefetch, so the loss
    // curves must match exactly; wall time must not regress (batch
    // generation overlaps the PJRT step). Timing gets a generous noise
    // margin — the hard claim is equality of numerics.
    let (plain, t_plain) = run_with(false, 1, 25);
    let (pre, t_pre) = run_with(true, 1, 25);
    assert_eq!(plain.losses.len(), pre.losses.len());
    for (a, b) in plain.losses.iter().zip(&pre.losses) {
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2, "prefetch changed numerics at step {}", a.1);
    }
    println!("round wall: prefetch {t_pre:.3}s vs plain {t_plain:.3}s");
    assert!(
        t_pre <= t_plain * 1.20,
        "prefetch regressed wall time: {t_pre:.3}s vs {t_plain:.3}s"
    );
}

#[test]
fn sharded_optimizer_path_is_bit_identical() {
    // pool_threads routes the leader update through the sharded fused
    // kernels; numerics must match the single-threaded path exactly.
    let (st, _) = run_with(true, 1, 15);
    let (mt, _) = run_with(true, 4, 15);
    for (a, b) in st.losses.iter().zip(&mt.losses) {
        assert_eq!(a.2, b.2, "sharded optimizer diverged at step {}", a.1);
    }
}

#[test]
fn loss_target_stops_early() {
    let mut runtime = Runtime::open(artifacts_dir()).unwrap();
    let mut cfg = ExperimentCfg::default();
    cfg.workers = cpu_cluster(&[8, 8]);
    cfg.policy = Policy::Uniform;
    let opts = TrainOpts {
        model: "linreg".into(),
        policy: Policy::Uniform,
        steps: 500,
        seed: 0,
        loss_target: 1.0, // init MSE is ~variance of y ≈ several
        ..TrainOpts::default()
    };
    let mut ds = data::for_model("linreg", 2, 0);
    let mut engine =
        Engine::new(&mut runtime, cfg, opts, Slowdowns::none(2)).unwrap();
    let r = engine.run(ds.as_mut()).unwrap();
    assert!(r.reached_target);
    assert!(
        r.total_iters < 500,
        "should stop early, ran {}",
        r.total_iters
    );
}

#[test]
fn engine_rejects_bad_setup() {
    let mut runtime = Runtime::open(artifacts_dir()).unwrap();
    let mut cfg = ExperimentCfg::default();
    cfg.workers = cpu_cluster(&[4, 8]);
    // Slowdown length mismatch.
    assert!(Engine::new(
        &mut runtime,
        cfg.clone(),
        TrainOpts::default(),
        Slowdowns::none(3)
    )
    .is_err());
    // Unknown model.
    let opts = TrainOpts {
        model: "bogus".into(),
        ..TrainOpts::default()
    };
    assert!(Engine::new(&mut runtime, cfg, opts, Slowdowns::none(2)).is_err());
}
