//! Integration tests over the real-execution backend: full training
//! sessions (PJRT train steps → λ-weighted aggregation → optimizer →
//! controller) on heterogeneous simulated clusters, driven by the same
//! `Session` loop the simulator uses — including ASP/SSP sync and
//! availability traces on real runs.

use hetero_batch::config::Policy;
use hetero_batch::controller::ControllerCfg;
use hetero_batch::metrics::RunReport;
use hetero_batch::ps::RetainPolicy;
use hetero_batch::runtime::Runtime;
use hetero_batch::session::{Backend, BspAgg, RealBackend, Session, SessionBuilder, Slowdowns};
use hetero_batch::sync::SyncMode;
use hetero_batch::trace::{
    AvailTrace, ClusterTraces, MembershipEvent, MembershipKind, MembershipPlan,
};

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

/// Real engine: executable swaps are cheap (pre-compiled), act fast.
fn fast_controller() -> ControllerCfg {
    ControllerCfg {
        min_obs: 3,
        ..ControllerCfg::default()
    }
}

fn real_run(builder: SessionBuilder) -> RunReport {
    let mut runtime = Runtime::open(artifacts_dir()).expect("make artifacts");
    builder
        .build_real(&mut runtime)
        .unwrap()
        .run()
        .unwrap()
}

fn run(model: &str, policy: Policy, steps: u64, cores: &[usize]) -> RunReport {
    real_run(
        Session::builder()
            .model(model)
            .cores(cores)
            .policy(policy)
            .steps(steps)
            .seed(1)
            .controller(fast_controller()),
    )
}

#[test]
fn mlp_trains_and_loss_decreases() {
    let r = run("mlp", Policy::Uniform, 40, &[8, 8]);
    assert_eq!(r.total_iters, 40);
    let first = r.losses.first().unwrap().2;
    let last = r.losses.last().unwrap().2;
    assert!(last < first * 0.8, "loss barely moved: {first} -> {last}");
    // Two workers × 40 iterations of records.
    assert_eq!(r.iters.len(), 80);
}

#[test]
fn dynamic_rebuckets_toward_fast_worker() {
    // Worker 1 has 4x the capacity of worker 0; the dynamic controller
    // must move batch share toward it.
    let r = run("mlp", Policy::Dynamic, 40, &[4, 16]);
    assert!(
        !r.adjustments.is_empty(),
        "controller never adjusted under 4x imbalance"
    );
    let final_b = r.final_batches().unwrap();
    assert!(
        final_b[1] > final_b[0],
        "fast worker should get the bigger bucket: {final_b:?}"
    );
}

#[test]
fn uniform_policy_never_adjusts() {
    let r = run("mlp", Policy::Uniform, 15, &[4, 16]);
    assert!(r.adjustments.is_empty());
    // All records share one batch size.
    let b0 = r.iters[0].batch;
    assert!(r.iters.iter().all(|i| i.batch == b0));
}

#[test]
fn static_policy_splits_by_flops_estimate() {
    let r = run("mlp", Policy::Static, 10, &[4, 16]);
    assert!(r.adjustments.is_empty(), "static is open-loop");
    let b: Vec<f64> = (0..2)
        .map(|w| r.iters.iter().find(|i| i.worker == w).unwrap().batch)
        .collect();
    // 4:16 cores ⇒ roughly 1:4 batch split (bucket-quantized).
    assert!(b[1] >= 3.0 * b[0], "split {b:?}");
}

#[test]
fn variable_batching_reduces_iteration_gap_in_real_engine() {
    let uni = run("mlp", Policy::Uniform, 30, &[4, 16]);
    let dyn_ = run("mlp", Policy::Dynamic, 30, &[4, 16]);
    let gap_u = uni.iteration_gap(2);
    // Skip the controller's warm-up iterations when judging the dynamic
    // run: look at the last 10 iterations only.
    let tail: Vec<_> = dyn_
        .iters
        .iter()
        .filter(|i| i.iter >= 20)
        .cloned()
        .collect();
    let mut tail_report = RunReport::new("tail");
    tail_report.iters = tail
        .into_iter()
        .map(|mut i| {
            i.iter -= 20;
            i
        })
        .collect();
    let gap_d = tail_report.iteration_gap(2);
    // The bucket floor limits equalization (the 4-core worker's smallest
    // bucket still carries the fixed dispatch cost x4 virtual slowdown),
    // and wall-clock noise is real here — require a solid reduction, not
    // the simulator-grade 2x.
    assert!(
        gap_d < gap_u * 0.85,
        "dynamic gap {gap_d} not below uniform {gap_u}"
    );
}

fn run_mlp_eval(eval_every: u64, steps: u64) -> RunReport {
    real_run(
        Session::builder()
            .model("mlp")
            .cores(&[8, 8])
            .policy(Policy::Uniform)
            .steps(steps)
            .eval_every(eval_every)
            .seed(1),
    )
}

#[test]
fn eval_every_records_periodic_evals() {
    let r = run_mlp_eval(4, 10);
    // Evals after steps 4 and 8.
    assert_eq!(r.evals.len(), 2, "expected 2 evals, got {:?}", r.evals);
    assert_eq!(r.evals[0].iter, 4);
    assert_eq!(r.evals[1].iter, 8);
    for e in &r.evals {
        assert!(e.loss.is_finite());
        assert!(e.metric.is_finite());
    }
    // Classification metric is accuracy in [0, 1].
    assert!(r.evals.iter().all(|e| (0.0..=1.0).contains(&e.metric)));
}

#[test]
fn eval_is_observation_only() {
    // Evals draw from the dedicated shard, so enabling them must not
    // change the training trajectory at all.
    let with = run_mlp_eval(3, 9);
    let without = run_mlp_eval(0, 9);
    assert_eq!(with.evals.len(), 3);
    assert!(without.evals.is_empty());
    for (a, b) in with.losses.iter().zip(&without.losses) {
        assert_eq!(a.2, b.2, "eval perturbed training at step {}", a.1);
    }
}

fn run_with(prefetch: bool, pool_threads: usize, steps: u64) -> (RunReport, f64) {
    let t0 = std::time::Instant::now();
    let r = real_run(
        Session::builder()
            .model("mlp")
            .cores(&[4, 16])
            .policy(Policy::Uniform)
            .steps(steps)
            .seed(1)
            .prefetch(prefetch)
            .pool_threads(pool_threads),
    );
    let wall = t0.elapsed().as_secs_f64();
    (r, wall)
}

#[test]
fn prefetch_is_bit_identical_and_not_slower() {
    // Batch generation order is unchanged by prefetch, so the loss
    // curves must match exactly; wall time must not regress (batch
    // generation overlaps the PJRT step). Timing gets a generous noise
    // margin — the hard claim is equality of numerics.
    let (plain, t_plain) = run_with(false, 1, 25);
    let (pre, t_pre) = run_with(true, 1, 25);
    assert_eq!(plain.losses.len(), pre.losses.len());
    for (a, b) in plain.losses.iter().zip(&pre.losses) {
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2, "prefetch changed numerics at step {}", a.1);
    }
    println!("round wall: prefetch {t_pre:.3}s vs plain {t_plain:.3}s");
    assert!(
        t_pre <= t_plain * 1.20,
        "prefetch regressed wall time: {t_pre:.3}s vs {t_plain:.3}s"
    );
}

#[test]
fn sharded_optimizer_path_is_bit_identical() {
    // pool_threads routes the leader update through the sharded fused
    // kernels; numerics must match the single-threaded path exactly.
    let (st, _) = run_with(true, 1, 15);
    let (mt, _) = run_with(true, 4, 15);
    for (a, b) in st.losses.iter().zip(&mt.losses) {
        assert_eq!(a.2, b.2, "sharded optimizer diverged at step {}", a.1);
    }
}

#[test]
fn loss_target_stops_early() {
    let r = real_run(
        Session::builder()
            .model("linreg")
            .cores(&[8, 8])
            .policy(Policy::Uniform)
            .steps(500)
            .seed(0)
            .loss_target(1.0), // init MSE is ~variance of y ≈ several
    );
    assert!(r.reached_target);
    assert!(r.total_iters < 500, "should stop early, ran {}", r.total_iters);
}

#[test]
fn session_rejects_bad_setup() {
    let mut runtime = Runtime::open(artifacts_dir()).unwrap();
    // Slowdown length mismatch.
    assert!(Session::builder()
        .model("mlp")
        .cores(&[4, 8])
        .steps(10)
        .slowdowns(Slowdowns::none(3))
        .build_real(&mut runtime)
        .is_err());
    // Unknown model.
    assert!(Session::builder()
        .model("bogus")
        .cores(&[4, 8])
        .steps(10)
        .build_real(&mut runtime)
        .is_err());
    // Real runs need an explicit step budget.
    assert!(Session::builder()
        .model("mlp")
        .cores(&[4, 8])
        .steps(0)
        .build_real(&mut runtime)
        .is_err());
}

// ---------------------------------------------------------------------
// New with the unified Session API: ASP/SSP and availability traces on
// the real runtime.

#[test]
fn asp_trains_on_real_runtime() {
    let r = real_run(
        Session::builder()
            .model("mlp")
            .cores(&[4, 16])
            .policy(Policy::Uniform)
            .sync(SyncMode::Asp)
            .steps(10)
            .seed(1),
    );
    // ASP counts individual worker updates: a 10-step budget on 2
    // workers is 20 updates, each applied as its own optimizer step.
    assert_eq!(r.total_iters, 20);
    assert_eq!(r.losses.len(), 20);
    assert!(r.reached_target);
    // No barrier ⇒ no wait time anywhere.
    assert!(r.iters.iter().all(|i| i.wait == 0.0));
    assert!(r.losses.iter().all(|l| l.2.is_finite()));
    let first = r.losses.first().unwrap().2;
    let last = r.losses.last().unwrap().2;
    assert!(last < first, "ASP made no progress: {first} -> {last}");
}

#[test]
fn ssp_bounds_lead_on_real_runtime() {
    let r = real_run(
        Session::builder()
            .model("mlp")
            .cores(&[4, 16])
            .policy(Policy::Uniform)
            .sync(SyncMode::Ssp { bound: 2 })
            .steps(12)
            .seed(1),
    );
    assert!(r.total_iters > 0);
    // Reconstruct clocks from the records: lead ≤ bound + 1.
    let mut max_clock = [0u64; 2];
    for rec in &r.iters {
        max_clock[rec.worker] = max_clock[rec.worker].max(rec.iter);
    }
    let lead = max_clock.iter().max().unwrap() - max_clock.iter().min().unwrap();
    assert!(lead <= 3, "ssp lead {lead} exceeds bound+1");
}

#[test]
fn trace_capacity_loss_triggers_dynamic_readjustment_in_real_run() {
    // Mirror of the simulator's trace_slowdown_triggers_dynamic_
    // readjustment, on the real runtime: a spot-style availability trace
    // halves worker 0's capacity partway through a *real* training run;
    // the controller must react with a smaller batch for worker 0.
    //
    // Virtual time scales with this machine's PJRT step time, so first
    // calibrate: measure the virtual round time of a short uniform run.
    let probe = real_run(
        Session::builder()
            .model("mlp")
            .cores(&[8, 8])
            .policy(Policy::Uniform)
            .steps(6)
            .seed(1),
    );
    let round_s = probe.total_time / 6.0;
    assert!(round_s > 0.0);
    // Capacity drops to 35% after ~8 rounds; 50 further rounds give the
    // drift detector plenty of post-change signal.
    let t_drop = round_s * 8.0;
    let traces = ClusterTraces {
        traces: vec![
            AvailTrace::from_segments(vec![(0.0, 1.0), (t_drop, 0.35)]),
            AvailTrace::constant(),
        ],
    };
    let r = real_run(
        Session::builder()
            .model("mlp")
            .cores(&[8, 8])
            .policy(Policy::Dynamic)
            .steps(60)
            .seed(1)
            .controller(fast_controller())
            .traces(traces),
    );
    let late: Vec<_> = r
        .adjustments
        .iter()
        .filter(|a| a.time > t_drop)
        .collect();
    assert!(
        !late.is_empty(),
        "no reaction to the capacity loss (drop at {t_drop:.3}s, \
         adjustments: {:?})",
        r.adjustments
    );
    let final_b = r.final_batches().unwrap();
    assert!(
        final_b[0] < final_b[1],
        "worker 0 batch {final_b:?} not reduced after capacity loss"
    );
}

// ---------------------------------------------------------------------
// Eager reduction-tree aggregation (§Perf iteration 6, DESIGN.md §11):
// the eager path must leave runs bit-identical to the
// collect-then-aggregate baseline — the tree's fixed rank-indexed shape
// makes the summation order independent of when combines happen.

#[test]
fn eager_and_collect_backends_bit_identical_under_scripted_churn() {
    // Backend-level script, free of wall-clock noise (virtual time
    // never enters the numerics here): two BSP rounds over 3 workers;
    // in round 2 worker 1's gradient is produced and then revoked
    // before the barrier, so the eager tree must rebuild the revoked
    // leaf's ancestor path from the surviving sibling partials —
    // landing on exactly the bits the collect path computes over the
    // survivors at the barrier.
    let run = |agg: BspAgg| -> Vec<u32> {
        let mut rt = Runtime::open(artifacts_dir()).unwrap();
        let mut be = RealBackend::new(
            &mut rt,
            "mlp",
            3,
            vec![1.0; 3],
            1,    // seed
            4,    // steps (optimizer schedule horizon)
            0,    // eval_every
            0,    // b0 hint
            4,    // pool shards
            true, // prefetch
            Some(agg),
        )
        .unwrap();
        let batches = vec![64.0, 64.0, 64.0];
        // Round 1: full cohort.
        be.execute_wave(&[0, 1, 2], &batches, 0.0).unwrap();
        for w in 0..3 {
            be.stage_update(w, &batches).unwrap();
        }
        be.apply_update(&[0, 1, 2], &batches).unwrap();
        // Round 2: worker 1 executes, then its instance is revoked
        // before the barrier; the round closes over the survivors.
        be.execute_wave(&[0, 1, 2], &batches, 1.0).unwrap();
        be.stage_update(0, &batches).unwrap();
        be.retire_worker(1).unwrap();
        be.stage_update(2, &batches).unwrap();
        be.apply_update(&[0, 2], &batches).unwrap();
        be.params().iter().map(|p| p.to_bits()).collect()
    };
    let eager = run(BspAgg::Eager(RetainPolicy::Retain));
    let collect = run(BspAgg::Collect);
    assert_eq!(eager, collect, "eager/collect parameters diverged");
}

#[test]
fn eager_and_collect_sessions_bit_identical() {
    // Full BSP sessions (uniform policy, so the trajectory carries no
    // wall-noise-dependent controller decisions): the loss curves must
    // match bitwise between the eager tree and the collect baseline.
    let mk = |eager: bool| {
        real_run(
            Session::builder()
                .model("mlp")
                .cores(&[4, 16])
                .policy(Policy::Uniform)
                .steps(12)
                .seed(1)
                .eager_agg(eager),
        )
    };
    let e = mk(true);
    let c = mk(false);
    assert_eq!(e.total_iters, c.total_iters);
    assert_eq!(e.losses.len(), c.losses.len());
    for (a, b) in e.losses.iter().zip(&c.losses) {
        assert_eq!(a.1, b.1);
        assert_eq!(
            a.2.to_bits(),
            b.2.to_bits(),
            "eager/collect loss diverged at step {}",
            a.1
        );
    }
}

#[test]
fn eager_and_collect_sessions_agree_under_churned_run() {
    // End-to-end churn: worker 0 is revoked mid-run (probe-calibrated,
    // as in the sim-vs-real parity test).  Epoch structure must match;
    // the full-cohort prefix — rounds both runs completed before their
    // revocation landed — must be bitwise identical, and when the
    // revocation lands in the same round on both sides (the common
    // case; wall drift can shift it by one) the entire curve must.
    let probe = real_run(
        Session::builder()
            .model("mlp")
            .cores(&[4, 16])
            .policy(Policy::Uniform)
            .steps(6)
            .seed(1),
    );
    let plan = MembershipPlan::new(vec![MembershipEvent {
        time: 3.5 * probe.total_time / 6.0,
        worker: 0,
        kind: MembershipKind::Revoke,
    }]);
    let mk = |eager: bool| {
        real_run(
            Session::builder()
                .model("mlp")
                .cores(&[4, 16])
                .policy(Policy::Uniform)
                .steps(8)
                .seed(1)
                .membership(plan.clone())
                .eager_agg(eager),
        )
    };
    let e = mk(true);
    let c = mk(false);
    let epochs = |r: &RunReport| -> Vec<(u64, usize, &'static str, usize)> {
        r.epochs
            .iter()
            .map(|ev| (ev.epoch, ev.worker, ev.kind.label(), ev.live))
            .collect()
    };
    assert_eq!(epochs(&e), epochs(&c), "epoch sequences diverged");
    assert_eq!(epochs(&e), vec![(1, 0, "revoke", 1)]);
    let pre = |r: &RunReport| r.iters.iter().filter(|i| i.worker == 0).count();
    let (pre_e, pre_c) = (pre(&e), pre(&c));
    let shared = pre_e.min(pre_c);
    assert!(shared >= 1, "revocation landed before any full round");
    for (a, b) in e.losses.iter().zip(&c.losses).take(shared) {
        assert_eq!(
            a.2.to_bits(),
            b.2.to_bits(),
            "full-cohort prefix diverged at round {}",
            a.1
        );
    }
    if pre_e == pre_c {
        assert_eq!(e.losses.len(), c.losses.len());
        for (a, b) in e.losses.iter().zip(&c.losses) {
            assert_eq!(
                a.2.to_bits(),
                b.2.to_bits(),
                "post-revocation curve diverged at round {}",
                a.1
            );
        }
    }
    assert_eq!(e.total_iters, 8);
    assert_eq!(c.total_iters, 8);
}

#[test]
fn sim_and_real_bsp_gating_sequences_match() {
    // The same Session loop gates both backends: under BSP the sequence
    // of (worker, round) records must be identical between a real run
    // and a simulated run of the same shape.
    let real = run("mlp", Policy::Uniform, 8, &[4, 16]);
    let sim = Session::builder()
        .model("mnist")
        .cores(&[4, 16])
        .policy(Policy::Uniform)
        .steps(8)
        .build_sim()
        .unwrap()
        .run()
        .unwrap();
    let gate = |r: &RunReport| -> Vec<(usize, u64)> {
        r.iters.iter().map(|i| (i.worker, i.iter)).collect()
    };
    assert_eq!(gate(&real), gate(&sim));
}

#[test]
fn sim_and_real_gating_and_epochs_match_under_revocation() {
    // Extension of the parity test above with a membership epoch: worker
    // 0 is revoked mid-round-3.  Round timescales differ between the
    // backends (virtual vs wall), so each side's event time is
    // denominated in its own probed round time.  The membership-epoch
    // sequence and the gating *structure* must match; the revocation's
    // exact round index on the real side is asserted loosely (wall-time
    // drift between probe and measured run can shift it by a round —
    // exact cross-backend sequence parity is pinned deterministically on
    // the mock backends in tests/property.rs).
    let plan_at = |round_s: f64| {
        MembershipPlan::new(vec![MembershipEvent {
            time: 3.5 * round_s,
            worker: 0,
            kind: MembershipKind::Revoke,
        }])
    };
    // Real: probe the wall round time, then rerun with the revocation.
    let probe = real_run(
        Session::builder()
            .model("mlp")
            .cores(&[4, 16])
            .policy(Policy::Uniform)
            .steps(6)
            .seed(1),
    );
    let real = real_run(
        Session::builder()
            .model("mlp")
            .cores(&[4, 16])
            .policy(Policy::Uniform)
            .steps(8)
            .seed(1)
            .membership(plan_at(probe.total_time / 6.0)),
    );
    // Sim: same shape, its own probed (virtual) round time.
    let sim_base = || {
        Session::builder()
            .model("mnist")
            .cores(&[4, 16])
            .policy(Policy::Uniform)
            .noise(0.01)
            .seed(1)
    };
    let sim_probe = sim_base().steps(6).build_sim().unwrap().run().unwrap();
    let sim = sim_base()
        .steps(8)
        .membership(plan_at(sim_probe.total_time / 6.0))
        .build_sim()
        .unwrap()
        .run()
        .unwrap();

    let epochs = |r: &RunReport| -> Vec<(u64, usize, &'static str, usize)> {
        r.epochs
            .iter()
            .map(|e| (e.epoch, e.worker, e.kind.label(), e.live))
            .collect()
    };
    assert_eq!(epochs(&real), epochs(&sim), "epoch sequences diverged");
    assert_eq!(epochs(&real), vec![(1, 0, "revoke", 1)]);
    // Gating structure, both backends: the survivor runs every round;
    // the revoked worker runs a contiguous prefix of rounds and then
    // never again.
    let rounds_of = |r: &RunReport, w: usize| -> Vec<u64> {
        r.iters
            .iter()
            .filter(|i| i.worker == w)
            .map(|i| i.iter)
            .collect()
    };
    for r in [&real, &sim] {
        assert_eq!(rounds_of(r, 1), (0..8).collect::<Vec<u64>>());
        let pre = rounds_of(r, 0);
        assert!(!pre.is_empty() && pre.len() < 8, "revocation round off: {pre:?}");
        assert_eq!(pre, (0..pre.len() as u64).collect::<Vec<u64>>());
    }
    // The sim timeline is deterministic (low noise, probe-calibrated):
    // the revocation lands exactly mid-round-3 there.
    assert_eq!(rounds_of(&sim, 0), vec![0, 1, 2]);
    // Σb conserved across the transition on both backends: the real
    // (bucketed) survivor snaps to exactly the freed mass (64+64 → 128
    // is on the mlp grid), the sim one is continuous.
    let sum = |r: &RunReport| -> f64 { r.epochs[0].batches.iter().sum() };
    assert_eq!(sum(&real), 128.0);
    assert!((sum(&sim) - 200.0).abs() < 1e-9);
    // Both runs complete their full 8-round budget on the survivor.
    assert_eq!(real.total_iters, 8);
    assert_eq!(sim.total_iters, 8);
    assert!(real.reached_target);
}
