//! Integration tests over the PJRT runtime: load real artifacts, execute
//! train/eval steps, and check numerics against closed forms.
//!
//! Requires `make artifacts`; tests panic with a clear message otherwise
//! (artifacts are part of the build contract, not an optional extra).

use hetero_batch::data::{self, Batch, Dataset};
use hetero_batch::ps;
use hetero_batch::runtime::{Runtime, StepKind};

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn open() -> Runtime {
    Runtime::open(artifacts_dir()).expect("run `make artifacts` first")
}

#[test]
fn manifest_covers_all_models() {
    let rt = open();
    for name in ["linreg", "mlp", "cnn", "transformer"] {
        let m = rt.model(name).unwrap();
        assert!(!m.buckets.is_empty(), "{name} has no buckets");
        assert!(m.param_total > 0);
    }
}

#[test]
fn init_params_load_and_are_finite() {
    let rt = open();
    for name in ["linreg", "mlp", "cnn", "transformer"] {
        let p = rt.init_params(name).unwrap();
        assert_eq!(p.len(), rt.model(name).unwrap().param_total);
        assert!(p.iter().all(|x| x.is_finite()), "{name} has non-finite init");
    }
}

#[test]
fn linreg_gradients_match_closed_form() {
    // dL/dw = 2/b · Xᵀ(Xw + b − y); dL/db = 2·mean(resid).
    let mut rt = open();
    let b = 8usize;
    let params = vec![0.5f32, -0.25, 0.1, 0.05]; // w=(.5,-.25,.1), b=.05
    let x: Vec<f32> = (0..b * 3).map(|i| (i as f32 * 0.37).sin()).collect();
    let y: Vec<f32> = (0..b).map(|i| (i as f32 * 0.11).cos()).collect();
    let batch = Batch {
        x_f32: x.clone(),
        x_i32: vec![],
        y_f32: y.clone(),
        y_i32: vec![],
        batch_size: b,
    };
    let out = rt.train_step("linreg", b, &params, &batch).unwrap();

    // Closed form in f64.
    let w = [0.5f64, -0.25, 0.1];
    let bias = 0.05f64;
    let mut gw = [0.0f64; 3];
    let mut gb = 0.0f64;
    let mut loss = 0.0f64;
    for i in 0..b {
        let xi = &x[i * 3..(i + 1) * 3];
        let pred: f64 =
            xi.iter().zip(&w).map(|(&a, &b)| a as f64 * b).sum::<f64>() + bias;
        let r = pred - y[i] as f64;
        loss += r * r;
        for j in 0..3 {
            gw[j] += 2.0 * r * xi[j] as f64;
        }
        gb += 2.0 * r;
    }
    loss /= b as f64;
    for j in 0..3 {
        gw[j] /= b as f64;
    }
    gb /= b as f64;

    assert!((out.loss as f64 - loss).abs() < 1e-4, "loss {} vs {loss}", out.loss);
    for j in 0..3 {
        assert!(
            (out.grads[j] as f64 - gw[j]).abs() < 1e-4,
            "gw[{j}] {} vs {}",
            out.grads[j],
            gw[j]
        );
    }
    assert!((out.grads[3] as f64 - gb).abs() < 1e-4);
}

#[test]
fn mlp_initial_loss_near_ln10() {
    let mut rt = open();
    let params = rt.init_params("mlp").unwrap();
    let mut ds = data::for_model("mlp", 1, 0);
    let batch = ds.next_batch(0, 32);
    let out = rt.train_step("mlp", 32, &params, &batch).unwrap();
    assert!(
        (out.loss - (10.0f32).ln()).abs() < 1.5,
        "initial CE {} far from ln10",
        out.loss
    );
    assert!(out.grads.iter().all(|g| g.is_finite()));
    // Gradient must be non-trivial.
    let norm: f32 = out.grads.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(norm > 1e-3, "zero gradient? norm={norm}");
}

#[test]
fn sgd_loop_reduces_loss_all_models() {
    let mut rt = open();
    for (name, bucket, lr, steps) in [
        ("linreg", 32usize, 0.05f32, 30),
        ("mlp", 16, 0.05, 25),
        ("cnn", 8, 0.05, 20),
        ("transformer", 4, 0.2, 25),
    ] {
        let mut params = rt.init_params(name).unwrap();
        let mut ds = data::for_model(name, 1, 7);
        let mut first = None;
        let mut last = 0.0f32;
        for _ in 0..steps {
            let batch = ds.next_batch(0, bucket);
            let out = rt.train_step(name, bucket, &params, &batch).unwrap();
            first.get_or_insert(out.loss);
            last = out.loss;
            for (p, g) in params.iter_mut().zip(&out.grads) {
                *p -= lr * g;
            }
        }
        let first = first.unwrap();
        assert!(
            last < first,
            "{name}: loss did not decrease ({first} -> {last})"
        );
    }
}

#[test]
fn eval_step_reports_metric() {
    let mut rt = open();
    let params = rt.init_params("mlp").unwrap();
    let mut ds = data::for_model("mlp", 1, 0);
    let batch = ds.next_batch(0, 64);
    let out = rt.eval_step("mlp", 64, &params, &batch).unwrap();
    assert!(out.loss.is_finite());
    // Accuracy at init ≈ 10% (10 classes).
    assert!((0.0..=1.0).contains(&out.metric), "acc={}", out.metric);
}

#[test]
fn bucket_mismatch_rejected() {
    let mut rt = open();
    let params = rt.init_params("mlp").unwrap();
    let mut ds = data::for_model("mlp", 1, 0);
    let batch = ds.next_batch(0, 16);
    // Batch of 16 against bucket 8 must fail fast, not execute.
    assert!(rt.train_step("mlp", 8, &params, &batch).is_err());
    // Bad param vector too.
    let batch = ds.next_batch(0, 8);
    assert!(rt.train_step("mlp", 8, &params[1..], &batch).is_err());
    // Unknown model.
    assert!(rt.train_step("nope", 8, &params, &batch).is_err());
}

#[test]
fn warmup_compiles_all_buckets() {
    let mut rt = open();
    rt.warmup("linreg", &[StepKind::Train, StepKind::Eval]).unwrap();
    let n = rt.model("linreg").unwrap().buckets.len();
    assert_eq!(rt.compiled_count(), 2 * n);
}

#[test]
fn xla_agg_matches_rust_agg() {
    // The Pallas grad_agg artifact and the Rust hot-path aggregation must
    // agree — this closes the loop L1 kernel ↔ L3 implementation.
    let mut rt = open();
    let d = 1_500_000usize; // spans 2 chunks of the 1M-wide kernel
    let mut rng = hetero_batch::util::rng::Rng::new(3);
    let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec_f32(d)).collect();
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let lambdas = ps::lambdas_from_batches(&[32.0, 64.0, 96.0]);

    let xla_out = rt.agg_step(&lambdas, &refs).unwrap();
    let mut rust_out = vec![0.0f32; d];
    ps::aggregate_into(&mut rust_out, &refs, &lambdas);

    for i in (0..d).step_by(997) {
        assert!(
            (xla_out[i] - rust_out[i]).abs() < 1e-5,
            "idx {i}: {} vs {}",
            xla_out[i],
            rust_out[i]
        );
    }
}

#[test]
fn transformer_train_step_runs_at_every_bucket() {
    let mut rt = open();
    let params = rt.init_params("transformer").unwrap();
    let buckets = rt.model("transformer").unwrap().buckets.clone();
    let mut ds = data::for_model("transformer", 1, 0);
    for &b in &buckets {
        let batch = ds.next_batch(0, b);
        let out = rt.train_step("transformer", b, &params, &batch).unwrap();
        assert!(out.loss.is_finite(), "bucket {b}");
        // LM loss at init ≈ ln(vocab) = ln(512) ≈ 6.24, plus O(1) spread
        // from He-init logits.
        assert!(
            (out.loss - 512.0f32.ln()).abs() < 2.0,
            "bucket {b}: init loss {}",
            out.loss
        );
    }
}
