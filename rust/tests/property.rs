//! Property-based tests on coordinator invariants (util::proptest).
//!
//! These are the "for all clusters/allocations/observation streams"
//! guarantees the paper's correctness story rests on:
//! conservation of the global batch, bound enforcement, λ normalization,
//! controller convergence on stationary throughputs, quantization
//! soundness, and aggregation linearity.

use hetero_batch::config::Policy;
use hetero_batch::controller::bucket::{quantize, quantize_alloc};
use hetero_batch::controller::{
    static_alloc, BatchPolicy, ControllerCfg, DynamicBatcher, OptimalBatcher,
    RlBatcher, RlTable,
};
use hetero_batch::fault::{
    AutoscalerCfg, Corruption, DetectorCfg, FaultEvent, FaultKind, FaultPlan,
    FaultState, GuardCfg, CORRUPT_SEED_TAG,
};
use hetero_batch::metrics::RunReport;
use hetero_batch::fleet::{FleetBuilder, JobSpec};
use hetero_batch::session::{Backend, Scheduler, Session, SessionBuilder, WorkerOutcome};
use hetero_batch::sync::{SyncMode, SyncState};
use hetero_batch::trace::{MembershipEvent, MembershipKind, MembershipPlan, SpotSpec};
use hetero_batch::ps::fused::{
    fused_agg_adam, fused_agg_adam_mt, fused_agg_momentum, fused_agg_momentum_mt,
    fused_agg_sgd, fused_agg_sgd_mt,
};
use hetero_batch::ps::{
    aggregate_into, aggregate_into_mt, aggregate_tree_into, lambdas_from_batches,
    Adam, LrSchedule, Momentum, ReduceTree, RetainPolicy, Sgd,
};
use hetero_batch::util::proptest::{check, FnStrategy, Strategy, UsizeRange, VecOf};
use hetero_batch::util::rng::Rng;

/// A random heterogeneous cluster scenario.
#[derive(Debug, Clone)]
struct Scenario {
    /// True throughputs X_k (samples/s).
    xs: Vec<f64>,
    /// Initial batch allocation.
    init: Vec<f64>,
    /// Fixed per-iteration overhead (comm) seconds.
    overhead: f64,
    noise: f64,
    seed: u64,
}

struct ScenarioStrategy;

impl Strategy<Scenario> for ScenarioStrategy {
    fn generate(&self, rng: &mut Rng) -> Scenario {
        let k = rng.range_usize(2, 7);
        let xs: Vec<f64> = (0..k).map(|_| rng.range_f64(5.0, 200.0)).collect();
        let init: Vec<f64> = (0..k).map(|_| rng.range_f64(16.0, 256.0)).collect();
        Scenario {
            xs,
            init,
            overhead: rng.range_f64(0.0, 0.05),
            noise: rng.range_f64(0.0, 0.05),
            seed: rng.next_u64(),
        }
    }

    fn shrink(&self, s: &Scenario) -> Vec<Scenario> {
        let mut out = Vec::new();
        if s.xs.len() > 2 {
            let mut t = s.clone();
            t.xs.pop();
            t.init.pop();
            out.push(t);
        }
        if s.noise > 0.0 {
            let mut t = s.clone();
            t.noise = 0.0;
            out.push(t);
        }
        out
    }
}

/// Drive a controller against the scenario's linear-time workers.
fn drive(s: &Scenario, iters: usize, cfg: ControllerCfg) -> DynamicBatcher {
    let mut ctl = DynamicBatcher::new(cfg, &s.init);
    let mut rng = Rng::new(s.seed);
    for _ in 0..iters {
        let b = ctl.batches();
        for (k, &x) in s.xs.iter().enumerate() {
            let noise = if s.noise > 0.0 {
                rng.lognormal(1.0, s.noise)
            } else {
                1.0
            };
            ctl.observe(k, (s.overhead + b[k] / x) * noise);
        }
        ctl.maybe_adjust();
    }
    ctl
}

fn default_cfg() -> ControllerCfg {
    ControllerCfg {
        min_obs: 3,
        ..ControllerCfg::default()
    }
}

#[test]
fn prop_global_batch_conserved() {
    check("global batch conserved", 150, ScenarioStrategy, |s| {
        let ctl = drive(s, 60, default_cfg());
        let sum: f64 = ctl.batches().iter().sum();
        let expect: f64 = s.init.iter().sum();
        (sum - expect).abs() / expect < 1e-6
    });
}

#[test]
fn prop_bounds_always_respected() {
    check("bounds respected", 150, ScenarioStrategy, |s| {
        let cfg = ControllerCfg {
            b_min: 8.0,
            b_max: 512.0,
            conserve_global: false,
            min_obs: 3,
            ..ControllerCfg::default()
        };
        let ctl = drive(s, 60, cfg);
        ctl.batches().iter().all(|&b| (8.0..=512.0).contains(&b))
    });
}

#[test]
fn prop_lambdas_normalized_and_positive() {
    check("lambdas normalized", 150, ScenarioStrategy, |s| {
        let ctl = drive(s, 40, default_cfg());
        let l = ctl.lambdas();
        let sum: f64 = l.iter().sum();
        (sum - 1.0).abs() < 1e-9 && l.iter().all(|&x| x > 0.0)
    });
}

#[test]
fn prop_converges_on_stationary_noiseless_throughputs() {
    // With zero noise and zero overhead, steady-state batches must be
    // throughput-proportional (the paper's equilibrium) within quantization
    // of the dead-band.
    check("stationary convergence", 100, ScenarioStrategy, |s| {
        let mut s = s.clone();
        s.noise = 0.0;
        s.overhead = 0.0;
        let ctl = drive(&s, 80, default_cfg());
        let b = ctl.batches();
        let bsum: f64 = b.iter().sum();
        let xsum: f64 = s.xs.iter().sum();
        b.iter().zip(&s.xs).all(|(&bk, &xk)| {
            let share_err = (bk / bsum - xk / xsum).abs() / (xk / xsum);
            share_err < 0.15 // dead-band leaves residual error
        })
    });
}

#[test]
fn prop_steady_state_goes_quiet() {
    // After convergence the controller must stop adjusting (dead-band +
    // cumulative-mean smoothing): no adjustments in the last half.
    check("steady state quiet", 80, ScenarioStrategy, |s| {
        let mut s = s.clone();
        s.noise = s.noise.min(0.02);
        let mut ctl = drive(&s, 100, default_cfg());
        let before = ctl.adjustments();
        // another 100 iterations
        let mut rng = Rng::new(s.seed ^ 0xABCD);
        for _ in 0..100 {
            let b = ctl.batches();
            for (k, &x) in s.xs.iter().enumerate() {
                let noise = if s.noise > 0.0 {
                    rng.lognormal(1.0, s.noise)
                } else {
                    1.0
                };
                ctl.observe(k, (s.overhead + b[k] / x) * noise);
            }
            ctl.maybe_adjust();
        }
        ctl.adjustments() - before <= 1
    });
}

#[test]
fn prop_static_alloc_conserves_and_orders() {
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 8);
        let est: Vec<f64> = (0..k).map(|_| rng.range_f64(0.5, 100.0)).collect();
        let b0 = rng.range_f64(8.0, 512.0);
        (est, b0)
    });
    check("static alloc", 300, strat, |(est, b0)| {
        let alloc = static_alloc(*b0, est);
        let sum: f64 = alloc.iter().sum();
        let conserved = (sum - b0 * est.len() as f64).abs() / sum < 1e-9;
        // Order-preserving: faster estimate ⇒ >= batch.
        let ordered = est
            .iter()
            .zip(est.iter().skip(1))
            .zip(alloc.iter().zip(alloc.iter().skip(1)))
            .all(|((e1, e2), (a1, a2))| (e1 <= e2) == (a1 <= a2) || e1 == e2);
        conserved && ordered
    });
}

#[test]
fn prop_quantize_picks_nearest_bucket() {
    let strat = FnStrategy(|rng: &mut Rng| {
        let n = rng.range_usize(1, 10);
        let mut buckets: Vec<usize> =
            (0..n).map(|_| rng.range_usize(1, 1024)).collect();
        buckets.sort_unstable();
        buckets.dedup();
        let proposal = rng.range_f64(0.0, 1200.0);
        (buckets, proposal)
    });
    check("quantize nearest", 500, strat, |(buckets, p)| {
        let q = quantize(*p, buckets);
        let dq = (q as f64 - p).abs();
        buckets.iter().all(|&b| dq <= (b as f64 - p).abs() + 1e-9)
    });
}

#[test]
fn prop_quantize_alloc_swap_mask_consistent() {
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(1, 6);
        let proposals: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 300.0)).collect();
        let current: Vec<usize> = (0..k).map(|_| 1 << rng.range_usize(0, 9)).collect();
        (proposals, current)
    });
    let buckets: Vec<usize> = (0..10).map(|i| 1 << i).collect();
    check("swap mask", 300, strat, move |(proposals, current)| {
        let (snapped, swaps) = quantize_alloc(proposals, &buckets, current);
        snapped
            .iter()
            .zip(current)
            .zip(&swaps)
            .all(|((s, c), &w)| (s != c) == w)
    });
}

#[test]
fn prop_aggregation_equals_weighted_sum_of_any_index() {
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(1, 6);
        let d = rng.range_usize(1, 2000);
        let grads: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec_f32(d)).collect();
        let batches: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 256.0)).collect();
        let idx = rng.range_usize(0, d);
        (grads, batches, idx)
    });
    check("aggregation pointwise", 200, strat, |(grads, batches, idx)| {
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let lambdas = lambdas_from_batches(batches);
        let mut out = vec![0.0f32; grads[0].len()];
        aggregate_into(&mut out, &refs, &lambdas);
        let manual: f64 = grads
            .iter()
            .zip(&lambdas)
            .map(|(g, &l)| g[*idx] as f64 * l)
            .sum();
        (out[*idx] as f64 - manual).abs() < 1e-4
    });
}

#[test]
fn prop_uniform_batches_give_uniform_lambdas() {
    let strat = FnStrategy(|rng: &mut Rng| {
        (rng.range_usize(1, 10), rng.range_f64(1.0, 512.0))
    });
    check("uniform lambda", 200, strat, |(k, b)| {
        let l = lambdas_from_batches(&vec![*b; *k]);
        l.iter().all(|&x| (x - 1.0 / *k as f64).abs() < 1e-12)
    });
}

#[test]
fn prop_hlevel_splits_conserve_total() {
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 6);
        let total = rng.range_usize(k * 4, 128);
        let h = rng.range_f64(1.0, 12.0);
        (total, k, h)
    });
    check("hlevel conservation", 300, strat, |(total, k, h)| {
        match hetero_batch::cluster::hlevel_split(*total, *k, *h) {
            None => true, // infeasible is fine
            Some(split) => {
                split.iter().sum::<usize>() == *total
                    && split.len() == *k
                    && split.windows(2).all(|w| w[0] <= w[1])
                    && split.iter().all(|&c| c >= 1)
            }
        }
    });
}

#[test]
fn prop_water_fill_conserves_and_bounds() {
    use hetero_batch::controller::water_fill;
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(1, 8);
        let proposal: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 500.0)).collect();
        let b_min = rng.range_f64(1.0, 8.0);
        let b_max: Vec<f64> = (0..k)
            .map(|_| rng.range_f64(b_min + 1.0, 1000.0))
            .collect();
        // Keep the target feasible for b_min (hard bound): >= k*b_min.
        let target = rng.range_f64(b_min * k as f64, 1500.0);
        (proposal, target, b_min, b_max)
    });
    check(
        "water_fill",
        400,
        strat,
        |(proposal, target, b_min, b_max)| {
            let mut p = proposal.clone();
            water_fill(&mut p, *target, *b_min, b_max);
            let sum: f64 = p.iter().sum();
            let min_ok = p.iter().all(|&x| x >= *b_min - 1e-9);
            // Conservation holds whenever target >= Σb_min (b_max is soft).
            let conserved = (sum - target).abs() / target < 1e-6;
            min_ok && conserved
        },
    );
}

#[test]
fn prop_water_fill_clamps_when_feasible() {
    use hetero_batch::controller::water_fill;
    // When the target is reachable inside [Σb_min, Σb_max], every entry
    // must land inside its own [b_min, b_max_i] box.
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(1, 8);
        let proposal: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 500.0)).collect();
        let b_min = rng.range_f64(1.0, 8.0);
        let b_max: Vec<f64> = (0..k)
            .map(|_| rng.range_f64(b_min + 4.0, 600.0))
            .collect();
        let lo = b_min * k as f64;
        let hi: f64 = b_max.iter().sum();
        let target = rng.range_f64(lo, hi.max(lo + 1.0));
        (proposal, target, b_min, b_max)
    });
    check("water_fill clamps", 400, strat, |(proposal, target, b_min, b_max)| {
        let mut p = proposal.clone();
        water_fill(&mut p, (*target).min(b_max.iter().sum()), *b_min, b_max);
        p.iter()
            .zip(b_max)
            .all(|(&x, &hi)| x >= *b_min - 1e-9 && x <= hi + 1e-9)
    });
}

#[test]
fn prop_water_fill_idempotent_at_fixed_point() {
    use hetero_batch::controller::water_fill;
    // Applying water_fill to its own output must be a no-op: the output
    // already sums to the target and sits inside the bounds.  Targets
    // are drawn from the *feasible* band [Σb_min, Σb_max] — outside it
    // the output is a documented compromise (hard b_min floor /
    // conservation-over-soft-caps), not a fixed point of the projection.
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(1, 8);
        let proposal: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 500.0)).collect();
        let b_min = rng.range_f64(1.0, 8.0);
        let b_max: Vec<f64> = (0..k)
            .map(|_| rng.range_f64(b_min + 1.0, 1000.0))
            .collect();
        let lo = b_min * k as f64;
        let hi: f64 = b_max.iter().sum();
        let target = lo + rng.f64() * (hi - lo);
        (proposal, target, b_min, b_max)
    });
    check("water_fill idempotent", 400, strat, |(proposal, target, b_min, b_max)| {
        let mut once = proposal.clone();
        water_fill(&mut once, *target, *b_min, b_max);
        let mut twice = once.clone();
        water_fill(&mut twice, *target, *b_min, b_max);
        once.iter()
            .zip(&twice)
            .all(|(&a, &b)| (a - b).abs() <= 1e-9 * a.abs().max(1.0))
    });
}

#[test]
fn prop_retire_admit_round_trip_restores_invariants() {
    // retire(k) then admit(k) must restore Σb to the construction-time
    // global batch with normalized λ over all ranks — for warm and cold
    // controllers alike.
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 7);
        let init: Vec<f64> = (0..k).map(|_| rng.range_f64(16.0, 256.0)).collect();
        let xs: Vec<f64> = (0..k).map(|_| rng.range_f64(5.0, 200.0)).collect();
        let victim = rng.range_usize(0, k);
        let warmup = rng.range_usize(0, 30);
        (init, xs, victim, warmup)
    });
    check("retire/admit round trip", 200, strat, |(init, xs, victim, warmup)| {
        let mut ctl = DynamicBatcher::new(default_cfg(), init);
        for _ in 0..*warmup {
            let b = ctl.batches();
            for (k, &x) in xs.iter().enumerate() {
                ctl.observe(k, b[k] / x);
            }
            ctl.maybe_adjust();
        }
        let global = ctl.global_batch();
        ctl.retire(*victim);
        let b = ctl.batches();
        let mid_ok = b[*victim] == 0.0
            && (b.iter().sum::<f64>() - global).abs() <= 1e-6 * global;
        ctl.admit(*victim);
        let b = ctl.batches();
        let l = ctl.lambdas();
        mid_ok
            && (b.iter().sum::<f64>() - global).abs() <= 1e-6 * global
            && b.iter().all(|&x| x > 0.0)
            && (l.iter().sum::<f64>() - 1.0).abs() < 1e-9
    });
}

#[test]
fn prop_controller_recovers_from_regime_change() {
    // Whatever stationary state the controller converged to, after a
    // sustained capacity change it must re-converge to the *new*
    // throughput-proportional split (drift detection + backoff reset).
    check("regime recovery", 60, ScenarioStrategy, |s| {
        let mut s = s.clone();
        s.noise = s.noise.min(0.03);
        s.overhead = 0.0;
        let mut ctl = drive(&s, 80, default_cfg());
        // Halve worker 0's true throughput and keep driving.
        let mut xs = s.xs.clone();
        xs[0] *= 0.5;
        let mut rng = Rng::new(s.seed ^ 0xFEED);
        for _ in 0..120 {
            let b = ctl.batches();
            for (k, &x) in xs.iter().enumerate() {
                let noise = if s.noise > 0.0 {
                    rng.lognormal(1.0, s.noise)
                } else {
                    1.0
                };
                ctl.observe(k, (b[k] / x) * noise);
            }
            ctl.maybe_adjust();
        }
        let b = ctl.batches();
        let bsum: f64 = b.iter().sum();
        let xsum: f64 = xs.iter().sum();
        // Worker 0's share tracks its halved throughput within 25%.
        let share_err =
            (b[0] / bsum - xs[0] / xsum).abs() / (xs[0] / xsum);
        share_err < 0.25
    });
}

// ---------------------------------------------------------------------
// Sharded PS hot path (§Perf iteration 4): pool-sharded aggregation and
// the sharded fused optimizer kernels must be elementwise equivalent to
// the single-threaded paths — across random dims (including
// non-multiples of the 8K tile and of the shard count), shard counts
// 1–8, and multi-step optimizer-state evolution.

const FUSED_TOL: f32 = 1e-6;

fn close(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(&x, &y)| (x - y).abs() <= FUSED_TOL)
}

/// Random (dim, k, shards, steps, seed) fused-kernel scenario.
fn fused_strategy() -> FnStrategy<impl Fn(&mut Rng) -> (usize, usize, usize, usize, u64)> {
    FnStrategy(|rng: &mut Rng| {
        // Dims span several 8192-element tiles; +1 below keeps hi > lo
        // exclusive bounds valid and lands on odd sizes.
        let d = rng.range_usize(1, 3 * 8192 + 70);
        let k = rng.range_usize(1, 6);
        let shards = rng.range_usize(1, 9);
        let steps = rng.range_usize(1, 4);
        (d, k, shards, steps, rng.next_u64())
    })
}

fn random_problem(
    d: usize,
    k: usize,
    seed: u64,
) -> (Vec<f32>, Vec<Vec<f32>>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let params = rng.normal_vec_f32(d);
    let grads: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec_f32(d)).collect();
    let batches: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 256.0)).collect();
    (params, grads, lambdas_from_batches(&batches))
}

#[test]
fn prop_sharded_fused_sgd_matches_single_threaded() {
    check("sharded fused sgd", 40, fused_strategy(), |c| {
        let &(d, k, shards, steps, seed) = c;
        let (params, grads, lambdas) = random_problem(d, k, seed);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let (mut p_st, mut p_mt) = (params.clone(), params);
        let mut o_st = Sgd::new(LrSchedule::Constant(0.05));
        let mut o_mt = Sgd::new(LrSchedule::Constant(0.05));
        for _ in 0..steps {
            fused_agg_sgd(&mut p_st, &refs, &lambdas, &mut o_st);
            fused_agg_sgd_mt(&mut p_mt, &refs, &lambdas, &mut o_mt, shards);
        }
        close(&p_st, &p_mt)
    });
}

#[test]
fn prop_sharded_fused_momentum_matches_with_state() {
    check("sharded fused momentum", 40, fused_strategy(), |c| {
        let &(d, k, shards, steps, seed) = c;
        let (params, grads, lambdas) = random_problem(d, k, seed);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let (mut p_st, mut p_mt) = (params.clone(), params);
        let mut o_st = Momentum::new(LrSchedule::Constant(0.05), 0.9, d);
        let mut o_mt = Momentum::new(LrSchedule::Constant(0.05), 0.9, d);
        for _ in 0..steps {
            fused_agg_momentum(&mut p_st, &refs, &lambdas, &mut o_st);
            fused_agg_momentum_mt(&mut p_mt, &refs, &lambdas, &mut o_mt, shards);
        }
        close(&p_st, &p_mt) && close(o_st.velocity(), o_mt.velocity())
    });
}

#[test]
fn prop_sharded_fused_adam_matches_with_state() {
    check("sharded fused adam", 40, fused_strategy(), |c| {
        let &(d, k, shards, steps, seed) = c;
        let (params, grads, lambdas) = random_problem(d, k, seed);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let (mut p_st, mut p_mt) = (params.clone(), params);
        let mut o_st = Adam::new(LrSchedule::Constant(0.001), d);
        let mut o_mt = Adam::new(LrSchedule::Constant(0.001), d);
        for _ in 0..steps {
            fused_agg_adam(&mut p_st, &refs, &lambdas, &mut o_st);
            fused_agg_adam_mt(&mut p_mt, &refs, &lambdas, &mut o_mt, shards);
        }
        close(&p_st, &p_mt)
            && close(o_st.m(), o_mt.m())
            && close(o_st.v(), o_mt.v())
    });
}

#[test]
fn sharded_fused_adam_exact_at_tile_and_shard_boundaries() {
    // Deterministic boundary sweep: dims exactly at / adjacent to the
    // 8K tile, and a dim above the MT_MIN_LEN heuristic cutoff.
    for &d in &[1usize, 2, 8191, 8192, 8193, 16384, 65_537] {
        let (params, grads, lambdas) = random_problem(d, 3, d as u64);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut p_st = params.clone();
        let mut o_st = Adam::new(LrSchedule::Constant(0.001), d);
        fused_agg_adam(&mut p_st, &refs, &lambdas, &mut o_st);
        for shards in [1usize, 2, 3, 5, 8] {
            let mut p_mt = params.clone();
            let mut o_mt = Adam::new(LrSchedule::Constant(0.001), d);
            fused_agg_adam_mt(&mut p_mt, &refs, &lambdas, &mut o_mt, shards);
            assert!(
                close(&p_st, &p_mt) && close(o_st.v(), o_mt.v()),
                "divergence at d={d} shards={shards}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Eager reduction-tree aggregation (ps/reduce.rs, DESIGN.md §11): the
// tree's summation order is fixed by its rank-indexed shape, so the
// result must be *bitwise* invariant under any completion-order
// permutation — and, numerically, within 1e-6 of the flat sequential
// sweep it replaced (the retained oracle).  Shapes deliberately include
// k = 1, odd, and non-power-of-two leaf counts (passthrough chains).

const TREE_ORACLE_KS: [usize; 6] = [1, 2, 3, 7, 8, 64];

/// Random (k, d, seed) with k drawn from the oracle shape set half the
/// time and uniformly otherwise.
fn tree_strategy() -> FnStrategy<impl Fn(&mut Rng) -> (usize, usize, u64)> {
    FnStrategy(|rng: &mut Rng| {
        let k = if rng.range_usize(0, 2) == 0 {
            TREE_ORACLE_KS[rng.range_usize(0, TREE_ORACLE_KS.len())]
        } else {
            rng.range_usize(1, 40)
        };
        (k, rng.range_usize(1, 5000), rng.next_u64())
    })
}

fn shuffled(n: usize, rng: &mut Rng) -> Vec<usize> {
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    order
}

#[test]
fn prop_tree_aggregation_is_bitwise_arrival_order_invariant() {
    check("tree arrival-order invariance", 80, tree_strategy(), |c| {
        let &(k, d, seed) = c;
        let (_, grads, lambdas) = random_problem(d, k, seed);
        let mut rng = Rng::new(seed ^ 0x7EE);
        let run = |policy: RetainPolicy, order: &[usize]| -> Vec<u32> {
            let mut t = ReduceTree::new(k, d, policy, 1);
            for &i in order {
                t.push(i, &grads[i], lambdas[i] as f32);
            }
            t.finalize().iter().map(|x| x.to_bits()).collect()
        };
        let asc: Vec<usize> = (0..k).collect();
        let base = run(RetainPolicy::Free, &asc);
        let perm_a = shuffled(k, &mut rng);
        let perm_b = shuffled(k, &mut rng);
        base == run(RetainPolicy::Free, &perm_a)
            && base == run(RetainPolicy::Retain, &perm_b)
            && base == run(RetainPolicy::Retain, &asc)
    });
}

#[test]
fn prop_tree_matches_flat_oracle_within_1e6() {
    check("tree == flat (1e-6)", 80, tree_strategy(), |c| {
        let &(k, d, seed) = c;
        let (_, grads, lambdas) = random_problem(d, k, seed);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut flat = vec![0.0f32; d];
        aggregate_into(&mut flat, &refs, &lambdas);
        let mut tree = vec![0.0f32; d];
        aggregate_tree_into(&mut tree, &refs, &lambdas, 1);
        close(&flat, &tree)
    });
}

#[test]
fn prop_tree_b_weighted_leaves_with_root_scale_match_flat() {
    // The real backend's scheme: leaves carry the λ *numerator* (the
    // batch b_w, known per worker even under churn) and the common 1/Σb
    // normalization rides the fused optimizer's λ slot at the root.
    // Must agree with the flat λ-weighted sweep to the oracle tolerance.
    check("tree b-weight + root scale", 80, tree_strategy(), |c| {
        let &(k, d, seed) = c;
        let mut rng = Rng::new(seed);
        let grads: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec_f32(d)).collect();
        let batches: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 256.0)).collect();
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut flat = vec![0.0f32; d];
        aggregate_into(&mut flat, &refs, &lambdas_from_batches(&batches));
        let mut t = ReduceTree::new(k, d, RetainPolicy::Free, 1);
        for i in 0..k {
            t.push(i, &grads[i], batches[i] as f32);
        }
        let inv = (1.0 / batches.iter().sum::<f64>()) as f32;
        let scaled: Vec<f32> = t.finalize().iter().map(|&x| inv * x).collect();
        close(&flat, &scaled)
    });
}

#[test]
fn prop_tree_retain_revoke_rebuild_is_bitwise_fresh() {
    // A mid-round revocation under RetainPolicy::Retain rebuilds only
    // the revoked leaf's ancestor path — and must land on exactly the
    // bits a fresh tree over the survivors produces (this is what makes
    // the eager and collect-then-aggregate session paths bit-identical
    // under churn).
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 20);
        (k, rng.range_usize(1, 3000), rng.range_usize(0, k), rng.next_u64())
    });
    check("tree revoke == fresh", 80, strat, |c| {
        let &(k, d, victim, seed) = c;
        let (_, grads, lambdas) = random_problem(d, k, seed);
        let mut rng = Rng::new(seed ^ 0xDEAD);
        let order = shuffled(k, &mut rng);
        let mut t = ReduceTree::new(k, d, RetainPolicy::Retain, 1);
        for &i in &order {
            t.push(i, &grads[i], lambdas[i] as f32);
        }
        t.revoke(victim);
        let rebuilt: Vec<u32> = t.finalize().iter().map(|x| x.to_bits()).collect();
        let mut fresh = ReduceTree::new(k, d, RetainPolicy::Retain, 1);
        for i in 0..k {
            if i != victim {
                fresh.push(i, &grads[i], lambdas[i] as f32);
            }
        }
        let want: Vec<u32> = fresh.finalize().iter().map(|x| x.to_bits()).collect();
        rebuilt == want
    });
}

#[test]
fn prop_pool_aggregation_matches_reference() {
    let strat = FnStrategy(|rng: &mut Rng| {
        let d = rng.range_usize(1, 200_000);
        let k = rng.range_usize(1, 6);
        let threads = rng.range_usize(1, 9);
        (d, k, threads, rng.next_u64())
    });
    check("pool aggregation", 30, strat, |c| {
        let &(d, k, threads, seed) = c;
        let (_, grads, lambdas) = random_problem(d, k, seed);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut st = vec![0.0f32; d];
        let mut mt = vec![0.0f32; d];
        aggregate_into(&mut st, &refs, &lambdas);
        aggregate_into_mt(&mut mt, &refs, &lambdas, threads);
        close(&st, &mt)
    });
}

// ---------------------------------------------------------------------
// SyncState invariants: the gating/staleness accounting the unified
// Session loop rests on, exercised by random *legal* schedules (a worker
// either starts an iteration — pull — if the gate admits it, or finishes
// one it has in flight — push).

/// One random legal scheduling trajectory through a SyncState.
fn drive_sync<F: FnMut(&SyncState, usize, u64, u64)>(
    mode: SyncMode,
    k: usize,
    steps: usize,
    seed: u64,
    mut on_push: F,
) {
    let mut s = SyncState::new(mode, k);
    let mut rng = Rng::new(seed);
    let mut in_flight = vec![false; k];
    // Pushes (by anyone) since each worker's last pull.
    let mut pushes_since_pull = vec![0u64; k];
    for _ in 0..steps {
        let legal: Vec<usize> = (0..k)
            .filter(|&w| in_flight[w] || s.may_proceed(w))
            .collect();
        assert!(!legal.is_empty(), "gate wedged: no legal action");
        let w = legal[rng.range_usize(0, legal.len())];
        if in_flight[w] {
            let staleness = s.push_update(w);
            in_flight[w] = false;
            on_push(&s, w, staleness, pushes_since_pull[w]);
            for v in 0..k {
                if v != w {
                    pushes_since_pull[v] += 1;
                }
            }
        } else {
            s.pull(w);
            pushes_since_pull[w] = 0;
            in_flight[w] = true;
        }
    }
}

fn sync_mode_strategy() -> FnStrategy<impl Fn(&mut Rng) -> (usize, SyncMode, u64)> {
    FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 6);
        let mode = match rng.range_usize(0, 3) {
            0 => SyncMode::Bsp,
            1 => SyncMode::Asp,
            _ => SyncMode::Ssp {
                bound: rng.range_usize(0, 4) as u64,
            },
        };
        (k, mode, rng.next_u64())
    })
}

#[test]
fn prop_staleness_never_exceeds_updates_since_pull() {
    check(
        "staleness <= updates since pull",
        150,
        sync_mode_strategy(),
        |&(k, mode, seed)| {
            let mut ok = true;
            drive_sync(mode, k, 300, seed, |_, _, staleness, since_pull| {
                ok &= staleness <= since_pull;
            });
            ok
        },
    );
}

#[test]
fn prop_bsp_implies_zero_staleness() {
    let strat = FnStrategy(|rng: &mut Rng| (rng.range_usize(2, 7), rng.next_u64()));
    check("bsp zero staleness", 150, strat, |&(k, seed)| {
        let mut ok = true;
        drive_sync(SyncMode::Bsp, k, 300, seed, |_, _, staleness, _| {
            ok &= staleness == 0;
        });
        ok
    });
}

#[test]
fn prop_ssp_lead_bounded_under_random_schedules() {
    let strat = FnStrategy(|rng: &mut Rng| {
        (
            rng.range_usize(2, 6),
            rng.range_usize(0, 5) as u64,
            rng.next_u64(),
        )
    });
    check("ssp lead bounded", 150, strat, |&(k, bound, seed)| {
        let mut ok = true;
        drive_sync(SyncMode::Ssp { bound }, k, 400, seed, |s, _, _, _| {
            ok &= s.max_clock() - s.min_clock() <= bound + 1;
        });
        ok
    });
}

// ---------------------------------------------------------------------
// Sim-vs-real gating parity: the Session loop must produce identical
// SyncState gating sequences for a fixed duration schedule regardless of
// backend *shape* — a simulator-shaped backend (no losses, continuous
// batches, modeled progress) and a real-engine-shaped backend (losses,
// per-update optimizer application) only differ in what they execute,
// never in who runs when.

struct FixedScheduleBackend {
    /// Constant per-worker iteration duration (seconds of work).
    durs: Vec<f64>,
    /// Mimic the real backend's report surface (losses) or the sim's.
    real_shaped: bool,
    /// Injected fault schedule (stall/slow perturb the fixed durations;
    /// crash is handled session-side, like every backend).
    faults: Option<FaultState>,
    /// Modeled update norms for the §16 guard, mirroring the sim
    /// backend: unit norm when healthy, perturbed by scripted
    /// corruptions at dispatch.
    pending_norm: Vec<f64>,
    corrupt_rng: Rng,
}

impl FixedScheduleBackend {
    fn new(durs: Vec<f64>, real_shaped: bool) -> Self {
        FixedScheduleBackend {
            pending_norm: vec![1.0; durs.len()],
            corrupt_rng: Rng::new(CORRUPT_SEED_TAG),
            durs,
            real_shaped,
            faults: None,
        }
    }
}

impl Backend for FixedScheduleBackend {
    fn k(&self) -> usize {
        self.durs.len()
    }

    fn label(&self) -> String {
        (if self.real_shaped { "mock-real" } else { "mock-sim" }).into()
    }

    fn buckets(&self) -> Option<Vec<usize>> {
        None
    }

    fn default_b0(&self) -> f64 {
        32.0
    }

    fn flops_estimates(&self) -> Vec<f64> {
        vec![1.0; self.durs.len()]
    }

    fn default_target(&self) -> u64 {
        50
    }

    fn set_fault_plan(&mut self, plan: &FaultPlan) {
        self.faults = Some(plan.state());
    }

    fn execute_wave(
        &mut self,
        wave: &[usize],
        _batches: &[f64],
        now: f64,
    ) -> anyhow::Result<Vec<WorkerOutcome>> {
        Ok(wave
            .iter()
            .map(|&w| {
                let mut out = WorkerOutcome {
                    work: self.durs[w],
                    fixed: 0.0,
                };
                self.pending_norm[w] = 1.0;
                if let Some(f) = self.faults.as_mut() {
                    f.perturb(w, now, &mut out);
                    if f.has_corrupt() {
                        for c in f.corruptions(w, now) {
                            self.pending_norm[w] = match c {
                                Corruption::Nan => f64::NAN,
                                Corruption::Inf => f64::INFINITY,
                                Corruption::Scale { factor } => {
                                    self.pending_norm[w] * factor.abs()
                                }
                                Corruption::Bitflip { flips } => {
                                    let mut bits = self.pending_norm[w].to_bits();
                                    for _ in 0..flips {
                                        bits ^= 1u64 << self.corrupt_rng.below(64);
                                    }
                                    f64::from_bits(bits)
                                }
                            };
                        }
                    }
                }
                out
            })
            .collect())
    }

    fn update_norm(&mut self, w: usize) -> Option<f64> {
        Some(self.pending_norm[w])
    }

    fn apply_update(
        &mut self,
        _workers: &[usize],
        _batches: &[f64],
    ) -> anyhow::Result<Option<f64>> {
        Ok(self.real_shaped.then_some(1.0))
    }

    fn staleness_discount(&self, _staleness: u64) -> f64 {
        1.0
    }

    fn eval(&mut self, _step: u64, _now: f64) -> anyhow::Result<Option<(f64, f64)>> {
        Ok(None)
    }
}

#[test]
fn sim_and_real_shaped_backends_gate_identically() {
    for sync in [SyncMode::Bsp, SyncMode::Asp, SyncMode::Ssp { bound: 2 }] {
        let durs = vec![3.0, 1.0, 2.0];
        let run_shape = |real_shaped: bool| -> RunReport {
            Session::builder()
                .policy(Policy::Uniform)
                .sync(sync)
                .steps(15)
                .build_with(FixedScheduleBackend::new(durs.clone(), real_shaped))
                .unwrap()
                .run()
                .unwrap()
        };
        let sim_shaped = run_shape(false);
        let real_shaped = run_shape(true);
        let gate = |r: &RunReport| -> Vec<(usize, u64)> {
            r.iters.iter().map(|i| (i.worker, i.iter)).collect()
        };
        assert_eq!(
            gate(&sim_shaped),
            gate(&real_shaped),
            "gating diverged under {sync:?}"
        );
        assert_eq!(sim_shaped.total_time, real_shaped.total_time);
        assert_eq!(sim_shaped.total_iters, real_shaped.total_iters);
        // The real-shaped run additionally carries a loss curve; the
        // sim-shaped one does not — report surface, not scheduling.
        assert!(sim_shaped.losses.is_empty());
        assert!(!real_shaped.losses.is_empty());
    }
}

#[test]
fn membership_epochs_identical_across_backend_shapes() {
    // The acceptance scenario: one revocation + one rejoin mid-run must
    // produce identical epoch AND gating sequences on a sim-shaped and a
    // real-shaped backend, with Σb conserved at every transition.
    for sync in [SyncMode::Bsp, SyncMode::Asp, SyncMode::Ssp { bound: 2 }] {
        let durs = vec![3.0, 1.0, 2.0];
        // BSP rounds take 3 s: revoke worker 0 mid-round-2 (t=7.5),
        // rejoin mid-round-4 (t=13.5).
        let plan = MembershipPlan::new(vec![
            MembershipEvent { time: 7.5, worker: 0, kind: MembershipKind::Revoke },
            MembershipEvent { time: 13.5, worker: 0, kind: MembershipKind::Join },
        ]);
        let run_shape = |real_shaped: bool| -> RunReport {
            Session::builder()
                .policy(Policy::Uniform)
                .sync(sync)
                .steps(12)
                .membership(plan.clone())
                .build_with(FixedScheduleBackend::new(durs.clone(), real_shaped))
                .unwrap()
                .run()
                .unwrap()
        };
        let sim_shaped = run_shape(false);
        let real_shaped = run_shape(true);
        let gate = |r: &RunReport| -> Vec<(usize, u64)> {
            r.iters.iter().map(|i| (i.worker, i.iter)).collect()
        };
        let epochs = |r: &RunReport| -> Vec<(u64, usize, &'static str, usize)> {
            r.epochs
                .iter()
                .map(|e| (e.epoch, e.worker, e.kind.label(), e.live))
                .collect()
        };
        assert_eq!(
            epochs(&sim_shaped),
            epochs(&real_shaped),
            "epoch sequence diverged under {sync:?}"
        );
        assert_eq!(
            epochs(&sim_shaped),
            vec![(1, 0, "revoke", 2), (2, 0, "join", 3)],
            "wrong epoch sequence under {sync:?}"
        );
        assert_eq!(
            gate(&sim_shaped),
            gate(&real_shaped),
            "gating diverged under {sync:?}"
        );
        assert_eq!(sim_shaped.total_time, real_shaped.total_time);
        // Σb conserved (to fp tolerance) across every epoch transition.
        for r in [&sim_shaped, &real_shaped] {
            for e in &r.epochs {
                let sum: f64 = e.batches.iter().sum();
                assert!(
                    (sum - 96.0).abs() < 1e-9,
                    "Σb {sum} != 96 at epoch {e:?} under {sync:?}"
                );
            }
        }
        // The revoked worker runs nothing between the transitions.
        let (t_rev, t_join) = (sim_shaped.epochs[0].time, sim_shaped.epochs[1].time);
        assert!(sim_shaped
            .iters
            .iter()
            .filter(|i| i.worker == 0)
            .all(|i| i.start + i.duration <= t_rev + 1e-9 || i.start >= t_join - 1e-9));
    }
}

// ---------------------------------------------------------------------
// O(log k) event scheduling (DESIGN.md §10): the heap scheduler must be
// observationally *identical* to the retained linear-scan baseline — not
// close, identical: same event order, same floats, same report — across
// random durations, sync modes, policies, and membership churn.

/// A random Session scenario on the fixed-duration mock backend.
#[derive(Debug, Clone)]
struct SchedScenario {
    durs: Vec<f64>,
    sync: SyncMode,
    dynamic: bool,
    steps: u64,
    /// Optional (worker, revoke_t, rejoin_t) churn bounce.
    churn: Option<(usize, f64, f64)>,
}

struct SchedStrategy;

impl Strategy<SchedScenario> for SchedStrategy {
    fn generate(&self, rng: &mut Rng) -> SchedScenario {
        let k = rng.range_usize(2, 6);
        let durs: Vec<f64> = (0..k).map(|_| rng.range_f64(0.5, 3.5)).collect();
        let sync = match rng.range_usize(0, 3) {
            0 => SyncMode::Bsp,
            1 => SyncMode::Asp,
            _ => SyncMode::Ssp {
                bound: rng.range_usize(0, 3) as u64,
            },
        };
        let dynamic = rng.range_usize(0, 2) == 1;
        let steps = rng.range_usize(8, 30) as u64;
        let churn = (rng.range_usize(0, 3) > 0).then(|| {
            let w = rng.range_usize(0, k);
            let t1 = rng.range_f64(1.0, 25.0);
            (w, t1, t1 + rng.range_f64(1.0, 20.0))
        });
        SchedScenario { durs, sync, dynamic, steps, churn }
    }

    fn shrink(&self, s: &SchedScenario) -> Vec<SchedScenario> {
        let mut out = Vec::new();
        if s.churn.is_some() {
            let mut t = s.clone();
            t.churn = None;
            out.push(t);
        }
        if s.steps > 8 {
            let mut t = s.clone();
            t.steps = 8;
            out.push(t);
        }
        out
    }
}

fn run_sched(s: &SchedScenario, scheduler: Scheduler) -> RunReport {
    let mut b = Session::builder()
        .policy(if s.dynamic { Policy::Dynamic } else { Policy::Uniform })
        .sync(s.sync)
        .steps(s.steps)
        .scheduler(scheduler);
    if let Some((w, t1, t2)) = s.churn {
        b = b.membership(MembershipPlan::new(vec![
            MembershipEvent { time: t1, worker: w, kind: MembershipKind::Revoke },
            MembershipEvent { time: t2, worker: w, kind: MembershipKind::Join },
        ]));
    }
    b.build_with(FixedScheduleBackend::new(s.durs.clone(), false))
    .unwrap()
    .run()
    .unwrap()
}

/// Bitwise report equality — any divergence in event ordering shows up
/// as a differing start/duration/iter somewhere.
fn reports_identical(a: &RunReport, b: &RunReport) -> bool {
    a.total_time == b.total_time
        && a.total_iters == b.total_iters
        && a.reached_target == b.reached_target
        && a.losses == b.losses
        && a.iters.len() == b.iters.len()
        && a.iters.iter().zip(&b.iters).all(|(x, y)| {
            x.worker == y.worker
                && x.iter == y.iter
                && x.start == y.start
                && x.duration == y.duration
                && x.batch == y.batch
                && x.wait == y.wait
        })
        && a.adjustments.len() == b.adjustments.len()
        && a.adjustments
            .iter()
            .zip(&b.adjustments)
            .all(|(x, y)| x.time == y.time && x.iter == y.iter && x.batches == y.batches)
        && a.epochs.len() == b.epochs.len()
        && a.epochs.iter().zip(&b.epochs).all(|(x, y)| {
            x.time == y.time
                && x.epoch == y.epoch
                && x.worker == y.worker
                && x.kind == y.kind
                && x.live == y.live
                && x.batches == y.batches
        })
        && a.suspicions.len() == b.suspicions.len()
        && a.suspicions.iter().zip(&b.suspicions).all(|(x, y)| {
            x.time == y.time && x.worker == y.worker && x.action == y.action
        })
        && a.spawns.len() == b.spawns.len()
        && a.spawns.iter().zip(&b.spawns).all(|(x, y)| {
            x.time == y.time
                && x.worker == y.worker
                && x.action == y.action
                && x.attempt == y.attempt
        })
        && a.rejections.len() == b.rejections.len()
        && a.rejections.iter().zip(&b.rejections).all(|(x, y)| {
            x.time == y.time && x.worker == y.worker && x.action == y.action
        })
        && a.quarantines.len() == b.quarantines.len()
        && a.quarantines.iter().zip(&b.quarantines).all(|(x, y)| {
            x.time == y.time && x.worker == y.worker && x.action == y.action
        })
}

#[test]
fn prop_heap_and_scan_schedulers_produce_identical_reports() {
    check("heap == scan", 120, SchedStrategy, |s| {
        let heap = run_sched(s, Scheduler::Heap);
        let scan = run_sched(s, Scheduler::Scan);
        reports_identical(&heap, &scan)
    });
}

// ---------------------------------------------------------------------
// Fault tolerance (DESIGN.md §12): injected crashes/stalls must never
// break the allocation invariants, a detector that never fires must be
// bitwise invisible, and a detector-initiated retire must be
// indistinguishable from a plan-scheduled revocation at the same time.

#[test]
fn prop_crashes_preserve_batch_conservation() {
    // Random crash (+ optional autoscaled replacement): the run must
    // terminate, and every epoch transition — detector retire,
    // autoscaled join — must conserve Σb exactly like plan churn does.
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 6);
        let durs: Vec<f64> = (0..k).map(|_| rng.range_f64(0.5, 3.5)).collect();
        let w = rng.range_usize(0, k);
        let t = rng.range_f64(0.5, 30.0);
        let auto = rng.range_usize(0, 2) == 1;
        let dynamic = rng.range_usize(0, 2) == 1;
        (durs, w, t, auto, dynamic)
    });
    check("crash conserves Σb", 60, strat, |s| {
        let (durs, w, t, auto, dynamic) = s;
        let k = durs.len();
        let plan = FaultPlan::new(vec![FaultEvent {
            time: *t,
            worker: *w,
            kind: FaultKind::Crash,
        }])
        .unwrap();
        let mut b = Session::builder()
            .policy(if *dynamic { Policy::Dynamic } else { Policy::Uniform })
            .sync(SyncMode::Bsp)
            .steps(25)
            .faults(plan)
            .detector(DetectorCfg::parse("grace=4,floor=8").unwrap());
        if *auto {
            b = b.autoscale(AutoscalerCfg::parse("pool=1,cold=2").unwrap());
        }
        let r = b
            .build_with(FixedScheduleBackend::new(durs.clone(), false))
            .unwrap()
            .run()
            .unwrap();
        let total = 32.0 * k as f64;
        r.total_iters >= 25
            && r.epochs.iter().all(|e| {
                let sum: f64 = e.batches.iter().sum();
                (sum - total).abs() < 1e-6 && e.batches.iter().all(|&b| b >= 0.0)
            })
    });
}

#[test]
fn prop_generous_detector_is_bitwise_invisible_under_stalls() {
    // Stall-only faults with a deadline far beyond any stall: the
    // detector arms and disarms but never fires, so the report must be
    // bitwise identical to the same faulted run with no detector at all.
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 6);
        let durs: Vec<f64> = (0..k).map(|_| rng.range_f64(0.5, 3.5)).collect();
        let w = rng.range_usize(0, k);
        let t = rng.range_f64(0.5, 20.0);
        let stall = rng.range_f64(0.5, 5.0);
        let sync = match rng.range_usize(0, 3) {
            0 => SyncMode::Bsp,
            1 => SyncMode::Asp,
            _ => SyncMode::Ssp { bound: rng.range_usize(0, 3) as u64 },
        };
        (durs, w, t, stall, sync)
    });
    check("generous detector == none", 60, strat, |s| {
        let (durs, w, t, stall, sync) = s;
        let run = |detect: bool| {
            let mut b = Session::builder()
                .policy(Policy::Dynamic)
                .sync(*sync)
                .steps(20)
                .faults(
                    FaultPlan::new(vec![FaultEvent {
                        time: *t,
                        worker: *w,
                        kind: FaultKind::Stall { stall_s: *stall },
                    }])
                    .unwrap(),
                );
            if detect {
                b = b.detector(DetectorCfg::parse("grace=1e5,floor=1e6").unwrap());
            }
            b.build_with(FixedScheduleBackend::new(durs.clone(), false))
            .unwrap()
            .run()
            .unwrap()
        };
        let (on, off) = (run(true), run(false));
        on.suspicions.is_empty() && reports_identical(&on, &off)
    });
}

#[test]
fn prop_detector_retire_matches_plan_revoke_bitwise() {
    // A huge stall trips the detector at some time t_s; replaying the
    // same scenario with a *plan-scheduled* revocation at exactly t_s
    // (and no detector) must yield a bitwise-identical report — the
    // suspicion path is the revocation path, not a parallel mechanism.
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 6);
        let durs: Vec<f64> = (0..k).map(|_| rng.range_f64(0.5, 3.5)).collect();
        let w = rng.range_usize(0, k);
        let t = rng.range_f64(0.5, 15.0);
        let dynamic = rng.range_usize(0, 2) == 1;
        (durs, w, t, dynamic)
    });
    check("detector retire == plan revoke", 60, strat, |s| {
        let (durs, w, t, dynamic) = s;
        let policy = if *dynamic { Policy::Dynamic } else { Policy::Uniform };
        let stall_plan = || {
            FaultPlan::new(vec![FaultEvent {
                time: *t,
                worker: *w,
                kind: FaultKind::Stall { stall_s: 1e6 },
            }])
            .unwrap()
        };
        let mock = || FixedScheduleBackend::new(durs.clone(), false);
        let detected = Session::builder()
            .policy(policy)
            .sync(SyncMode::Bsp)
            .steps(20)
            .faults(stall_plan())
            .detector(DetectorCfg::parse("grace=4,floor=10,late=drop").unwrap())
            .build_with(mock())
            .unwrap()
            .run()
            .unwrap();
        if detected.suspicions.is_empty() {
            // Stall landed after the run finished — nothing to compare.
            return true;
        }
        let t_s = detected.suspicions[0].time;
        let planned = Session::builder()
            .policy(policy)
            .sync(SyncMode::Bsp)
            .steps(20)
            .faults(stall_plan())
            .membership(MembershipPlan::new(vec![MembershipEvent {
                time: t_s,
                worker: *w,
                kind: MembershipKind::Revoke,
            }]))
            .build_with(mock())
            .unwrap()
            .run()
            .unwrap();
        // The detector run's only extra surface is the suspicion record.
        let mut scrubbed = detected.clone();
        scrubbed.suspicions.clear();
        reports_identical(&scrubbed, &planned)
    });
}

// ---------------------------------------------------------------------
// Data-plane fault tolerance (DESIGN.md §16): an enabled-but-idle
// update guard must be bitwise invisible, and a guard rejection must be
// indistinguishable from a plan-scheduled revocation at the same time —
// the rejection path IS the drop-contribution/λ-renormalization path,
// not a parallel mechanism.

#[test]
fn prop_idle_guard_is_bitwise_invisible_under_churn() {
    // Full sim backend across sync modes × batch policies under spot
    // churn: with no corruption in the plan every modeled norm is 1.0,
    // the guard accepts everything, and the report must be bitwise
    // identical to the guard-off run (the norm probe runs either way).
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 5);
        let cores: Vec<usize> = (0..k).map(|_| rng.range_usize(2, 33)).collect();
        let sync = match rng.range_usize(0, 3) {
            0 => SyncMode::Bsp,
            1 => SyncMode::Asp,
            _ => SyncMode::Ssp { bound: rng.range_usize(0, 3) as u64 },
        };
        let policy = match rng.range_usize(0, 3) {
            0 => Policy::Dynamic, // the pid alias
            1 => Policy::Optimal,
            _ => Policy::Rl,
        };
        (cores, sync, policy, rng.next_u64())
    });
    check("idle guard == none", 40, strat, |s| {
        let (cores, sync, policy, seed) = s;
        let run = |guard: bool| {
            let mut b = SessionBuilder::default()
                .model("mnist")
                .cores(cores)
                .policy(*policy)
                .sync(*sync)
                .steps(40)
                .adjust_cost(1.0)
                .seed(*seed)
                .spot(SpotSpec { mttf_s: 10.0, down_s: 2.0, grace_s: 0.3 });
            if guard {
                b = b.guard(GuardCfg::parse("norm=8,strikes=2,probation=30").unwrap());
            }
            b.build_sim().unwrap().run().unwrap()
        };
        let (on, off) = (run(true), run(false));
        on.rejections.is_empty()
            && on.quarantines.is_empty()
            && reports_identical(&on, &off)
    });
}

#[test]
fn prop_guard_quarantine_matches_plan_revoke_bitwise() {
    // A one-shot NaN with strikes=1/late=drop quarantines the corrupted
    // worker at its completion time t_q.  Replaying the same scenario
    // with no corruption and a *plan-scheduled* revocation at exactly
    // t_q must yield a bitwise-identical report: the plan revoke lands
    // right after the completion (completions win timestamp ties) and
    // drops the just-staged contribution through the same
    // drop-contribution/λ-renormalization path the guard used.  The
    // corrupted worker is pinned strictly fastest so it can never be
    // its round's last finisher — were it last, run B's round would
    // close *with* the contribution before the revoke fires.
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 6);
        let mut durs: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 3.5)).collect();
        let w = rng.range_usize(0, k);
        durs[w] = rng.range_f64(0.3, 0.9); // strictly first finisher
        let t = rng.range_f64(0.5, 15.0);
        let dynamic = rng.range_usize(0, 2) == 1;
        (durs, w, t, dynamic)
    });
    check("guard quarantine == plan revoke", 60, strat, |s| {
        let (durs, w, t, dynamic) = s;
        let policy = if *dynamic { Policy::Dynamic } else { Policy::Uniform };
        let guard = || GuardCfg::parse("norm=8,strikes=1,probation=5,late=drop").unwrap();
        let corrupted = Session::builder()
            .policy(policy)
            .sync(SyncMode::Bsp)
            .steps(20)
            .corrupt(FaultPlan::parse_corrupt(&format!("{w}@{t}:nan")).unwrap())
            .guard(guard())
            .build_with(FixedScheduleBackend::new(durs.clone(), false))
            .unwrap()
            .run()
            .unwrap();
        if corrupted.quarantines.is_empty() {
            // Corruption landed after the run finished — nothing to compare.
            return true;
        }
        let t_q = corrupted.quarantines[0].time;
        // Same guard, no corruption: the guard idles and the plan
        // revoke drops the contribution instead.
        let planned = Session::builder()
            .policy(policy)
            .sync(SyncMode::Bsp)
            .steps(20)
            .guard(guard())
            .membership(MembershipPlan::new(vec![MembershipEvent {
                time: t_q,
                worker: *w,
                kind: MembershipKind::Revoke,
            }]))
            .build_with(FixedScheduleBackend::new(durs.clone(), false))
            .unwrap()
            .run()
            .unwrap();
        // The guard run's only extra surface is the quarantine record.
        let mut scrubbed = corrupted.clone();
        scrubbed.quarantines.clear();
        planned.quarantines.is_empty() && reports_identical(&scrubbed, &planned)
    });
}

// ---------------------------------------------------------------------
// SyncState incremental aggregates: the O(1)/O(log k) gates must match a
// from-scratch shadow scan after every operation of a random legal
// schedule that includes churn (retire/admit interleaved with pulls and
// pushes) — this is the cross-check the in-library debug_asserts run,
// promoted to an explicit property over churned schedules.

#[test]
fn prop_sync_incremental_gates_match_shadow_scan_under_churn() {
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(2, 7);
        let mode = match rng.range_usize(0, 3) {
            0 => SyncMode::Bsp,
            1 => SyncMode::Asp,
            _ => SyncMode::Ssp {
                bound: rng.range_usize(0, 4) as u64,
            },
        };
        (k, mode, rng.next_u64())
    });
    check("incremental == shadow scan", 120, strat, |&(k, mode, seed)| {
        let mut s = SyncState::new(mode, k);
        let mut rng = Rng::new(seed);
        // Shadow model: plain vectors, aggregates recomputed by scan.
        let mut clocks = vec![0u64; k];
        let mut live = vec![true; k];
        let mut in_flight = vec![false; k];
        let mut ok = true;
        for _ in 0..250 {
            let live_ws: Vec<usize> = (0..k).filter(|&w| live[w]).collect();
            let dead_ws: Vec<usize> = (0..k).filter(|&w| !live[w]).collect();
            let churn = rng.range_usize(0, 5) == 0;
            if churn && !dead_ws.is_empty() {
                let w = dead_ws[rng.range_usize(0, dead_ws.len())];
                s.admit(w);
                // Shadow admit: seed at the live minimum (if any).
                if let Some(m) = live_ws.iter().map(|&v| clocks[v]).min() {
                    clocks[w] = m;
                }
                live[w] = true;
            } else if churn && live_ws.len() > 1 {
                let w = live_ws[rng.range_usize(0, live_ws.len())];
                s.retire(w);
                live[w] = false;
                in_flight[w] = false; // its in-flight work dies with it
            } else {
                let legal: Vec<usize> = live_ws
                    .iter()
                    .copied()
                    .filter(|&w| in_flight[w] || s.may_proceed(w))
                    .collect();
                if legal.is_empty() {
                    continue;
                }
                let w = legal[rng.range_usize(0, legal.len())];
                if in_flight[w] {
                    s.push_update(w);
                    clocks[w] += 1;
                    in_flight[w] = false;
                } else {
                    s.pull(w);
                    in_flight[w] = true;
                }
            }
            // Cross-check every aggregate against the shadow scan.
            let lc: Vec<u64> = (0..k).filter(|&w| live[w]).map(|w| clocks[w]).collect();
            let smin = lc.iter().min().copied().unwrap_or(0);
            let smax = lc.iter().max().copied().unwrap_or(0);
            ok &= s.min_clock() == smin
                && s.max_clock() == smax
                && s.live_count() == lc.len()
                && s.at_barrier() == (smin == smax);
            for w in 0..k {
                let expect = live[w]
                    && match mode {
                        SyncMode::Bsp => clocks[w] == smin,
                        SyncMode::Asp => true,
                        SyncMode::Ssp { bound } => clocks[w] < smin + bound + 1,
                    };
                ok &= s.may_proceed(w) == expect;
            }
        }
        ok
    });
}

#[test]
fn prop_vecof_strategy_smoke() {
    // Exercise VecOf shrinking machinery itself.
    let strat = VecOf {
        elem: UsizeRange(0, 100),
        min_len: 1,
        max_len: 8,
    };
    check("vecof in bounds", 200, strat, |v| {
        (1..=8).contains(&v.len()) && v.iter().all(|&x| x <= 100)
    });
}

// =====================================================================
// Pluggable batch policies (DESIGN.md §14): every BatchPolicy
// implementation — PID reference, one-shot optimal, tabular RL — must
// conserve the global batch across adjustments AND membership churn,
// and the "pid" policy spec must be a pure alias for the dynamic
// controller (bitwise-identical reports).

/// All shipped BatchPolicy implementations over the same start state.
fn policy_zoo(init: &[f64]) -> Vec<Box<dyn BatchPolicy>> {
    vec![
        Box::new(DynamicBatcher::new(default_cfg(), init)),
        Box::new(OptimalBatcher::new(default_cfg(), init)),
        Box::new(RlBatcher::new(default_cfg(), init, RlTable::builtin())),
    ]
}

#[test]
fn prop_every_batch_policy_conserves_global_batch_under_churn() {
    let strat = FnStrategy(|rng: &mut Rng| {
        let s = ScenarioStrategy.generate(rng);
        let victim = rng.range_usize(0, s.xs.len());
        let retire_at = rng.range_usize(5, 40);
        let admit_back = rng.range_usize(0, 2) == 1;
        (s, victim, retire_at, admit_back)
    });
    check("all policies conserve Σb", 40, strat, |c| {
        let (s, victim, retire_at, admit_back) = c;
        let expect: f64 = s.init.iter().sum();
        let mut ok = true;
        for mut ctl in policy_zoo(&s.init) {
            let mut rng = Rng::new(s.seed);
            let mut active = vec![true; s.xs.len()];
            let mut b = Vec::new();
            for it in 0..60usize {
                if it == *retire_at && active.iter().filter(|&&a| a).count() > 1 {
                    ctl.retire(*victim);
                    active[*victim] = false;
                }
                if *admit_back && it == retire_at + 10 && !active[*victim] {
                    ctl.admit(*victim);
                    active[*victim] = true;
                }
                ctl.batches_into(&mut b);
                for (w, &x) in s.xs.iter().enumerate() {
                    if !active[w] {
                        continue;
                    }
                    let noise = if s.noise > 0.0 {
                        rng.lognormal(1.0, s.noise)
                    } else {
                        1.0
                    };
                    ctl.observe(w, (s.overhead + b[w] / x) * noise);
                }
                ctl.maybe_adjust();
                ctl.batches_into(&mut b);
                let sum: f64 = b.iter().sum();
                ok &= (sum - expect).abs() / expect < 1e-6;
                ok &= (ctl.global_batch() - expect).abs() / expect < 1e-6;
                ok &= active
                    .iter()
                    .zip(&b)
                    .all(|(&a, &bk)| if a { bk > 0.0 } else { bk == 0.0 });
            }
        }
        ok
    });
}

#[test]
fn prop_controller_policies_conserve_global_batch_in_session_runs() {
    // Same invariant end-to-end: a churned Session run under each
    // controller policy conserves Σb at every epoch transition.
    let strat = FnStrategy(|rng: &mut Rng| {
        let s = SchedStrategy.generate(rng);
        let policy = [Policy::Dynamic, Policy::Optimal, Policy::Rl]
            [rng.range_usize(0, 3)];
        (s, policy)
    });
    check("session Σb per policy", 40, strat, |(s, policy)| {
        let mut b = Session::builder()
            .policy(*policy)
            .sync(s.sync)
            .steps(s.steps);
        if let Some((w, t1, t2)) = s.churn {
            b = b.membership(MembershipPlan::new(vec![
                MembershipEvent { time: t1, worker: w, kind: MembershipKind::Revoke },
                MembershipEvent { time: t2, worker: w, kind: MembershipKind::Join },
            ]));
        }
        let r = b
            .build_with(FixedScheduleBackend::new(s.durs.clone(), false))
            .unwrap()
            .run()
            .unwrap();
        let total = 32.0 * s.durs.len() as f64;
        r.epochs.iter().all(|e| {
            (e.batches.iter().sum::<f64>() - total).abs() < 1e-6
        }) && r.adjustments.iter().all(|a| {
            (a.batches.iter().sum::<f64>() - total).abs() < 1e-6
        })
    });
}

#[test]
fn prop_pid_spec_is_bitwise_identical_to_dynamic() {
    // "pid" is documentation, not behavior: a builder parsed from a
    // `"policy": "pid"` spec must reproduce the Policy::Dynamic run
    // bitwise — same floats, same events, same adjustments.
    check("pid == dynamic bitwise", 60, SchedStrategy, |s| {
        let run = |spec: &str| -> RunReport {
            let mut b = SessionBuilder::from_json_str(spec)
                .unwrap()
                .sync(s.sync)
                .steps(s.steps);
            if let Some((w, t1, t2)) = s.churn {
                b = b.membership(MembershipPlan::new(vec![
                    MembershipEvent { time: t1, worker: w, kind: MembershipKind::Revoke },
                    MembershipEvent { time: t2, worker: w, kind: MembershipKind::Join },
                ]));
            }
            b.build_with(FixedScheduleBackend::new(s.durs.clone(), false))
            .unwrap()
            .run()
            .unwrap()
        };
        let pid = run(r#"{"policy": "pid"}"#);
        let dynamic = run(r#"{"policy": "dynamic"}"#);
        reports_identical(&pid, &dynamic)
    });
}

// =====================================================================
// Fleet isolation (DESIGN.md §13)

/// A random multi-job fleet: mixed cluster shapes, sync-free mnist sims
/// with every event source the fleet could plausibly disturb (faults +
/// detector, autoscaled spawns, spot churn) cycling through the jobs.
#[derive(Debug, Clone)]
struct FleetJob {
    cores: Vec<usize>,
    dynamic: bool,
    steps: u64,
    seed: u64,
    arrival: f64,
    /// 0 plain | 1 crash+detector | 2 autoscaled recovery | 3 spot churn.
    shape: usize,
}

#[derive(Debug, Clone)]
struct FleetScenario {
    jobs: Vec<FleetJob>,
}

struct FleetStrategy;

impl Strategy<FleetScenario> for FleetStrategy {
    fn generate(&self, rng: &mut Rng) -> FleetScenario {
        let n = rng.range_usize(2, 6);
        let jobs = (0..n)
            .map(|_| FleetJob {
                cores: (0..rng.range_usize(2, 5))
                    .map(|_| [4, 8, 16][rng.range_usize(0, 3)])
                    .collect(),
                dynamic: rng.range_usize(0, 2) == 1,
                steps: rng.range_usize(6, 20) as u64,
                seed: rng.next_u64(),
                arrival: rng.range_f64(0.0, 30.0),
                shape: rng.range_usize(0, 4),
            })
            .collect();
        FleetScenario { jobs }
    }

    fn shrink(&self, s: &FleetScenario) -> Vec<FleetScenario> {
        let mut out = Vec::new();
        if s.jobs.len() > 2 {
            let mut t = s.clone();
            t.jobs.pop();
            out.push(t);
        }
        if s.jobs.iter().any(|j| j.shape != 0) {
            let mut t = s.clone();
            for j in &mut t.jobs {
                j.shape = 0;
            }
            out.push(t);
        }
        if s.jobs.iter().any(|j| j.arrival != 0.0) {
            let mut t = s.clone();
            for j in &mut t.jobs {
                j.arrival = 0.0;
            }
            out.push(t);
        }
        out
    }
}

fn fleet_job_builder(j: &FleetJob) -> SessionBuilder {
    let b = Session::builder()
        .model("mnist")
        .cores(&j.cores)
        .policy(if j.dynamic { Policy::Dynamic } else { Policy::Uniform })
        .steps(j.steps)
        .adjust_cost(1.0)
        .seed(j.seed);
    match j.shape {
        1 => b
            .faults(FaultPlan::parse("crash:0@3").unwrap())
            .detector(DetectorCfg::parse("grace=4,floor=2").unwrap()),
        2 => b
            .faults(FaultPlan::parse("crash:1@2").unwrap())
            .detector(DetectorCfg::parse("grace=3,floor=2").unwrap())
            .autoscale(AutoscalerCfg::parse("pool=1,cold=2").unwrap()),
        3 => b.spot(SpotSpec::parse("25:6:1").unwrap()),
        _ => b,
    }
}

/// Isolation invariant: an uncontended fleet (capacity = total demand)
/// never touches its tenants' event or rng streams, so every per-job
/// report is *bitwise identical* to the same builder run standalone —
/// across any mix of arrivals, shapes, and interleavings the merged
/// clock produces.
#[test]
fn prop_fleet_isolation_uncontended_bitwise() {
    check("fleet isolation", 40, FleetStrategy, |s| {
        let builders: Vec<SessionBuilder> = s.jobs.iter().map(fleet_job_builder).collect();
        let solo: Vec<RunReport> = builders
            .iter()
            .map(|b| b.clone().build_sim().unwrap().run().unwrap())
            .collect();
        let mut f = FleetBuilder::new().interleave(true);
        for (i, (j, b)) in s.jobs.iter().zip(&builders).enumerate() {
            let mut spec = JobSpec::new(&format!("job{i}"), b.clone());
            spec.arrival = j.arrival;
            f = f.job(spec);
        }
        let reports = f.build().unwrap().run().unwrap().into_reports();
        reports.len() == solo.len()
            && reports.iter().zip(&solo).all(|(a, b)| a.bitwise_eq(b))
    });
}

// =====================================================================
// Checkpoint round-trip (DESIGN.md §15)

/// Snapshot at a *random* `step()` boundary — not just a round boundary
/// — under BSP/ASP/SSP schedules with and without churn, across every
/// controller family, then restore into a freshly built session and run
/// both to completion: the resumed report must be bitwise identical to
/// the uninterrupted one.
#[test]
fn prop_ckpt_snapshot_restore_replays_bitwise() {
    let strat = FnStrategy(|rng: &mut Rng| {
        let k = rng.range_usize(3, 6);
        let durs: Vec<f64> = (0..k).map(|_| rng.range_f64(0.5, 3.5)).collect();
        (
            durs,
            rng.range_usize(0, 3),      // sync selector
            rng.range_usize(0, 4),      // policy selector
            rng.range_usize(1, 60),     // steps before the snapshot
            rng.range_usize(0, 2) == 1, // churn on/off
        )
    });
    check("ckpt roundtrip bitwise", 50, strat, |s| {
        let (durs, si, pi, boundary, churn) = s;
        let sync = [SyncMode::Bsp, SyncMode::Asp, SyncMode::Ssp { bound: 2 }][*si];
        let policy = [Policy::Dynamic, Policy::Optimal, Policy::Rl, Policy::Uniform][*pi];
        let mut builder = Session::builder()
            .policy(policy)
            .sync(sync)
            .steps(25)
            .adjust_cost(0.5);
        if *churn {
            builder = builder.membership(MembershipPlan::new(vec![
                MembershipEvent {
                    time: 6.5,
                    worker: 0,
                    kind: MembershipKind::Revoke,
                },
                MembershipEvent {
                    time: 14.5,
                    worker: 0,
                    kind: MembershipKind::Join,
                },
            ]));
        }
        let mock = || FixedScheduleBackend::new(durs.clone(), false);
        // Uninterrupted reference.
        let mut b_sess = builder.clone().build_with(mock()).unwrap();
        let mut b_rs = b_sess.start().unwrap();
        while b_sess.step(&mut b_rs).unwrap() {}
        let base = b_sess.finish(b_rs);
        // Interrupted at `boundary` steps (or wherever the run ends).
        let mut s1 = builder.clone().build_with(mock()).unwrap();
        let mut rs1 = s1.start().unwrap();
        let mut alive = true;
        for _ in 0..*boundary {
            if !alive {
                break;
            }
            alive = s1.step(&mut rs1).unwrap();
        }
        let snap = s1.snapshot_run(&rs1);
        // A fresh session restores the snapshot and finishes the run.
        let mut s2 = builder.clone().build_with(mock()).unwrap();
        let mut rs2 = s2.restore_run(&snap, None).unwrap();
        if alive {
            while s2.step(&mut rs2).unwrap() {}
        }
        let resumed = s2.finish(rs2);
        base.bitwise_eq(&resumed)
    });
}
