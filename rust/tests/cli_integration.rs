//! CLI integration: drive the `hbatch` binary end to end.

use std::process::Command;

fn hbatch() -> Command {
    Command::new(env!("CARGO_BIN_EXE_hbatch"))
}

fn run_ok(args: &[&str]) -> String {
    let out = hbatch()
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn hbatch");
    assert!(
        out.status.success(),
        "hbatch {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn no_args_prints_usage_and_fails() {
    let out = hbatch().output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("commands:"));
}

#[test]
fn unknown_command_fails() {
    let out = hbatch().arg("bogus").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn simulate_emits_json_report() {
    let out = run_ok(&[
        "simulate",
        "--workload",
        "mnist",
        "--cores",
        "4,8,16",
        "--policy",
        "dynamic",
        "--iters",
        "200",
    ]);
    let j = hetero_batch::util::json::Json::parse(&out).expect("valid json");
    assert_eq!(j.get("total_iters").as_i64(), Some(200));
    assert!(j.get("total_time_s").as_f64().unwrap() > 0.0);
    assert_eq!(j.get("workers").as_arr().unwrap().len(), 3);
}

#[test]
fn simulate_hlevel_generates_cluster() {
    let out = run_ok(&[
        "simulate",
        "--workload",
        "resnet",
        "--hlevel",
        "6",
        "--policy",
        "static",
        "--iters",
        "100",
    ]);
    let j = hetero_batch::util::json::Json::parse(&out).unwrap();
    assert_eq!(j.get("workers").as_arr().unwrap().len(), 3);
}

#[test]
fn figure_5_writes_csv() {
    let dir = std::env::temp_dir().join("hbatch_cli_fig5");
    let _ = std::fs::remove_dir_all(&dir);
    let out = run_ok(&["figure", "5", "--out-dir", dir.to_str().unwrap()]);
    assert!(out.contains("fig5_throughput_vs_batch"));
    let csv =
        std::fs::read_to_string(dir.join("fig5_throughput_vs_batch.csv")).unwrap();
    assert!(csv.starts_with("device,batch,throughput_sps"));
    assert!(csv.lines().count() > 10);
}

#[test]
fn throughput_scan_is_csvish() {
    let out = run_ok(&["throughput-scan", "--device", "gpu:T4", "--workload", "resnet"]);
    assert!(out.starts_with("batch,throughput_sps,iter_time_s"));
    assert!(out.lines().count() > 5);
}

#[test]
fn info_lists_models() {
    let out = run_ok(&["info"]);
    for m in ["linreg", "mlp", "cnn", "transformer"] {
        assert!(out.contains(m), "missing {m} in: {out}");
    }
    assert!(out.contains("grad_agg"));
}

#[test]
fn train_exercises_pool_eval_and_prefetch_flags() {
    // Needs built artifacts (like engine_integration). Exercises the
    // §Perf iteration 4 knobs end to end from the CLI.
    let out = run_ok(&[
        "train",
        "--model",
        "mlp",
        "--steps",
        "6",
        "--eval-every",
        "3",
        "--pool-threads",
        "2",
        "--no-prefetch",
        "--cores",
        "4,8",
    ]);
    assert!(out.contains("steps: 6"), "missing step count in: {out}");
    // Evals at steps 3 and 6.
    assert!(out.contains("evals: 2"), "missing eval summary in: {out}");
}

#[test]
fn train_collect_agg_flag_runs_the_barrier_baseline() {
    // --collect-agg selects the collect-then-aggregate BSP baseline
    // (per-worker arena + barrier-built tree).  Bit-identity with the
    // default eager path is locked in engine_integration; here we just
    // exercise the flag end to end.
    let out = run_ok(&[
        "train",
        "--model",
        "mlp",
        "--steps",
        "4",
        "--cores",
        "4,8",
        "--collect-agg",
    ]);
    assert!(out.contains("steps: 4"), "missing step count in: {out}");
}

#[test]
fn train_runs_asp_sync_end_to_end() {
    // ASP on the real runtime: a 4-step budget on 2 workers applies 8
    // individual (stale-capable) updates.
    let out = run_ok(&[
        "train", "--model", "mlp", "--steps", "4", "--cores", "4,8", "--sync", "asp",
        "--policy", "uniform",
    ]);
    assert!(out.contains("steps: 8"), "missing ASP update count in: {out}");
    assert!(out.contains("run: real/mlp/uniform/asp"), "bad label in: {out}");
}

#[test]
fn train_and_simulate_reject_bad_sync_identically() {
    // `--sync` must be validated on BOTH subcommands, with the same
    // error text, and before `train` ever touches the artifacts.
    let stderr_of = |args: &[&str]| {
        let out = hbatch()
            .args(args)
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .unwrap();
        assert!(!out.status.success(), "{args:?} should fail");
        String::from_utf8_lossy(&out.stderr).into_owned()
    };
    let from_train = stderr_of(&["train", "--sync", "ssp:bad"]);
    let from_sim = stderr_of(&["simulate", "--sync", "ssp:bad"]);
    assert!(from_train.contains("bad --sync"), "train stderr: {from_train}");
    assert_eq!(from_train, from_sim, "error text diverged between subcommands");
}

#[test]
fn simulate_join_schedules_membership_epoch() {
    // Worker 2 joins at t=0: deterministic single epoch, visible in the
    // JSON report.
    let out = run_ok(&[
        "simulate", "--workload", "mnist", "--cores", "4,8,16", "--policy", "static",
        "--iters", "50", "--join", "2@0",
    ]);
    let j = hetero_batch::util::json::Json::parse(&out).expect("valid json");
    assert_eq!(j.get("n_epochs").as_i64(), Some(1));
    let e = j.get("epochs").idx(0);
    assert_eq!(e.get("kind").as_str(), Some("join"));
    assert_eq!(e.get("worker").as_i64(), Some(2));
    assert_eq!(e.get("live").as_i64(), Some(3));
}

#[test]
fn simulate_spot_flag_runs_end_to_end() {
    // Spot churn is seeded; with a huge mttf the trace is event-free and
    // the run must look like a plain one (flag plumbing, not behavior —
    // behavior is pinned by tests/scenario_regression.rs).
    let out = run_ok(&[
        "simulate", "--workload", "mnist", "--cores", "4,8", "--iters", "40",
        "--spot", "1000000000:1:0",
    ]);
    let j = hetero_batch::util::json::Json::parse(&out).expect("valid json");
    assert_eq!(j.get("total_iters").as_i64(), Some(40));
    assert_eq!(j.get("n_epochs").as_i64(), Some(0));
}

#[test]
fn train_join_runs_membership_epoch_end_to_end() {
    // Needs built artifacts. Worker 1 joins at t=0 on the real runtime.
    let out = run_ok(&[
        "train", "--model", "mlp", "--steps", "5", "--cores", "4,8", "--policy",
        "uniform", "--join", "1@0",
    ]);
    assert!(out.contains("steps: 5"), "missing step count in: {out}");
    assert!(out.contains("membership epochs: 1"), "missing epoch line in: {out}");
}

#[test]
fn train_spot_flag_trains_normally_when_trace_is_event_free() {
    // A *valid* --spot on train with huge mttf trains normally.
    let out = run_ok(&[
        "train", "--model", "mlp", "--steps", "4", "--cores", "4,8",
        "--spot", "1000000000:1",
    ]);
    assert!(out.contains("steps: 4"), "missing step count in: {out}");
}

#[test]
fn train_and_simulate_reject_bad_spot_and_join_identically() {
    // Same convention as bad --sync: validated on BOTH subcommands with
    // identical error text, before `train` touches the artifacts.
    let stderr_of = |args: &[&str]| {
        let out = hbatch()
            .args(args)
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .unwrap();
        assert!(!out.status.success(), "{args:?} should fail");
        String::from_utf8_lossy(&out.stderr).into_owned()
    };
    for (flag, bad) in [
        ("--spot", "100"),
        ("--spot", "a:b"),
        ("--join", "1@"),
        ("--faults", "bogus"),
        ("--faults", "crash:x@3"),
        ("--faults", "stall:1@5"),
        ("--detect", "grace=0"),
        ("--detect", "late=sometimes"),
        ("--autoscale", "jitter=2"),
        ("--autoscale", "pool=x"),
        ("--corrupt", "bogus"),
        ("--corrupt", "1@5:zap"),
        ("--corrupt", "1@5:scale"),
        ("--guard", "strikes=0"),
        ("--guard", "late=sometimes"),
        ("--guard", "norm=x"),
    ] {
        let from_train = stderr_of(&["train", flag, bad]);
        let from_sim = stderr_of(&["simulate", flag, bad]);
        assert!(
            from_train.contains(&format!("bad {flag}")),
            "train stderr for {flag} {bad}: {from_train}"
        );
        assert_eq!(
            from_train, from_sim,
            "error text diverged between subcommands for {flag} {bad}"
        );
    }
}

#[test]
fn simulate_crash_with_detector_and_autoscaler_recovers_end_to_end() {
    // The ISSUE acceptance scenario from the CLI: an unannounced crash
    // mid-BSP, a progress-deadline detector, and a one-VM pool.  The
    // run must complete and the JSON report must carry the suspicion,
    // the spawn trail, and the revoke/join epochs.
    let out = run_ok(&[
        "simulate", "--workload", "mnist", "--cores", "4,4,8", "--policy", "dynamic",
        "--iters", "60", "--seed", "2", "--faults", "crash:1@1",
        "--detect", "grace=4,floor=5", "--autoscale", "pool=1,cold=1",
    ]);
    let j = hetero_batch::util::json::Json::parse(&out).expect("valid json");
    assert_eq!(j.get("total_iters").as_i64(), Some(60));
    let sus = j.get("suspicions");
    assert_eq!(sus.idx(0).get("worker").as_i64(), Some(1));
    assert_eq!(sus.idx(0).get("action").as_str(), Some("suspect"));
    let spawns = j.get("spawns").as_arr().expect("spawns array").clone();
    assert!(spawns.iter().any(|s| s.get("action").as_str() == Some("ready")));
    assert_eq!(j.get("n_epochs").as_i64(), Some(2));
}

#[test]
fn simulate_rejects_crash_without_detector() {
    // A crash fault with no detector can never be reclaimed — the
    // builder must refuse it up front rather than hang the barrier.
    let out = hbatch()
        .args([
            "simulate", "--workload", "mnist", "--cores", "4,8", "--faults",
            "crash:1@10",
        ])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("detector"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn simulate_corruption_with_guard_recovers_end_to_end() {
    // The DESIGN.md §16 acceptance scenario from the CLI: a one-shot
    // NaN poisoning of worker 1's update, caught by a single-strike
    // guard.  The run must complete and the JSON report must carry the
    // quarantine/readmit trail and the revoke/join epochs.  Onset and
    // probation are fractions of the clean run's measured makespan so
    // the readmit always lands inside the run, whatever the workload's
    // absolute time scale.
    // --adjust-cost 1: the simulate default charges 30 s per applied
    // readjustment, and a single such pause straddling the onset could
    // push the probation expiry past the end of the run.
    let base = [
        "simulate", "--workload", "mnist", "--cores", "4,4,8", "--policy", "dynamic",
        "--iters", "60", "--seed", "2", "--adjust-cost", "1",
    ];
    let clean = run_ok(&base);
    let t = hetero_batch::util::json::Json::parse(&clean)
        .expect("valid json")
        .get("total_time_s")
        .as_f64()
        .expect("clean run reports total_time_s");
    let corrupt = format!("1@{:.4}:nan", 0.35 * t);
    let guard = format!("norm=8,strikes=1,probation={:.4}", 0.3 * t);
    let mut args: Vec<&str> = base.to_vec();
    args.extend_from_slice(&["--corrupt", &corrupt, "--guard", &guard]);
    let out = run_ok(&args);
    let j = hetero_batch::util::json::Json::parse(&out).expect("valid json");
    assert_eq!(j.get("total_iters").as_i64(), Some(60));
    // strikes=1 escalates straight to quarantine: no standalone rejects.
    assert!(j.get("rejections").is_null(), "unexpected rejections in: {out}");
    let q = j.get("quarantines");
    assert_eq!(q.idx(0).get("worker").as_i64(), Some(1));
    assert_eq!(q.idx(0).get("action").as_str(), Some("quarantine"));
    assert_eq!(q.idx(1).get("worker").as_i64(), Some(1));
    assert_eq!(q.idx(1).get("action").as_str(), Some("readmit"));
    assert_eq!(j.get("n_epochs").as_i64(), Some(2));
}

#[test]
fn simulate_rejects_corruption_without_guard() {
    // A corruption plan with no update guard would silently poison the
    // model — the builder must refuse it up front (same convention as
    // crash-without-detector).
    let out = hbatch()
        .args([
            "simulate", "--workload", "mnist", "--cores", "4,8", "--corrupt",
            "1@10:nan",
        ])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("guard"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn resume_refuses_real_backend_checkpoints_with_roadmap_pointer() {
    // Needs built artifacts. `hbatch train --checkpoint` commits a
    // seq-0 snapshot whose config names the real backend; `resume`
    // must refuse it by name and point at the open deterministic-replay
    // gap rather than resume into a silently non-bit-identical run.
    let dir = std::env::temp_dir().join("hbatch_cli_real_resume");
    let _ = std::fs::remove_dir_all(&dir);
    run_ok(&[
        "train", "--model", "mlp", "--steps", "4", "--cores", "4,8",
        "--checkpoint", dir.to_str().unwrap(),
    ]);
    let out = hbatch()
        .args(["resume", "--from", dir.to_str().unwrap()])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!out.status.success(), "resume should refuse a real-backend checkpoint");
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(
        err.contains(dir.to_str().unwrap()),
        "refusal must name the checkpoint dir: {err}"
    );
    assert!(
        err.contains("Real-backend bit-identical resume"),
        "refusal must cite the ROADMAP gap: {err}"
    );
    assert!(err.contains("hbatch train"), "refusal must suggest a restart: {err}");
}

#[test]
fn simulate_scheduler_scan_matches_heap_byte_for_byte() {
    // The O(k) baseline and the O(log k) heap must produce the same
    // run — including every float in the JSON report.
    let base = [
        "simulate", "--workload", "mnist", "--cores", "4,8,16", "--policy", "dynamic",
        "--iters", "120",
    ];
    let mut heap_args = base.to_vec();
    heap_args.extend(["--scheduler", "heap"]);
    let mut scan_args = base.to_vec();
    scan_args.extend(["--scheduler", "scan"]);
    assert_eq!(run_ok(&heap_args), run_ok(&scan_args));
    // Bad value fails with the `bad --sync`-style error text.
    let out = hbatch()
        .args(["simulate", "--scheduler", "bogus"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("bad --scheduler"));
}

#[test]
fn simulate_report_sample_thins_records_not_the_run() {
    let report = |sample: &str| {
        let out = run_ok(&[
            "simulate", "--workload", "mnist", "--cores", "4,8,16", "--policy",
            "static", "--iters", "90", "--report-sample", sample,
        ]);
        hetero_batch::util::json::Json::parse(&out).expect("valid json")
    };
    let full = report("1");
    let thin = report("9");
    // The trajectory is untouched; only report density changes.
    assert_eq!(
        full.get("total_time_s").as_f64(),
        thin.get("total_time_s").as_f64()
    );
    assert_eq!(full.get("total_iters").as_i64(), thin.get("total_iters").as_i64());
    let records = |j: &hetero_batch::util::json::Json| -> i64 {
        j.get("workers")
            .as_arr()
            .unwrap()
            .iter()
            .map(|w| w.get("n").as_i64().unwrap())
            .sum()
    };
    // 90 BSP rounds × 3 workers = 270 records; every 9th round kept
    // whole ⇒ 10 rounds × 3 workers = 30.
    assert_eq!(records(&full), 270);
    assert_eq!(records(&thin), 30);
    // The config-file key works too: the CLI default (1) must not
    // clobber it when --report-sample is not passed.
    let cfg = std::env::temp_dir().join("hbatch_report_sample_cfg.json");
    std::fs::write(&cfg, r#"{"report_sample": 9}"#).unwrap();
    let out = run_ok(&[
        "simulate", "--config", cfg.to_str().unwrap(), "--workload", "mnist",
        "--cores", "4,8,16", "--policy", "static", "--iters", "90",
    ]);
    let via_cfg = hetero_batch::util::json::Json::parse(&out).expect("valid json");
    assert_eq!(records(&via_cfg), 30);
    // report_sample must be >= 1.
    let out = hbatch()
        .args(["simulate", "--report-sample", "0"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn bad_flag_values_fail_cleanly() {
    for args in [
        vec!["simulate", "--policy", "bogus"],
        vec!["simulate", "--sync", "bogus"],
        vec!["simulate", "--sync", "ssp:bad"],
        vec!["train", "--sync", "bogus"],
        vec!["train", "--sync", "ssp:bad"],
        vec!["train", "--policy", "bogus"],
        vec!["simulate", "--spot", "100"],
        vec!["simulate", "--spot", "100:0"],
        vec!["simulate", "--spot", "1:2:3:4"],
        vec!["simulate", "--join", "x@3"],
        vec!["simulate", "--join", "1@-5"],
        // Join for a worker outside the cluster fails validation.
        vec!["simulate", "--cores", "4,8", "--join", "7@10"],
        vec!["train", "--spot", "0:5"],
        vec!["train", "--join", "bogus"],
        vec!["train", "--cores", "4,8", "--join", "7@10"],
        // Fault for a worker outside the cluster fails validation.
        vec!["simulate", "--cores", "4,8", "--faults", "stall:7@10:5"],
        // Autoscaler floor above the cluster size fails validation.
        vec!["simulate", "--cores", "4,8", "--autoscale", "pool=1,floor=9"],
        vec!["figure", "99"],
        vec!["throughput-scan", "--device", "quantum:1"],
    ] {
        let out = hbatch()
            .args(&args)
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .unwrap();
        assert!(!out.status.success(), "{args:?} should fail");
    }
}

#[test]
fn simulate_accepts_config_file() {
    let path = std::env::temp_dir().join("hbatch_cfg.json");
    std::fs::write(
        &path,
        r#"{"workload": "mnist", "policy": "static", "b0": 50,
            "workers": [{"cpu": 4}, {"cpu": 16}]}"#,
    )
    .unwrap();
    // CLI flags still override the file (cores here).
    let out = run_ok(&[
        "simulate",
        "--config",
        path.to_str().unwrap(),
        "--workload",
        "mnist",
        "--cores",
        "4,16",
        "--policy",
        "static",
        "--iters",
        "50",
    ]);
    let j = hetero_batch::util::json::Json::parse(&out).unwrap();
    assert_eq!(j.get("total_iters").as_i64(), Some(50));
}
