//! Fleet scheduler integration tests (DESIGN.md §13): contention
//! actuated through the membership revocation path, seed derivation
//! from the fleet config, and interleaved/parallel path agreement.

use hetero_batch::ckpt::{has_ckpts, CkptSpec};
use hetero_batch::config::Policy;
use hetero_batch::fleet::{job_seed, ArbiterPolicy, FleetBuilder, JobSpec};
use hetero_batch::metrics::RunReport;
use hetero_batch::session::{Session, SessionBuilder};
use hetero_batch::trace::MembershipKind;

fn job(seed: u64, cores: &[usize], steps: u64) -> SessionBuilder {
    Session::builder()
        .model("mnist")
        .cores(cores)
        .policy(Policy::Dynamic)
        .steps(steps)
        .adjust_cost(1.0)
        .seed(seed)
}

/// Strict-priority contention: two long low-priority jobs saturate the
/// fleet; a short high-priority arrival preempts them down to their
/// floors *through the PR'd membership revocation path* (the same
/// plan-revoke machinery spot churn uses), and its completion re-grants
/// the revoked ranks as plan joins.  Everyone still finishes.
#[test]
fn priority_preemption_retires_and_regrants_through_membership_path() {
    let mut f = FleetBuilder::new()
        .capacity(8)
        .policy(ArbiterPolicy::Priority)
        .interleave(true);
    for i in 0..2 {
        let mut spec = JobSpec::new(&format!("low{i}"), job(10 + i, &[4, 8, 4, 8], 400));
        spec.priority = 0;
        f = f.job(spec);
    }
    let mut hi = JobSpec::new("high", job(99, &[8, 8, 8, 8, 8, 8], 20));
    hi.priority = 5;
    hi.arrival = 5.0;
    f = f.job(hi);

    let report = f.build().unwrap().run().unwrap();
    assert!(report.interleaved);
    assert_eq!(report.jobs.len(), 3);
    assert!(report.makespan > 0.0);

    let high = &report.jobs[2];
    assert_eq!(high.name, "high");
    assert_eq!(high.fleet_preemptions, 0, "highest priority is never preempted");
    // Admitted at its arrival: floors (1+1) + its 6 ranks fit in 8.
    assert_eq!(high.admission, 5.0);
    assert_eq!(high.granted_final, 6);

    for low in &report.jobs[..2] {
        // 4 ranks → floor 1: three ranks revoked at the arrival, three
        // re-granted after the high job completes.
        assert_eq!(low.fleet_preemptions, 3, "{}: {low:?}", low.name);
        assert_eq!(low.fleet_regrants, 3, "{}", low.name);
        assert_eq!(low.granted_final, 4, "{}", low.name);
        let revokes: Vec<f64> = low
            .report
            .epochs
            .iter()
            .filter(|e| e.kind == MembershipKind::Revoke)
            .map(|e| e.time)
            .collect();
        let joins: Vec<f64> = low
            .report
            .epochs
            .iter()
            .filter(|e| e.kind == MembershipKind::Join)
            .map(|e| e.time)
            .collect();
        assert_eq!(revokes.len(), 3, "{}", low.name);
        assert_eq!(joins.len(), 3, "{}", low.name);
        // Preemption lands at (or after) the high job's arrival on the
        // job-local clock (offset 0 here) and the regrants strictly
        // after its completion began.
        assert!(revokes.iter().all(|&t| t >= 5.0), "{}: {revokes:?}", low.name);
        let first_join = joins.iter().cloned().fold(f64::INFINITY, f64::min);
        let last_revoke = revokes.iter().cloned().fold(0.0, f64::max);
        assert!(first_join > last_revoke, "{}", low.name);
    }
    // Low jobs kept running at their floor: they produced iterations
    // between preemption and regrant.
    assert!(report.jobs[0].report.total_iters > 0);
    assert!(report.utilization > 0.0 && report.utilization <= 1.0);
}

/// Satellite 1: fleet-config jobs without a pinned seed derive
/// `job_seed(fleet_seed, job_id)` — bitwise equal to standalone runs
/// seeded the same way, and distinct across job ids.
#[test]
fn fleet_json_derives_per_job_seed_stream() {
    let cfg = r#"{
        "seed": 42,
        "jobs": [
            {"name": "a", "model": "mnist", "workers": [{"cpu": 4}, {"cpu": 8}], "steps": 12},
            {"model": "mnist", "workers": [{"cpu": 4}, {"cpu": 8}], "steps": 12},
            {"name": "pinned", "model": "mnist", "workers": [{"cpu": 4}, {"cpu": 8}], "steps": 12, "seed": 7}
        ]
    }"#;
    let reports = FleetBuilder::from_json_str(cfg)
        .unwrap()
        .build()
        .unwrap()
        .run()
        .unwrap()
        .into_reports();

    let solo = |seed: u64| -> RunReport {
        job(seed, &[4, 8], 12).build_sim().unwrap().run().unwrap()
    };
    assert!(reports[0].bitwise_eq(&solo(job_seed(42, 0))));
    assert!(reports[1].bitwise_eq(&solo(job_seed(42, 1))));
    // A pinned seed wins over the derived stream.
    assert!(reports[2].bitwise_eq(&solo(7)));
    // Identical configs, different job ids ⇒ decorrelated runs.
    assert_ne!(job_seed(42, 0), job_seed(42, 1));
    assert!(!reports[0].bitwise_eq(&reports[1]));
}

/// The interleaved scheduler and the parallel fast path agree bitwise
/// on uncontended fleets, staggered arrivals included.
#[test]
fn interleaved_matches_parallel_fast_path() {
    let build = || {
        let mut f = FleetBuilder::new();
        for i in 0..5u64 {
            let mut spec =
                JobSpec::new(&format!("j{i}"), job(i, &[4, 8, 16], 10 + i));
            spec.arrival = 3.0 * i as f64;
            f = f.job(spec);
        }
        f
    };
    let inter = build().interleave(true).build().unwrap().run().unwrap();
    let par = build().interleave(false).build().unwrap().run().unwrap();
    assert!(inter.interleaved);
    assert!(!par.interleaved);
    assert_eq!(inter.jobs.len(), par.jobs.len());
    for (a, b) in inter.jobs.iter().zip(&par.jobs) {
        assert!(a.report.bitwise_eq(&b.report), "{} diverged", a.name);
        assert_eq!(a.completion, b.completion, "{}", a.name);
    }
}

/// Forcing the parallel path on a contended fleet is a config error.
#[test]
fn contended_fleet_rejects_parallel_mode() {
    let f = FleetBuilder::new()
        .capacity(2)
        .interleave(false)
        .job(JobSpec::new("a", job(0, &[4, 8], 5)))
        .job(JobSpec::new("b", job(1, &[4, 8], 5)));
    assert!(f.build().is_err());
}

/// FleetReport::to_json carries the fleet-level aggregates and per-job
/// wasted-spawn accounting the EXPERIMENTS harness reads.
#[test]
fn fleet_report_json_schema() {
    let report = FleetBuilder::new()
        .seed(3)
        .job(JobSpec::new("a", job(1, &[4, 8], 6)))
        .job(JobSpec::new("b", job(2, &[4, 8], 6)))
        .build()
        .unwrap()
        .run()
        .unwrap();
    let j = report.to_json();
    for key in [
        "policy",
        "capacity",
        "seed",
        "interleaved",
        "n_jobs",
        "makespan",
        "completion_p50",
        "completion_p99",
        "utilization",
        "total_wasted_spawns",
    ] {
        assert!(!j.get(key).is_null(), "missing {key}");
    }
    let jobs = j.get("jobs").as_arr().unwrap();
    assert_eq!(jobs.len(), 2);
    for jj in jobs {
        for key in [
            "name",
            "arrival",
            "admission",
            "completion",
            "total_iters",
            "granted_final",
            "fleet_preemptions",
            "spawn_requests",
            "wasted_spawns",
        ] {
            assert!(!jj.get(key).is_null(), "missing job key {key}");
        }
    }
}

/// Tentpole (DESIGN.md §15): a contended priority fleet is killed
/// mid-run by coordinator crash injection — twice — and each rerun of
/// the same command (same checkpoint dir) resumes from the latest
/// durable snapshot.  The final report must be bitwise identical to an
/// uninterrupted run: preempt-to-disk means no granted rank, pending
/// regrant, or half-finished tenant session is lost across the kills,
/// and commits after a restore continue the same sequence numbers.
#[test]
fn fleet_crash_resume_is_bitwise_identical() {
    let dir = std::env::temp_dir().join(format!("hbatch_fleet_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let build = || {
        let mut f = FleetBuilder::new()
            .capacity(8)
            .policy(ArbiterPolicy::Priority)
            .interleave(true)
            .seed(11);
        for i in 0..2 {
            let mut spec = JobSpec::new(&format!("low{i}"), job(10 + i, &[4, 8, 4, 8], 400));
            spec.priority = 0;
            f = f.job(spec);
        }
        let mut hi = JobSpec::new("high", job(99, &[8, 8, 8, 8, 8, 8], 20));
        hi.priority = 5;
        hi.arrival = 5.0;
        f.job(hi)
    };

    // Uninterrupted reference (same builder, no checkpointing).
    let base = build().build().unwrap().run().unwrap();
    assert!(base.makespan > 0.0);
    // Sparse cadence on top of the forced membership-change commits, so
    // both commit triggers are on the exercised path.
    let spec = CkptSpec {
        dir: dir.clone(),
        every_s: base.makespan / 20.0,
        keep_n: 3,
    };

    // First kill: mid-run, while the preempted low jobs are at their
    // floors or the regrants are still pending.
    let crashed = build()
        .checkpoint(spec.clone())
        .crash_at(base.makespan * 0.35)
        .build()
        .unwrap()
        .run_resumable()
        .unwrap();
    assert!(crashed.is_none(), "crash injection must stop the fleet");
    assert!(has_ckpts(&dir), "preempt-to-disk left no checkpoint behind");

    // Second kill: the resumed coordinator crashes again later on.
    let crashed = build()
        .checkpoint(spec.clone())
        .crash_at(base.makespan * 0.7)
        .build()
        .unwrap()
        .run_resumable()
        .unwrap();
    assert!(crashed.is_none(), "second crash injection must stop the fleet");

    // Final rerun with no injection drains the fleet.
    let resumed = build()
        .checkpoint(spec)
        .build()
        .unwrap()
        .run_resumable()
        .unwrap()
        .expect("no crash injected on the final rerun");
    assert_eq!(base.jobs.len(), resumed.jobs.len());
    for (a, b) in base.jobs.iter().zip(&resumed.jobs) {
        assert!(a.report.bitwise_eq(&b.report), "{} diverged across crashes", a.name);
        assert_eq!(a.completion, b.completion, "{}", a.name);
        assert_eq!(a.fleet_preemptions, b.fleet_preemptions, "{}", a.name);
        assert_eq!(a.fleet_regrants, b.fleet_regrants, "{}", a.name);
    }
    assert_eq!(
        base.to_json().to_pretty(),
        resumed.to_json().to_pretty(),
        "fleet aggregates diverged across crash/resume"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
