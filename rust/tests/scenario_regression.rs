//! Scenario-regression harness: seeded sim Sessions across the
//! BSP/ASP/SSP × static/dynamic × spot-churn matrix, locked against
//! committed golden summaries.
//!
//! Each scenario runs the virtual-time simulator (no artifacts needed),
//! reduces the report to a small summary — final batches, λ vector,
//! adjustment count, makespan, and the membership-epoch sequence — and
//! compares it field-by-field against `tests/golden/<name>.json`
//! (floats to 1e-9 relative).  The point is to pin the *trajectory* of
//! the controller + membership machinery: any change to gating,
//! water-filling, warm-starts, or event ordering shows up as a golden
//! diff, not as a silently different paper figure.
//!
//! Workflows:
//! - `cargo test --test scenario_regression` — compare against goldens.
//! - `UPDATE_GOLDEN=1 cargo test --test scenario_regression` —
//!   regenerate every golden (commit the diff deliberately).
//! - A missing golden bootstraps itself (first toolchain run writes it,
//!   loudly) — see `tests/golden/README.md`.
//! - On mismatch the expected/actual pair is written to
//!   `target/golden-diff/<name>.json`; CI uploads that directory as an
//!   artifact when the gate fails.
//!
//! Event times are denominated in *probed round times* (a short seeded
//! probe run measures the scenario's BSP round), so the revocation and
//! rejoin land mid-run for every workload/cluster without hard-coding
//! absolute virtual-time constants.

use hetero_batch::config::Policy;
use hetero_batch::fault::{
    AutoscalerCfg, DetectorCfg, FaultEvent, FaultKind, FaultPlan, GuardCfg, LatePolicy,
};
use hetero_batch::metrics::{DetectorAction, GuardAction, RunReport, SpawnAction};
use hetero_batch::session::{Session, SessionBuilder};
use hetero_batch::sync::SyncMode;
use hetero_batch::trace::{
    AvailTrace, ClusterTraces, JoinSpec, MembershipPlan, SpotSpec, DOWN_EPS,
};
use hetero_batch::util::json::Json;

const CORES: [usize; 3] = [4, 8, 16];
const STEPS: u64 = 60;
const SEED: u64 = 42;
const REL_TOL: f64 = 1e-9;

fn golden_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
}

fn diff_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("golden-diff")
}

/// Measured BSP round time of the scenario cluster (seeded, so this is
/// as deterministic as the runs it calibrates).
fn probe_round_s() -> f64 {
    let r = Session::builder()
        .model("mnist")
        .cores(&CORES)
        .policy(Policy::Uniform)
        .steps(20)
        .seed(SEED)
        .build_sim()
        .unwrap()
        .run()
        .unwrap();
    assert!(r.total_time > 0.0);
    r.total_time / 20.0
}

/// The deterministic churn fixture: worker 0's VM goes down at 10.2
/// rounds for 15.8 rounds; with a 2-round grace the membership plan
/// derived from the trace revokes at ~12.2R and rejoins at ~26R.
fn outage(round_s: f64) -> (ClusterTraces, MembershipPlan) {
    let down_at = 10.2 * round_s;
    let up_at = 26.0 * round_s;
    let traces = ClusterTraces {
        traces: vec![
            AvailTrace::from_segments(vec![(0.0, 1.0), (down_at, DOWN_EPS), (up_at, 1.0)]),
            AvailTrace::constant(),
            AvailTrace::constant(),
        ],
    };
    let plan = MembershipPlan::from_traces(&traces, 2.0 * round_s).unwrap();
    (traces, plan)
}

/// The deterministic fault fixtures (DESIGN.md §12), denominated in
/// probed rounds like the outage: an unannounced crash of worker 1, a
/// long stall of worker 2 (suspected then readmitted), and the crash
/// again with a one-VM autoscaler pool covering the loss.
fn fault_crash(round_s: f64) -> (FaultPlan, DetectorCfg) {
    let plan = FaultPlan::new(vec![FaultEvent {
        time: 12.3 * round_s,
        worker: 1,
        kind: FaultKind::Crash,
    }])
    .unwrap();
    let det = DetectorCfg {
        grace: 4.0,
        floor_s: 3.0 * round_s,
        late: LatePolicy::Readmit,
    };
    (plan, det)
}

fn fault_stall(round_s: f64) -> (FaultPlan, DetectorCfg) {
    let plan = FaultPlan::new(vec![FaultEvent {
        time: 9.7 * round_s,
        worker: 2,
        kind: FaultKind::Stall { stall_s: 20.0 * round_s },
    }])
    .unwrap();
    let det = DetectorCfg {
        grace: 2.0,
        floor_s: 3.0 * round_s,
        late: LatePolicy::Readmit,
    };
    (plan, det)
}

/// Measured makespan of the clean dynamic-BSP scenario run.  The
/// corruption fixtures are denominated in fractions of *this* (not in
/// uniform-probe round multiples like the outage/fault fixtures): the
/// dynamic policy pays `adjust_cost` seconds per applied readjustment,
/// so early pauses shift the absolute clock by whole seconds and a
/// round-multiple window could land entirely inside a pause.  A guarded
/// run replays the clean run's timeline bitwise until the corruption
/// onset (the §16 invisibility invariant), so fractions of the clean
/// makespan stay aligned with the timeline they cut into.
fn probe_dynamic_t() -> f64 {
    let r = base(Policy::Dynamic, SyncMode::Bsp)
        .build_sim()
        .unwrap()
        .run()
        .unwrap();
    assert!(r.total_time > 0.0);
    r.total_time
}

/// The deterministic corruption fixtures (DESIGN.md §16), denominated
/// in fractions of the clean dynamic makespan `t` (see
/// [`probe_dynamic_t`]): a one-shot NaN poisoning of worker 1's update
/// with a single-strike guard (immediate quarantine, probation
/// readmit), and a windowed 100× scale inflation that burns a
/// three-strike budget (two rejections, then quarantine, then probation
/// readmit after the corruption window has expired).
fn corrupt_nan(t: f64) -> (FaultPlan, GuardCfg) {
    let plan = FaultPlan::new(vec![FaultEvent {
        time: 0.35 * t,
        worker: 1,
        kind: FaultKind::CorruptNan,
    }])
    .unwrap();
    let guard = GuardCfg {
        strikes: 1,
        probation_s: 0.3 * t,
        ..GuardCfg::default()
    };
    (plan, guard)
}

fn corrupt_scale(t: f64) -> (FaultPlan, GuardCfg) {
    let plan = FaultPlan::new(vec![FaultEvent {
        time: 0.35 * t,
        worker: 1,
        kind: FaultKind::CorruptScale {
            factor: 100.0,
            dur_s: 0.45 * t,
        },
    }])
    .unwrap();
    // Probation outlives the corruption window by construction
    // (quarantine >= onset, so readmit >= 0.85t > the 0.80t window
    // end), so the readmitted worker's first post-probation update is
    // clean and stays accepted.  The window is generous — three
    // consecutive worker-1 dispatches plus any readjustment pauses fit
    // with room to spare — so the third strike cannot slip past its
    // end and reset the budget.
    let guard = GuardCfg {
        strikes: 3,
        probation_s: 0.5 * t,
        ..GuardCfg::default()
    };
    (plan, guard)
}

fn base(policy: Policy, sync: SyncMode) -> SessionBuilder {
    Session::builder()
        .model("mnist")
        .cores(&CORES)
        .policy(policy)
        .sync(sync)
        .steps(STEPS)
        .adjust_cost(1.0)
        .seed(SEED)
}

/// The scenario matrix: name → configured builder.
fn scenarios() -> Vec<(&'static str, SessionBuilder)> {
    let round_s = probe_round_s();
    let dynamic_t = probe_dynamic_t();
    let churn = |policy, sync| {
        let (traces, plan) = outage(round_s);
        base(policy, sync).traces(traces).membership(plan)
    };
    vec![
        ("bsp_static_churn", churn(Policy::Static, SyncMode::Bsp)),
        ("bsp_dynamic_churn", churn(Policy::Dynamic, SyncMode::Bsp)),
        ("asp_static_churn", churn(Policy::Static, SyncMode::Asp)),
        ("asp_dynamic_churn", churn(Policy::Dynamic, SyncMode::Asp)),
        (
            "ssp2_static_churn",
            churn(Policy::Static, SyncMode::Ssp { bound: 2 }),
        ),
        (
            "ssp2_dynamic_churn",
            churn(Policy::Dynamic, SyncMode::Ssp { bound: 2 }),
        ),
        // Scheduled mid-run join: worker 2 is a late arrival.
        (
            "bsp_dynamic_join",
            base(Policy::Dynamic, SyncMode::Bsp).joins(&[JoinSpec {
                worker: 2,
                time: 8.4 * round_s,
            }]),
        ),
        // Seeded random spot churn through the full `--spot` path.
        (
            "bsp_dynamic_spot",
            base(Policy::Dynamic, SyncMode::Bsp).steps(120).spot(SpotSpec {
                mttf_s: 40.0 * round_s,
                down_s: 12.0 * round_s,
                grace_s: 2.0 * round_s,
            }),
        ),
        // No-churn baseline: pins the static-membership trajectory too.
        ("bsp_dynamic_baseline", base(Policy::Dynamic, SyncMode::Bsp)),
        // Fault family (DESIGN.md §12): unannounced crash detected and
        // retired; false suspicion on a stall, readmitted on return;
        // crash recovered by an autoscaled replacement.
        ("fault_crash", {
            let (plan, det) = fault_crash(round_s);
            base(Policy::Dynamic, SyncMode::Bsp).faults(plan).detector(det)
        }),
        ("fault_stall_readmit", {
            let (plan, det) = fault_stall(round_s);
            base(Policy::Dynamic, SyncMode::Bsp).faults(plan).detector(det)
        }),
        ("fault_crash_autoscale", {
            let (plan, det) = fault_crash(round_s);
            base(Policy::Dynamic, SyncMode::Bsp)
                .faults(plan)
                .detector(det)
                .autoscale(AutoscalerCfg {
                    pool: 1,
                    cold_s: 5.0 * round_s,
                    ..AutoscalerCfg::default()
                })
        }),
        // Corruption family (DESIGN.md §16): the update guard catches a
        // poisoned gradient, quarantines the worker through the revoke
        // path, and readmits it after probation.
        ("fault_corrupt_nan_quarantine", {
            let (plan, guard) = corrupt_nan(dynamic_t);
            base(Policy::Dynamic, SyncMode::Bsp).corrupt(plan).guard(guard)
        }),
        ("fault_corrupt_scale_probation", {
            let (plan, guard) = corrupt_scale(dynamic_t);
            base(Policy::Dynamic, SyncMode::Bsp).corrupt(plan).guard(guard)
        }),
    ]
}

/// Reduce a run to the summary the goldens pin down.
fn summarize(name: &str, r: &RunReport) -> Json {
    let mut o = Json::obj();
    o.set("scenario", Json::Str(name.into()));
    o.set("label", Json::Str(r.label.clone()));
    o.set("total_time_s", Json::Num(r.total_time));
    o.set("total_iters", Json::Num(r.total_iters as f64));
    o.set("reached_target", Json::Bool(r.reached_target));
    o.set("n_adjustments", Json::Num(r.adjustments.len() as f64));
    let final_b: Vec<Json> = r
        .final_batches()
        .map(|b| b.iter().map(|&x| Json::Num(x)).collect())
        .unwrap_or_default();
    o.set("final_batches", Json::Arr(final_b));
    // λ over the live cohort at the end of the run.
    if let Some(b) = r.final_batches() {
        let total: f64 = b.iter().sum();
        o.set(
            "lambda",
            Json::Arr(b.iter().map(|&x| Json::Num(x / total)).collect()),
        );
    }
    let epochs: Vec<Json> = r
        .epochs
        .iter()
        .map(|e| {
            let mut eo = Json::obj();
            eo.set("time_s", Json::Num(e.time));
            eo.set("epoch", Json::Num(e.epoch as f64));
            eo.set("worker", Json::Num(e.worker as f64));
            eo.set("kind", Json::Str(e.kind.label().into()));
            eo.set("live", Json::Num(e.live as f64));
            eo.set(
                "batch_sum",
                Json::Num(e.batches.iter().sum::<f64>()),
            );
            eo
        })
        .collect();
    o.set("epochs", Json::Arr(epochs));
    // Detector / autoscaler trajectory (empty arrays for fault-free
    // scenarios, so the fault goldens pin detection times too).
    let suspicions: Vec<Json> = r
        .suspicions
        .iter()
        .map(|s| {
            let mut so = Json::obj();
            so.set("time_s", Json::Num(s.time));
            so.set("worker", Json::Num(s.worker as f64));
            so.set("action", Json::Str(s.action.label().into()));
            so
        })
        .collect();
    o.set("suspicions", Json::Arr(suspicions));
    let spawns: Vec<Json> = r
        .spawns
        .iter()
        .map(|s| {
            let mut so = Json::obj();
            so.set("time_s", Json::Num(s.time));
            so.set("action", Json::Str(s.action.label().into()));
            so
        })
        .collect();
    o.set("spawns", Json::Arr(spawns));
    // Update-guard trail (empty for guard-free scenarios, so the
    // corruption goldens pin rejection and quarantine times too).
    let guard_events = |evts: &[hetero_batch::metrics::GuardEvent]| -> Vec<Json> {
        evts.iter()
            .map(|g| {
                let mut go = Json::obj();
                go.set("time_s", Json::Num(g.time));
                go.set("worker", Json::Num(g.worker as f64));
                go.set("action", Json::Str(g.action.label().into()));
                go
            })
            .collect()
    };
    o.set("rejections", Json::Arr(guard_events(&r.rejections)));
    o.set("quarantines", Json::Arr(guard_events(&r.quarantines)));
    o
}

/// Structural compare with a relative float tolerance, recording the
/// first divergence path.
fn json_close(a: &Json, b: &Json, path: &str, diff: &mut Vec<String>) {
    match (a, b) {
        (Json::Num(x), Json::Num(y)) => {
            let scale = x.abs().max(y.abs()).max(1.0);
            if (x - y).abs() > REL_TOL * scale {
                diff.push(format!("{path}: {x} != {y}"));
            }
        }
        (Json::Arr(xs), Json::Arr(ys)) => {
            if xs.len() != ys.len() {
                diff.push(format!("{path}: len {} != {}", xs.len(), ys.len()));
                return;
            }
            for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
                json_close(x, y, &format!("{path}[{i}]"), diff);
            }
        }
        (Json::Obj(xo), Json::Obj(yo)) => {
            let keys: std::collections::BTreeSet<&String> =
                xo.keys().chain(yo.keys()).collect();
            for k in keys {
                match (xo.get(k), yo.get(k)) {
                    (Some(x), Some(y)) => json_close(x, y, &format!("{path}.{k}"), diff),
                    _ => diff.push(format!("{path}.{k}: present on one side only")),
                }
            }
        }
        (x, y) => {
            if x != y {
                diff.push(format!("{path}: {x:?} != {y:?}"));
            }
        }
    }
}

/// Invariants that must hold regardless of goldens: Σb conserved at
/// every epoch transition, epoch numbering dense, live counts sane.
fn assert_invariants(name: &str, r: &RunReport) {
    let k = CORES.len();
    for (i, e) in r.epochs.iter().enumerate() {
        assert_eq!(e.epoch, i as u64 + 1, "{name}: epoch numbering gap at {i}");
        assert!(e.live >= 1 && e.live <= k, "{name}: bad live count {e:?}");
        assert!(e.worker < k, "{name}: bad worker {e:?}");
    }
    // Conservation: every rebalance carries the same global batch.
    if let Some(first) = r.epochs.first() {
        let expected: f64 = first.batches.iter().sum();
        for e in &r.epochs {
            let sum: f64 = e.batches.iter().sum();
            assert!(
                (sum - expected).abs() <= 1e-6 * expected.max(1.0),
                "{name}: Σb {sum} != {expected} at epoch {}",
                e.epoch
            );
        }
        // When every worker ran before the first transition (churn
        // scenarios), that sum is the initial allocation's too.
        let pre: Vec<f64> = (0..k)
            .filter_map(|w| {
                r.iters
                    .iter()
                    .find(|it| it.worker == w)
                    .filter(|it| it.start < first.time)
                    .map(|it| it.batch)
            })
            .collect();
        if pre.len() == k {
            let initial: f64 = pre.iter().sum();
            assert!(
                (expected - initial).abs() <= 1e-6 * initial.max(1.0),
                "{name}: epoch Σb {expected} != initial {initial}"
            );
        }
    }
}

#[test]
fn scenario_matrix_matches_goldens() {
    let update = std::env::var("UPDATE_GOLDEN").map_or(false, |v| v == "1");
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let mut failures: Vec<String> = Vec::new();
    for (name, builder) in scenarios() {
        let run = || builder.clone().build_sim().unwrap().run().unwrap();
        let r = run();
        assert_invariants(name, &r);
        // Determinism: the exact same scenario must replay bit-for-bit.
        let r2 = run();
        assert_eq!(r.total_time, r2.total_time, "{name}: nondeterministic makespan");
        assert_eq!(r.epochs.len(), r2.epochs.len(), "{name}: nondeterministic epochs");

        let actual = summarize(name, &r);
        let path = dir.join(format!("{name}.json"));
        if update || !path.exists() {
            // Bootstrap-on-missing exists because the goldens were first
            // authored without a local toolchain (see golden/README.md).
            // Once the set is committed, HBATCH_REQUIRE_GOLDEN=1 turns a
            // missing file into a hard failure so the gate can never
            // silently compare nothing.
            if !update && std::env::var("HBATCH_REQUIRE_GOLDEN").map_or(false, |v| v == "1")
            {
                failures.push(format!(
                    "{name}: golden {} missing and HBATCH_REQUIRE_GOLDEN=1",
                    path.display()
                ));
                continue;
            }
            hetero_batch::util::fs::atomic_write_str(&path, &actual.to_pretty());
            eprintln!(
                "scenario_regression: {} golden {}",
                if update { "updated" } else { "bootstrapped" },
                path.display()
            );
            continue;
        }
        let expected = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap_or_else(|e| panic!("{name}: unparsable golden: {e}"));
        let mut diff = Vec::new();
        json_close(&expected, &actual, name, &mut diff);
        if !diff.is_empty() {
            let dd = diff_dir();
            std::fs::create_dir_all(&dd).unwrap();
            let mut pair = Json::obj();
            pair.set("expected", expected);
            pair.set("actual", actual);
            pair.set(
                "diff",
                Json::Arr(diff.iter().map(|d| Json::Str(d.clone())).collect()),
            );
            let dp = dd.join(format!("{name}.json"));
            hetero_batch::util::fs::atomic_write_str(&dp, &pair.to_pretty());
            failures.push(format!("{name}: {} (full diff: {})", diff[0], dp.display()));
        }
    }
    assert!(
        failures.is_empty(),
        "golden mismatches (regenerate deliberately with UPDATE_GOLDEN=1):\n{}",
        failures.join("\n")
    );
}

#[test]
fn churn_scenarios_actually_churn() {
    // The deterministic-outage scenarios must contain exactly one
    // revocation of worker 0 and one rejoin — otherwise the goldens
    // would silently pin a churn-free run.
    let round_s = probe_round_s();
    for sync in [SyncMode::Bsp, SyncMode::Asp, SyncMode::Ssp { bound: 2 }] {
        for policy in [Policy::Static, Policy::Dynamic] {
            let (traces, plan) = outage(round_s);
            let r = base(policy, sync)
                .traces(traces)
                .membership(plan)
                .build_sim()
                .unwrap()
                .run()
                .unwrap();
            let kinds: Vec<&str> = r.epochs.iter().map(|e| e.kind.label()).collect();
            assert_eq!(
                kinds,
                vec!["revoke", "join"],
                "{policy:?}/{sync:?}: epochs {kinds:?}"
            );
            assert!(r.epochs.iter().all(|e| e.worker == 0));
        }
    }
}

#[test]
fn fault_scenarios_actually_fault() {
    // Mirror of `churn_scenarios_actually_churn` for the fault family:
    // each fixture must exercise the machinery it exists to pin —
    // otherwise the goldens would silently lock a fault-free run.
    let round_s = probe_round_s();
    let run = |b: SessionBuilder| b.build_sim().unwrap().run().unwrap();

    // Crash: exactly one suspicion of worker 1, one revoke epoch, no
    // readmission (a crashed rank never returns), run completes.
    let (plan, det) = fault_crash(round_s);
    let r = run(base(Policy::Dynamic, SyncMode::Bsp).faults(plan).detector(det));
    assert!(r.total_iters >= STEPS, "crash run stalled: {}", r.total_iters);
    assert_eq!(r.suspicions.len(), 1, "{:?}", r.suspicions);
    assert_eq!(r.suspicions[0].worker, 1);
    assert_eq!(r.suspicions[0].action, DetectorAction::Suspect);
    let kinds: Vec<&str> = r.epochs.iter().map(|e| e.kind.label()).collect();
    assert_eq!(kinds, vec!["revoke"], "crash epochs {kinds:?}");

    // Stall: suspicion then readmission of worker 2; epochs revoke+join;
    // the detection must land while the stall is still in flight.
    let (plan, det) = fault_stall(round_s);
    let stall_t = plan.events()[0].time;
    let r = run(base(Policy::Dynamic, SyncMode::Bsp).faults(plan).detector(det));
    assert!(r.total_iters >= STEPS);
    let acts: Vec<(usize, DetectorAction)> =
        r.suspicions.iter().map(|s| (s.worker, s.action)).collect();
    assert_eq!(
        acts,
        vec![(2, DetectorAction::Suspect), (2, DetectorAction::Readmit)],
        "stall detector trail {acts:?}"
    );
    assert!(r.suspicions[0].time > stall_t);
    let kinds: Vec<&str> = r.epochs.iter().map(|e| e.kind.label()).collect();
    assert_eq!(kinds, vec!["revoke", "join"], "stall epochs {kinds:?}");

    // Crash + autoscaler: the pool VM must be requested, come up after
    // the cold start, and rejoin at the vacated rank.
    let (plan, det) = fault_crash(round_s);
    let r = run(base(Policy::Dynamic, SyncMode::Bsp)
        .faults(plan)
        .detector(det)
        .autoscale(AutoscalerCfg {
            pool: 1,
            cold_s: 5.0 * round_s,
            ..AutoscalerCfg::default()
        }));
    assert!(r.total_iters >= STEPS);
    assert_eq!(r.suspicions.len(), 1);
    let ready: Vec<&hetero_batch::metrics::SpawnEvent> = r
        .spawns
        .iter()
        .filter(|s| s.action == SpawnAction::Ready)
        .collect();
    assert_eq!(ready.len(), 1, "spawns {:?}", r.spawns);
    assert_eq!(ready[0].worker, Some(1));
    assert!(ready[0].time > r.suspicions[0].time);
    let kinds: Vec<&str> = r.epochs.iter().map(|e| e.kind.label()).collect();
    assert_eq!(kinds, vec!["revoke", "join"], "autoscale epochs {kinds:?}");
    assert_eq!(r.epochs.last().unwrap().live, CORES.len());
}

#[test]
fn corruption_scenarios_actually_corrupt() {
    // Mirror of `fault_scenarios_actually_fault` for the corruption
    // family: each fixture must walk the full reject → quarantine →
    // probation-readmit lifecycle, otherwise the goldens would silently
    // pin a corruption-free (guard-invisible) run.
    let dynamic_t = probe_dynamic_t();
    let run = |b: SessionBuilder| b.build_sim().unwrap().run().unwrap();

    // NaN + single-strike guard: no standalone rejection (the first
    // strike spends the whole budget), one quarantine of worker 1, one
    // probation readmission, and the run still completes at full
    // strength.
    let (plan, guard) = corrupt_nan(dynamic_t);
    let corrupt_t = plan.events()[0].time;
    let probation_s = guard.probation_s;
    let r = run(base(Policy::Dynamic, SyncMode::Bsp).corrupt(plan).guard(guard));
    assert!(r.total_iters >= STEPS, "nan run stalled: {}", r.total_iters);
    assert!(r.rejections.is_empty(), "strikes=1 must skip Reject: {:?}", r.rejections);
    let acts: Vec<(usize, GuardAction)> =
        r.quarantines.iter().map(|g| (g.worker, g.action)).collect();
    assert_eq!(
        acts,
        vec![(1, GuardAction::Quarantine), (1, GuardAction::Readmit)],
        "nan guard trail {acts:?}"
    );
    assert!(r.quarantines[0].time > corrupt_t);
    assert!(r.quarantines[1].time >= r.quarantines[0].time + probation_s);
    let kinds: Vec<&str> = r.epochs.iter().map(|e| e.kind.label()).collect();
    assert_eq!(kinds, vec!["revoke", "join"], "nan epochs {kinds:?}");
    assert!(r.epochs.iter().all(|e| e.worker == 1));
    assert_eq!(r.epochs.last().unwrap().live, CORES.len());

    // Windowed scale + three-strike guard: exactly two rejections of
    // worker 1 inside the corruption window, then quarantine on the
    // third strike; probation outlives the window, so the readmitted
    // worker is clean and is never rejected again.
    let (plan, guard) = corrupt_scale(dynamic_t);
    let corrupt_t = plan.events()[0].time;
    let window_end = corrupt_t + 0.45 * dynamic_t;
    let r = run(base(Policy::Dynamic, SyncMode::Bsp).corrupt(plan).guard(guard));
    assert!(r.total_iters >= STEPS, "scale run stalled: {}", r.total_iters);
    assert_eq!(r.rejections.len(), 2, "scale rejections {:?}", r.rejections);
    for g in &r.rejections {
        assert_eq!(g.worker, 1);
        assert_eq!(g.action, GuardAction::Reject);
        // Rejections are stamped at *completion* time, so they trail
        // the in-window dispatch by up to one iteration; only the
        // lower bound and the ordering vs the quarantine are exact.
        assert!(g.time > corrupt_t, "reject before onset: {g:?}");
    }
    let acts: Vec<(usize, GuardAction)> =
        r.quarantines.iter().map(|g| (g.worker, g.action)).collect();
    assert_eq!(
        acts,
        vec![(1, GuardAction::Quarantine), (1, GuardAction::Readmit)],
        "scale guard trail {acts:?}"
    );
    assert!(r.quarantines[0].time > r.rejections.last().unwrap().time);
    assert!(
        r.quarantines[1].time > window_end,
        "probation must outlive the corruption window: readmit at {} <= {window_end}",
        r.quarantines[1].time
    );
    let kinds: Vec<&str> = r.epochs.iter().map(|e| e.kind.label()).collect();
    assert_eq!(kinds, vec!["revoke", "join"], "scale epochs {kinds:?}");
    assert_eq!(r.epochs.last().unwrap().live, CORES.len());
}

#[test]
fn pid_policy_spec_reproduces_dynamic_scenario_bitwise() {
    // The BatchPolicy refactor must leave "pid" a pure alias: a builder
    // parsed from a `"policy": "pid"` spec replays the dynamic churn
    // scenario bit-for-bit — same label, same summary (so the committed
    // bsp_dynamic_churn golden pins both spellings), same makespan bits.
    let round_s = probe_round_s();
    let configure = |b: SessionBuilder| {
        let (traces, plan) = outage(round_s);
        b.model("mnist")
            .cores(&CORES)
            .sync(SyncMode::Bsp)
            .steps(STEPS)
            .adjust_cost(1.0)
            .seed(SEED)
            .traces(traces)
            .membership(plan)
    };
    let dynamic = configure(Session::builder().policy(Policy::Dynamic))
        .build_sim()
        .unwrap()
        .run()
        .unwrap();
    let pid = configure(SessionBuilder::from_json_str(r#"{"policy": "pid"}"#).unwrap())
        .build_sim()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(pid.label, dynamic.label, "pid must keep the dynamic label");
    assert_eq!(pid.total_time.to_bits(), dynamic.total_time.to_bits());
    assert_eq!(
        summarize("bsp_dynamic_churn", &pid).to_pretty(),
        summarize("bsp_dynamic_churn", &dynamic).to_pretty(),
        "pid spec diverged from Policy::Dynamic"
    );
}
