//! Session event-loop benches (util::bench): the fleet-scale scheduling
//! rework of DESIGN.md §10, measured head-to-head against the retained
//! linear-scan baseline.
//!
//! Scenario grid: k ∈ {8, 64, 512, 4096} × {BSP, ASP} ×
//! {static, dynamic, churn} × {heap, scan}.  Step budgets shrink with k
//! so every cell stays inside a bench window while the per-event cost —
//! O(log k) for the heap scheduler, O(k) for the scan baseline — stays
//! the dominant term at large k.  The churn cells attach seeded spot
//! traces + the membership plan derived from them, so the revocation /
//! rejoin machinery is on the measured path too.  The timed unit is one
//! whole run *including* session construction (clone + build_sim);
//! construction is identical in both arms, so the derived ratios are
//! conservative lower bounds on the scheduling speedup (see
//! `steps_for`).
//!
//! Results land machine-readably in `BENCH_session.json` at the repo
//! root (full grid, full windows) with derived `heap_vs_scan/...`
//! speedups; quick runs (`HBATCH_BENCH_QUICK=1`) or truncated grids
//! (`--max-k n`, the `scripts/tier1.sh` smoke uses `--max-k 64`) write
//! `BENCH_session_quick.json` instead — same convention as the hotpath
//! suite.  No PJRT artifacts are needed: everything runs on the
//! virtual-time simulator.
//!
//! Before measuring, each scenario is run once under both schedulers and
//! the reports are asserted identical (makespan, iterations, epochs) —
//! the bench refuses to record a speedup over a baseline that computes
//! something else.

use hetero_batch::ckpt::{Checkpointer, CkptSpec};
use hetero_batch::config::Policy;
use hetero_batch::fault::GuardCfg;
use hetero_batch::metrics::RunReport;
use hetero_batch::session::{CkptOutcome, Scheduler, Session, SessionBuilder};
use hetero_batch::sync::SyncMode;
use hetero_batch::trace::{ClusterTraces, MembershipPlan};
use hetero_batch::util::bench::{find_mean_ns, suite_json, Bench};
use hetero_batch::util::fs::atomic_write_str;
use hetero_batch::util::json::Json;

/// Worker counts of the grid (the last is the fleet-scale headline).
const KS: [usize; 4] = [8, 64, 512, 4096];
const SYNCS: [(&str, SyncMode); 2] = [("bsp", SyncMode::Bsp), ("asp", SyncMode::Asp)];
const VARIANTS: [&str; 3] = ["static", "dynamic", "churn"];

/// Heterogeneous cores, cycled to any k.
fn cores_for(k: usize) -> Vec<usize> {
    (0..k).map(|i| [4usize, 8, 16][i % 3]).collect()
}

/// Step budget per k.  Sized so the event loop dominates the timed
/// closure: each sample also pays an O(k) builder clone + build_sim
/// (spot traces, membership plan, initial allocation), which would
/// swamp the heap arm at large k if the run were only a round or two.
/// Scan-side cost grows as steps·k² so the budget still shrinks with k
/// to keep the baseline measurable.  Construction cost is identical in
/// both arms, so the derived heap_vs_scan ratios are *conservative*
/// (they understate the pure scheduling speedup).
fn steps_for(k: usize) -> u64 {
    match k {
        0..=64 => 30,
        65..=512 => 12,
        _ => 4,
    }
}

fn builder(k: usize, sync: SyncMode, variant: &str) -> SessionBuilder {
    let policy = if variant == "static" {
        Policy::Static
    } else {
        Policy::Dynamic
    };
    let mut b = Session::builder()
        .model("mnist")
        .cores(&cores_for(k))
        .policy(policy)
        .sync(sync)
        .steps(steps_for(k))
        .adjust_cost(1.0)
        .seed(7)
        // Fleet-scale reports are exactly what --report-sample exists
        // for; keep the bench's allocation profile flat in k.
        .report_sample(if k > 64 { 16 } else { 1 });
    if variant == "churn" {
        // Seeded per-worker spot traces over a short horizon (the
        // builder's own --spot path generates 100k-second traces —
        // far more segments than a bench window ever reaches).
        let traces = ClusterTraces::spot_cluster(k, 60.0, 20.0, 2.0, 11);
        let plan = MembershipPlan::from_traces(&traces, 0.3).unwrap();
        b = b.traces(traces).membership(plan);
    }
    b
}

fn run_once(b: &SessionBuilder, scheduler: Scheduler) -> RunReport {
    b.clone()
        .scheduler(scheduler)
        .build_sim()
        .expect("bench scenario")
        .run()
        .expect("bench run")
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let max_k = args
        .iter()
        .position(|a| a == "--max-k")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(usize::MAX);

    let mut b = Bench::new("session");
    for &k in KS.iter().filter(|&&k| k <= max_k) {
        for (sname, sync) in SYNCS {
            for variant in VARIANTS {
                let bld = builder(k, sync, variant);
                // Self-check: both schedulers must produce the same run.
                let heap = run_once(&bld, Scheduler::Heap);
                let scan = run_once(&bld, Scheduler::Scan);
                assert_eq!(
                    (heap.total_time, heap.total_iters, heap.epochs.len()),
                    (scan.total_time, scan.total_iters, scan.epochs.len()),
                    "heap/scan divergence at k={k} {sname} {variant}"
                );
                for (lbl, sched) in [("heap", Scheduler::Heap), ("scan", Scheduler::Scan)] {
                    b.run(&format!("{lbl}/k{k}/{sname}/{variant}"), || {
                        run_once(&bld, sched).total_time
                    });
                }
            }
        }
    }
    // Policy head-to-head (DESIGN.md §14): PID reference vs one-shot
    // optimal vs tabular RL on one churned mid-size cluster, all on the
    // heap scheduler.  The timed unit is host time for a whole run; the
    // *simulated* makespans and adjustment counts — the numbers the
    // paper comparison actually cares about — land in the derived
    // section below.
    let hk = if max_k >= 64 { 64 } else { 8 };
    let policies = [
        ("pid", Policy::Dynamic),
        ("optimal", Policy::Optimal),
        ("rl", Policy::Rl),
    ];
    let mut sims: Vec<(&str, RunReport)> = Vec::new();
    for (label, policy) in policies {
        let bld = builder(hk, SyncMode::Bsp, "churn").policy(policy);
        sims.push((label, run_once(&bld, Scheduler::Heap)));
        b.run(&format!("policy_head2head/{label}/k{hk}/bsp/churn"), || {
            run_once(&bld, Scheduler::Heap).total_time
        });
    }
    // Checkpoint overhead (EXPERIMENTS.md §Recovery): the same run with
    // durable whole-state snapshots at every round boundary (every_s =
    // 0), on a sparse cadence, and with checkpointing off.  The timed
    // unit is a whole run either way, so derived
    // `ckpt_overhead/<cell>/time_vs_off` reads directly as the
    // durability tax.
    let ck_bld = builder(8, SyncMode::Bsp, "dynamic");
    let ck_dir = std::env::temp_dir().join(format!("hbatch_bench_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ck_dir);
    let ck_config = ck_bld.to_json().expect("bench scenario is config-expressible");
    for (label, every) in [("off", None), ("every0", Some(0.0)), ("every60", Some(60.0))] {
        b.run(&format!("ckpt_overhead/{label}/k8/bsp/dynamic"), || match every {
            None => run_once(&ck_bld, Scheduler::Heap).total_time,
            Some(every_s) => {
                let mut ck = Checkpointer::open(CkptSpec {
                    dir: ck_dir.clone(),
                    every_s,
                    keep_n: 2,
                })
                .expect("bench ckpt dir");
                let mut sess = ck_bld
                    .clone()
                    .scheduler(Scheduler::Heap)
                    .build_sim()
                    .expect("bench scenario");
                match sess
                    .run_checkpointed(&ck_config, &mut ck, None)
                    .expect("bench run")
                {
                    CkptOutcome::Completed(r) => r.total_time,
                    CkptOutcome::Stopped { .. } => unreachable!("no crash injection"),
                }
            }
        });
    }
    let _ = std::fs::remove_dir_all(&ck_dir);
    // Update-guard overhead (DESIGN.md §16): the same runs with the
    // finite/norm gate armed but nothing corrupted — the guard checks
    // every completion and accepts all of them — against guard-off.
    // The idle guard is *bitwise* invisible (locked by
    // tests/property.rs), so derived `guard_overhead/<cell>/time_vs_off`
    // reads directly as the pure gate cost, on a quiet cluster and
    // under membership churn.
    for variant in ["dynamic", "churn"] {
        let off_bld = builder(8, SyncMode::Bsp, variant);
        let on_bld = off_bld.clone().guard(GuardCfg::default());
        // Self-check: an enabled-but-never-firing guard must not change
        // the run it is pricing.
        let off_r = run_once(&off_bld, Scheduler::Heap);
        let on_r = run_once(&on_bld, Scheduler::Heap);
        assert_eq!(
            (off_r.total_time, off_r.total_iters, off_r.epochs.len()),
            (on_r.total_time, on_r.total_iters, on_r.epochs.len()),
            "idle guard changed the {variant} run"
        );
        assert!(
            on_r.rejections.is_empty() && on_r.quarantines.is_empty(),
            "guard fired without corruption at {variant}"
        );
        for (label, bld) in [("off", &off_bld), ("on", &on_bld)] {
            b.run(&format!("guard_overhead/{label}/k8/bsp/{variant}"), || {
                run_once(bld, Scheduler::Heap).total_time
            });
        }
    }
    b.report();

    // Derived heap-vs-scan speedups (scan_mean / heap_mean; > 1 = the
    // O(log k) scheduler wins) — the ISSUE acceptance reads these at
    // k = 512+.
    let groups = [&b];
    let mut derived = Json::obj();
    let pid_time = sims
        .iter()
        .find(|(l, _)| *l == "pid")
        .map(|(_, r)| r.total_time)
        .unwrap_or(0.0);
    for (label, r) in &sims {
        derived.set(
            &format!("policy_head2head/{label}/sim_total_time_s"),
            Json::Num(r.total_time),
        );
        derived.set(
            &format!("policy_head2head/{label}/adjustments"),
            Json::Num(r.adjustments.len() as f64),
        );
        if pid_time > 0.0 {
            derived.set(
                &format!("policy_head2head/{label}/time_vs_pid"),
                Json::Num(r.total_time / pid_time),
            );
        }
    }
    let ck_off = find_mean_ns(&groups, "session/ckpt_overhead/off/k8/bsp/dynamic");
    for label in ["every0", "every60"] {
        let on = find_mean_ns(&groups, &format!("session/ckpt_overhead/{label}/k8/bsp/dynamic"));
        if let (Some(off), Some(on)) = (ck_off, on) {
            if off > 0.0 {
                derived.set(
                    &format!("ckpt_overhead/{label}/time_vs_off"),
                    Json::Num(on / off),
                );
            }
        }
    }
    for variant in ["dynamic", "churn"] {
        let off = find_mean_ns(&groups, &format!("session/guard_overhead/off/k8/bsp/{variant}"));
        let on = find_mean_ns(&groups, &format!("session/guard_overhead/on/k8/bsp/{variant}"));
        if let (Some(off), Some(on)) = (off, on) {
            if off > 0.0 {
                derived.set(
                    &format!("guard_overhead/{variant}/time_vs_off"),
                    Json::Num(on / off),
                );
            }
        }
    }
    for &k in KS.iter().filter(|&&k| k <= max_k) {
        for (sname, _) in SYNCS {
            for variant in VARIANTS {
                let scan = find_mean_ns(&groups, &format!("session/scan/k{k}/{sname}/{variant}"));
                let heap = find_mean_ns(&groups, &format!("session/heap/k{k}/{sname}/{variant}"));
                if let (Some(s), Some(h)) = (scan, heap) {
                    if h > 0.0 {
                        derived.set(
                            &format!("heap_vs_scan/k{k}/{sname}/{variant}"),
                            Json::Num(s / h),
                        );
                    }
                }
            }
        }
    }

    let json = suite_json("session", &groups, derived);
    // Quick windows or a truncated grid must not clobber the canonical
    // perf-trajectory artifact.
    let partial = b.is_quick() || max_k < *KS.last().unwrap();
    let fname = if partial {
        "BENCH_session_quick.json"
    } else {
        "BENCH_session.json"
    };
    let path = format!("{}/../{fname}", env!("CARGO_MANIFEST_DIR"));
    atomic_write_str(std::path::Path::new(&path), &json.to_pretty());
    println!("\nwrote {path}");
    println!("all session benches complete");
}
