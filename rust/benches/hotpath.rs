//! Hot-path micro benches (util::bench): the L3 operations on the
//! per-iteration critical path, plus the PJRT step itself.
//!
//! Used by the §Perf pass in EXPERIMENTS.md: aggregation (single- vs
//! pool-sharded vs spawn-per-call vs the AOT Pallas kernel), optimizer
//! updates (unfused / fused / sharded fused), the controller step, data
//! generation, and real train-step execution per model/bucket.
//!
//! Results are also written machine-readably to `BENCH_hotpath.json` at
//! the repo root (the ROADMAP perf trajectory artifact), including the
//! `fused_mt{2,4,8}`, `pool_vs_spawn`, and `tree_vs_flat` series plus
//! derived speedup ratios and the `peak_live_gradient_bytes` record
//! (eager reduction tree vs the flat k-buffer arena, §Perf it. 6).
//!
//! Flags: `--agg-only` limits the run to the aggregation + optimizer
//! groups (no PJRT artifacts needed) — used by `scripts/tier1.sh` as a
//! CI smoke. `HBATCH_BENCH_QUICK=1` shrinks measurement windows.

use hetero_batch::controller::{ControllerCfg, DynamicBatcher};
use hetero_batch::data::{self};
use hetero_batch::ps::{
    self, aggregate_into, aggregate_into_mt, aggregate_into_spawn,
    aggregate_tree_into, lambdas_from_batches, Optimizer, ReduceTree, RetainPolicy,
};
use hetero_batch::runtime::Runtime;
use hetero_batch::util::bench::{find_mean_ns, suite_json, Bench};
use hetero_batch::util::json::Json;
use hetero_batch::util::rng::Rng;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn bench_aggregation() -> Bench {
    let mut b = Bench::new("agg");
    let mut rng = Rng::new(0);
    // e2e-transformer-sized gradient set: K=3 × 12.6M params.
    for &(k, d, tag) in &[
        (3usize, 400_000usize, "3x400k"),
        (3, 12_600_000, "3x12.6M"),
        (8, 1_000_000, "8x1M"),
    ] {
        let grads: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec_f32(d)).collect();
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let lambdas = lambdas_from_batches(&vec![32.0; k]);
        let mut out = vec![0.0f32; d];
        b.run(&format!("st/{tag}"), || {
            aggregate_into(&mut out, &refs, &lambdas);
            out[0]
        });
        // pool_vs_spawn series: identical sharding, persistent pool
        // dispatch vs the seed's spawn-per-call scoped threads.
        for threads in [2, 4, 8] {
            b.run(&format!("mt{threads}/{tag}"), || {
                aggregate_into_mt(&mut out, &refs, &lambdas, threads);
                out[0]
            });
            b.run(&format!("spawn{threads}/{tag}"), || {
                aggregate_into_spawn(&mut out, &refs, &lambdas, threads);
                out[0]
            });
        }
    }
    b.report();
    b
}

/// §Perf iteration 6 — `tree_vs_flat` series: the eager reduction tree
/// against the flat sequential sweep it replaced, k ∈ {4, 16, 64, 256}
/// × small (400k) / transformer (12.6M) parameter counts.  The timed
/// unit is one full round (k pushes + finalize + reset); note the tree's
/// headline win is *placement* — combines land in the straggler window
/// and the barrier-critical path drops from O(d·k) to O(d·log k) — so
/// the end-to-end ratio here is the conservative total-throughput view.
/// Also records `peak_live_gradient_bytes`: RetainPolicy::Free holds
/// ⌈log₂k⌉+1 partial buffers (asserted) vs the flat arena's k.
///
/// The flat arm materializes k full gradient vectors — infeasible at
/// k = 256 × 12.6M (12.9 GB) — so that cell runs tree-only over a
/// rotating 8-buffer source set (memory record, no ratio); quick smoke
/// runs (`scripts/tier1.sh`) restrict to the small model.
fn bench_tree_vs_flat() -> (Bench, Json) {
    let mut b = Bench::new("agg_tree");
    let mut peaks = Json::obj();
    let quick = std::env::var("HBATCH_BENCH_QUICK").is_ok();
    let small = 400_000usize;
    let xf = 12_600_000usize;
    let mut cells: Vec<(usize, usize, &str, bool)> = vec![
        (4, small, "400k", true),
        (16, small, "400k", true),
        (64, small, "400k", true),
        (256, small, "400k", true),
    ];
    if !quick {
        cells.extend([
            (4, xf, "12.6M", true),
            (16, xf, "12.6M", true),
            (64, xf, "12.6M", true),
            (256, xf, "12.6M", false),
        ]);
    }
    let mut rng = Rng::new(7);
    for (k, d, tag, flat_arm) in cells {
        let n_src = if flat_arm { k } else { 8 };
        let srcs: Vec<Vec<f32>> = (0..n_src).map(|_| rng.normal_vec_f32(d)).collect();
        let batches: Vec<f64> = (0..k).map(|i| 16.0 + i as f64).collect();
        let lambdas = lambdas_from_batches(&batches);
        // Both arms run at the same 4-shard pool request, so the
        // derived ratio isolates the reduction *scheme* — a sharded
        // tree against a single-threaded sweep would just measure
        // thread count.
        let mut tree = ReduceTree::new(k, d, RetainPolicy::Free, 4);
        if flat_arm {
            let refs: Vec<&[f32]> = srcs.iter().map(|g| g.as_slice()).collect();
            let mut flat = vec![0.0f32; d];
            b.run(&format!("flat/k{k}/{tag}"), || {
                aggregate_into_mt(&mut flat, &refs, &lambdas, 4);
                flat[0]
            });
            // Self-check before timing the candidate: the tree must
            // agree with the flat oracle.
            let mut out = vec![0.0f32; d];
            aggregate_tree_into(&mut out, &refs, &lambdas, 4);
            for (i, (&a, &o)) in flat.iter().zip(&out).enumerate() {
                assert!(
                    (a - o).abs() <= 1e-5,
                    "tree/flat divergence at k={k} {tag} idx {i}: {a} vs {o}"
                );
            }
        }
        b.run(&format!("tree/k{k}/{tag}"), || {
            for i in 0..k {
                tree.push(i, &srcs[i % n_src], lambdas[i] as f32);
            }
            tree.finalize();
            let x = tree.root()[0];
            tree.reset();
            x
        });
        assert!(
            tree.peak_buffers() <= tree.depth() + 1,
            "RetainPolicy::Free peak {} exceeded ⌈log₂{k}⌉+1 = {}",
            tree.peak_buffers(),
            tree.depth() + 1
        );
        peaks.set(
            &format!("tree_free/k{k}/{tag}"),
            Json::Num(tree.peak_live_bytes() as f64),
        );
        peaks.set(
            &format!("flat_arena/k{k}/{tag}"),
            Json::Num((k * d * std::mem::size_of::<f32>()) as f64),
        );
    }
    b.report();
    (b, peaks)
}

fn bench_agg_xla_vs_rust() -> Option<Bench> {
    let mut rt = match Runtime::open(artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping XLA agg bench: {e}");
            return None;
        }
    };
    let mut b = Bench::new("agg_xla");
    let mut rng = Rng::new(1);
    let d = 2_000_000usize;
    let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec_f32(d)).collect();
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let lambdas = lambdas_from_batches(&[32.0, 64.0, 96.0]);
    // Warm the executable cache.
    let _ = rt.agg_step(&lambdas, &refs).unwrap();
    b.run("pallas_hlo/3x2M", || rt.agg_step(&lambdas, &refs).unwrap()[0]);
    let mut out = vec![0.0f32; d];
    b.run("rust_native/3x2M", || {
        ps::aggregate_into(&mut out, &refs, &lambdas);
        out[0]
    });
    b.report();
    Some(b)
}

fn bench_optimizers() -> Bench {
    let mut b = Bench::new("optimizer");
    let d = 12_600_000usize;
    let mut rng = Rng::new(2);
    let grad = rng.normal_vec_f32(d);
    let mut params = rng.normal_vec_f32(d);
    let mut sgd = ps::Sgd::new(ps::LrSchedule::Constant(0.01));
    b.run("sgd/12.6M", || {
        sgd.step(&mut params, &grad);
        params[0]
    });
    let mut mom = ps::Momentum::new(ps::LrSchedule::Constant(0.01), 0.9, d);
    b.run("momentum/12.6M", || {
        mom.step(&mut params, &grad);
        params[0]
    });
    let mut adam = ps::Adam::new(ps::LrSchedule::Constant(0.001), d);
    b.run("adam/12.6M", || {
        adam.step(&mut params, &grad);
        params[0]
    });
    // §Perf iteration 1: fused aggregation+optimizer (one memory pass)
    // vs the separate agg-then-step pipeline above.
    let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec_f32(d)).collect();
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let lambdas = lambdas_from_batches(&[32.0, 64.0, 96.0]);
    let mut agg = vec![0.0f32; d];
    let mut adam2 = ps::Adam::new(ps::LrSchedule::Constant(0.001), d);
    b.run("unfused_agg+adam/3x12.6M", || {
        aggregate_into(&mut agg, &refs, &lambdas);
        adam2.step(&mut params, &agg);
        params[0]
    });
    let mut fused = ps::FusedOptimizer::Adam(ps::Adam::new(
        ps::LrSchedule::Constant(0.001),
        d,
    ));
    b.run("fused_agg+adam/3x12.6M", || {
        fused.step(&mut params, &refs, &lambdas);
        params[0]
    });
    // §Perf iteration 4: sharded fused pass on the persistent pool.
    for threads in [2usize, 4, 8] {
        let mut fused_mt = ps::FusedOptimizer::Adam(ps::Adam::new(
            ps::LrSchedule::Constant(0.001),
            d,
        ));
        b.run(&format!("fused_mt{threads}_agg+adam/3x12.6M"), || {
            fused_mt.step_mt(&mut params, &refs, &lambdas, threads);
            params[0]
        });
    }
    let mut sgd2 = ps::Sgd::new(ps::LrSchedule::Constant(0.01));
    b.run("unfused_agg+sgd/3x12.6M", || {
        aggregate_into(&mut agg, &refs, &lambdas);
        sgd2.step(&mut params, &agg);
        params[0]
    });
    let mut fused_sgd =
        ps::FusedOptimizer::Sgd(ps::Sgd::new(ps::LrSchedule::Constant(0.01)));
    b.run("fused_agg+sgd/3x12.6M", || {
        fused_sgd.step(&mut params, &refs, &lambdas);
        params[0]
    });
    let mut fused_sgd_mt =
        ps::FusedOptimizer::Sgd(ps::Sgd::new(ps::LrSchedule::Constant(0.01)));
    b.run("fused_mt4_agg+sgd/3x12.6M", || {
        fused_sgd_mt.step_mt(&mut params, &refs, &lambdas, 4);
        params[0]
    });
    b.report();
    b
}

fn bench_controller() -> Bench {
    let mut b = Bench::new("controller");
    for k in [3usize, 16, 64] {
        let init = vec![64.0; k];
        let mut ctl = DynamicBatcher::new(
            ControllerCfg {
                min_obs: 1,
                deadband: 0.0,
                backoff: false,
                ..ControllerCfg::default()
            },
            &init,
        );
        let mut i = 0u64;
        b.run(&format!("observe+adjust/k{k}"), || {
            i += 1;
            for w in 0..k {
                ctl.observe(w, 1.0 + (w as f64) * 0.01 + (i % 7) as f64 * 0.001);
            }
            ctl.maybe_adjust()
        });
    }
    b.report();
    b
}

fn bench_datagen() -> Bench {
    let mut b = Bench::new("datagen");
    let mut mnist = data::for_model("mlp", 1, 0);
    b.run("mlp/b64", || mnist.next_batch(0, 64).x_f32.len());
    let mut lm = data::for_model("transformer", 1, 0);
    b.run("transformer/b8", || lm.next_batch(0, 8).x_i32.len());
    b.report();
    b
}

fn bench_train_steps() -> Option<Bench> {
    let mut rt = match Runtime::open(artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping train-step bench: {e}");
            return None;
        }
    };
    let mut b = Bench::new("train_step");
    for (model, buckets) in [
        ("linreg", vec![32usize, 256]),
        ("mlp", vec![16, 64, 256]),
        ("cnn", vec![4, 32]),
        ("transformer", vec![2, 8]),
    ] {
        let params = rt.init_params(model).unwrap();
        let mut ds = data::for_model(model, 1, 0);
        for bu in buckets {
            let batch = ds.next_batch(0, bu);
            // Warm compile outside the timed region.
            let _ = rt.train_step(model, bu, &params, &batch).unwrap();
            b.run(&format!("{model}/b{bu}"), || {
                rt.train_step(model, bu, &params, &batch).unwrap().loss
            });
        }
    }
    b.report();
    Some(b)
}

/// Derived speedup ratios (baseline_mean / candidate_mean; > 1 = faster)
/// for the headline series: sharded fused vs single-threaded fused, and
/// pool dispatch vs spawn-per-call at equal thread counts.
fn derived_ratios(groups: &[&Bench]) -> Json {
    let mut o = Json::obj();
    let mut ratio = |label: &str, base: &str, cand: &str| {
        if let (Some(b), Some(c)) = (find_mean_ns(groups, base), find_mean_ns(groups, cand)) {
            if c > 0.0 {
                o.set(label, Json::Num(b / c));
            }
        }
    };
    for t in [2, 4, 8] {
        ratio(
            &format!("fused_adam_mt{t}_vs_st/3x12.6M"),
            "optimizer/fused_agg+adam/3x12.6M",
            &format!("optimizer/fused_mt{t}_agg+adam/3x12.6M"),
        );
        for tag in ["3x400k", "3x12.6M", "8x1M"] {
            ratio(
                &format!("pool{t}_vs_spawn{t}/{tag}"),
                &format!("agg/spawn{t}/{tag}"),
                &format!("agg/mt{t}/{tag}"),
            );
        }
    }
    ratio(
        "fused_sgd_mt4_vs_st/3x12.6M",
        "optimizer/fused_agg+sgd/3x12.6M",
        "optimizer/fused_mt4_agg+sgd/3x12.6M",
    );
    // §Perf iteration 6: eager reduction tree vs the flat sequential
    // sweep (ratio > 1 = tree faster end-to-end; the barrier-critical-
    // path win is structural and not captured by this total).
    for k in [4, 16, 64, 256] {
        for tag in ["400k", "12.6M"] {
            ratio(
                &format!("tree_vs_flat/k{k}/{tag}"),
                &format!("agg_tree/flat/k{k}/{tag}"),
                &format!("agg_tree/tree/k{k}/{tag}"),
            );
        }
    }
    o
}

fn main() {
    let agg_only = std::env::args().any(|a| a == "--agg-only");
    let mut groups: Vec<Bench> = Vec::new();
    // A full run must include every group: if the artifact-dependent
    // benches are skipped (no PJRT artifacts on this machine), the run
    // is *partial* and must not masquerade as the canonical record.
    let mut skipped_artifact_groups = false;
    groups.push(bench_aggregation());
    let (tree_bench, tree_peaks) = bench_tree_vs_flat();
    groups.push(tree_bench);
    groups.push(bench_optimizers());
    if !agg_only {
        match bench_agg_xla_vs_rust() {
            Some(b) => groups.push(b),
            None => skipped_artifact_groups = true,
        }
        groups.push(bench_controller());
        groups.push(bench_datagen());
        match bench_train_steps() {
            Some(b) => groups.push(b),
            None => skipped_artifact_groups = true,
        }
    }
    if skipped_artifact_groups {
        println!(
            "\nNOTE: PJRT artifact benches skipped (run `python3 \
             python/compile/aot.py --out-dir rust/artifacts` first) — \
             writing the quick/partial file, not the canonical one"
        );
    }
    let refs: Vec<&Bench> = groups.iter().collect();
    let mut derived = derived_ratios(&refs);
    derived.set("peak_live_gradient_bytes", tree_peaks);
    let json = suite_json("hotpath", &refs, derived);
    // Quick/partial runs must not clobber the canonical perf-trajectory
    // artifact (full windows, all groups) with 8-sample smoke data.
    let partial =
        agg_only || skipped_artifact_groups || refs.iter().any(|b| b.is_quick());
    let fname = if partial {
        "BENCH_hotpath_quick.json"
    } else {
        "BENCH_hotpath.json"
    };
    let path = format!("{}/../{fname}", env!("CARGO_MANIFEST_DIR"));
    hetero_batch::util::fs::atomic_write_str(std::path::Path::new(&path), &json.to_pretty());
    println!("\nwrote {path}");
    println!("all hotpath benches complete");
}
