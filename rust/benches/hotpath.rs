//! Hot-path micro benches (util::bench): the L3 operations on the
//! per-iteration critical path, plus the PJRT step itself.
//!
//! Used by the §Perf pass in EXPERIMENTS.md: aggregation (single- vs
//! multi-threaded vs the AOT Pallas kernel), optimizer updates, the
//! controller step, data generation, and real train-step execution per
//! model/bucket.

use hetero_batch::controller::{ControllerCfg, DynamicBatcher};
use hetero_batch::data::{self};
use hetero_batch::ps::{
    self, aggregate_into, aggregate_into_mt, lambdas_from_batches, Optimizer,
};
use hetero_batch::runtime::Runtime;
use hetero_batch::util::bench::Bench;
use hetero_batch::util::rng::Rng;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn bench_aggregation() {
    let mut b = Bench::new("agg");
    let mut rng = Rng::new(0);
    // e2e-transformer-sized gradient set: K=3 × 12.6M params.
    for &(k, d, tag) in &[
        (3usize, 400_000usize, "3x400k"),
        (3, 12_600_000, "3x12.6M"),
        (8, 1_000_000, "8x1M"),
    ] {
        let grads: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec_f32(d)).collect();
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let lambdas = lambdas_from_batches(&vec![32.0; k]);
        let mut out = vec![0.0f32; d];
        b.run(&format!("st/{tag}"), || {
            aggregate_into(&mut out, &refs, &lambdas);
            out[0]
        });
        for threads in [2, 4, 8] {
            b.run(&format!("mt{threads}/{tag}"), || {
                aggregate_into_mt(&mut out, &refs, &lambdas, threads);
                out[0]
            });
        }
    }
    b.report();
}

fn bench_agg_xla_vs_rust() {
    let mut rt = match Runtime::open(artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping XLA agg bench: {e}");
            return;
        }
    };
    let mut b = Bench::new("agg_xla");
    let mut rng = Rng::new(1);
    let d = 2_000_000usize;
    let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec_f32(d)).collect();
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let lambdas = lambdas_from_batches(&[32.0, 64.0, 96.0]);
    // Warm the executable cache.
    let _ = rt.agg_step(&lambdas, &refs).unwrap();
    b.run("pallas_hlo/3x2M", || rt.agg_step(&lambdas, &refs).unwrap()[0]);
    let mut out = vec![0.0f32; d];
    b.run("rust_native/3x2M", || {
        ps::aggregate_into(&mut out, &refs, &lambdas);
        out[0]
    });
    b.report();
}

fn bench_optimizers() {
    let mut b = Bench::new("optimizer");
    let d = 12_600_000usize;
    let mut rng = Rng::new(2);
    let grad = rng.normal_vec_f32(d);
    let mut params = rng.normal_vec_f32(d);
    let mut sgd = ps::Sgd::new(ps::LrSchedule::Constant(0.01));
    b.run("sgd/12.6M", || {
        sgd.step(&mut params, &grad);
        params[0]
    });
    let mut mom = ps::Momentum::new(ps::LrSchedule::Constant(0.01), 0.9, d);
    b.run("momentum/12.6M", || {
        mom.step(&mut params, &grad);
        params[0]
    });
    let mut adam = ps::Adam::new(ps::LrSchedule::Constant(0.001), d);
    b.run("adam/12.6M", || {
        adam.step(&mut params, &grad);
        params[0]
    });
    // §Perf iteration 1: fused aggregation+optimizer (one memory pass)
    // vs the separate agg-then-step pipeline above.
    let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec_f32(d)).collect();
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let lambdas = lambdas_from_batches(&[32.0, 64.0, 96.0]);
    let mut agg = vec![0.0f32; d];
    let mut adam2 = ps::Adam::new(ps::LrSchedule::Constant(0.001), d);
    b.run("unfused_agg+adam/3x12.6M", || {
        aggregate_into(&mut agg, &refs, &lambdas);
        adam2.step(&mut params, &agg);
        params[0]
    });
    let mut fused = ps::FusedOptimizer::Adam(ps::Adam::new(
        ps::LrSchedule::Constant(0.001),
        d,
    ));
    b.run("fused_agg+adam/3x12.6M", || {
        fused.step(&mut params, &refs, &lambdas);
        params[0]
    });
    let mut sgd2 = ps::Sgd::new(ps::LrSchedule::Constant(0.01));
    b.run("unfused_agg+sgd/3x12.6M", || {
        aggregate_into(&mut agg, &refs, &lambdas);
        sgd2.step(&mut params, &agg);
        params[0]
    });
    let mut fused_sgd =
        ps::FusedOptimizer::Sgd(ps::Sgd::new(ps::LrSchedule::Constant(0.01)));
    b.run("fused_agg+sgd/3x12.6M", || {
        fused_sgd.step(&mut params, &refs, &lambdas);
        params[0]
    });
    b.report();
}

fn bench_controller() {
    let mut b = Bench::new("controller");
    for k in [3usize, 16, 64] {
        let init = vec![64.0; k];
        let mut ctl = DynamicBatcher::new(
            ControllerCfg {
                min_obs: 1,
                deadband: 0.0,
                backoff: false,
                ..ControllerCfg::default()
            },
            &init,
        );
        let mut i = 0u64;
        b.run(&format!("observe+adjust/k{k}"), || {
            i += 1;
            for w in 0..k {
                ctl.observe(w, 1.0 + (w as f64) * 0.01 + (i % 7) as f64 * 0.001);
            }
            ctl.maybe_adjust()
        });
    }
    b.report();
}

fn bench_datagen() {
    let mut b = Bench::new("datagen");
    let mut mnist = data::for_model("mlp", 1, 0);
    b.run("mlp/b64", || mnist.next_batch(0, 64).x_f32.len());
    let mut lm = data::for_model("transformer", 1, 0);
    b.run("transformer/b8", || lm.next_batch(0, 8).x_i32.len());
    b.report();
}

fn bench_train_steps() {
    let mut rt = match Runtime::open(artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping train-step bench: {e}");
            return;
        }
    };
    let mut b = Bench::new("train_step");
    for (model, buckets) in [
        ("linreg", vec![32usize, 256]),
        ("mlp", vec![16, 64, 256]),
        ("cnn", vec![4, 32]),
        ("transformer", vec![2, 8]),
    ] {
        let params = rt.init_params(model).unwrap();
        let mut ds = data::for_model(model, 1, 0);
        for bu in buckets {
            let batch = ds.next_batch(0, bu);
            // Warm compile outside the timed region.
            let _ = rt.train_step(model, bu, &params, &batch).unwrap();
            b.run(&format!("{model}/b{bu}"), || {
                rt.train_step(model, bu, &params, &batch).unwrap().loss
            });
        }
    }
    b.report();
}

fn main() {
    bench_aggregation();
    bench_agg_xla_vs_rust();
    bench_optimizers();
    bench_controller();
    bench_datagen();
    bench_train_steps();
    println!("\nall hotpath benches complete");
}
