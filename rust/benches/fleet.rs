//! Multi-tenant fleet scheduler benches (util::bench; DESIGN.md §13).
//!
//! Measures what the fleet layer *adds* per job: the interleaved
//! scheduler runs fleets of 10 / 100 / 1000 concurrent k=8 simulations
//! (capped by `--jobs`) plus a few k=512 fleets (skipped under
//! `--max-k` below 512), and the derived `overhead_per_job/...` series
//! divides each fleet's mean wall-clock by its job count and subtracts
//! the `standalone/job` baseline.  The fleet's merge heap is O(log n)
//! per event, so per-job cost must stay flat as the fleet grows — the
//! bench asserts the 10→1000 growth factor stays under 2× before it
//! records anything.
//!
//! Before timing, the isolation invariant is self-asserted: a mixed
//! fleet (plain, crash + detector, autoscaled recovery, spot churn)
//! runs through the interleaved scheduler *and* the parallel fast path,
//! and every per-job report must be **bitwise identical** to the same
//! builder run standalone ([`RunReport::bitwise_eq`]).  A fleet that
//! perturbs its tenants' results would make every number below
//! meaningless.
//!
//! Results land in `BENCH_fleet.json` at the repo root; quick windows
//! (`HBATCH_BENCH_QUICK=1`) or truncated grids (`--jobs n`, `--max-k n`
//! — the `scripts/tier1.sh` smoke uses `--jobs 32 --max-k 8`) write
//! `BENCH_fleet_quick.json` instead, same convention as the session
//! suite.

use hetero_batch::config::Policy;
use hetero_batch::fault::{AutoscalerCfg, DetectorCfg, FaultPlan};
use hetero_batch::fleet::{FleetBuilder, JobSpec};
use hetero_batch::metrics::RunReport;
use hetero_batch::session::{Session, SessionBuilder};
use hetero_batch::trace::SpotSpec;
use hetero_batch::util::bench::{find_mean_ns, suite_json, Bench};
use hetero_batch::util::json::Json;

/// Fleet sizes of the k=8 overhead series.
const SIZES: [usize; 3] = [10, 100, 1000];

/// Heterogeneous cores, cycled to any k.
fn cores_for(k: usize) -> Vec<usize> {
    (0..k).map(|i| [4usize, 8, 16][i % 3]).collect()
}

fn plain_job(seed: u64, k: usize, steps: u64) -> SessionBuilder {
    Session::builder()
        .model("mnist")
        .cores(&cores_for(k))
        .policy(Policy::Dynamic)
        .steps(steps)
        .adjust_cost(1.0)
        .report_sample(if k > 64 { 16 } else { 1 })
        .seed(seed)
}

/// Mixed-shape jobs for the isolation self-check: every event source
/// the fleet could plausibly disturb (faults + detector retirement,
/// autoscaled spawns drawing on the shared pool, spot churn) cycles
/// through the fleet.
fn mixed_job(i: usize) -> SessionBuilder {
    let b = plain_job(100 + i as u64, 8, 16);
    match i % 4 {
        1 => b
            .faults(FaultPlan::parse("crash:1@3").unwrap())
            .detector(DetectorCfg::parse("grace=4,floor=2").unwrap()),
        2 => b
            .faults(FaultPlan::parse("crash:2@2,slow:0@4:3:6").unwrap())
            .detector(DetectorCfg::parse("grace=4,floor=2").unwrap())
            .autoscale(AutoscalerCfg::parse("pool=2,cold=3").unwrap()),
        3 => b.spot(SpotSpec::parse("30:8:1").unwrap()),
        _ => b,
    }
}

/// Uncontended fleet over `builders` with the scheduling mode forced.
fn fleet_of(builders: &[SessionBuilder], interleave: bool) -> Vec<RunReport> {
    let specs = builders
        .iter()
        .enumerate()
        .map(|(i, b)| JobSpec::new(&format!("job{i}"), b.clone()))
        .collect();
    FleetBuilder::new()
        .jobs(specs)
        .interleave(interleave)
        .build()
        .expect("fleet config")
        .run()
        .expect("fleet run")
        .into_reports()
}

fn standalone(b: &SessionBuilder) -> RunReport {
    b.clone().build_sim().expect("bench scenario").run().expect("bench run")
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let flag = |name: &str, default: usize| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(default)
    };
    let jobs_cap = flag("--jobs", *SIZES.last().unwrap()).max(1);
    let max_k = flag("--max-k", 512);

    // Isolation self-check: the fleet must not perturb its tenants.
    let n_iso = jobs_cap.clamp(4, 12);
    let builders: Vec<SessionBuilder> = (0..n_iso).map(mixed_job).collect();
    let solo: Vec<RunReport> = builders.iter().map(standalone).collect();
    let inter = fleet_of(&builders, true);
    let par = fleet_of(&builders, false);
    for (j, s) in solo.iter().enumerate() {
        assert!(
            s.bitwise_eq(&inter[j]),
            "isolation violation: interleaved fleet perturbed job {j}"
        );
        assert!(
            s.bitwise_eq(&par[j]),
            "isolation violation: parallel fast path perturbed job {j}"
        );
    }
    println!("isolation invariant holds for {n_iso} mixed jobs (interleaved + parallel)");

    let mut b = Bench::new("fleet");

    // Per-job baseline: the same simulation the fleets below multiplex,
    // run alone (one build + one event loop, no merge heap).
    let base = plain_job(7, 8, 10);
    b.run("standalone/job", || standalone(&base).total_time);

    for &n in SIZES.iter().filter(|&&n| n <= jobs_cap) {
        let builders: Vec<SessionBuilder> =
            (0..n).map(|i| plain_job(7 + i as u64, 8, 10)).collect();
        b.run(&format!("interleaved/jobs{n}"), || {
            fleet_of(&builders, true)
                .iter()
                .map(|r| r.total_time)
                .sum::<f64>()
        });
    }

    // A few fleet-scale tenants: the merge heap's n is small but every
    // per-job event pays the k=512 session machinery.
    if max_k >= 512 {
        let builders: Vec<SessionBuilder> =
            (0..4).map(|i| plain_job(50 + i as u64, 512, 4)).collect();
        b.run("interleaved/k512/jobs4", || {
            fleet_of(&builders, true)
                .iter()
                .map(|r| r.total_time)
                .sum::<f64>()
        });
    }
    b.report();

    // Derived per-job overhead series — the ISSUE acceptance reads
    // `overhead_per_job/...` and expects sublinear growth 10 → 1000.
    let groups = [&b];
    let mut derived = Json::obj();
    let t1 = find_mean_ns(&groups, "fleet/standalone/job");
    let mut per_job: Vec<(usize, f64)> = Vec::new();
    for &n in SIZES.iter().filter(|&&n| n <= jobs_cap) {
        if let Some(m) = find_mean_ns(&groups, &format!("fleet/interleaved/jobs{n}")) {
            per_job.push((n, m / n as f64));
        }
    }
    for &(n, p) in &per_job {
        derived.set(&format!("overhead_per_job/jobs{n}/per_job_ns"), Json::Num(p));
        if let Some(t1) = t1 {
            derived.set(
                &format!("overhead_per_job/jobs{n}/overhead_ns"),
                Json::Num(p - t1),
            );
        }
        derived.set(
            &format!("overhead_per_job/jobs{n}/growth_vs_smallest"),
            Json::Num(p / per_job[0].1),
        );
    }
    if per_job.len() >= 2 {
        let (n0, p0) = per_job[0];
        let (n1, p1) = *per_job.last().unwrap();
        // O(log n) merge heap on top of constant per-job work: the
        // per-job cost must stay essentially flat.  2× is a generous
        // ceiling covering allocator noise on shared hardware.
        assert!(
            p1 / p0 < 2.0,
            "fleet overhead grew superlinearly: {p0:.0} ns/job at {n0} jobs vs {p1:.0} ns/job at {n1} jobs"
        );
        println!(
            "sublinear check: per-job cost x{:.2} from {n0} to {n1} jobs",
            p1 / p0
        );
    }

    let json = suite_json("fleet", &groups, derived);
    // Quick windows or a truncated grid must not clobber the canonical
    // perf-trajectory artifact.
    let partial = b.is_quick() || jobs_cap < *SIZES.last().unwrap() || max_k < 512;
    let fname = if partial {
        "BENCH_fleet_quick.json"
    } else {
        "BENCH_fleet.json"
    };
    let path = format!("{}/../{fname}", env!("CARGO_MANIFEST_DIR"));
    hetero_batch::util::fs::atomic_write_str(std::path::Path::new(&path), &json.to_pretty());
    println!("\nwrote {path}");
    println!("all fleet benches complete");
}
