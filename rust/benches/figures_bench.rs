//! End-to-end benches: one per paper evaluation artifact (DESIGN.md §4).
//!
//! Each bench regenerates a figure's full experiment through the
//! simulator and prints the same rows the paper plots, plus how long the
//! regeneration took. Run via `cargo bench` or `make bench`.

use std::time::Instant;

use hetero_batch::figures;

fn timed(name: &str, f: impl FnOnce() -> hetero_batch::util::csv::Table) {
    let t0 = Instant::now();
    let table = f();
    let dt = t0.elapsed();
    println!("\n=== {name} (regenerated in {dt:?}) ===");
    print!("{}", table.to_string());
}

fn main() {
    let seed = 0;
    timed("fig1_hetero_penalty", || figures::fig1(seed));
    timed("fig2_timeline", || figures::fig2(seed));
    timed("fig3_iter_time_hist", || figures::fig3(seed).0);
    timed("fig4a_convergence", || figures::fig4(true, seed));
    timed("fig4b_oscillation", || figures::fig4(false, seed));
    timed("fig5_throughput_vs_batch", figures::fig5);
    timed("fig6_bsp_hlevel", || figures::fig6(seed));
    timed("fig7a_gpu_cpu", || figures::fig7a(seed));
    timed("fig7cloud_t4_p4", || figures::fig7_cloud(seed));
    timed("fig_asp", || figures::fig_asp(seed));
    timed("fig_buckets_ablation", || figures::fig_buckets(seed));
    timed("fig_revocation_timeline", || figures::fig_revocation(seed));
    println!("\nall figure benches complete");
}
