//! Crash-consistent checkpointing (DESIGN.md §15).
//!
//! A checkpoint is a directory `ckpt-<seq>` holding a versioned
//! `manifest.json` plus the payload files it names (`config.json`,
//! `state.json`, optionally `backend.bin`), each entry carrying an
//! FNV-1a checksum and byte count.  Commits are atomic: payloads are
//! staged in a temp directory, fsynced, the manifest written last, and
//! the whole directory renamed into place — so a crash at any instant
//! leaves either the new checkpoint complete or the previous one as the
//! newest *valid* checkpoint.  Recovery scans newest→oldest and skips
//! anything torn, truncated, or from a different format version.
//!
//! The serialization story is deliberately exact: every `f64` that is
//! finite (and not `-0.0`) round-trips bit-identically through the
//! in-house JSON writer's shortest-representation formatting; the
//! leftovers (NaN, ±Inf, `-0.0`) and 128-bit RNG state are carried as
//! `"bits:<hex>"` strings.  That is what makes resume == uninterrupted
//! a *bitwise* claim rather than an approximate one.

use std::fs::{self, File};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Format version of the checkpoint manifest + state schema.  Bump on
/// any incompatible change; recovery rejects mismatched checkpoints
/// instead of misinterpreting them.  v2: update-guard state
/// (quarantine/probation vectors, guard window, corrupt rng stream)
/// joined the run snapshot (DESIGN.md §16).
pub const CKPT_VERSION: i64 = 2;

/// Manifest format tag.
pub const CKPT_FORMAT: &str = "hbatch-ckpt";

/// Default snapshot spacing (virtual seconds) when `--checkpoint dir`
/// gives no `every_s`: snapshot at every eligible boundary.
pub const DEFAULT_EVERY_S: f64 = 0.0;

/// Default number of committed checkpoints retained.
pub const DEFAULT_KEEP_N: usize = 2;

// ---------------------------------------------------------------- codec

/// Exact `f64` → JSON.  Finite values (except `-0.0`) go through the
/// numeric writer, which emits either an exact integer or the shortest
/// decimal that re-parses to the same bits.  NaN / ±Inf / `-0.0` — all
/// legitimate sentinel states in the run loop (`deadline`, `next_done`)
/// — become `"bits:<16-hex>"` strings.
pub fn enc_f64(x: f64) -> Json {
    if x.is_finite() && !(x == 0.0 && x.is_sign_negative()) {
        Json::Num(x)
    } else {
        Json::Str(format!("bits:{:016x}", x.to_bits()))
    }
}

/// Inverse of [`enc_f64`].
pub fn dec_f64(j: &Json) -> Result<f64, String> {
    match j {
        Json::Num(n) => Ok(*n),
        Json::Str(s) => {
            let hex = s
                .strip_prefix("bits:")
                .ok_or_else(|| format!("expected bits:<hex> f64, got {s:?}"))?;
            u64::from_str_radix(hex, 16)
                .map(f64::from_bits)
                .map_err(|e| format!("bad f64 bits {s:?}: {e}"))
        }
        other => Err(format!("expected f64, got {other:?}")),
    }
}

/// Exact `f64` slice → JSON array (element-wise [`enc_f64`]).
pub fn enc_f64_slice(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| enc_f64(x)).collect())
}

/// Inverse of [`enc_f64_slice`].
pub fn dec_f64_vec(j: &Json) -> Result<Vec<f64>, String> {
    j.as_arr()
        .ok_or_else(|| format!("expected f64 array, got {j:?}"))?
        .iter()
        .map(dec_f64)
        .collect()
}

/// `u64` → JSON, exact across the whole range: values beyond the f64
/// integer window are carried as hex strings.
pub fn enc_u64(x: u64) -> Json {
    if x < (1u64 << 53) {
        Json::Num(x as f64)
    } else {
        Json::Str(format!("bits:{x:016x}"))
    }
}

/// Inverse of [`enc_u64`].
pub fn dec_u64(j: &Json) -> Result<u64, String> {
    match j {
        Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 9e15 => Ok(*n as u64),
        Json::Str(s) => {
            let hex = s
                .strip_prefix("bits:")
                .ok_or_else(|| format!("expected bits:<hex> u64, got {s:?}"))?;
            u64::from_str_radix(hex, 16).map_err(|e| format!("bad u64 bits {s:?}: {e}"))
        }
        other => Err(format!("expected u64, got {other:?}")),
    }
}

/// `u128` → `"bits:<32-hex>"` (RNG state words).
pub fn enc_u128(x: u128) -> Json {
    Json::Str(format!("bits:{x:032x}"))
}

/// Inverse of [`enc_u128`].
pub fn dec_u128(j: &Json) -> Result<u128, String> {
    let s = j
        .as_str()
        .ok_or_else(|| format!("expected bits:<hex> u128, got {j:?}"))?;
    let hex = s
        .strip_prefix("bits:")
        .ok_or_else(|| format!("expected bits:<hex> u128, got {s:?}"))?;
    u128::from_str_radix(hex, 16).map_err(|e| format!("bad u128 bits {s:?}: {e}"))
}

/// `usize` decode with the standard error shape.
pub fn dec_usize(j: &Json) -> Result<usize, String> {
    j.as_usize().ok_or_else(|| format!("expected usize, got {j:?}"))
}

/// Optional-f64 encode: `None` → `Json::Null`.
pub fn enc_opt_f64(x: Option<f64>) -> Json {
    match x {
        Some(v) => enc_f64(v),
        None => Json::Null,
    }
}

/// Inverse of [`enc_opt_f64`].
pub fn dec_opt_f64(j: &Json) -> Result<Option<f64>, String> {
    if j.is_null() {
        Ok(None)
    } else {
        dec_f64(j).map(Some)
    }
}

// ------------------------------------------------------ binary sidecar

/// Magic prefix of the `backend.bin` sidecar (RealBackend parameters +
/// optimizer moments; little-endian throughout).
pub const BIN_MAGIC: &[u8; 8] = b"HBCKPTB1";

/// Start a sidecar buffer (magic already written).
pub fn bin_new() -> Vec<u8> {
    BIN_MAGIC.to_vec()
}

pub fn bin_put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

/// Length-prefixed `f32` slice.
pub fn bin_put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    bin_put_u64(buf, xs.len() as u64);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked cursor over a sidecar produced with the `bin_put_*`
/// writers.
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    pub fn new(buf: &'a [u8]) -> Result<Self, String> {
        if buf.len() < BIN_MAGIC.len() || &buf[..BIN_MAGIC.len()] != BIN_MAGIC {
            return Err("backend.bin: bad magic".into());
        }
        Ok(BinReader {
            buf,
            pos: BIN_MAGIC.len(),
        })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                format!(
                    "backend.bin: truncated (want {n} bytes at offset {})",
                    self.pos
                )
            })?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u64()? as usize;
        let b = self.take(n.checked_mul(4).ok_or("backend.bin: length overflow")?)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
            .collect())
    }

    /// Assert the whole buffer was consumed.
    pub fn finish(self) -> Result<(), String> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(format!(
                "backend.bin: {} trailing bytes",
                self.buf.len() - self.pos
            ))
        }
    }
}

// ------------------------------------------------------------- checksum

/// FNV-1a 64-bit — tiny, dependency-free, and plenty for torn-write
/// detection (this guards against truncation/corruption, not
/// adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ----------------------------------------------------------------- spec

/// Parsed `--checkpoint dir[:every_s][:keep_n]` spec.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptSpec {
    pub dir: PathBuf,
    /// Minimum virtual seconds between snapshots (0 = snapshot at every
    /// eligible boundary).
    pub every_s: f64,
    /// Committed checkpoints retained (older ones are pruned).
    pub keep_n: usize,
}

impl CkptSpec {
    /// Parse `dir[:every_s][:keep_n]`.  The directory itself must not
    /// contain `:` (same restriction as the `rl:table.json` policy
    /// spec's first field).
    pub fn parse(s: &str) -> Result<CkptSpec, String> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.is_empty() || parts[0].is_empty() || parts.len() > 3 {
            return Err(format!("expected dir[:every_s][:keep_n], got {s:?}"));
        }
        let every_s = match parts.get(1) {
            Some(p) => p
                .parse::<f64>()
                .map_err(|_| format!("bad every_s {p:?}"))?,
            None => DEFAULT_EVERY_S,
        };
        let keep_n = match parts.get(2) {
            Some(p) => p
                .parse::<usize>()
                .map_err(|_| format!("bad keep_n {p:?}"))?,
            None => DEFAULT_KEEP_N,
        };
        if !every_s.is_finite() || every_s < 0.0 {
            return Err(format!("every_s {every_s} must be finite and >= 0"));
        }
        if keep_n == 0 {
            return Err("keep_n must be >= 1".to_string());
        }
        Ok(CkptSpec {
            dir: PathBuf::from(parts[0]),
            every_s,
            keep_n,
        })
    }
}

// ---------------------------------------------------------- checkpointer

/// One committed-or-loadable checkpoint's payload.
#[derive(Debug, Clone)]
pub struct LoadedCkpt {
    pub seq: u64,
    pub path: PathBuf,
    pub config: Json,
    pub state: Json,
    pub backend_bin: Option<Vec<u8>>,
}

/// Writes checkpoints under `spec.dir` with the atomic
/// stage→fsync→rename protocol and prunes beyond `keep_n`.
#[derive(Debug)]
pub struct Checkpointer {
    spec: CkptSpec,
    next_seq: u64,
}

impl Checkpointer {
    /// Open (creating the directory if needed).  `next_seq` continues
    /// past any checkpoints already present, so a resumed run never
    /// overwrites the checkpoint it restored from.
    pub fn open(spec: CkptSpec) -> Result<Checkpointer, String> {
        fs::create_dir_all(&spec.dir)
            .map_err(|e| format!("checkpoint dir {}: {e}", spec.dir.display()))?;
        let next_seq = list_seqs(&spec.dir)
            .into_iter()
            .max()
            .map(|s| s + 1)
            .unwrap_or(0);
        Ok(Checkpointer { spec, next_seq })
    }

    pub fn spec(&self) -> &CkptSpec {
        &self.spec
    }

    /// Commit one checkpoint: `config.json` + `state.json` (+ optional
    /// `backend.bin`).  Returns the committed directory.
    pub fn commit(
        &mut self,
        config: &Json,
        state: &Json,
        backend_bin: Option<&[u8]>,
    ) -> Result<PathBuf, String> {
        let seq = self.next_seq;
        let mut files: Vec<(&str, Vec<u8>)> = vec![
            ("config.json", config.to_pretty().into_bytes()),
            ("state.json", state.to_pretty().into_bytes()),
        ];
        if let Some(bin) = backend_bin {
            files.push(("backend.bin", bin.to_vec()));
        }

        let staging = self
            .spec
            .dir
            .join(format!(".staging-{}-{}", std::process::id(), seq));
        let _ = fs::remove_dir_all(&staging);
        fs::create_dir_all(&staging).map_err(|e| format!("stage {}: {e}", staging.display()))?;

        let mut manifest = Json::obj();
        manifest.set("format", Json::Str(CKPT_FORMAT.to_string()));
        manifest.set("version", Json::Num(CKPT_VERSION as f64));
        manifest.set("seq", enc_u64(seq));
        let mut entries = Json::obj();
        for (name, bytes) in &files {
            write_synced(&staging.join(name), bytes)?;
            let mut e = Json::obj();
            e.set("fnv1a64", Json::Str(format!("{:016x}", fnv1a64(bytes))));
            e.set("bytes", Json::Num(bytes.len() as f64));
            entries.set(name, e);
        }
        manifest.set("files", entries);
        // Manifest last: its presence marks the payload set complete.
        write_synced(&staging.join("manifest.json"), manifest.to_pretty().as_bytes())?;

        let dest = self.spec.dir.join(format!("ckpt-{seq:08}"));
        fs::rename(&staging, &dest).map_err(|e| format!("commit {}: {e}", dest.display()))?;
        let _ = File::open(&self.spec.dir).and_then(|d| d.sync_all());
        self.next_seq += 1;
        self.prune();
        Ok(dest)
    }

    fn prune(&self) {
        let mut seqs = list_seqs(&self.spec.dir);
        seqs.sort_unstable();
        while seqs.len() > self.spec.keep_n {
            let seq = seqs.remove(0);
            let _ = fs::remove_dir_all(self.spec.dir.join(format!("ckpt-{seq:08}")));
        }
    }
}

fn write_synced(path: &Path, bytes: &[u8]) -> Result<(), String> {
    let mut f =
        File::create(path).map_err(|e| format!("write {}: {e}", path.display()))?;
    f.write_all(bytes)
        .and_then(|_| f.sync_all())
        .map_err(|e| format!("write {}: {e}", path.display()))
}

fn list_seqs(dir: &Path) -> Vec<u64> {
    let Ok(rd) = fs::read_dir(dir) else {
        return vec![];
    };
    rd.filter_map(|e| e.ok())
        .filter_map(|e| {
            e.file_name()
                .to_str()
                .and_then(|n| n.strip_prefix("ckpt-").map(str::to_string))
                .and_then(|s| s.parse::<u64>().ok())
        })
        .collect()
}

/// Validate one committed checkpoint directory: manifest parses, format
/// and version match, every named file is present with matching length
/// and checksum.
pub fn validate_ckpt(path: &Path) -> Result<LoadedCkpt, String> {
    let manifest_path = path.join("manifest.json");
    let text = fs::read_to_string(&manifest_path)
        .map_err(|e| format!("{}: {e}", manifest_path.display()))?;
    let manifest =
        Json::parse(&text).map_err(|e| format!("{}: {e}", manifest_path.display()))?;
    if manifest.get("format").as_str() != Some(CKPT_FORMAT) {
        return Err(format!("{}: not a {CKPT_FORMAT} manifest", path.display()));
    }
    let version = manifest.get("version").as_i64().unwrap_or(-1);
    if version != CKPT_VERSION {
        return Err(format!(
            "{}: format version {version} (this build reads {CKPT_VERSION})",
            path.display()
        ));
    }
    let seq = dec_u64(manifest.get("seq")).map_err(|e| format!("{}: {e}", path.display()))?;
    let files = manifest
        .get("files")
        .as_obj()
        .ok_or_else(|| format!("{}: manifest has no files map", path.display()))?;

    let mut config = None;
    let mut state = None;
    let mut backend_bin = None;
    for (name, entry) in files {
        let fpath = path.join(name);
        let mut bytes = Vec::new();
        File::open(&fpath)
            .and_then(|mut f| f.read_to_end(&mut bytes))
            .map_err(|e| format!("{}: {e}", fpath.display()))?;
        let want_len = entry.get("bytes").as_usize().unwrap_or(usize::MAX);
        if bytes.len() != want_len {
            return Err(format!(
                "{}: {} bytes on disk, manifest says {want_len} (torn write?)",
                fpath.display(),
                bytes.len()
            ));
        }
        let want_sum = entry.get("fnv1a64").as_str().unwrap_or("");
        let got_sum = format!("{:016x}", fnv1a64(&bytes));
        if got_sum != want_sum {
            return Err(format!(
                "{}: checksum {got_sum} != manifest {want_sum}",
                fpath.display()
            ));
        }
        match name.as_str() {
            "config.json" => {
                config = Some(
                    Json::parse(std::str::from_utf8(&bytes).map_err(|e| e.to_string())?)
                        .map_err(|e| format!("{}: {e}", fpath.display()))?,
                )
            }
            "state.json" => {
                state = Some(
                    Json::parse(std::str::from_utf8(&bytes).map_err(|e| e.to_string())?)
                        .map_err(|e| format!("{}: {e}", fpath.display()))?,
                )
            }
            "backend.bin" => backend_bin = Some(bytes),
            other => return Err(format!("{}: unknown payload {other}", path.display())),
        }
    }
    Ok(LoadedCkpt {
        seq,
        path: path.to_path_buf(),
        config: config.ok_or_else(|| format!("{}: missing config.json", path.display()))?,
        state: state.ok_or_else(|| format!("{}: missing state.json", path.display()))?,
        backend_bin,
    })
}

/// Whether `dir` holds any committed checkpoint at all (valid or not).
/// Restart-style callers ([`crate::fleet`]) use this to distinguish
/// "fresh start" (no checkpoints — just begin) from "resume" (some
/// exist — [`recover_latest`] must succeed or the run refuses to start,
/// rather than silently restarting from zero over a corrupt history).
pub fn has_ckpts(dir: &Path) -> bool {
    !list_seqs(dir).is_empty()
}

/// Load the newest *valid* checkpoint under `dir`, scanning past torn,
/// corrupt, or version-mismatched ones (each skip is reported on
/// stderr so operators see why a rollback happened).  Errors only when
/// no checkpoint validates.
pub fn recover_latest(dir: &Path) -> Result<LoadedCkpt, String> {
    let mut seqs = list_seqs(dir);
    if seqs.is_empty() {
        return Err(format!("no checkpoints under {}", dir.display()));
    }
    seqs.sort_unstable_by(|a, b| b.cmp(a));
    let mut failures = Vec::new();
    for seq in seqs {
        let path = dir.join(format!("ckpt-{seq:08}"));
        match validate_ckpt(&path) {
            Ok(c) => {
                for f in &failures {
                    eprintln!("ckpt: skipped invalid checkpoint: {f}");
                }
                return Ok(c);
            }
            Err(e) => failures.push(e),
        }
    }
    Err(format!(
        "no valid checkpoint under {}:\n  {}",
        dir.display(),
        failures.join("\n  ")
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_codec_is_bit_exact() {
        for x in [
            0.0,
            -0.0,
            1.5,
            -3.25e-300,
            1.0 / 3.0,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            f64::MIN_POSITIVE,
            9.007199254740993e15,
        ] {
            let j = enc_f64(x);
            let round = Json::parse(&j.to_string()).unwrap();
            let back = dec_f64(&round).unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "x={x}");
        }
    }

    #[test]
    fn int_codecs_are_exact_at_the_edges() {
        for x in [0u64, 1, (1 << 53) - 1, 1 << 53, u64::MAX] {
            let j = enc_u64(x);
            let round = Json::parse(&j.to_string()).unwrap();
            assert_eq!(dec_u64(&round).unwrap(), x);
        }
        for x in [0u128, 7, u128::MAX] {
            let j = enc_u128(x);
            let round = Json::parse(&j.to_string()).unwrap();
            assert_eq!(dec_u128(&round).unwrap(), x);
        }
    }

    #[test]
    fn binary_sidecar_round_trips_and_checks_bounds() {
        let mut buf = bin_new();
        bin_put_u64(&mut buf, 42);
        bin_put_f32s(&mut buf, &[1.5, -0.0, f32::MIN_POSITIVE]);
        bin_put_f32s(&mut buf, &[]);
        let mut r = BinReader::new(&buf).unwrap();
        assert_eq!(r.u64().unwrap(), 42);
        let xs = r.f32s().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[0].to_bits(), 1.5f32.to_bits());
        assert_eq!(xs[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(xs[2].to_bits(), f32::MIN_POSITIVE.to_bits());
        assert!(r.f32s().unwrap().is_empty());
        r.finish().unwrap();
        // Bad magic, truncation, trailing garbage all error.
        assert!(BinReader::new(b"NOTMAGIC").is_err());
        let mut r = BinReader::new(&buf[..buf.len() - 2]).unwrap();
        assert_eq!(r.u64().unwrap(), 42);
        let _ = r.f32s().unwrap();
        assert!(r.f32s().is_err());
        let mut r = BinReader::new(&buf).unwrap();
        let _ = r.u64().unwrap();
        assert!(r.finish().is_err());
    }

    #[test]
    fn spec_parses_and_rejects() {
        let s = CkptSpec::parse("/tmp/ck:30:5").unwrap();
        assert_eq!(s.every_s, 30.0);
        assert_eq!(s.keep_n, 5);
        let d = CkptSpec::parse("ckdir").unwrap();
        assert_eq!(d.every_s, DEFAULT_EVERY_S);
        assert_eq!(d.keep_n, DEFAULT_KEEP_N);
        for bad in ["", ":30", "d:x", "d:30:0", "d:30:x", "d:-1", "d:nan", "d:1:2:3"] {
            assert!(CkptSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    fn tmp_ckpt_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hbatch_ckpt_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn commit_load_round_trip_and_prune() {
        let dir = tmp_ckpt_dir("rt");
        let spec = CkptSpec {
            dir: dir.clone(),
            every_s: 0.0,
            keep_n: 2,
        };
        let mut ck = Checkpointer::open(spec).unwrap();
        let mut cfg = Json::obj();
        cfg.set("workload", Json::Str("mnist".into()));
        for i in 0..4u64 {
            let mut st = Json::obj();
            st.set("t", enc_f64(1.0 / 3.0 * i as f64));
            ck.commit(&cfg, &st, (i == 3).then_some(&[1u8, 2, 3][..])).unwrap();
        }
        // keep_n=2: only seqs 2 and 3 survive.
        let mut seqs = list_seqs(&dir);
        seqs.sort_unstable();
        assert_eq!(seqs, vec![2, 3]);
        let loaded = recover_latest(&dir).unwrap();
        assert_eq!(loaded.seq, 3);
        assert_eq!(loaded.config.get("workload").as_str(), Some("mnist"));
        assert_eq!(
            dec_f64(loaded.state.get("t")).unwrap().to_bits(),
            (1.0f64).to_bits()
        );
        assert_eq!(loaded.backend_bin.as_deref(), Some(&[1u8, 2, 3][..]));
        // A fresh Checkpointer continues the sequence.
        let ck2 = Checkpointer::open(CkptSpec {
            dir: dir.clone(),
            every_s: 0.0,
            keep_n: 2,
        })
        .unwrap();
        assert_eq!(ck2.next_seq, 4);
    }

    #[test]
    fn torn_newest_falls_back_to_previous_valid() {
        let dir = tmp_ckpt_dir("torn");
        let mut ck = Checkpointer::open(CkptSpec {
            dir: dir.clone(),
            every_s: 0.0,
            keep_n: 3,
        })
        .unwrap();
        let cfg = Json::obj();
        for i in 0..2u64 {
            let mut st = Json::obj();
            st.set("seq", enc_u64(i));
            ck.commit(&cfg, &st, None).unwrap();
        }
        // Truncate the newest checkpoint's state file mid-byte.
        let newest_state = dir.join("ckpt-00000001/state.json");
        let full = fs::read(&newest_state).unwrap();
        fs::write(&newest_state, &full[..full.len() / 2]).unwrap();
        let loaded = recover_latest(&dir).unwrap();
        assert_eq!(loaded.seq, 0);
        assert_eq!(dec_u64(loaded.state.get("seq")).unwrap(), 0);
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let dir = tmp_ckpt_dir("ver");
        let mut ck = Checkpointer::open(CkptSpec {
            dir: dir.clone(),
            every_s: 0.0,
            keep_n: 3,
        })
        .unwrap();
        ck.commit(&Json::obj(), &Json::obj(), None).unwrap();
        // Rewrite the manifest claiming a future version (checksums
        // intact otherwise).
        let mpath = dir.join("ckpt-00000000/manifest.json");
        let text = fs::read_to_string(&mpath).unwrap();
        let mut m = Json::parse(&text).unwrap();
        m.set("version", Json::Num(99.0));
        fs::write(&mpath, m.to_pretty()).unwrap();
        let err = recover_latest(&dir).unwrap_err();
        assert!(err.contains("version 99"), "{err}");
    }

    #[test]
    fn missing_dir_and_empty_dir_error_cleanly() {
        let dir = tmp_ckpt_dir("empty");
        assert!(recover_latest(&dir).is_err());
        fs::create_dir_all(&dir).unwrap();
        assert!(recover_latest(&dir).is_err());
    }
}
