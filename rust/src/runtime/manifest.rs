//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (parameter order/shapes, bucket sets, artifact filenames).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Name + shape of one parameter tensor (manifest order == ABI order).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn is_empty(&self) -> bool {
        false // a scalar still occupies one slot
    }
}

/// Everything the runtime needs to know about one model.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub params: Vec<TensorSpec>,
    pub param_total: usize,
    pub x_shape: Vec<usize>,
    pub x_dtype: String,
    pub y_shape: Vec<usize>,
    pub y_dtype: String,
    pub task: String,
    pub buckets: Vec<usize>,
    pub train: BTreeMap<usize, String>,
    pub eval: BTreeMap<usize, String>,
    pub init: String,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelManifest>,
    /// K → grad_agg artifact filename.
    pub agg: BTreeMap<usize, String>,
    pub agg_chunk: usize,
}

fn usize_arr(j: &Json, what: &str) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("{what}: expected array"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("{what}: bad int")))
        .collect()
}

fn str_field(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow!("missing string field {key:?}"))
}

fn bucket_map(j: &Json, what: &str) -> Result<BTreeMap<usize, String>> {
    let obj = j.as_obj().ok_or_else(|| anyhow!("{what}: expected object"))?;
    let mut out = BTreeMap::new();
    for (k, v) in obj {
        let bucket: usize = k.parse().map_err(|_| anyhow!("{what}: bad bucket key {k:?}"))?;
        let fname = v
            .as_str()
            .ok_or_else(|| anyhow!("{what}: filename must be a string"))?;
        out.insert(bucket, fname.to_string());
    }
    Ok(out)
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let version = j.get("version").as_i64().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut models = BTreeMap::new();
        let models_obj = j
            .get("models")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest has no models object"))?;
        for (name, m) in models_obj {
            let params: Vec<TensorSpec> = m
                .get("params")
                .as_arr()
                .ok_or_else(|| anyhow!("{name}: params must be an array"))?
                .iter()
                .map(|p| {
                    Ok(TensorSpec {
                        name: str_field(p, "name")?,
                        shape: usize_arr(p.get("shape"), "param shape")?,
                    })
                })
                .collect::<Result<_>>()?;
            let param_total = m
                .get("param_total")
                .as_usize()
                .ok_or_else(|| anyhow!("{name}: missing param_total"))?;
            let computed: usize = params.iter().map(|p| p.len()).sum();
            if computed != param_total {
                bail!("{name}: param_total {param_total} != computed {computed}");
            }
            let buckets = usize_arr(m.get("buckets"), "buckets")?;
            let train = bucket_map(m.get("train"), "train")?;
            let eval = bucket_map(m.get("eval"), "eval")?;
            for &b in &buckets {
                if !train.contains_key(&b) {
                    bail!("{name}: bucket {b} has no train artifact");
                }
            }
            models.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    params,
                    param_total,
                    x_shape: usize_arr(m.get("x_shape"), "x_shape")?,
                    x_dtype: str_field(m, "x_dtype")?,
                    y_shape: usize_arr(m.get("y_shape"), "y_shape")?,
                    y_dtype: str_field(m, "y_dtype")?,
                    task: str_field(m, "task")?,
                    buckets,
                    train,
                    eval,
                    init: str_field(m, "init")?,
                },
            );
        }
        let agg = bucket_map(j.get("agg"), "agg").unwrap_or_default();
        let agg_chunk = j.get("agg_chunk").as_usize().unwrap_or(1 << 20);
        Ok(Manifest {
            models,
            agg,
            agg_chunk,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "models": {
            "mlp": {
                "params": [
                    {"name": "fc1/w", "shape": [4, 2]},
                    {"name": "fc1/b", "shape": [2]}
                ],
                "param_total": 10,
                "x_shape": [4], "x_dtype": "f32",
                "y_shape": [], "y_dtype": "i32",
                "task": "classification",
                "buckets": [8, 16],
                "train": {"8": "mlp_train_b8.hlo.txt", "16": "mlp_train_b16.hlo.txt"},
                "eval": {"8": "mlp_eval_b8.hlo.txt", "16": "mlp_eval_b16.hlo.txt"},
                "init": "mlp_init.bin"
            }
        },
        "agg": {"2": "grad_agg_k2.hlo.txt"},
        "agg_chunk": 1048576
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let mlp = &m.models["mlp"];
        assert_eq!(mlp.params.len(), 2);
        assert_eq!(mlp.params[0].len(), 8);
        assert_eq!(mlp.param_total, 10);
        assert_eq!(mlp.buckets, vec![8, 16]);
        assert_eq!(mlp.train[&16], "mlp_train_b16.hlo.txt");
        assert_eq!(mlp.x_dtype, "f32");
        assert_eq!(m.agg[&2], "grad_agg_k2.hlo.txt");
        assert_eq!(m.agg_chunk, 1 << 20);
    }

    #[test]
    fn rejects_bad_param_total() {
        let bad = SAMPLE.replace("\"param_total\": 10", "\"param_total\": 11");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_train_artifact() {
        let bad = SAMPLE.replace(
            r#""train": {"8": "mlp_train_b8.hlo.txt", "16": "mlp_train_b16.hlo.txt"}"#,
            r#""train": {"8": "mlp_train_b8.hlo.txt"}"#,
        );
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 99");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn scalar_tensor_len_is_one() {
        let t = TensorSpec {
            name: "s".into(),
            shape: vec![],
        };
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // Best-effort check against the actual artifacts dir.
        if let Ok(text) = std::fs::read_to_string(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"),
        ) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.models.contains_key("mlp"));
            assert!(m.models.contains_key("linreg"));
            for model in m.models.values() {
                assert!(!model.buckets.is_empty());
                assert_eq!(
                    model.param_total,
                    model.params.iter().map(|p| p.len()).sum::<usize>()
                );
            }
        }
    }
}
