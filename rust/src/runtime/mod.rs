//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, built
//! once by `python/compile/aot.py`) and executes them on the hot path.
//!
//! Python is never on the request path: the manifest fixes parameter
//! layouts and bucket sets at build time, and this module compiles each
//! (model, kind, bucket) HLO once on the PJRT CPU client, caching the
//! loaded executables.  Batch-size changes rebind a different cached
//! executable (DESIGN.md §6).

pub mod manifest;

pub use manifest::{Manifest, ModelManifest, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::Batch;

/// Step kind → artifact selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepKind {
    Train,
    Eval,
}

/// Output of a train step: scalar loss + flattened gradients.
#[derive(Debug, Clone)]
pub struct TrainOut {
    pub loss: f32,
    /// Concatenated gradients in manifest parameter order.
    pub grads: Vec<f32>,
}

/// Output of an eval step.
#[derive(Debug, Clone)]
pub struct EvalOut {
    pub loss: f32,
    /// Accuracy (classification/lm) or MSE (regression).
    pub metric: f32,
}

/// The PJRT-backed execution engine.
pub struct Runtime {
    dir: PathBuf,
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: HashMap<(String, StepKind, usize), xla::PjRtLoadedExecutable>,
    agg_exes: HashMap<usize, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open an artifacts directory (compiles nothing yet).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            dir,
            client,
            manifest,
            exes: HashMap::new(),
            agg_exes: HashMap::new(),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest
            .models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    /// Read a model's initial parameters (`<model>_init.bin`).
    pub fn init_params(&self, name: &str) -> Result<Vec<f32>> {
        let m = self.model(name)?;
        let path = self.dir.join(&m.init);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != 4 * m.param_total {
            bail!(
                "init blob {} has {} bytes, expected {}",
                m.init,
                bytes.len(),
                4 * m.param_total
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn compile_file(&self, fname: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(fname);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {fname}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {fname}: {e}"))
    }

    /// Ensure the executable for (model, kind, bucket) is compiled.
    pub fn ensure_compiled(
        &mut self,
        model: &str,
        kind: StepKind,
        bucket: usize,
    ) -> Result<()> {
        let key = (model.to_string(), kind, bucket);
        if self.exes.contains_key(&key) {
            return Ok(());
        }
        let m = self.model(model)?;
        let table = match kind {
            StepKind::Train => &m.train,
            StepKind::Eval => &m.eval,
        };
        let fname = table
            .get(&bucket)
            .ok_or_else(|| {
                anyhow!(
                    "no {kind:?} artifact for model {model} bucket {bucket} (buckets: {:?})",
                    m.buckets
                )
            })?
            .clone();
        let exe = self.compile_file(&fname)?;
        self.exes.insert(key, exe);
        Ok(())
    }

    /// Pre-compile every bucket of a model (done at startup so bucket
    /// swaps on the hot path only rebind, never compile).
    pub fn warmup(&mut self, model: &str, kinds: &[StepKind]) -> Result<()> {
        let buckets = self.model(model)?.buckets.clone();
        for &b in &buckets {
            for &k in kinds {
                self.ensure_compiled(model, k, b)?;
            }
        }
        Ok(())
    }

    /// Number of compiled executables (cache introspection).
    pub fn compiled_count(&self) -> usize {
        self.exes.len() + self.agg_exes.len()
    }

    // ----------------------------------------------------------- marshal

    fn f32_literal(data: &[f32], dims: &[usize]) -> xla::Literal {
        let n: usize = dims.iter().product::<usize>().max(1);
        debug_assert_eq!(n, data.len());
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            dims,
            bytes,
        )
        .expect("f32 literal")
    }

    fn i32_literal(data: &[i32], dims: &[usize]) -> xla::Literal {
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            dims,
            bytes,
        )
        .expect("i32 literal")
    }

    /// Marshal the parameter vector into per-tensor literals.
    ///
    /// §Perf iteration 3: the engine prepares these **once per BSP
    /// round** and shares them across all K workers' train steps —
    /// params are identical within a round, and re-marshaling them per
    /// worker costs (K−1) full parameter copies per iteration.
    pub fn prepare_params(&self, model: &str, params: &[f32]) -> Result<Vec<xla::Literal>> {
        let m = self.model(model)?;
        if params.len() != m.param_total {
            bail!(
                "param vector len {} != manifest total {}",
                params.len(),
                m.param_total
            );
        }
        let mut lits = Vec::with_capacity(m.params.len());
        let mut off = 0;
        for spec in &m.params {
            let len = spec.len();
            lits.push(Self::f32_literal(&params[off..off + len], &spec.shape));
            off += len;
        }
        Ok(lits)
    }

    /// Marshal the batch (x, y) literals.
    fn batch_args(m: &ModelManifest, batch: &Batch) -> Result<Vec<xla::Literal>> {
        let b = batch.batch_size;
        let mut args = Vec::with_capacity(2);
        // x
        let mut x_dims = vec![b];
        x_dims.extend(&m.x_shape);
        match m.x_dtype.as_str() {
            "f32" => {
                let want = b * m.x_shape.iter().product::<usize>().max(1);
                if batch.x_f32.len() != want {
                    bail!("x_f32 len {} != {}", batch.x_f32.len(), want);
                }
                args.push(Self::f32_literal(&batch.x_f32, &x_dims));
            }
            "i32" => {
                args.push(Self::i32_literal(&batch.x_i32, &x_dims));
            }
            other => bail!("unsupported x_dtype {other}"),
        }
        // y
        let mut y_dims = vec![b];
        y_dims.extend(&m.y_shape);
        match m.y_dtype.as_str() {
            "f32" => args.push(Self::f32_literal(&batch.y_f32, &y_dims)),
            "i32" => args.push(Self::i32_literal(&batch.y_i32, &y_dims)),
            other => bail!("unsupported y_dtype {other}"),
        }
        Ok(args)
    }

    fn execute_refs(
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e}"))
    }

    /// Train step with pre-marshaled parameter literals (shared across
    /// the round — see [`Runtime::prepare_params`]); gradients are
    /// written into `grads_out` (no per-call allocation).
    pub fn train_step_prepared(
        &mut self,
        model: &str,
        bucket: usize,
        param_lits: &[xla::Literal],
        batch: &Batch,
        grads_out: &mut [f32],
    ) -> Result<f32> {
        if batch.batch_size != bucket {
            bail!("batch size {} != bucket {}", batch.batch_size, bucket);
        }
        self.ensure_compiled(model, StepKind::Train, bucket)?;
        let m = self.model(model)?;
        if param_lits.len() != m.params.len() {
            bail!("prepared params: {} literals != {} tensors", param_lits.len(), m.params.len());
        }
        if grads_out.len() != m.param_total {
            bail!("grads_out len {} != param total {}", grads_out.len(), m.param_total);
        }
        let batch_lits = Self::batch_args(m, batch)?;
        let lens: Vec<usize> = m.params.iter().map(|s| s.len()).collect();
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(param_lits.len() + 2);
        refs.extend(param_lits.iter());
        refs.extend(batch_lits.iter());
        let exe = &self.exes[&(model.to_string(), StepKind::Train, bucket)];
        let outs = Self::execute_refs(exe, &refs)?;
        if outs.len() != lens.len() + 1 {
            bail!("train step returned {} outputs, expected {}", outs.len(), lens.len() + 1);
        }
        let loss: f32 = outs[0]
            .get_first_element()
            .map_err(|e| anyhow!("loss readout: {e}"))?;
        let mut off = 0;
        for (i, len) in lens.iter().enumerate() {
            outs[i + 1]
                .copy_raw_to(&mut grads_out[off..off + len])
                .map_err(|e| anyhow!("grad {i} readout: {e}"))?;
            off += len;
        }
        Ok(loss)
    }

    fn execute(
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e}"))
    }

    // -------------------------------------------------------------- steps

    /// Run one training step: returns loss + flat gradients.
    pub fn train_step(
        &mut self,
        model: &str,
        bucket: usize,
        params: &[f32],
        batch: &Batch,
    ) -> Result<TrainOut> {
        if batch.batch_size != bucket {
            bail!("batch size {} != bucket {}", batch.batch_size, bucket);
        }
        let param_lits = self.prepare_params(model, params)?;
        let mut grads = vec![0.0f32; self.model(model)?.param_total];
        let loss =
            self.train_step_prepared(model, bucket, &param_lits, batch, &mut grads)?;
        Ok(TrainOut { loss, grads })
    }

    /// Run one eval step: loss + task metric.
    pub fn eval_step(
        &mut self,
        model: &str,
        bucket: usize,
        params: &[f32],
        batch: &Batch,
    ) -> Result<EvalOut> {
        if batch.batch_size != bucket {
            bail!("batch size {} != bucket {}", batch.batch_size, bucket);
        }
        self.ensure_compiled(model, StepKind::Eval, bucket)?;
        let param_lits = self.prepare_params(model, params)?;
        let m = self.model(model)?;
        let batch_lits = Self::batch_args(m, batch)?;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(param_lits.len() + 2);
        refs.extend(param_lits.iter());
        refs.extend(batch_lits.iter());
        let exe = &self.exes[&(model.to_string(), StepKind::Eval, bucket)];
        let outs = Self::execute_refs(exe, &refs)?;
        if outs.len() != 2 {
            bail!("eval step returned {} outputs, expected 2", outs.len());
        }
        Ok(EvalOut {
            loss: outs[0].get_first_element().map_err(|e| anyhow!("{e}"))?,
            metric: outs[1].get_first_element().map_err(|e| anyhow!("{e}"))?,
        })
    }

    // ------------------------------------------------ XLA-side aggregation

    /// λ-weighted aggregation through the AOT Pallas kernel
    /// (`grad_agg_k<K>.hlo.txt`).  The Rust-native path in [`crate::ps`]
    /// is the production one; this validates the kernel end to end and
    /// feeds the bench comparison (`benches/agg.rs`).
    pub fn agg_step(&mut self, lambdas: &[f64], grads: &[&[f32]]) -> Result<Vec<f32>> {
        let k = lambdas.len();
        if grads.len() != k {
            bail!("grads/lambdas length mismatch");
        }
        if !self.manifest.agg.contains_key(&k) {
            bail!(
                "no grad_agg artifact for K={k} (have {:?})",
                self.manifest.agg.keys().collect::<Vec<_>>()
            );
        }
        if !self.agg_exes.contains_key(&k) {
            let fname = self.manifest.agg[&k].clone();
            let exe = self.compile_file(&fname)?;
            self.agg_exes.insert(k, exe);
        }
        let d = grads[0].len();
        for g in grads {
            if g.len() != d {
                bail!("ragged gradient lengths");
            }
        }
        let chunk = self.manifest.agg_chunk;
        let lam_f32: Vec<f32> = lambdas.iter().map(|&l| l as f32).collect();
        let exe = &self.agg_exes[&k];
        let mut out = vec![0.0f32; d];
        let mut stacked = vec![0.0f32; k * chunk];
        let mut off = 0;
        while off < d {
            let len = chunk.min(d - off);
            // Stack the K chunk slices (zero-pad the tail).
            for (w, g) in grads.iter().enumerate() {
                stacked[w * chunk..w * chunk + len]
                    .copy_from_slice(&g[off..off + len]);
                stacked[w * chunk + len..(w + 1) * chunk].fill(0.0);
            }
            let lam_lit = Self::f32_literal(&lam_f32, &[k]);
            let g_lit = Self::f32_literal(&stacked, &[k, chunk]);
            let outs = Self::execute(exe, &[lam_lit, g_lit])?;
            let mut chunk_out = vec![0.0f32; chunk];
            outs[0]
                .copy_raw_to(&mut chunk_out)
                .map_err(|e| anyhow!("agg readout: {e}"))?;
            out[off..off + len].copy_from_slice(&chunk_out[..len]);
            off += len;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // Runtime correctness lives in rust/tests/runtime_integration.rs —
    // it needs built artifacts, which unit tests must not assume.
}
