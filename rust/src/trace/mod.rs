//! Time-varying resource availability traces.
//!
//! The paper's *dynamic* heterogeneity scenarios — performance
//! interference from colocated applications, provider over-commitment,
//! and transient-VM preemptions (EC2 spot / GCP preemptible) — are
//! modeled as a per-worker capacity multiplier over time.  The dynamic
//! batching controller never sees these traces; it only observes their
//! effect on iteration times, exactly as the paper's system does.

use crate::util::rng::Rng;

/// A step function: capacity multiplier in (0, 1] over time (seconds).
/// Segments are half-open `[start, next_start)`; the last extends to ∞.
#[derive(Debug, Clone)]
pub struct AvailTrace {
    /// (start_time, multiplier), sorted by start_time; first at t=0.
    segments: Vec<(f64, f64)>,
}

impl AvailTrace {
    /// Constant full availability.
    pub fn constant() -> Self {
        AvailTrace {
            segments: vec![(0.0, 1.0)],
        }
    }

    /// Build from explicit (start, multiplier) segments.
    pub fn from_segments(segments: Vec<(f64, f64)>) -> Self {
        assert!(!segments.is_empty(), "empty trace");
        assert_eq!(segments[0].0, 0.0, "first segment must start at t=0");
        for w in segments.windows(2) {
            assert!(w[0].0 < w[1].0, "segments must be strictly ordered");
        }
        for &(_, m) in &segments {
            assert!(m > 0.0 && m <= 1.0, "multiplier out of (0,1]: {m}");
        }
        AvailTrace { segments }
    }

    /// Capacity multiplier at time `t`.
    pub fn at(&self, t: f64) -> f64 {
        match self
            .segments
            .binary_search_by(|&(s, _)| s.partial_cmp(&t).unwrap())
        {
            Ok(i) => self.segments[i].1,
            Err(0) => self.segments[0].1, // t before 0: clamp
            Err(i) => self.segments[i - 1].1,
        }
    }

    pub fn segments(&self) -> &[(f64, f64)] {
        &self.segments
    }

    /// Interference trace: an on/off process. Bursts arrive Poisson with
    /// `mean_gap_s` between them, last Exp(`mean_len_s`), and squeeze the
    /// worker to `depth` (e.g. 0.5 = half capacity).
    pub fn interference(
        horizon_s: f64,
        mean_gap_s: f64,
        mean_len_s: f64,
        depth: f64,
        rng: &mut Rng,
    ) -> Self {
        assert!(depth > 0.0 && depth <= 1.0);
        let mut segments = vec![(0.0, 1.0)];
        let mut t = rng.exp(1.0 / mean_gap_s);
        while t < horizon_s {
            let len = rng.exp(1.0 / mean_len_s).max(1.0);
            segments.push((t, depth));
            segments.push((t + len, 1.0));
            t += len + rng.exp(1.0 / mean_gap_s).max(1.0);
        }
        AvailTrace::from_segments(segments)
    }

    /// Over-commitment trace: capacity steps between levels at Poisson
    /// epochs — the provider packs more tenants on the host for a while.
    pub fn overcommit(
        horizon_s: f64,
        mean_epoch_s: f64,
        levels: &[f64],
        rng: &mut Rng,
    ) -> Self {
        assert!(!levels.is_empty());
        let mut segments = vec![(0.0, 1.0)];
        let mut t = rng.exp(1.0 / mean_epoch_s);
        while t < horizon_s {
            segments.push((t, *rng.choice(levels)));
            t += rng.exp(1.0 / mean_epoch_s).max(1.0);
        }
        AvailTrace::from_segments(segments)
    }

    /// Spot/preemptible trace: the worker is fully available until a
    /// preemption arrives (Exp with `mttf_s`), stays down for
    /// `down_s` (re-provisioning), then returns. "Down" is modeled as
    /// a very small multiplier so iteration times blow up rather than
    /// divide by zero — the sync engine treats ≤`DOWN_EPS` as absent.
    pub fn spot(horizon_s: f64, mttf_s: f64, down_s: f64, rng: &mut Rng) -> Self {
        let mut segments = vec![(0.0, 1.0)];
        let mut t = rng.exp(1.0 / mttf_s);
        while t < horizon_s {
            segments.push((t, DOWN_EPS));
            segments.push((t + down_s, 1.0));
            t += down_s + rng.exp(1.0 / mttf_s);
        }
        AvailTrace::from_segments(segments)
    }

    /// True if the worker is preempted (down) at `t`.
    pub fn is_down(&self, t: f64) -> bool {
        self.at(t) <= DOWN_EPS
    }

    /// Wall-clock time to complete `work` seconds of full-capacity compute
    /// starting at `t0`, integrating capacity over the trace segments —
    /// so a 2-minute preemption costs ~2 minutes, not
    /// work/DOWN_EPS (availability changes mid-iteration are honored).
    pub fn time_to_complete(&self, t0: f64, work: f64) -> f64 {
        assert!(work >= 0.0 && t0 >= 0.0);
        let mut remaining = work;
        let mut t = t0;
        // Find the segment containing t0.
        let mut idx = match self
            .segments
            .binary_search_by(|&(s, _)| s.partial_cmp(&t0).unwrap())
        {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) => i - 1,
        };
        loop {
            let cap = self.segments[idx].1;
            let seg_end = self
                .segments
                .get(idx + 1)
                .map(|&(s, _)| s)
                .unwrap_or(f64::INFINITY);
            let width = seg_end - t;
            let doable = cap * width;
            if doable >= remaining {
                return (t + remaining / cap) - t0;
            }
            remaining -= doable;
            t = seg_end;
            idx += 1;
        }
    }
}

/// Capacity multiplier that stands for "preempted".
pub const DOWN_EPS: f64 = 1e-3;

/// Per-worker trace set for a cluster.
#[derive(Debug, Clone)]
pub struct ClusterTraces {
    pub traces: Vec<AvailTrace>,
}

impl ClusterTraces {
    pub fn constant(k: usize) -> Self {
        ClusterTraces {
            traces: vec![AvailTrace::constant(); k],
        }
    }

    pub fn at(&self, worker: usize, t: f64) -> f64 {
        self.traces[worker].at(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one_everywhere() {
        let tr = AvailTrace::constant();
        assert_eq!(tr.at(0.0), 1.0);
        assert_eq!(tr.at(1e9), 1.0);
    }

    #[test]
    fn step_lookup() {
        let tr = AvailTrace::from_segments(vec![(0.0, 1.0), (10.0, 0.5), (20.0, 0.8)]);
        assert_eq!(tr.at(0.0), 1.0);
        assert_eq!(tr.at(9.999), 1.0);
        assert_eq!(tr.at(10.0), 0.5);
        assert_eq!(tr.at(15.0), 0.5);
        assert_eq!(tr.at(20.0), 0.8);
        assert_eq!(tr.at(1e6), 0.8);
    }

    #[test]
    #[should_panic]
    fn rejects_unsorted() {
        AvailTrace::from_segments(vec![(0.0, 1.0), (5.0, 0.5), (3.0, 1.0)]);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_multiplier() {
        AvailTrace::from_segments(vec![(0.0, 0.0)]);
    }

    #[test]
    fn interference_dips_and_recovers() {
        let mut rng = Rng::new(42);
        let tr = AvailTrace::interference(10_000.0, 300.0, 100.0, 0.4, &mut rng);
        let mut saw_dip = false;
        let mut saw_full = false;
        for i in 0..10_000 {
            let v = tr.at(i as f64);
            if (v - 0.4).abs() < 1e-9 {
                saw_dip = true;
            }
            if (v - 1.0).abs() < 1e-9 {
                saw_full = true;
            }
            assert!(v == 0.4 || v == 1.0);
        }
        assert!(saw_dip && saw_full);
    }

    #[test]
    fn interference_duty_cycle_roughly_matches() {
        let mut rng = Rng::new(7);
        let tr = AvailTrace::interference(200_000.0, 300.0, 100.0, 0.5, &mut rng);
        let dipped = (0..200_000)
            .filter(|&i| tr.at(i as f64) < 1.0)
            .count() as f64
            / 200_000.0;
        // Expected duty ≈ 100/(300+100) = 0.25.
        assert!((dipped - 0.25).abs() < 0.08, "duty={dipped}");
    }

    #[test]
    fn spot_has_down_periods_of_right_length() {
        let mut rng = Rng::new(3);
        let tr = AvailTrace::spot(100_000.0, 5_000.0, 120.0, &mut rng);
        let down: f64 = (0..100_000).filter(|&i| tr.is_down(i as f64)).count() as f64;
        assert!(down > 0.0, "no preemptions in 100k s at mttf 5k");
        // Each preemption is 120 s; with ~20 expected events, total down
        // time should be in the low thousands of seconds.
        assert!(down < 10_000.0, "down={down}");
    }

    #[test]
    fn overcommit_uses_given_levels() {
        let mut rng = Rng::new(11);
        let tr = AvailTrace::overcommit(50_000.0, 1_000.0, &[0.6, 0.8], &mut rng);
        for i in 0..50_000 {
            let v = tr.at(i as f64);
            assert!(v == 1.0 || v == 0.6 || v == 0.8, "v={v}");
        }
    }

    #[test]
    fn time_to_complete_full_capacity() {
        let tr = AvailTrace::constant();
        assert!((tr.time_to_complete(5.0, 3.0) - 3.0).abs() < 1e-12);
        assert_eq!(tr.time_to_complete(0.0, 0.0), 0.0);
    }

    #[test]
    fn time_to_complete_integrates_across_segments() {
        // Half capacity in [10, 20): 4s of work starting at t=8 does
        // 2 work-sec by t=10, then needs 2/0.5 = 4s more -> total 6s.
        let tr = AvailTrace::from_segments(vec![(0.0, 1.0), (10.0, 0.5), (20.0, 1.0)]);
        assert!((tr.time_to_complete(8.0, 4.0) - 6.0).abs() < 1e-12);
        // Starting inside the slow segment.
        assert!((tr.time_to_complete(10.0, 2.0) - 4.0).abs() < 1e-12);
        // Work spanning recovery: start t=18, work 3: 1 work-sec by 20
        // (2s), then 2s at full -> 4s.
        assert!((tr.time_to_complete(18.0, 3.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn time_to_complete_preemption_costs_downtime_not_division() {
        // 120s preemption at t=100; 3s of work starting at t=99 costs
        // ~1s before + ~120s down (doing ~0.12 work-sec) + remainder.
        let tr = AvailTrace::from_segments(vec![(0.0, 1.0), (100.0, DOWN_EPS), (220.0, 1.0)]);
        let dt = tr.time_to_complete(99.0, 3.0);
        assert!(dt > 120.0 && dt < 125.0, "dt={dt}");
    }

    #[test]
    fn cluster_traces_indexing() {
        let ct = ClusterTraces::constant(3);
        assert_eq!(ct.at(2, 100.0), 1.0);
    }
}
