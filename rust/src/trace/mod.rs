//! Time-varying resource availability traces.
//!
//! The paper's *dynamic* heterogeneity scenarios — performance
//! interference from colocated applications, provider over-commitment,
//! and transient-VM preemptions (EC2 spot / GCP preemptible) — are
//! modeled as a per-worker capacity multiplier over time.  The dynamic
//! batching controller never sees these traces; it only observes their
//! effect on iteration times, exactly as the paper's system does.

use crate::util::rng::Rng;

/// A step function: capacity multiplier in (0, 1] over time (seconds).
/// Segments are half-open `[start, next_start)`; the last extends to ∞.
#[derive(Debug, Clone)]
pub struct AvailTrace {
    /// (start_time, multiplier), sorted by start_time; first at t=0.
    segments: Vec<(f64, f64)>,
}

impl AvailTrace {
    /// Constant full availability.
    pub fn constant() -> Self {
        AvailTrace {
            segments: vec![(0.0, 1.0)],
        }
    }

    /// Build from explicit (start, multiplier) segments.
    pub fn from_segments(segments: Vec<(f64, f64)>) -> Self {
        assert!(!segments.is_empty(), "empty trace");
        assert_eq!(segments[0].0, 0.0, "first segment must start at t=0");
        for w in segments.windows(2) {
            assert!(w[0].0 < w[1].0, "segments must be strictly ordered");
        }
        for &(_, m) in &segments {
            assert!(m > 0.0 && m <= 1.0, "multiplier out of (0,1]: {m}");
        }
        AvailTrace { segments }
    }

    /// Capacity multiplier at time `t`.
    pub fn at(&self, t: f64) -> f64 {
        // total_cmp: segment starts are finite by construction, but `t`
        // arrives from virtual-time arithmetic — a NaN must land on the
        // deterministic total order (clamping to an end), not panic the
        // session mid-run (finishes PR 4's comparator sweep).
        match self
            .segments
            .binary_search_by(|&(s, _)| s.total_cmp(&t))
        {
            Ok(i) => self.segments[i].1,
            Err(0) => self.segments[0].1, // t before 0: clamp
            Err(i) => self.segments[i - 1].1,
        }
    }

    pub fn segments(&self) -> &[(f64, f64)] {
        &self.segments
    }

    /// Interference trace: an on/off process. Bursts arrive Poisson with
    /// `mean_gap_s` between them, last Exp(`mean_len_s`), and squeeze the
    /// worker to `depth` (e.g. 0.5 = half capacity).
    pub fn interference(
        horizon_s: f64,
        mean_gap_s: f64,
        mean_len_s: f64,
        depth: f64,
        rng: &mut Rng,
    ) -> Self {
        assert!(depth > 0.0 && depth <= 1.0);
        let mut segments = vec![(0.0, 1.0)];
        let mut t = rng.exp(1.0 / mean_gap_s);
        while t < horizon_s {
            let len = rng.exp(1.0 / mean_len_s).max(1.0);
            segments.push((t, depth));
            segments.push((t + len, 1.0));
            t += len + rng.exp(1.0 / mean_gap_s).max(1.0);
        }
        AvailTrace::from_segments(segments)
    }

    /// Over-commitment trace: capacity steps between levels at Poisson
    /// epochs — the provider packs more tenants on the host for a while.
    pub fn overcommit(
        horizon_s: f64,
        mean_epoch_s: f64,
        levels: &[f64],
        rng: &mut Rng,
    ) -> Self {
        assert!(!levels.is_empty());
        let mut segments = vec![(0.0, 1.0)];
        let mut t = rng.exp(1.0 / mean_epoch_s);
        while t < horizon_s {
            segments.push((t, *rng.choice(levels)));
            t += rng.exp(1.0 / mean_epoch_s).max(1.0);
        }
        AvailTrace::from_segments(segments)
    }

    /// Spot/preemptible trace: the worker is fully available until a
    /// preemption arrives (Exp with `mttf_s`), stays down for
    /// `down_s` (re-provisioning), then returns. "Down" is modeled as
    /// a very small multiplier so iteration times blow up rather than
    /// divide by zero — the sync engine treats ≤`DOWN_EPS` as absent.
    pub fn spot(horizon_s: f64, mttf_s: f64, down_s: f64, rng: &mut Rng) -> Self {
        let mut segments = vec![(0.0, 1.0)];
        let mut t = rng.exp(1.0 / mttf_s);
        while t < horizon_s {
            segments.push((t, DOWN_EPS));
            segments.push((t + down_s, 1.0));
            t += down_s + rng.exp(1.0 / mttf_s);
        }
        AvailTrace::from_segments(segments)
    }

    /// True if the worker is preempted (down) at `t`.
    pub fn is_down(&self, t: f64) -> bool {
        self.at(t) <= DOWN_EPS
    }

    /// Wall-clock time to complete `work` seconds of full-capacity compute
    /// starting at `t0`, integrating capacity over the trace segments —
    /// so a 2-minute preemption costs ~2 minutes, not
    /// work/DOWN_EPS (availability changes mid-iteration are honored).
    pub fn time_to_complete(&self, t0: f64, work: f64) -> f64 {
        assert!(work >= 0.0 && t0 >= 0.0);
        let mut remaining = work;
        let mut t = t0;
        // Find the segment containing t0 (total_cmp, as in `at`: a NaN
        // query must not panic the comparator).
        let mut idx = match self
            .segments
            .binary_search_by(|&(s, _)| s.total_cmp(&t0))
        {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) => i - 1,
        };
        loop {
            let cap = self.segments[idx].1;
            let seg_end = self
                .segments
                .get(idx + 1)
                .map(|&(s, _)| s)
                .unwrap_or(f64::INFINITY);
            let width = seg_end - t;
            let doable = cap * width;
            if doable >= remaining {
                return (t + remaining / cap) - t0;
            }
            remaining -= doable;
            t = seg_end;
            idx += 1;
        }
    }
}

/// Capacity multiplier that stands for "preempted".
pub const DOWN_EPS: f64 = 1e-3;

/// Horizon over which `--spot` scenario traces (and their membership
/// events) are generated.  Runs ending earlier simply never reach the
/// tail; virtual and wall clocks both fit comfortably inside it.
pub const SPOT_HORIZON_S: f64 = 100_000.0;

/// Per-worker trace set for a cluster.
#[derive(Debug, Clone)]
pub struct ClusterTraces {
    pub traces: Vec<AvailTrace>,
}

impl ClusterTraces {
    pub fn constant(k: usize) -> Self {
        ClusterTraces {
            traces: vec![AvailTrace::constant(); k],
        }
    }

    /// A cluster of spot VMs: every worker gets an independent
    /// preemption trace (forked streams off one seed).
    pub fn spot_cluster(
        k: usize,
        horizon_s: f64,
        mttf_s: f64,
        down_s: f64,
        seed: u64,
    ) -> Self {
        let mut root = Rng::new(seed);
        ClusterTraces {
            traces: (0..k)
                .map(|i| {
                    let mut rng = root.fork(3000 + i as u64);
                    AvailTrace::spot(horizon_s, mttf_s, down_s, &mut rng)
                })
                .collect(),
        }
    }

    pub fn at(&self, worker: usize, t: f64) -> f64 {
        self.traces[worker].at(t)
    }
}

// ---------------------------------------------------------------------
// Elastic membership: revocation / join events over the cluster's life.

/// Spot-churn scenario spec, the `--spot mttf:down[:grace]` CLI shape
/// (all seconds): preemptions arrive Exp(`mttf_s`) per worker, last
/// `down_s`, and a worker down longer than `grace_s` is *revoked* from
/// the training group (rejoining when its VM returns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpotSpec {
    pub mttf_s: f64,
    pub down_s: f64,
    pub grace_s: f64,
}

impl SpotSpec {
    /// Parse `mttf:down[:grace]`; `None` on any malformed field.
    pub fn parse(s: &str) -> Option<SpotSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() < 2 || parts.len() > 3 {
            return None;
        }
        let mttf_s: f64 = parts[0].parse().ok()?;
        let down_s: f64 = parts[1].parse().ok()?;
        let grace_s: f64 = match parts.get(2) {
            Some(p) => p.parse().ok()?,
            None => 0.0,
        };
        let valid = mttf_s.is_finite()
            && down_s.is_finite()
            && grace_s.is_finite()
            && mttf_s > 0.0
            && down_s > 0.0
            && grace_s >= 0.0;
        valid.then_some(SpotSpec {
            mttf_s,
            down_s,
            grace_s,
        })
    }

    pub fn label(&self) -> String {
        format!("spot:{}:{}:{}", self.mttf_s, self.down_s, self.grace_s)
    }
}

/// Scheduled mid-run join, the `--join k@t` CLI shape: worker `k` first
/// appears at time `t` (it starts the run absent).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JoinSpec {
    pub worker: usize,
    pub time: f64,
}

impl JoinSpec {
    /// Parse a single `k@t`.
    pub fn parse(s: &str) -> Option<JoinSpec> {
        let (w, t) = s.split_once('@')?;
        let worker: usize = w.parse().ok()?;
        let time: f64 = t.parse().ok()?;
        (time.is_finite() && time >= 0.0).then_some(JoinSpec { worker, time })
    }

    /// Parse a comma-separated list `k@t[,k@t...]` (empty string = none).
    pub fn parse_list(s: &str) -> Option<Vec<JoinSpec>> {
        if s.is_empty() {
            return Some(vec![]);
        }
        s.split(',').map(|p| JoinSpec::parse(p.trim())).collect()
    }
}

/// Kind of membership transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipKind {
    /// The worker leaves the training group (spot revocation).
    Revoke,
    /// The worker (re)joins, seeded from the current global model.
    Join,
}

impl MembershipKind {
    pub fn label(&self) -> &'static str {
        match self {
            MembershipKind::Revoke => "revoke",
            MembershipKind::Join => "join",
        }
    }
}

/// One scheduled membership transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MembershipEvent {
    pub time: f64,
    pub worker: usize,
    pub kind: MembershipKind,
}

/// The run's membership schedule: revocations and joins over time,
/// derived from availability traces (a worker down past the grace
/// period is revoked, rejoining on recovery) and/or listed explicitly
/// (`join_at` scenarios).  Events are kept sorted by
/// (time, worker, revoke-before-join) so processing is deterministic.
#[derive(Debug, Clone, Default)]
pub struct MembershipPlan {
    events: Vec<MembershipEvent>,
}

impl MembershipPlan {
    pub fn new(mut events: Vec<MembershipEvent>) -> Self {
        sort_events(&mut events);
        MembershipPlan { events }
    }

    /// Derive revocation/rejoin events from availability traces: every
    /// down period (multiplier ≤ [`DOWN_EPS`]) longer than `grace_s`
    /// revokes the worker at `down_start + grace_s` and rejoins it when
    /// the trace recovers.  A bad grace is a config-shaped input
    /// (`--spot grace`), so it is a parse-style error, not a panic.
    pub fn from_traces(traces: &ClusterTraces, grace_s: f64) -> Result<Self, String> {
        if !grace_s.is_finite() || grace_s < 0.0 {
            return Err(format!("grace {grace_s} must be finite and non-negative"));
        }
        let mut events = Vec::new();
        for (w, tr) in traces.traces.iter().enumerate() {
            let segs = tr.segments();
            let mut i = 0;
            while i < segs.len() {
                if segs[i].1 > DOWN_EPS {
                    i += 1;
                    continue;
                }
                // Coalesce consecutive down segments into one period.
                let start = segs[i].0;
                let mut j = i + 1;
                while j < segs.len() && segs[j].1 <= DOWN_EPS {
                    j += 1;
                }
                let end = segs.get(j).map(|&(s, _)| s).unwrap_or(f64::INFINITY);
                if end - start > grace_s {
                    events.push(MembershipEvent {
                        time: start + grace_s,
                        worker: w,
                        kind: MembershipKind::Revoke,
                    });
                    if end.is_finite() {
                        events.push(MembershipEvent {
                            time: end,
                            worker: w,
                            kind: MembershipKind::Join,
                        });
                    }
                }
                i = j;
            }
        }
        Ok(MembershipPlan::new(events))
    }

    /// Add scheduled joins (`k@t`): each worker listed starts absent and
    /// first appears at its join time.
    pub fn with_joins(mut self, joins: &[JoinSpec]) -> Self {
        for j in joins {
            self.events.push(MembershipEvent {
                time: j.time,
                worker: j.worker,
                kind: MembershipKind::Join,
            });
        }
        sort_events(&mut self.events);
        self
    }

    /// Merge another plan's events into this one.
    pub fn merged(mut self, other: &MembershipPlan) -> Self {
        self.events.extend(other.events.iter().copied());
        sort_events(&mut self.events);
        self
    }

    pub fn events(&self) -> &[MembershipEvent] {
        &self.events
    }

    /// Initial membership for a k-worker cluster: a worker whose *first*
    /// scheduled event is a Join starts the run absent (it cannot join a
    /// group it is already part of); everyone else starts live.
    pub fn initial_live(&self, k: usize) -> Vec<bool> {
        let mut live = vec![true; k];
        let mut seen = vec![false; k];
        for ev in &self.events {
            if ev.worker < k && !seen[ev.worker] {
                seen[ev.worker] = true;
                if ev.kind == MembershipKind::Join {
                    live[ev.worker] = false;
                }
            }
        }
        live
    }

    /// Largest worker index referenced (None when empty).
    pub fn max_worker(&self) -> Option<usize> {
        self.events.iter().map(|e| e.worker).max()
    }
}

/// Deterministic processing order for membership events:
/// (time, worker, revoke-before-join).  Public so out-of-plan
/// injections (the fleet arbiter's grant/reclaim actuations) slot into
/// a running session's queue exactly like plan events would.
pub fn cmp_events(a: &MembershipEvent, b: &MembershipEvent) -> std::cmp::Ordering {
    a.time
        .total_cmp(&b.time)
        .then(a.worker.cmp(&b.worker))
        // Same worker, same instant: process the revoke first so a
        // revoke+join pair is a bounce, not a no-op.
        .then((a.kind == MembershipKind::Join).cmp(&(b.kind == MembershipKind::Join)))
}

fn sort_events(events: &mut [MembershipEvent]) {
    events.sort_by(cmp_events);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one_everywhere() {
        let tr = AvailTrace::constant();
        assert_eq!(tr.at(0.0), 1.0);
        assert_eq!(tr.at(1e9), 1.0);
    }

    #[test]
    fn step_lookup() {
        let tr = AvailTrace::from_segments(vec![(0.0, 1.0), (10.0, 0.5), (20.0, 0.8)]);
        assert_eq!(tr.at(0.0), 1.0);
        assert_eq!(tr.at(9.999), 1.0);
        assert_eq!(tr.at(10.0), 0.5);
        assert_eq!(tr.at(15.0), 0.5);
        assert_eq!(tr.at(20.0), 0.8);
        assert_eq!(tr.at(1e6), 0.8);
    }

    #[test]
    #[should_panic]
    fn rejects_unsorted() {
        AvailTrace::from_segments(vec![(0.0, 1.0), (5.0, 0.5), (3.0, 1.0)]);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_multiplier() {
        AvailTrace::from_segments(vec![(0.0, 0.0)]);
    }

    #[test]
    fn interference_dips_and_recovers() {
        let mut rng = Rng::new(42);
        let tr = AvailTrace::interference(10_000.0, 300.0, 100.0, 0.4, &mut rng);
        let mut saw_dip = false;
        let mut saw_full = false;
        for i in 0..10_000 {
            let v = tr.at(i as f64);
            if (v - 0.4).abs() < 1e-9 {
                saw_dip = true;
            }
            if (v - 1.0).abs() < 1e-9 {
                saw_full = true;
            }
            assert!(v == 0.4 || v == 1.0);
        }
        assert!(saw_dip && saw_full);
    }

    #[test]
    fn interference_duty_cycle_roughly_matches() {
        let mut rng = Rng::new(7);
        let tr = AvailTrace::interference(200_000.0, 300.0, 100.0, 0.5, &mut rng);
        let dipped = (0..200_000)
            .filter(|&i| tr.at(i as f64) < 1.0)
            .count() as f64
            / 200_000.0;
        // Expected duty ≈ 100/(300+100) = 0.25.
        assert!((dipped - 0.25).abs() < 0.08, "duty={dipped}");
    }

    #[test]
    fn spot_has_down_periods_of_right_length() {
        let mut rng = Rng::new(3);
        let tr = AvailTrace::spot(100_000.0, 5_000.0, 120.0, &mut rng);
        let down: f64 = (0..100_000).filter(|&i| tr.is_down(i as f64)).count() as f64;
        assert!(down > 0.0, "no preemptions in 100k s at mttf 5k");
        // Each preemption is 120 s; with ~20 expected events, total down
        // time should be in the low thousands of seconds.
        assert!(down < 10_000.0, "down={down}");
    }

    #[test]
    fn overcommit_uses_given_levels() {
        let mut rng = Rng::new(11);
        let tr = AvailTrace::overcommit(50_000.0, 1_000.0, &[0.6, 0.8], &mut rng);
        for i in 0..50_000 {
            let v = tr.at(i as f64);
            assert!(v == 1.0 || v == 0.6 || v == 0.8, "v={v}");
        }
    }

    #[test]
    fn time_to_complete_full_capacity() {
        let tr = AvailTrace::constant();
        assert!((tr.time_to_complete(5.0, 3.0) - 3.0).abs() < 1e-12);
        assert_eq!(tr.time_to_complete(0.0, 0.0), 0.0);
    }

    #[test]
    fn time_to_complete_integrates_across_segments() {
        // Half capacity in [10, 20): 4s of work starting at t=8 does
        // 2 work-sec by t=10, then needs 2/0.5 = 4s more -> total 6s.
        let tr = AvailTrace::from_segments(vec![(0.0, 1.0), (10.0, 0.5), (20.0, 1.0)]);
        assert!((tr.time_to_complete(8.0, 4.0) - 6.0).abs() < 1e-12);
        // Starting inside the slow segment.
        assert!((tr.time_to_complete(10.0, 2.0) - 4.0).abs() < 1e-12);
        // Work spanning recovery: start t=18, work 3: 1 work-sec by 20
        // (2s), then 2s at full -> 4s.
        assert!((tr.time_to_complete(18.0, 3.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn time_to_complete_preemption_costs_downtime_not_division() {
        // 120s preemption at t=100; 3s of work starting at t=99 costs
        // ~1s before + ~120s down (doing ~0.12 work-sec) + remainder.
        let tr = AvailTrace::from_segments(vec![(0.0, 1.0), (100.0, DOWN_EPS), (220.0, 1.0)]);
        let dt = tr.time_to_complete(99.0, 3.0);
        assert!(dt > 120.0 && dt < 125.0, "dt={dt}");
    }

    #[test]
    fn cluster_traces_indexing() {
        let ct = ClusterTraces::constant(3);
        assert_eq!(ct.at(2, 100.0), 1.0);
    }

    #[test]
    fn spot_spec_parses_and_rejects() {
        let s = SpotSpec::parse("800:120:30").unwrap();
        assert_eq!(s.mttf_s, 800.0);
        assert_eq!(s.down_s, 120.0);
        assert_eq!(s.grace_s, 30.0);
        // Grace defaults to 0 (revoke as soon as the VM is preempted).
        assert_eq!(SpotSpec::parse("800:120").unwrap().grace_s, 0.0);
        for bad in ["", "800", "800:120:30:4", "a:b", "800:0", "0:120", "-1:5", "800:120:-1", "nan:120"] {
            assert!(SpotSpec::parse(bad).is_none(), "accepted {bad:?}");
        }
    }

    #[test]
    fn join_spec_parses_and_rejects() {
        let j = JoinSpec::parse("2@350.5").unwrap();
        assert_eq!(j.worker, 2);
        assert_eq!(j.time, 350.5);
        let l = JoinSpec::parse_list("0@10, 2@20").unwrap();
        assert_eq!(l.len(), 2);
        assert_eq!(l[1].worker, 2);
        assert!(JoinSpec::parse_list("").unwrap().is_empty());
        for bad in ["1", "@3", "1@", "x@3", "1@y", "1@-5", "1@nan", "0@1,bogus"] {
            assert!(
                JoinSpec::parse(bad).is_none() || bad.contains(','),
                "accepted {bad:?}"
            );
            assert!(JoinSpec::parse_list(bad).is_none(), "list accepted {bad:?}");
        }
    }

    #[test]
    fn membership_from_traces_applies_grace() {
        // Worker 0: 300 s outage at t=100; worker 1: 20 s blip at t=50.
        let traces = ClusterTraces {
            traces: vec![
                AvailTrace::from_segments(vec![(0.0, 1.0), (100.0, DOWN_EPS), (400.0, 1.0)]),
                AvailTrace::from_segments(vec![(0.0, 1.0), (50.0, DOWN_EPS), (70.0, 1.0)]),
            ],
        };
        let plan = MembershipPlan::from_traces(&traces, 30.0).unwrap();
        // The blip is shorter than the grace period: ridden out.
        let evs = plan.events();
        assert_eq!(evs.len(), 2, "{evs:?}");
        assert_eq!(
            evs[0],
            MembershipEvent { time: 130.0, worker: 0, kind: MembershipKind::Revoke }
        );
        assert_eq!(
            evs[1],
            MembershipEvent { time: 400.0, worker: 0, kind: MembershipKind::Join }
        );
        // Everyone starts live (first events are revokes or nothing).
        assert_eq!(plan.initial_live(2), vec![true, true]);
    }

    #[test]
    fn membership_from_traces_rejects_bad_grace() {
        let traces = ClusterTraces {
            traces: vec![AvailTrace::from_segments(vec![(0.0, 1.0)])],
        };
        for bad in [-1.0, f64::NAN, f64::INFINITY] {
            let err = MembershipPlan::from_traces(&traces, bad);
            assert!(err.is_err(), "grace {bad} should be rejected");
        }
    }

    #[test]
    fn membership_join_first_starts_absent() {
        let plan = MembershipPlan::default()
            .with_joins(&[JoinSpec { worker: 2, time: 40.0 }]);
        assert_eq!(plan.initial_live(3), vec![true, true, false]);
        assert_eq!(plan.max_worker(), Some(2));
    }

    #[test]
    fn membership_events_sorted_revoke_before_join() {
        let plan = MembershipPlan::new(vec![
            MembershipEvent { time: 10.0, worker: 1, kind: MembershipKind::Join },
            MembershipEvent { time: 10.0, worker: 1, kind: MembershipKind::Revoke },
            MembershipEvent { time: 5.0, worker: 0, kind: MembershipKind::Revoke },
        ]);
        let evs = plan.events();
        assert_eq!(evs[0].time, 5.0);
        assert_eq!(evs[1].kind, MembershipKind::Revoke);
        assert_eq!(evs[2].kind, MembershipKind::Join);
    }

    #[test]
    fn spot_cluster_is_deterministic_and_independent() {
        let a = ClusterTraces::spot_cluster(3, 50_000.0, 2_000.0, 120.0, 9);
        let b = ClusterTraces::spot_cluster(3, 50_000.0, 2_000.0, 120.0, 9);
        for w in 0..3 {
            assert_eq!(a.traces[w].segments(), b.traces[w].segments());
        }
        // Different workers draw from different forked streams.
        assert_ne!(a.traces[0].segments(), a.traces[1].segments());
    }
}
