//! Figure harnesses: one generator per evaluation artifact in the paper.
//!
//! Each function runs the experiment behind a paper figure and returns a
//! [`Table`] with the same series the paper plots, printing paper-style
//! rows.  `hbatch figure <id>` drives these; `cargo bench` wraps the
//! heavier ones.  Absolute numbers come from the simulated substrate —
//! the *shape* (who wins, by what factor, where crossovers sit) is the
//! reproduction target (DESIGN.md §4).

use crate::cluster::{
    cloud_gpu_cluster, cpu_cluster, hlevel_split, mixed_gpu_cpu_cluster,
    CapacityModel, DeviceKind, GpuModel, WorkloadProfile,
};
use crate::config::Policy;
use crate::controller::{ControllerCfg, DynamicBatcher};
use crate::metrics::RunReport;
use crate::session::{Session, SessionBuilder};
use crate::sync::SyncMode;
use crate::util::csv::Table;
use crate::util::stats::Histogram;

fn sim(
    workload: &str,
    cores: &[usize],
    policy: Policy,
    max_iters: u64,
    seed: u64,
) -> SessionBuilder {
    Session::builder()
        .model(workload)
        .workers(cpu_cluster(cores))
        .policy(policy)
        .steps(max_iters)
        .seed(seed)
}

fn run(builder: SessionBuilder) -> RunReport {
    builder.build_sim().expect("figure config").run().expect("figure run")
}

/// Run many independent seeded simulations concurrently, returning
/// reports in input order.
///
/// Every figure sweep is embarrassingly parallel — each builder carries
/// its own seed and the simulator holds no shared state — so results
/// are identical to a sequential loop no matter how the pool interleaves
/// them; only the wall-clock drops.  Dispatches through
/// [`crate::fleet::run_uncontended`]: an uncontended fleet whose
/// capacity equals total demand, so the arbiter never intervenes and
/// the jobs fan out on the process-wide worker pool
/// ([`crate::util::pool::global`]) with a slot-ordered gather — each
/// task writes its own preallocated slot, so gathering is
/// deterministic by construction.
pub fn run_batch(builders: Vec<SessionBuilder>) -> Vec<RunReport> {
    crate::fleet::run_uncontended(builders)
}

/// Figures that measure *time-to-accuracy* run to each workload's full
/// iteration target (virtual time is cheap), so readjustment costs
/// amortize exactly as on the paper's testbed. `0` = run to target.
pub const TO_TARGET: u64 = 0;

// =====================================================================
// Fig. 1 — heterogeneity-induced slowdown under uniform batching

/// Training-time increase of a heterogeneous cluster vs a homogeneous one
/// with the same total capacity, uniform batching, 3 workloads.
pub fn fig1(seed: u64) -> Table {
    const WORKLOADS: [&str; 3] = ["resnet", "mnist", "linreg"];
    const HLEVELS: [f64; 3] = [2.0, 6.0, 10.0];
    let mut builders = Vec::new();
    for workload in WORKLOADS {
        builders.push(sim(workload, &[13, 13, 13], Policy::Uniform, TO_TARGET, seed));
        for &h in &HLEVELS {
            let cores = hlevel_split(39, 3, h).expect("split");
            builders.push(sim(workload, &cores, Policy::Uniform, TO_TARGET, seed));
        }
    }
    let mut reports = run_batch(builders).into_iter();
    let mut t = Table::new(&["workload", "hlevel", "slowdown_vs_homogeneous"]);
    for workload in WORKLOADS {
        let homo = reports.next().expect("homogeneous baseline");
        for &h in &HLEVELS {
            let hetero = reports.next().expect("hetero run");
            let slowdown = hetero.total_time / homo.total_time;
            t.rowf(&[&workload, &h, &format!("{slowdown:.2}")]);
        }
    }
    t
}

// =====================================================================
// Fig. 2 — per-worker timeline, uniform vs variable (concept figure)

/// Two workers with 1:3 capacity; emit per-iteration start/stop times so
/// the "no worker waits" effect is visible as a timeline.
pub fn fig2(seed: u64) -> Table {
    let mut t = Table::new(&[
        "policy", "worker", "iter", "start_s", "duration_s", "wait_s",
    ]);
    for policy in [Policy::Uniform, Policy::Static] {
        let r = run(sim("mnist", &[4, 12], policy, 6, seed));
        for rec in &r.iters {
            t.rowf(&[
                &policy.label(),
                &rec.worker,
                &rec.iter,
                &format!("{:.3}", rec.start),
                &format!("{:.3}", rec.duration),
                &format!("{:.3}", rec.wait),
            ]);
        }
    }
    t
}

// =====================================================================
// Fig. 3 — iteration-time frequency distributions

/// (3, 5, 12)-core workers, ResNet BSP: histogram of per-worker iteration
/// times under uniform vs variable batching.
pub fn fig3(seed: u64) -> (Table, Vec<f64>) {
    let mut t = Table::new(&["policy", "worker", "bin_center_s", "freq"]);
    let mut cvs = Vec::new();
    let policies = [Policy::Uniform, Policy::Static];
    let reports = run_batch(
        policies
            .iter()
            .map(|&p| sim("resnet", &[3, 5, 12], p, 500, seed))
            .collect(),
    );
    for (policy, r) in policies.iter().zip(reports) {
        // Common range across workers for comparable bins.
        let all: Vec<f64> = r.iters.iter().map(|i| i.duration).collect();
        let lo = all.iter().cloned().fold(f64::MAX, f64::min) * 0.9;
        let hi = all.iter().cloned().fold(f64::MIN, f64::max) * 1.1;
        let mut spread = crate::util::stats::Running::new();
        for w in 0..3 {
            let mut h = Histogram::new(lo, hi, 30);
            for d in r.worker_durations(w) {
                h.push(d);
            }
            for (center, freq) in h.freqs() {
                if freq > 0.0 {
                    t.rowf(&[
                        &policy.label(),
                        &w,
                        &format!("{center:.3}"),
                        &format!("{freq:.4}"),
                    ]);
                }
            }
            spread.push(r.worker_time_stats(3)[w].mean());
        }
        cvs.push(spread.cv());
    }
    (t, cvs)
}

// =====================================================================
// Fig. 4 — controller dynamics

/// 4a: batch-size trajectory from a uniform start on (3, 5, 12)-core
/// workers — converges within ~2 adjustments.
/// 4b: the same with dead-banding disabled — oscillates.
pub fn fig4(deadband_on: bool, seed: u64) -> Table {
    let mut t = Table::new(&["adjustment", "worker0_b", "worker1_b", "worker2_b"]);
    let model = CapacityModel::new(WorkloadProfile::resnet()).with_noise(0.04);
    let devices = [
        DeviceKind::Cpu { cores: 3 },
        DeviceKind::Cpu { cores: 5 },
        DeviceKind::Cpu { cores: 12 },
    ];
    let cfg = ControllerCfg {
        deadband: if deadband_on { 0.05 } else { 0.0 },
        min_obs: 5,
        backoff: false, // Fig. 4 isolates the paper's dead-band mechanism
        ..ControllerCfg::default()
    };
    // Uniform (sub-optimal) start, as in the paper's Fig. 4a.
    let mut ctl = DynamicBatcher::new(cfg, &[64.0, 64.0, 64.0]);
    let mut rng = crate::util::rng::Rng::new(seed);
    // Per-iteration batch reads reuse one scratch allocation
    // (DynamicBatcher::batches_into) — this loop runs every simulated
    // round.
    let mut b = Vec::new();
    ctl.batches_into(&mut b);
    t.rowf(&[&0, &fmt(b[0]), &fmt(b[1]), &fmt(b[2])]);
    let mut n_adj = 0;
    for _iter in 0..120 {
        ctl.batches_into(&mut b);
        for (k, d) in devices.iter().enumerate() {
            ctl.observe(k, model.iter_time(d, b[k].max(1.0), 1.0, &mut rng));
        }
        if let crate::controller::Adjustment::Apply(nb) = ctl.maybe_adjust() {
            n_adj += 1;
            t.rowf(&[&n_adj, &fmt(nb[0]), &fmt(nb[1]), &fmt(nb[2])]);
        }
    }
    t
}

fn fmt(x: f64) -> String {
    format!("{x:.1}")
}

// =====================================================================
// Fig. 5 — throughput vs batch size

/// Throughput (samples/s) as batch grows, GPU (P100, ResNet) and CPU
/// (16-core, MNIST): rises, then a sharp GPU cliff / gradual CPU decline.
pub fn fig5() -> Table {
    let mut t = Table::new(&["device", "batch", "throughput_sps"]);
    let gm = CapacityModel::new(WorkloadProfile::resnet()).with_noise(0.0);
    let gpu = DeviceKind::Gpu {
        model: GpuModel::P100,
    };
    let cm = CapacityModel::new(WorkloadProfile::mnist()).with_noise(0.0);
    let cpu = DeviceKind::Cpu { cores: 16 };
    let mut b = 1.0;
    while b <= 4096.0 {
        t.rowf(&[&"P100/resnet", &b, &format!("{:.1}", gm.throughput(&gpu, b))]);
        t.rowf(&[&"cpu16/mnist", &b, &format!("{:.1}", cm.throughput(&cpu, b))]);
        b *= 2.0;
    }
    t
}

// =====================================================================
// Fig. 6 — BSP time-to-accuracy vs H-level (the headline result)

/// For each workload and H-level ∈ {1,2,4,6,8,10}: total training time
/// under uniform vs variable batching, 3 workers, 39 total cores.
pub fn fig6(seed: u64) -> Table {
    let mut t = Table::new(&[
        "workload",
        "hlevel",
        "cores",
        "uniform_s",
        "variable_s",
        "speedup",
    ]);
    // The headline sweep: 3 workloads × 6 H-levels × 2 policies = 36
    // independent to-target runs, fanned out over the worker pool.
    const WORKLOADS: [&str; 3] = ["resnet", "mnist", "linreg"];
    let mut builders = Vec::new();
    for workload in WORKLOADS {
        for &h in &crate::cluster::hlevel::PAPER_HLEVELS {
            let cores = hlevel_split(39, 3, h).expect("split");
            builders.push(sim(workload, &cores, Policy::Uniform, TO_TARGET, seed));
            builders.push(sim(workload, &cores, Policy::Static, TO_TARGET, seed));
        }
    }
    let mut reports = run_batch(builders).into_iter();
    for workload in WORKLOADS {
        for &h in &crate::cluster::hlevel::PAPER_HLEVELS {
            let cores = hlevel_split(39, 3, h).expect("split");
            let u = reports.next().expect("uniform run");
            let v = reports.next().expect("variable run");
            t.rowf(&[
                &workload,
                &h,
                &format!("{cores:?}"),
                &format!("{:.0}", u.total_time),
                &format!("{:.0}", v.total_time),
                &format!("{:.2}", u.total_time / v.total_time),
            ]);
        }
    }
    t
}

// =====================================================================
// Fig. 7a — mixed GPU+CPU cluster

/// P100 + 48-core Xeon: uniform vs static-variable vs dynamic batching,
/// ResNet and MNIST.
pub fn fig7a(seed: u64) -> Table {
    let mut t = Table::new(&["workload", "policy", "time_s", "speedup_vs_uniform"]);
    const WORKLOADS: [&str; 2] = ["resnet", "mnist"];
    const POLICIES: [Policy; 3] = [Policy::Uniform, Policy::Static, Policy::Dynamic];
    let mut builders = Vec::new();
    for workload in WORKLOADS {
        for policy in POLICIES {
            builders.push(
                Session::builder()
                    .model(workload)
                    .workers(mixed_gpu_cpu_cluster())
                    .policy(policy)
                    .steps(TO_TARGET)
                    .seed(seed)
                    .adjust_cost(20.0),
            );
        }
    }
    let mut reports = run_batch(builders).into_iter();
    for workload in WORKLOADS {
        let mut base = 0.0;
        for policy in POLICIES {
            let r = reports.next().expect("fig7a run");
            if policy == Policy::Uniform {
                base = r.total_time;
            }
            t.rowf(&[
                &workload,
                &policy.label(),
                &format!("{:.0}", r.total_time),
                &format!("{:.2}", base / r.total_time),
            ]);
        }
    }
    t
}

/// Fig. 7b / in-text cloud result: 2×T4 + 2×P4, ResNet BSP.
/// Paper: 90 min uniform → 20 min variable (4.5×).
pub fn fig7_cloud(seed: u64) -> Table {
    let mut t = Table::new(&["policy", "time_s", "speedup_vs_uniform"]);
    let policies = [Policy::Uniform, Policy::Static];
    let reports = run_batch(
        policies
            .iter()
            .map(|&policy| {
                Session::builder()
                    .model("resnet")
                    .workers(cloud_gpu_cluster())
                    .policy(policy)
                    .steps(TO_TARGET)
                    .seed(seed)
            })
            .collect(),
    );
    let mut base = 0.0;
    for (policy, r) in policies.iter().zip(reports) {
        if *policy == Policy::Uniform {
            base = r.total_time;
        }
        t.rowf(&[
            &policy.label(),
            &format!("{:.0}", r.total_time),
            &format!("{:.2}", base / r.total_time),
        ]);
    }
    t
}

// =====================================================================
// §III-B — ASP staleness amelioration (secondary claim)

/// ASP on a heterogeneous cluster: uniform vs variable batching — variable
/// reduces staleness-induced extra updates, "albeit not as effectively as
/// BSP".
pub fn fig_asp(seed: u64) -> Table {
    let mut t = Table::new(&["sync", "policy", "time_s", "updates", "speedup"]);
    const SYNCS: [SyncMode; 2] = [SyncMode::Bsp, SyncMode::Asp];
    const POLICIES: [Policy; 2] = [Policy::Uniform, Policy::Static];
    let mut builders = Vec::new();
    for sync in SYNCS {
        for policy in POLICIES {
            // Run to a shrunk accuracy target so the sweep stays fast.
            builders.push(
                sim("mnist", &[3, 16, 20], policy, 0, seed)
                    .sync(sync)
                    .target_iters(2_000),
            );
        }
    }
    let mut reports = run_batch(builders).into_iter();
    for sync in SYNCS {
        let mut base = 0.0;
        for policy in POLICIES {
            let r = reports.next().expect("asp run");
            if policy == Policy::Uniform {
                base = r.total_time;
            }
            t.rowf(&[
                &sync.label(),
                &policy.label(),
                &format!("{:.0}", r.total_time),
                &r.total_iters,
                &format!("{:.2}", base / r.total_time),
            ]);
        }
    }
    t
}

// =====================================================================
// Ablation — bucket-grid coarseness (ours; DESIGN.md §6)

/// Dynamic policy with batch proposals quantized to bucket grids of
/// different coarseness: measures the cost of the static-shape constraint.
pub fn fig_buckets(seed: u64) -> Table {
    use crate::controller::bucket::quantize;
    let mut t = Table::new(&["grid", "time_s", "slowdown_vs_continuous"]);
    let grids: [(&str, Option<Vec<usize>>); 4] = [
        ("continuous", None),
        ("pow2", Some(vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512])),
        (
            "pow2+mids",
            Some(vec![
                1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512,
            ]),
        ),
        ("coarse", Some(vec![16, 64, 256])),
    ];
    let mut base = 0.0;
    for (name, grid) in grids {
        // Simulate with the grid applied through a wrapper controller.
        let builder = sim("resnet", &[3, 12, 24], Policy::Dynamic, 2_000, seed);
        // Approximate grid effect: quantize the static initial allocation
        // and disable further adjustment for coarse grids via deadband.
        let r = if let Some(g) = grid {
            // Custom run: quantize controller outputs each adjustment.
            let mut report = run(builder.controller(ControllerCfg {
                deadband: 0.05,
                ..ControllerCfg::default()
            }));
            // Post-hoc: apply quantization error as extra imbalance.
            let err: f64 = report
                .final_batches()
                .map(|bs| {
                    bs.iter()
                        .map(|&b| {
                            let q = quantize(b, &g) as f64;
                            ((q - b) / b).abs()
                        })
                        .fold(0.0, f64::max)
                })
                .unwrap_or(0.0);
            report.total_time *= 1.0 + err;
            report
        } else {
            run(builder)
        };
        if base == 0.0 {
            base = r.total_time;
        }
        t.rowf(&[
            &name,
            &format!("{:.0}", r.total_time),
            &format!("{:.3}", r.total_time / base),
        ]);
    }
    t
}

// =====================================================================
// Revocation timeline — elastic membership under spot churn

/// Timeline of one spot revocation + rejoin on a 3-worker dynamic BSP
/// session: every membership epoch and every controller adjustment as a
/// row, with the live count and per-worker batch allocation after each.
/// Shows the mechanism end to end: mass water-fills onto survivors at
/// the revocation, and the rejoiner comes back warm-started from the
/// controller's throughput estimates.
pub fn fig_revocation(seed: u64) -> Table {
    use crate::trace::{AvailTrace, ClusterTraces, MembershipPlan, DOWN_EPS};
    // Worker 0 is preempted at t=120 s for 240 s; 20 s grace.
    let traces = ClusterTraces {
        traces: vec![
            AvailTrace::from_segments(vec![(0.0, 1.0), (120.0, DOWN_EPS), (360.0, 1.0)]),
            AvailTrace::constant(),
            AvailTrace::constant(),
        ],
    };
    let plan = MembershipPlan::from_traces(&traces, 20.0).unwrap();
    let r = run(sim("resnet", &[9, 12, 18], Policy::Dynamic, 200, seed)
        .adjust_cost(5.0)
        .traces(traces)
        .membership(plan));
    let mut t = Table::new(&["time_s", "event", "worker", "live", "b0", "b1", "b2"]);
    // Merge epochs and adjustments into one time-ordered timeline.
    let mut rows: Vec<(f64, String, String, usize, Vec<f64>)> = Vec::new();
    for e in &r.epochs {
        rows.push((
            e.time,
            e.kind.label().to_string(),
            e.worker.to_string(),
            e.live,
            e.batches.clone(),
        ));
    }
    let live_at = |time: f64| -> usize {
        r.epochs
            .iter()
            .filter(|e| e.time <= time)
            .last()
            .map(|e| e.live)
            .unwrap_or(3)
    };
    for a in &r.adjustments {
        rows.push((
            a.time,
            "adjust".into(),
            "-".into(),
            live_at(a.time),
            a.batches.clone(),
        ));
    }
    rows.sort_by(|x, y| x.0.total_cmp(&y.0));
    for (time, event, worker, live, b) in rows {
        t.rowf(&[
            &format!("{time:.1}"),
            &event,
            &worker,
            &live,
            &format!("{:.1}", b[0]),
            &format!("{:.1}", b[1]),
            &format!("{:.1}", b[2]),
        ]);
    }
    t
}

// =====================================================================
// Policy head-to-head — PID vs one-shot optimal vs tabular RL (§14)

/// Convergence time and adjustment count for the three closed-loop
/// policies across static heterogeneity levels and a spot-churn
/// scenario.  The one-shot optimal policy should reach the equalizing
/// allocation with fewer adjustments than the PID controller's
/// geometric approach; the RL policy trades a few extra moves for
/// model-free operation.
pub fn fig_policies(seed: u64) -> Table {
    use crate::trace::SpotSpec;
    const POLICIES: [Policy; 3] = [Policy::Dynamic, Policy::Optimal, Policy::Rl];
    const STATIC: [(&str, [usize; 3]); 3] =
        [("1x", [12, 12, 12]), ("2x", [8, 12, 16]), ("4x", [4, 8, 16])];
    let mut builders = Vec::new();
    for (_, cores) in STATIC {
        for policy in POLICIES {
            builders.push(sim("resnet", &cores, policy, TO_TARGET, seed));
        }
    }
    // Churn: spot revocations force mid-run rebalances on every policy.
    for policy in POLICIES {
        builders.push(
            sim("resnet", &[9, 12, 18], policy, 400, seed).spot(SpotSpec {
                mttf_s: 4_000.0,
                down_s: 200.0,
                grace_s: 20.0,
            }),
        );
    }
    let mut reports = run_batch(builders).into_iter();
    let mut t = Table::new(&[
        "scenario", "policy", "total_time_s", "adjustments", "time_vs_dynamic",
    ]);
    let names: [&str; 4] = [STATIC[0].0, STATIC[1].0, STATIC[2].0, "churn"];
    for scenario in names {
        let rs: Vec<RunReport> = POLICIES
            .iter()
            .map(|_| reports.next().expect("policy run"))
            .collect();
        let base = rs[0].total_time;
        for (policy, r) in POLICIES.iter().zip(&rs) {
            t.rowf(&[
                &scenario,
                &policy.label(),
                &format!("{:.0}", r.total_time),
                &r.adjustments.len(),
                &format!("{:.3}", r.total_time / base),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_batch_matches_sequential_in_order() {
        // The pooled sweep driver must be a pure wall-clock optimization:
        // same reports, same order, regardless of pool interleaving.
        let builders: Vec<_> = (0..5)
            .map(|i| sim("mnist", &[4, 8, 16], Policy::Dynamic, 60, i as u64))
            .collect();
        let seq: Vec<(f64, u64, usize)> = builders
            .iter()
            .map(|b| {
                let r = run(b.clone());
                (r.total_time, r.total_iters, r.adjustments.len())
            })
            .collect();
        let par: Vec<(f64, u64, usize)> = run_batch(builders)
            .iter()
            .map(|r| (r.total_time, r.total_iters, r.adjustments.len()))
            .collect();
        assert_eq!(seq, par);
    }

    #[test]
    fn fig_policies_covers_all_policies_and_scenarios() {
        let t = fig_policies(3);
        assert_eq!(t.len(), 12); // (3 static + churn) × 3 policies
        let text = t.to_string();
        for needle in ["dynamic", "optimal", "rl", "churn,"] {
            assert!(text.contains(needle), "missing {needle}");
        }
        // The dynamic baseline rows normalize to exactly 1.000.
        assert!(text
            .lines()
            .filter(|l| l.contains(",dynamic,"))
            .all(|l| l.ends_with("1.000")));
    }

    #[test]
    fn fig1_shows_hetero_penalty_ordering() {
        let t = fig1(1);
        assert_eq!(t.len(), 9);
        let text = t.to_string();
        // ResNet at H=10 must show a substantial slowdown (>1.5x).
        let resnet_h10: f64 = text
            .lines()
            .find(|l| l.starts_with("resnet,10"))
            .unwrap()
            .split(',')
            .nth(2)
            .unwrap()
            .parse()
            .unwrap();
        assert!(resnet_h10 > 1.5, "resnet h10 slowdown {resnet_h10}");
        // LinReg is comm-bound: its penalty must be the smallest of the
        // three at H=10.
        let lr_h10: f64 = text
            .lines()
            .find(|l| l.starts_with("linreg,10"))
            .unwrap()
            .split(',')
            .nth(2)
            .unwrap()
            .parse()
            .unwrap();
        assert!(lr_h10 < resnet_h10);
    }

    #[test]
    fn fig3_variable_shrinks_cross_worker_spread() {
        let (_, cvs) = fig3(2);
        // CV of worker mean iteration times: uniform >> variable.
        assert!(cvs[0] > 3.0 * cvs[1], "uniform cv {} vs variable {}", cvs[0], cvs[1]);
    }

    #[test]
    fn fig4a_converges_in_few_adjustments() {
        let t = fig4(true, 3);
        // Initial row + at most ~4 adjustments (paper: 2).
        assert!(t.len() >= 2 && t.len() <= 6, "rows={}", t.len());
    }

    #[test]
    fn fig4b_oscillates_without_deadband() {
        let with_db = fig4(true, 3).len();
        let without = fig4(false, 3).len();
        assert!(without > 3 * with_db, "with={with_db} without={without}");
    }

    #[test]
    fn fig5_shapes() {
        let t = fig5();
        let text = t.to_string();
        let gpu: Vec<f64> = text
            .lines()
            .filter(|l| l.starts_with("P100"))
            .map(|l| l.split(',').nth(2).unwrap().parse().unwrap())
            .collect();
        let peak = gpu.iter().cloned().fold(f64::MIN, f64::max);
        let peak_idx = gpu.iter().position(|&x| x == peak).unwrap();
        assert!(peak_idx > 2, "peak too early");
        assert!(*gpu.last().unwrap() < peak * 0.5, "no GPU cliff");
    }

    #[test]
    fn fig_revocation_has_revoke_and_rejoin_rows() {
        let t = fig_revocation(1);
        let text = t.to_string();
        let revoke = text.lines().find(|l| l.contains(",revoke,"));
        let join = text.lines().find(|l| l.contains(",join,"));
        assert!(revoke.is_some(), "no revoke row:\n{text}");
        assert!(join.is_some(), "no join row:\n{text}");
        // The revoke row zeroes worker 0's batch and keeps Σb on the
        // survivors; the join row restores a positive share.
        let cells = |l: &str| -> Vec<String> {
            l.split(',').map(|s| s.to_string()).collect()
        };
        let rv = cells(revoke.unwrap());
        assert_eq!(rv[2], "0");
        assert_eq!(rv[3], "2");
        assert_eq!(rv[4], "0.0");
        let jn = cells(join.unwrap());
        assert_eq!(jn[3], "3");
        assert!(jn[4].parse::<f64>().unwrap() > 0.0);
    }

    #[test]
    fn fig7a_resnet_speedup_near_paper() {
        let t = fig7a(4);
        let text = t.to_string();
        let static_speedup: f64 = text
            .lines()
            .find(|l| l.starts_with("resnet,static"))
            .unwrap()
            .split(',')
            .nth(3)
            .unwrap()
            .parse()
            .unwrap();
        // Paper: "more than 4x". Our calibrated substrate reaches ~2-3x
        // for the open-loop static policy (see EXPERIMENTS.md §Fig7 for
        // the calibration analysis); require the qualitative win.
        assert!(
            static_speedup > 1.5 && static_speedup < 8.0,
            "speedup={static_speedup}"
        );
        let dynamic_speedup: f64 = text
            .lines()
            .find(|l| l.starts_with("resnet,dynamic"))
            .unwrap()
            .split(',')
            .nth(3)
            .unwrap()
            .parse()
            .unwrap();
        // Closed-loop must not be materially worse than open-loop once
        // adjustment costs amortize over the full run.
        assert!(
            dynamic_speedup > 0.8 * static_speedup,
            "dynamic={dynamic_speedup} static={static_speedup}"
        );
    }
}
