//! Streaming statistics: EWMA (the controller's "integrator" component,
//! paper §III-C), Welford mean/variance, histograms and percentiles.

/// Exponentially weighted moving average.
///
/// The paper smooths per-worker iteration times with an EWMA computed over
/// all iterations since the previous batch readjustment; `reset()` is
/// called at each readjustment so outliers inside one control interval
/// cannot trigger spurious updates.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
    count: usize,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "alpha must be in [0,1]");
        Ewma {
            alpha,
            value: None,
            count: 0,
        }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        self.count += 1;
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn reset(&mut self) {
        self.value = None;
        self.count = 0;
    }

    /// Snapshot `(value, count)` for checkpointing (alpha is config, not
    /// state — the restorer already knows it).
    pub fn state(&self) -> (Option<f64>, usize) {
        (self.value, self.count)
    }

    /// Restore a snapshot taken with [`Ewma::state`].
    pub fn set_state(&mut self, value: Option<f64>, count: usize) {
        self.value = value;
        self.count = count;
    }
}

/// Welford online mean/variance.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Coefficient of variation (std/mean) — used to quantify how well
    /// variable batching equalized iteration times (paper Fig. 3).
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std() / self.mean
        }
    }
}

/// Fixed-width histogram over [lo, hi); out-of-range values clamp into the
/// edge bins. Used for the Fig. 3 iteration-time frequency distributions.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    n: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            n: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        let idx = ((x - self.lo) / w).floor() as i64;
        let idx = idx.clamp(0, self.bins.len() as i64 - 1) as usize;
        self.bins[idx] += 1;
        self.n += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    /// (bin_center, relative frequency) pairs.
    pub fn freqs(&self) -> Vec<(f64, f64)> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                (
                    self.lo + w * (i as f64 + 0.5),
                    if self.n == 0 { 0.0 } else { c as f64 / self.n as f64 },
                )
            })
            .collect()
    }
}

/// Percentile of a sample (linear interpolation, q in [0,1]).
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    assert!(!samples.is_empty());
    assert!((0.0..=1.0).contains(&q));
    samples.sort_by(f64::total_cmp);
    let pos = q * (samples.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        samples[lo]
    } else {
        let frac = pos - lo as f64;
        samples[lo] * (1.0 - frac) + samples[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_first_value_passthrough() {
        let mut e = Ewma::new(0.3);
        assert_eq!(e.get(), None);
        assert_eq!(e.push(10.0), 10.0);
        assert!((e.push(20.0) - 13.0).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges_to_constant() {
        let mut e = Ewma::new(0.2);
        for _ in 0..200 {
            e.push(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_reset() {
        let mut e = Ewma::new(0.5);
        e.push(100.0);
        e.reset();
        assert_eq!(e.get(), None);
        assert_eq!(e.count(), 0);
        assert_eq!(e.push(1.0), 1.0);
    }

    #[test]
    #[should_panic]
    fn ewma_rejects_bad_alpha() {
        Ewma::new(1.5);
    }

    #[test]
    fn running_matches_closed_form() {
        let mut r = Running::new();
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.n(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
        assert!((r.cv() - r.std() / 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.6, 9.9, -5.0, 25.0] {
            h.push(x);
        }
        assert_eq!(h.bins()[0], 2); // 0.5 and clamped -5
        assert_eq!(h.bins()[1], 2);
        assert_eq!(h.bins()[9], 2); // 9.9 and clamped 25
        assert_eq!(h.n(), 6);
        let f = h.freqs();
        assert!((f[0].0 - 0.5).abs() < 1e-12);
        assert!((f.iter().map(|&(_, p)| p).sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&mut v, 0.0), 1.0);
        assert_eq!(percentile(&mut v, 1.0), 4.0);
        assert!((percentile(&mut v, 0.5) - 2.5).abs() < 1e-12);
        let mut one = vec![7.0];
        assert_eq!(percentile(&mut one, 0.9), 7.0);
    }
}
