//! Micro-bench harness (criterion is unavailable offline).
//!
//! Usage inside a `[[bench]]` target with `harness = false`:
//! ```ignore
//! let mut b = Bench::new("agg_throughput");
//! b.run("fused_4x1M", || ps::aggregate(...));
//! b.report();
//! ```
//! Measures wall time with warmup, auto-scales iteration counts toward a
//! target measurement window, and reports mean / p50 / p95 / throughput.

use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()));
        o.set("iters", Json::Num(self.iters as f64));
        o.set("mean_ns", Json::Num(self.mean_ns));
        o.set("p50_ns", Json::Num(self.p50_ns));
        o.set("p95_ns", Json::Num(self.p95_ns));
        o.set("min_ns", Json::Num(self.min_ns));
        o
    }
}

/// Bench group: run closures, collect measurements, print a table.
pub struct Bench {
    group: String,
    target: Duration,
    samples: usize,
    results: Vec<Measurement>,
    quick: bool,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // HBATCH_BENCH_QUICK=1 shrinks windows for CI-style smoke runs.
        let quick = std::env::var("HBATCH_BENCH_QUICK").is_ok();
        Bench {
            group: group.to_string(),
            target: if quick {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(400)
            },
            samples: if quick { 8 } else { 20 },
            results: Vec::new(),
            quick,
        }
    }

    pub fn with_target(mut self, target: Duration) -> Self {
        if !self.quick {
            self.target = target;
        }
        self
    }

    /// Measure `f`, which should return something to defeat DCE.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup + calibration: find iters/sample so one sample ≈ target/samples.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let per_sample = (self.target / self.samples as u32).max(Duration::from_micros(20));
        let iters = (per_sample.as_nanos() / once.as_nanos()).clamp(1, 1_000_000) as u64;

        let mut sample_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            sample_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        sample_ns.sort_by(f64::total_cmp);
        let mean = sample_ns.iter().sum::<f64>() / sample_ns.len() as f64;
        let m = Measurement {
            name: format!("{}/{}", self.group, name),
            iters: iters * self.samples as u64,
            mean_ns: mean,
            p50_ns: sample_ns[sample_ns.len() / 2],
            p95_ns: sample_ns
                [((sample_ns.len() as f64 * 0.95) as usize).min(sample_ns.len() - 1)],
            min_ns: sample_ns[0],
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    pub fn group(&self) -> &str {
        &self.group
    }

    /// Was this run in HBATCH_BENCH_QUICK smoke mode?
    pub fn is_quick(&self) -> bool {
        self.quick
    }

    /// Print the criterion-style report table.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>10}",
            "name", "mean", "p50", "p95", "iters"
        );
        for m in &self.results {
            println!(
                "{:<44} {:>12} {:>12} {:>12} {:>10}",
                m.name,
                fmt_ns(m.mean_ns),
                fmt_ns(m.p50_ns),
                fmt_ns(m.p95_ns),
                m.iters
            );
        }
    }
}

/// Machine-readable results for a whole bench suite: flat measurement
/// list plus caller-supplied derived ratios. `benches/hotpath.rs` writes
/// this to `BENCH_hotpath.json` so the ROADMAP perf trajectory has a
/// durable artifact per run.
pub fn suite_json(suite: &str, groups: &[&Bench], derived: Json) -> Json {
    let mut o = Json::obj();
    o.set("suite", Json::Str(suite.to_string()));
    o.set(
        "quick",
        Json::Bool(groups.iter().any(|b| b.is_quick())),
    );
    // Thread-count series (mt8 etc.) are clamped to this machine cap —
    // consumers need it to tell a real 8-thread run from a capped one.
    o.set(
        "available_parallelism",
        Json::Num(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1) as f64,
        ),
    );
    let results: Vec<Json> = groups
        .iter()
        .flat_map(|b| b.results().iter().map(Measurement::to_json))
        .collect();
    o.set("results", Json::Arr(results));
    o.set("derived", derived);
    o
}

/// Mean of a measurement by full name (`group/name`) across groups.
pub fn find_mean_ns(groups: &[&Bench], full_name: &str) -> Option<f64> {
    groups
        .iter()
        .flat_map(|b| b.results())
        .find(|m| m.name == full_name)
        .map(|m| m.mean_ns)
}

/// Human-format nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        std::env::set_var("HBATCH_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        let m = b
            .run("sum1k", || (0..1000u64).sum::<u64>())
            .clone();
        assert!(m.mean_ns > 0.0);
        assert!(m.p50_ns <= m.p95_ns * 1.001);
        assert!(m.min_ns <= m.mean_ns * 1.001);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn ordering_detects_obvious_costs() {
        std::env::set_var("HBATCH_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        let small = b.run("small", || (0..100u64).sum::<u64>()).mean_ns;
        let big = b.run("big", || (0..100_000u64).sum::<u64>()).mean_ns;
        assert!(big > small * 5.0, "big={big} small={small}");
    }

    #[test]
    fn suite_json_flattens_groups_and_derives() {
        std::env::set_var("HBATCH_BENCH_QUICK", "1");
        let mut a = Bench::new("g1");
        a.run("x", || 1u64 + 1);
        let mut b = Bench::new("g2");
        b.run("y", || 2u64 + 2);
        let groups = [&a, &b];
        assert!(find_mean_ns(&groups, "g1/x").is_some());
        assert!(find_mean_ns(&groups, "g1/nope").is_none());
        let mut derived = Json::obj();
        derived.set("ratio", Json::Num(2.0));
        let j = suite_json("test_suite", &groups, derived);
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("suite").as_str(), Some("test_suite"));
        assert_eq!(parsed.get("results").as_arr().unwrap().len(), 2);
        assert_eq!(
            parsed.get("results").idx(0).get("name").as_str(),
            Some("g1/x")
        );
        assert_eq!(parsed.get("derived").get("ratio").as_f64(), Some(2.0));
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }
}
