//! Minimal JSON: parser + serializer (serde/serde_json are unavailable in
//! this offline build).
//!
//! Covers the full JSON grammar (RFC 8259) minus exotic number forms we
//! don't emit; numbers are stored as `f64` with an integer fast-path in
//! the accessors.  Used for the artifact manifest (written by
//! `python/compile/aot.py`), experiment configs, and metrics dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects are ordered maps (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ------------------------------------------------------------ access

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns `Json::Null` when missing/not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Index into an array; `Json::Null` when out of bounds/not an array.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------- construction

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), val);
        }
        self
    }

    pub fn from_f64_slice(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_usize_slice(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---------------------------------------------------------- serialize

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        // JSON has no Inf/NaN; emit null (matches python json's strictness
        // being off — we never emit these on purpose).
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("d"));
        assert_eq!(v.get("a").idx(0).as_i64(), Some(1));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\é😀"));
        let v = Json::parse("\"é直接\"").unwrap();
        assert_eq!(v.as_str(), Some("é直接"));
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null},"z":-1}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_exact() {
        let v = Json::parse("123456789012").unwrap();
        assert_eq!(v.as_i64(), Some(123456789012));
        assert_eq!(v.to_string(), "123456789012");
    }

    #[test]
    fn missing_keys_are_null() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(v.get("nope").is_null());
        assert!(v.get("a").get("deeper").is_null());
        assert!(v.idx(0).is_null());
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("x", Json::Num(1.0));
        o.set("y", Json::from_f64_slice(&[1.0, 2.0]));
        assert_eq!(
            Json::parse(&o.to_string()).unwrap().get("y").idx(1).as_f64(),
            Some(2.0)
        );
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":1,"models":{"mlp":{"buckets":[8,16],
            "params":[{"name":"fc1/w","shape":[784,256]}]}}}"#;
        let v = Json::parse(src).unwrap();
        let shape: Vec<usize> = v
            .get("models")
            .get("mlp")
            .get("params")
            .idx(0)
            .get("shape")
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![784, 256]);
    }
}
