//! Tiny declarative CLI parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positionals,
//! and generates a usage string.  Typed getters parse on access with
//! defaults, so command code stays one-liner-per-option.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument parser for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    cmd: String,
    about: String,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(cmd: &str, about: &str) -> Self {
        Args {
            cmd: cmd.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare an option with a default (shown in --help).
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Parse raw args (no argv[0]). Unknown `--options` are errors.
    pub fn parse(mut self, raw: &[String]) -> Result<Args, String> {
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.usage()))?
                    .clone();
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} takes no value"));
                    }
                    self.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .ok_or_else(|| format!("--{key} needs a value"))?
                                .clone()
                        }
                    };
                    self.values.insert(key, val);
                }
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.cmd, self.about);
        let _ = writeln!(s, "options:");
        for spec in &self.specs {
            let d = match (&spec.default, spec.is_flag) {
                (_, true) => "(flag)".to_string(),
                (Some(d), _) => format!("[default: {d}]"),
                _ => String::new(),
            };
            let _ = writeln!(s, "  --{:<18} {} {}", spec.name, spec.help, d);
        }
        s
    }

    fn lookup(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
            .unwrap_or_else(|| panic!("option --{name} was never declared"))
    }

    /// Was this option/flag explicitly passed on the command line (vs
    /// falling back to its declared default)?  Lets a subcommand layer
    /// CLI values over config-file values without the declared defaults
    /// silently clobbering the file's settings.
    pub fn provided(&self, name: &str) -> bool {
        self.values.contains_key(name) || self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> String {
        self.lookup(name)
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.lookup(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.lookup(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.lookup(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated list of usize, e.g. `--cores 3,5,12`.
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        let raw = self.lookup(name);
        if raw.is_empty() {
            return vec![];
        }
        raw.split(',')
            .map(|p| {
                p.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name}: bad int {p:?}"))
            })
            .collect()
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn base() -> Args {
        Args::new("test", "test command")
            .opt("steps", "100", "number of steps")
            .opt("lr", "0.1", "learning rate")
            .opt("cores", "3,5,12", "worker cores")
            .flag("verbose", "log more")
    }

    #[test]
    fn defaults_apply() {
        let a = base().parse(&raw(&[])).unwrap();
        assert_eq!(a.get_usize("steps"), 100);
        assert_eq!(a.get_f64("lr"), 0.1);
        assert!(!a.get_flag("verbose"));
        assert_eq!(a.get_usize_list("cores"), vec![3, 5, 12]);
    }

    #[test]
    fn provided_distinguishes_explicit_from_default() {
        let a = base().parse(&raw(&["--steps", "7", "--verbose"])).unwrap();
        assert!(a.provided("steps"));
        assert!(a.provided("verbose"));
        // Falls back to the default, but was never passed.
        assert!(!a.provided("lr"));
        assert_eq!(a.get_f64("lr"), 0.1);
    }

    #[test]
    fn overrides_and_forms() {
        let a = base()
            .parse(&raw(&["--steps", "7", "--lr=0.5", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("steps"), 7);
        assert_eq!(a.get_f64("lr"), 0.5);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positionals(), &["pos1".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(base().parse(&raw(&["--nope", "1"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(base().parse(&raw(&["--steps"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = base().parse(&raw(&["--help"])).unwrap_err();
        assert!(err.contains("--steps"));
        assert!(err.contains("default: 100"));
    }

    #[test]
    #[should_panic]
    fn undeclared_get_panics() {
        base().parse(&raw(&[])).unwrap().get("never");
    }
}
