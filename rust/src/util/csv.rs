//! CSV writer for figure outputs (each figure harness dumps the series it
//! prints, so plots can be regenerated outside this repo).

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// In-memory CSV table with RFC-4180 quoting.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: row of display-ables.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&v);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for r in &self.rows {
            write_record(&mut out, r);
        }
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        // Atomic so a kill mid-save never leaves a torn figure CSV.
        crate::util::fs::atomic_write(path.as_ref(), self.to_string().as_bytes())
    }
}

fn write_record(out: &mut String, cells: &[String]) {
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if c.contains(',') || c.contains('"') || c.contains('\n') {
            let _ = write!(out, "\"{}\"", c.replace('"', "\"\""));
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_table() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.rowf(&[&3.5, &"x"]);
        assert_eq!(t.to_string(), "a,b\n1,2\n3.5,x\n");
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn quoting() {
        let mut t = Table::new(&["v"]);
        t.row(&["has,comma".into()]);
        t.row(&["has\"quote".into()]);
        assert_eq!(t.to_string(), "v\n\"has,comma\"\n\"has\"\"quote\"\n");
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn save_creates_dirs() {
        let dir = std::env::temp_dir().join("hbatch_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut t = Table::new(&["x"]);
        t.row(&["1".into()]);
        let path = dir.join("nested/out.csv");
        t.save(&path).unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "x\n1\n");
    }
}
