//! Crash-safe file writes.
//!
//! Every durable artifact this project emits — bench JSON, golden
//! scenarios, figure CSVs, run reports, checkpoints — goes through
//! [`atomic_write`]: write to a temp file in the destination directory,
//! fsync it, then rename over the target.  A kill at any point leaves
//! either the old bytes or the new bytes, never a torn file.

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::Path;

/// Atomically replace `path` with `bytes`.
///
/// The temp file lives in `path`'s parent directory so the final
/// `rename` stays within one filesystem (cross-device renames are not
/// atomic).  The temp name is keyed on the process id, so concurrent
/// writers in different processes never collide on the staging file;
/// concurrent writers of the *same* target race benignly (last rename
/// wins, both outcomes are complete files).
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let name = path
        .file_name()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "path has no file name"))?;
    let tmp = dir.join(format!(
        ".{}.tmp.{}",
        name.to_string_lossy(),
        std::process::id()
    ));
    let mut f = File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    if let Err(e) = fs::rename(&tmp, path) {
        let _ = fs::remove_file(&tmp);
        return Err(e);
    }
    // Persist the rename itself: fsync the containing directory.  Some
    // platforms (and some filesystems) refuse to open a directory for
    // writing — a failure here downgrades durability, not atomicity, so
    // it is deliberately ignored.
    let _ = File::open(&dir).and_then(|d| d.sync_all());
    Ok(())
}

/// [`atomic_write`] for string content with a panic on failure — the
/// drop-in shape for the bench/figure/golden emitters that previously
/// used `std::fs::write(..).expect(..)`.
pub fn atomic_write_str(path: &Path, content: &str) {
    atomic_write(path, content.as_bytes())
        .unwrap_or_else(|e| panic!("atomic write {}: {e}", path.display()));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_replaces() {
        let dir = std::env::temp_dir().join("hbatch_fs_test");
        let _ = fs::create_dir_all(&dir);
        let p = dir.join("out.json");
        atomic_write(&p, b"first").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second, longer payload").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"second, longer payload");
        // No staging litter left behind.
        let leftovers: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
    }

    #[test]
    fn bare_filename_targets_cwd() {
        // A relative path with no parent component must not panic.
        let name = format!("hbatch_fs_bare_{}.tmp_target", std::process::id());
        atomic_write(Path::new(&name), b"x").unwrap();
        assert_eq!(fs::read(&name).unwrap(), b"x");
        let _ = fs::remove_file(&name);
    }
}
