//! Mini property-testing harness (proptest is unavailable offline).
//!
//! [`check`] runs a property over N randomized cases drawn from a
//! generator; on failure it greedily shrinks the failing case via the
//! strategy's `shrink` and reports the minimal reproduction with its seed.
//!
//! ```ignore
//! proptest::check("conservation", 200, gen_cluster, |c| controller_conserves(c));
//! ```

use crate::util::rng::Rng;

/// A generation + shrinking strategy for `T`.
pub trait Strategy<T> {
    fn generate(&self, rng: &mut Rng) -> T;
    /// Candidate simplifications of a failing value (may be empty).
    fn shrink(&self, value: &T) -> Vec<T> {
        let _ = value;
        Vec::new()
    }
}

/// Functional strategy from a closure (no shrinking).
pub struct FnStrategy<F>(pub F);

impl<T, F: Fn(&mut Rng) -> T> Strategy<T> for FnStrategy<F> {
    fn generate(&self, rng: &mut Rng) -> T {
        (self.0)(rng)
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<T> {
    Ok { cases: usize },
    Failed { seed: u64, case: T, shrinks: usize },
}

/// Run `prop` over `cases` random inputs; panics with the (shrunk) failing
/// case. Seed comes from `HBATCH_PROPTEST_SEED` or a fixed default so CI
/// is deterministic.
pub fn check<T: std::fmt::Debug + Clone>(
    name: &str,
    cases: usize,
    strategy: impl Strategy<T>,
    prop: impl Fn(&T) -> bool,
) {
    let seed = std::env::var("HBATCH_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    match check_seeded(seed, cases, &strategy, &prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed {
            seed,
            case,
            shrinks,
        } => panic!(
            "property '{name}' failed (seed={seed}, after {shrinks} shrinks):\n{case:#?}"
        ),
    }
}

/// Like [`check`] but returns the result instead of panicking.
pub fn check_seeded<T: std::fmt::Debug + Clone>(
    seed: u64,
    cases: usize,
    strategy: &impl Strategy<T>,
    prop: &impl Fn(&T) -> bool,
) -> PropResult<T> {
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let case = strategy.generate(&mut rng);
        if !prop(&case) {
            let (min_case, shrinks) = shrink_loop(strategy, prop, case);
            return PropResult::Failed {
                seed: seed.wrapping_add(i as u64),
                case: min_case,
                shrinks,
            };
        }
    }
    PropResult::Ok { cases }
}

fn shrink_loop<T: Clone>(
    strategy: &impl Strategy<T>,
    prop: &impl Fn(&T) -> bool,
    mut failing: T,
) -> (T, usize) {
    let mut shrinks = 0;
    // Bounded greedy descent: take the first still-failing simplification.
    'outer: for _ in 0..1000 {
        for cand in strategy.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                shrinks += 1;
                continue 'outer;
            }
        }
        break;
    }
    (failing, shrinks)
}

// ---------------------------------------------------------------- common
// strategies

/// usize in [lo, hi], shrinking toward lo.
pub struct UsizeRange(pub usize, pub usize);

impl Strategy<usize> for UsizeRange {
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range_usize(self.0, self.1 + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// f64 in [lo, hi], shrinking toward lo.
pub struct F64Range(pub f64, pub f64);

impl Strategy<f64> for F64Range {
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.0, self.1)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        if *v > self.0 {
            vec![self.0, self.0 + (*v - self.0) / 2.0]
        } else {
            vec![]
        }
    }
}

/// Vec<T> with length in [min_len, max_len]; shrinks by halving length
/// then element-wise shrinking.
pub struct VecOf<S> {
    pub elem: S,
    pub min_len: usize,
    pub max_len: usize,
}

impl<T: Clone, S: Strategy<T>> Strategy<Vec<T>> for VecOf<S> {
    fn generate(&self, rng: &mut Rng) -> Vec<T> {
        let n = rng.range_usize(self.min_len, self.max_len + 1);
        (0..n).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, v: &Vec<T>) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
            let mut minus_one = v.clone();
            minus_one.pop();
            out.push(minus_one);
        }
        // Shrink one element at a time (first shrinkable element only, to
        // bound the candidate count).
        for (i, e) in v.iter().enumerate() {
            let cands = self.elem.shrink(e);
            if !cands.is_empty() {
                for c in cands {
                    let mut copy = v.clone();
                    copy[i] = c;
                    out.push(copy);
                }
                break;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_ok() {
        let r = check_seeded(1, 500, &UsizeRange(1, 100), &|&x| x >= 1 && x <= 100);
        assert!(matches!(r, PropResult::Ok { cases: 500 }));
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        // Property "x < 17" fails for x >= 17; minimal failing case is 17.
        let r = check_seeded(1, 500, &UsizeRange(0, 1000), &|&x| x < 17);
        match r {
            PropResult::Failed { case, .. } => assert_eq!(case, 17),
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn vec_strategy_respects_bounds_and_shrinks() {
        let strat = VecOf {
            elem: UsizeRange(0, 9),
            min_len: 2,
            max_len: 6,
        };
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let v = strat.generate(&mut rng);
            assert!((2..=6).contains(&v.len()));
            assert!(v.iter().all(|&x| x <= 9));
        }
        // Property: "sum < 20". Shrinker should find a small failing vec.
        let r = check_seeded(3, 500, &strat, &|v: &Vec<usize>| {
            v.iter().sum::<usize>() < 20
        });
        match r {
            PropResult::Failed { case, .. } => {
                assert!(case.iter().sum::<usize>() >= 20);
                assert!(case.len() <= 4, "shrunk case still long: {case:?}");
            }
            _ => panic!("expected failure"),
        }
    }

    #[test]
    #[should_panic(expected = "property")]
    fn check_panics_with_context() {
        check("always-false", 10, UsizeRange(0, 10), |_| false);
    }

    #[test]
    fn f64_range_generates_in_bounds() {
        let mut rng = Rng::new(5);
        let s = F64Range(0.5, 2.0);
        for _ in 0..1000 {
            let x = s.generate(&mut rng);
            assert!((0.5..2.0).contains(&x));
        }
    }
}
