//! Persistent worker thread pool (§Perf iteration 4).
//!
//! The PS hot path runs a memory-bound pass over parameter-sized vectors
//! every iteration. Spawning OS threads per call (as the seed's
//! `aggregate_into_mt` did via `std::thread::scope`) costs tens of
//! microseconds of clone/teardown per thread per iteration — comparable
//! to the pass itself for mid-sized models. This pool keeps long-lived
//! workers parked on a condvar-backed queue and gives the hot path three
//! dispatch shapes:
//!
//! - [`ThreadPool::run_sharded`]: split one `&mut [T]` into disjoint
//!   contiguous shards and run a kernel on each — the shape of
//!   λ-aggregation and the sharded fused optimizer kernels.
//! - [`ThreadPool::run_tasks`]: a scoped fork-join over arbitrary
//!   borrowing closures (used when several parallel `&mut` slices —
//!   params + optimizer state — must be sharded together).
//! - [`ThreadPool::run_collect`]: fork-join over value-returning tasks,
//!   results gathered in task order — the deterministic-gather shape of
//!   the figure sweep driver (`figures::run_batch`).
//! - [`ThreadPool::submit`]: fire one task and get a [`JobHandle`] to
//!   join later — the engine's batch-prefetch pipelining.
//!
//! The `run_*` entry points are *scoped*: they block until every
//! dispatched task has finished, so borrows captured by tasks cannot
//! expire first — that guarantee is what makes the internal lifetime
//! erasure ([`erase`]) sound. [`ThreadPool::submit`] offers the same
//! join via [`JobHandle`] but cannot stop safe code from leaking the
//! handle, so it is an `unsafe fn` with that contract.
//!
//! Tasks must not dispatch onto the same pool they run on (the workers
//! they would wait for may be occupied by their parents — deadlock).

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// A dispatched task, lifetime-erased (see [`erase`]).
type Task = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Task),
    Shutdown,
}

/// Unbounded MPMC queue: `Mutex<VecDeque>` + condvar. mpsc's `Sender`
/// is not usable from a shared `&'static` pool on older toolchains, and
/// the hot path enqueues at most a handful of shards per pass, so the
/// single lock is nowhere near contended.
struct Queue {
    q: Mutex<VecDeque<Msg>>,
    cv: Condvar,
}

impl Queue {
    fn new() -> Self {
        Queue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
        }
    }

    fn push(&self, m: Msg) {
        self.q.lock().unwrap().push_back(m);
        self.cv.notify_one();
    }

    fn pop(&self) -> Msg {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(m) = q.pop_front() {
                return m;
            }
            q = self.cv.wait(q).unwrap();
        }
    }
}

/// Fork-join completion latch: counts dispatched tasks down to zero and
/// records whether any of them panicked.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    poisoned: AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            remaining: Mutex::new(n),
            cv: Condvar::new(),
            poisoned: AtomicBool::new(false),
        }
    }

    fn count_down(&self) {
        let mut n = self.remaining.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut n = self.remaining.lock().unwrap();
        while *n > 0 {
            n = self.cv.wait(n).unwrap();
        }
    }

    fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
    }

    fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }
}

/// Waits the latch even if the enclosing scope unwinds, so borrows held
/// by in-flight tasks stay valid until the workers are done with them.
struct WaitGuard<'l>(&'l Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// Erase a task's borrow lifetime so it can cross the worker channel.
///
/// # Safety
/// The caller must not let `'a` end before the task has finished
/// executing. Every dispatch path in this module blocks on a [`Latch`]
/// (directly, via [`WaitGuard`], or in [`JobHandle`]'s `Drop`) before
/// the borrowed data can go out of scope.
unsafe fn erase<'a>(t: Box<dyn FnOnce() + Send + 'a>) -> Task {
    std::mem::transmute(t)
}

/// Wrap a task so worker threads survive its panic; the latch records it
/// for the joining thread to re-raise.
fn instrumented(t: Task, latch: Arc<Latch>) -> Task {
    Box::new(move || {
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(t)).is_err() {
            latch.poison();
        }
        latch.count_down();
    })
}

/// Long-lived worker pool. Workers park on the queue between calls, so
/// steady-state dispatch is one lock + one condvar wake per shard.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `threads` persistent workers (at least 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let queue = Arc::new(Queue::new());
        let workers = (0..threads)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("hbatch-pool-{i}"))
                    .spawn(move || loop {
                        match q.pop() {
                            Msg::Run(task) => task(),
                            Msg::Shutdown => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { queue, workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Run every task to completion before returning (fork-join). The
    /// final task runs inline on the calling thread — with `shards ==
    /// workers + 1` nobody idles. Panics in any task are re-raised here
    /// after all tasks finish.
    pub fn run_tasks<'a>(&self, mut tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        let Some(last) = tasks.pop() else { return };
        if tasks.is_empty() {
            return last();
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        for t in tasks {
            // SAFETY: the WaitGuard below blocks (even on unwind) until
            // every dispatched task has run, so `'a` outlives them.
            let t = unsafe { erase(t) };
            self.queue.push(Msg::Run(instrumented(t, Arc::clone(&latch))));
        }
        {
            let _join = WaitGuard(&latch);
            last();
        }
        if latch.is_poisoned() {
            panic!("thread pool task panicked");
        }
    }

    /// Run every task to completion and collect the return values *in
    /// task order* (fork-join; order is independent of how the pool
    /// interleaves execution — each task writes its own preallocated
    /// slot).  Panics in any task re-raise here after all finish.
    pub fn run_collect<'a, T: Send>(
        &self,
        tasks: Vec<Box<dyn FnOnce() -> T + Send + 'a>>,
    ) -> Vec<T> {
        let mut slots: Vec<Option<T>> = Vec::new();
        slots.resize_with(tasks.len(), || None);
        {
            let wrapped: Vec<Box<dyn FnOnce() + Send + '_>> = slots
                .iter_mut()
                .zip(tasks)
                .map(|(slot, task)| {
                    Box::new(move || *slot = Some(task())) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            self.run_tasks(wrapped);
        }
        slots
            .into_iter()
            .map(|s| s.expect("pool task completed"))
            .collect()
    }

    /// Split `data` into `shards` contiguous chunks and run
    /// `f(shard_idx, global_start, shard)` on each in parallel.
    /// `shards` is clamped to `data.len()`; tasks beyond the worker
    /// count queue up (correct, just no extra parallelism).
    pub fn run_sharded<T, F>(&self, data: &mut [T], shards: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        let n = data.len();
        let shards = shards.max(1).min(n.max(1));
        if shards == 1 {
            return f(0, 0, data);
        }
        let chunk = (n + shards - 1) / shards;
        let fr = &f;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(chunk)
            .enumerate()
            .map(|(i, shard)| {
                Box::new(move || fr(i, i * chunk, shard)) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        self.run_tasks(tasks);
    }

    /// Dispatch one task; the returned handle joins it (in `wait()` or
    /// in `Drop`). Used to overlap work with the calling thread (engine
    /// batch prefetch).
    ///
    /// # Safety
    /// The caller must let the returned handle join — normally or by
    /// unwinding — before the borrows captured by `f` end. Leaking the
    /// handle (`mem::forget`, `Box::leak`, reference cycles) defeats
    /// the `Drop` join and leaves the worker executing `f` against
    /// freed borrows; that is why this is not a safe fn (the classic
    /// pre-1.0 `thread::scoped` hole). Prefer [`ThreadPool::run_tasks`]
    /// / [`ThreadPool::run_sharded`], which block before returning.
    pub unsafe fn submit<'a, F: FnOnce() + Send + 'a>(&self, f: F) -> JobHandle<'a> {
        let latch = Arc::new(Latch::new(1));
        let boxed: Box<dyn FnOnce() + Send + 'a> = Box::new(f);
        // SAFETY: the caller upholds that the handle joins before `'a`
        // ends (this fn's contract).
        let t = unsafe { erase(boxed) };
        self.queue.push(Msg::Run(instrumented(t, Arc::clone(&latch))));
        JobHandle {
            latch,
            joined: false,
            _scope: PhantomData,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            self.queue.push(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Join handle for a [`ThreadPool::submit`] task. Must complete before
/// the task's borrows end, so `Drop` blocks if `wait` was never called.
pub struct JobHandle<'a> {
    latch: Arc<Latch>,
    joined: bool,
    _scope: PhantomData<&'a mut &'a ()>,
}

impl JobHandle<'_> {
    /// Block until the task finishes; re-raises its panic, if any.
    pub fn wait(mut self) {
        self.join();
        if self.latch.is_poisoned() {
            panic!("thread pool task panicked");
        }
    }

    fn join(&mut self) {
        if !self.joined {
            self.latch.wait();
            self.joined = true;
        }
    }
}

impl Drop for JobHandle<'_> {
    fn drop(&mut self) {
        // No panic propagation here: panicking in Drop during an unwind
        // aborts. `wait()` is the loud path.
        self.join();
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool the PS hot path dispatches onto, sized to the
/// machine's available parallelism. Callers pick a *shard count* per
/// call (e.g. `SessionBuilder::pool_threads`); the worker count is fixed.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

/// Worker count for [`global`]: `available_parallelism`, min 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_tasks_executes_every_task() {
        let pool = ThreadPool::new(3);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..17)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_tasks(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 17);
    }

    #[test]
    fn run_sharded_covers_disjoint_mut_shards() {
        let pool = ThreadPool::new(4);
        let mut data: Vec<u64> = (0..10_001).collect();
        pool.run_sharded(&mut data, 4, |_, start, shard| {
            for (i, x) in shard.iter_mut().enumerate() {
                // Each element sees exactly its own global index.
                assert_eq!(*x, (start + i) as u64);
                *x *= 2;
            }
        });
        assert!(data.iter().enumerate().all(|(i, &x)| x == 2 * i as u64));
    }

    #[test]
    fn run_sharded_single_and_oversharded_edges() {
        let pool = ThreadPool::new(2);
        let mut tiny = vec![7u64; 3];
        // More shards than elements: clamped, still correct.
        pool.run_sharded(&mut tiny, 16, |_, _, s| {
            for x in s {
                *x += 1;
            }
        });
        assert_eq!(tiny, vec![8, 8, 8]);
        // Empty data degenerates to one call on the empty slice.
        let mut empty: Vec<u64> = vec![];
        pool.run_sharded(&mut empty, 4, |i, start, s| {
            assert_eq!((i, start), (0, 0));
            assert!(s.is_empty());
        });
    }

    #[test]
    fn run_collect_returns_results_in_task_order() {
        let pool = ThreadPool::new(3);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..17usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = pool.run_collect(tasks);
        assert_eq!(out, (0..17usize).map(|i| i * i).collect::<Vec<_>>());
        // Empty input degenerates cleanly.
        let none: Vec<Box<dyn FnOnce() -> usize + Send>> = Vec::new();
        assert!(pool.run_collect(none).is_empty());
    }

    #[test]
    fn pool_is_reusable_across_many_calls() {
        let pool = ThreadPool::new(2);
        let mut data = vec![0u64; 1000];
        for _ in 0..100 {
            pool.run_sharded(&mut data, 3, |_, _, s| {
                for x in s {
                    *x += 1;
                }
            });
        }
        assert!(data.iter().all(|&x| x == 100));
    }

    #[test]
    fn submit_joins_before_borrow_ends() {
        let pool = ThreadPool::new(2);
        let mut slot: Option<Vec<u32>> = None;
        {
            let slot_ref = Mutex::new(&mut slot);
            // SAFETY: the handle is waited before slot_ref drops.
            let h = unsafe {
                pool.submit(|| {
                    **slot_ref.lock().unwrap() = Some(vec![1, 2, 3]);
                })
            };
            h.wait();
        }
        assert_eq!(slot, Some(vec![1, 2, 3]));
    }

    #[test]
    fn dropped_handle_still_joins() {
        let pool = ThreadPool::new(1);
        let done = AtomicBool::new(false);
        {
            // SAFETY: the handle drops (and joins) before `done` does.
            let _h = unsafe {
                pool.submit(|| {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    done.store(true, Ordering::SeqCst);
                })
            };
            // _h dropped here without wait(): Drop must block.
        }
        assert!(done.load(Ordering::SeqCst));
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("boom")),
                Box::new(|| {}),
            ];
            pool.run_tasks(tasks);
        }));
        assert!(caught.is_err(), "panic must re-raise on the caller");
        // Workers caught the panic and are still serving.
        let mut data = vec![1u64; 100];
        pool.run_sharded(&mut data, 2, |_, _, s| {
            for x in s {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 2));
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = global() as *const ThreadPool;
        let b = global() as *const ThreadPool;
        assert_eq!(a, b);
        assert!(global().threads() >= 1);
        assert_eq!(global().threads(), default_threads());
    }
}
