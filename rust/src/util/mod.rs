//! Std-only substrates.
//!
//! This build runs fully offline with only `xla` + `anyhow` available, so
//! the usual ecosystem crates are reimplemented here at the size this
//! project needs: [`json`] (serde_json), [`rng`] (rand), [`cli`] (clap),
//! [`stats`] (streaming statistics), [`bench`] (criterion),
//! [`proptest`] (property testing), [`csv`] (csv writer),
//! [`pool`] (rayon-style scoped thread pool).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod fs;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
