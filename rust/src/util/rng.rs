//! Deterministic RNG (the `rand` crate is unavailable offline).
//!
//! [`Rng`] is PCG64 (XSL-RR 128/64) seeded via SplitMix64 — fast, small,
//! and statistically solid for simulation use.  Gaussian sampling uses the
//! Marsaglia polar method; every experiment takes an explicit seed so
//! figures regenerate bit-identically.

/// SplitMix64: used to expand a single u64 seed into PCG state.
#[derive(Debug, Clone)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG64 XSL-RR generator with Gaussian/exponential helpers.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
    gauss_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        let state = ((sm.next() as u128) << 64) | sm.next() as u128;
        let inc = (((sm.next() as u128) << 64) | sm.next() as u128) | 1;
        let mut rng = Rng {
            state: 0,
            inc,
            gauss_spare: None,
        };
        rng.state = rng.inc.wrapping_add(state);
        rng.next_u64();
        rng
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Marsaglia polar (caches the spare deviate).
    pub fn gauss(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * m);
                return u * m;
            }
        }
    }

    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Lognormal such that the *median* is `median` and sigma is the
    /// log-space std — the shape used for iteration-time noise.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (median.ln() + sigma * self.gauss()).exp()
    }

    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len() as u64) as usize]
    }

    /// Standard-normal f32 vector (for synthetic data generation).
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gauss() as f32).collect()
    }

    /// The full generator state, for checkpointing: `(state, inc,
    /// gauss_spare)`.  Restoring via [`Rng::from_parts`] resumes the
    /// stream exactly — including a cached Marsaglia spare deviate.
    pub fn state_parts(&self) -> (u128, u128, Option<f64>) {
        (self.state, self.inc, self.gauss_spare)
    }

    /// Rebuild a generator from [`Rng::state_parts`] output.
    pub fn from_parts(state: u128, inc: u128, gauss_spare: Option<f64>) -> Self {
        Rng {
            state,
            inc,
            gauss_spare,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Rng::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Rng::new(3);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.gauss();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut rng = Rng::new(4);
        let mut v: Vec<f64> = (0..50_001).map(|_| rng.lognormal(3.0, 0.5)).collect();
        v.sort_by(f64::total_cmp);
        let med = v[v.len() / 2];
        assert!((med - 3.0).abs() < 0.1, "median={med}");
        assert!(v.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn exp_mean() {
        let mut rng = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exp(0.25)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn state_parts_round_trip_resumes_stream() {
        let mut a = Rng::new(11);
        a.gauss(); // leave a spare deviate cached
        let (s, i, g) = a.state_parts();
        let mut b = Rng::from_parts(s, i, g);
        for _ in 0..10 {
            assert_eq!(a.gauss().to_bits(), b.gauss().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
