//! Synthetic datasets with learnable structure, plus per-worker sharding.
//!
//! Stand-ins for the paper's datasets (DESIGN.md §1): each generator is
//! deterministic from its seed and produces batches directly in the flat
//! layout the runtime marshals into XLA literals.
//!
//! - [`Regression`]: y = x·w* + b* + ε  (bar-crawl stand-in, 3 features).
//! - [`Classification`]: Gaussian class blobs in D dims (MNIST/CIFAR
//!   stand-ins at 784 / 32·32·3 dims).
//! - [`TokenStream`]: order-1 Markov token stream with a low-entropy
//!   transition matrix (LM stand-in — a transformer can push loss well
//!   below the unigram floor by learning the bigram structure).

use crate::util::rng::Rng;

/// One batch in flat layout.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Flattened x: len = batch * x_elem.
    pub x_f32: Vec<f32>,
    /// Token/class x for integer inputs (LM) — used instead of x_f32.
    pub x_i32: Vec<i32>,
    /// Flattened float labels (regression).
    pub y_f32: Vec<f32>,
    /// Class/token labels.
    pub y_i32: Vec<i32>,
    pub batch_size: usize,
}

/// A dataset that can produce batches of any size on demand.
pub trait Dataset: Send {
    /// Per-example x element count (f32 path) or token count (i32 path).
    fn x_elems(&self) -> usize;
    fn y_elems(&self) -> usize;
    /// Number of independent shard streams this dataset was built with
    /// (valid `shard` arguments to [`Dataset::next_batch`]).
    fn shards(&self) -> usize;
    /// Draw the next batch of `b` examples for shard `shard`.
    fn next_batch(&mut self, shard: usize, b: usize) -> Batch;
    /// The loss a perfect model would approach (monitoring floor).
    fn bayes_floor(&self) -> f64;
}

// ---------------------------------------------------------------------
// Regression

/// y = x·w* + b* + N(0, σ²), fixed ground truth from seed.
pub struct Regression {
    pub dim: usize,
    w_star: Vec<f32>,
    b_star: f32,
    noise: f64,
    rngs: Vec<Rng>,
}

impl Regression {
    pub fn new(dim: usize, shards: usize, noise: f64, seed: u64) -> Self {
        let mut root = Rng::new(seed);
        let w_star: Vec<f32> = (0..dim).map(|_| root.gauss() as f32).collect();
        let b_star = root.gauss() as f32;
        let rngs = (0..shards).map(|i| root.fork(i as u64)).collect();
        Regression {
            dim,
            w_star,
            b_star,
            noise,
            rngs,
        }
    }

    pub fn bar_crawl_standin(shards: usize, seed: u64) -> Self {
        // 3 accelerometer features, modest label noise.
        Regression::new(3, shards, 0.1, seed)
    }
}

impl Dataset for Regression {
    fn x_elems(&self) -> usize {
        self.dim
    }

    fn y_elems(&self) -> usize {
        1
    }

    fn shards(&self) -> usize {
        self.rngs.len()
    }

    fn next_batch(&mut self, shard: usize, b: usize) -> Batch {
        let rng = &mut self.rngs[shard];
        let mut x = Vec::with_capacity(b * self.dim);
        let mut y = Vec::with_capacity(b);
        for _ in 0..b {
            let mut dot = self.b_star;
            for j in 0..self.dim {
                let xi = rng.gauss() as f32;
                x.push(xi);
                dot += xi * self.w_star[j];
            }
            y.push(dot + (rng.gauss() * self.noise) as f32);
        }
        Batch {
            x_f32: x,
            x_i32: vec![],
            y_f32: y,
            y_i32: vec![],
            batch_size: b,
        }
    }

    fn bayes_floor(&self) -> f64 {
        self.noise * self.noise
    }
}

// ---------------------------------------------------------------------
// Classification

/// Gaussian blobs: class c has mean μ_c (random unit-ish vector × sep).
pub struct Classification {
    pub dim: usize,
    pub classes: usize,
    means: Vec<Vec<f32>>,
    rngs: Vec<Rng>,
}

impl Classification {
    pub fn new(dim: usize, classes: usize, sep: f64, shards: usize, seed: u64) -> Self {
        let mut root = Rng::new(seed);
        let means = (0..classes)
            .map(|_| {
                (0..dim)
                    .map(|_| (root.gauss() * sep / (dim as f64).sqrt()) as f32)
                    .collect()
            })
            .collect();
        let rngs = (0..shards).map(|i| root.fork(1000 + i as u64)).collect();
        Classification {
            dim,
            classes,
            means,
            rngs,
        }
    }

    /// 784-dim, 10-class (MNIST stand-in), well-separated.
    pub fn mnist_standin(shards: usize, seed: u64) -> Self {
        Classification::new(784, 10, 6.0, shards, seed)
    }

    /// 32·32·3-dim, 10-class (CIFAR stand-in), moderately separated.
    pub fn cifar_standin(shards: usize, seed: u64) -> Self {
        Classification::new(32 * 32 * 3, 10, 4.0, shards, seed)
    }
}

impl Dataset for Classification {
    fn x_elems(&self) -> usize {
        self.dim
    }

    fn y_elems(&self) -> usize {
        1
    }

    fn shards(&self) -> usize {
        self.rngs.len()
    }

    fn next_batch(&mut self, shard: usize, b: usize) -> Batch {
        let rng = &mut self.rngs[shard];
        let mut x = Vec::with_capacity(b * self.dim);
        let mut y = Vec::with_capacity(b);
        for _ in 0..b {
            let c = rng.below(self.classes as u64) as usize;
            y.push(c as i32);
            let mu = &self.means[c];
            for j in 0..self.dim {
                x.push(mu[j] + rng.gauss() as f32);
            }
        }
        Batch {
            x_f32: x,
            x_i32: vec![],
            y_f32: vec![],
            y_i32: y,
            batch_size: b,
        }
    }

    fn bayes_floor(&self) -> f64 {
        // Separated blobs ⇒ near-zero misclassification; CE floor ~0.
        0.02
    }
}

// ---------------------------------------------------------------------
// Token stream (LM)

/// Order-1 Markov chain over `vocab` tokens; each row of the transition
/// matrix concentrates mass on `fanout` successors, giving an entropy
/// floor ≈ ln(fanout) that a transformer can learn down to.
pub struct TokenStream {
    pub vocab: usize,
    pub seq: usize,
    fanout: usize,
    /// successors[t] = the `fanout` tokens reachable from t.
    successors: Vec<Vec<u32>>,
    states: Vec<u32>,
    rngs: Vec<Rng>,
}

impl TokenStream {
    pub fn new(vocab: usize, seq: usize, fanout: usize, shards: usize, seed: u64) -> Self {
        assert!(fanout >= 1 && fanout <= vocab);
        let mut root = Rng::new(seed);
        let successors = (0..vocab)
            .map(|_| {
                (0..fanout)
                    .map(|_| root.below(vocab as u64) as u32)
                    .collect()
            })
            .collect();
        let rngs: Vec<Rng> = (0..shards).map(|i| root.fork(2000 + i as u64)).collect();
        TokenStream {
            vocab,
            seq,
            fanout,
            successors,
            states: vec![0; shards],
            rngs,
        }
    }

    /// Entropy floor of the chain (nats/token) — uniform over successors.
    pub fn entropy_floor(&self) -> f64 {
        (self.fanout as f64).ln()
    }
}

impl Dataset for TokenStream {
    fn x_elems(&self) -> usize {
        self.seq
    }

    fn y_elems(&self) -> usize {
        self.seq
    }

    fn shards(&self) -> usize {
        self.rngs.len()
    }

    fn next_batch(&mut self, shard: usize, b: usize) -> Batch {
        let rng = &mut self.rngs[shard];
        let mut x = Vec::with_capacity(b * self.seq);
        let mut y = Vec::with_capacity(b * self.seq);
        let state = &mut self.states[shard];
        for _ in 0..b {
            // Sequence of seq+1 tokens: x = [0..seq], y = [1..seq+1].
            let mut toks = Vec::with_capacity(self.seq + 1);
            toks.push(*state);
            for i in 0..self.seq {
                let succ = &self.successors[toks[i] as usize];
                toks.push(succ[rng.below(succ.len() as u64) as usize]);
            }
            *state = *toks.last().unwrap();
            for i in 0..self.seq {
                x.push(toks[i] as i32);
                y.push(toks[i + 1] as i32);
            }
        }
        Batch {
            x_f32: vec![],
            x_i32: x,
            y_f32: vec![],
            y_i32: y,
            batch_size: b,
        }
    }

    fn bayes_floor(&self) -> f64 {
        self.entropy_floor()
    }
}

// ---------------------------------------------------------------------
// Elastic shard routing

/// Maps dataset shard streams to live workers under elastic membership.
///
/// Worker `w` starts as the owner of its home shard `w`.  When a worker
/// is revoked, its shards are handed round-robin to the survivors so the
/// departed rank's data keeps flowing; when it rejoins it reclaims its
/// home shard.  Shard *streams* are never reset or duplicated — each
/// shard's RNG lives in the [`Dataset`] and continues wherever it left
/// off — so reassignment never repeats a sample and never skips one.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    /// owner[s] = worker currently drawing shard s.
    owner: Vec<usize>,
    /// Per-worker round-robin cursor over its owned shards.
    cursor: Vec<usize>,
    live: Vec<bool>,
}

impl ShardRouter {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        ShardRouter {
            owner: (0..k).collect(),
            cursor: vec![0; k],
            live: vec![true; k],
        }
    }

    pub fn is_live(&self, w: usize) -> bool {
        self.live[w]
    }

    /// Shards currently owned by `w`, ascending.
    pub fn shards_of(&self, w: usize) -> Vec<usize> {
        (0..self.owner.len()).filter(|&s| self.owner[s] == w).collect()
    }

    /// Revoke worker `w`: its shards go round-robin to the survivors.
    /// With no survivors the shards stay parked on `w` (nobody draws).
    pub fn revoke(&mut self, w: usize) {
        assert!(self.live[w], "revoke of dead worker {w}");
        self.live[w] = false;
        let survivors: Vec<usize> =
            (0..self.live.len()).filter(|&v| self.live[v]).collect();
        if survivors.is_empty() {
            return;
        }
        for (i, s) in self.shards_of(w).into_iter().enumerate() {
            self.owner[s] = survivors[i % survivors.len()];
        }
    }

    /// Re-admit worker `w`: it reclaims exactly its home shard (the
    /// current holder keeps any others it inherited).
    pub fn admit(&mut self, w: usize) {
        assert!(!self.live[w], "admit of live worker {w}");
        self.live[w] = true;
        self.owner[w] = w;
        self.cursor[w] = 0;
    }

    /// Next shard worker `w` should draw from (round-robin over its
    /// owned shards).
    pub fn next_shard(&mut self, w: usize) -> usize {
        let owned = self.shards_of(w);
        assert!(!owned.is_empty(), "worker {w} owns no shards");
        let s = owned[self.cursor[w] % owned.len()];
        self.cursor[w] = self.cursor[w].wrapping_add(1);
        s
    }
}

/// Build the stand-in dataset for a registry model name.
pub fn for_model(name: &str, shards: usize, seed: u64) -> Box<dyn Dataset> {
    match name {
        "linreg" => Box::new(Regression::bar_crawl_standin(shards, seed)),
        "mlp" => Box::new(Classification::mnist_standin(shards, seed)),
        "cnn" => Box::new(Classification::cifar_standin(shards, seed)),
        "transformer" => Box::new(TokenStream::new(512, 64, 4, shards, seed)),
        "transformer_e2e" => Box::new(TokenStream::new(2048, 128, 4, shards, seed)),
        _ => panic!("no dataset for model {name}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regression_learnable_structure() {
        let mut d = Regression::new(3, 1, 0.0, 42);
        let b = d.next_batch(0, 1000);
        assert_eq!(b.x_f32.len(), 3000);
        assert_eq!(b.y_f32.len(), 1000);
        // With zero noise, y is an exact linear function: solve for w via
        // normal equations on 3 points and check residual of the rest.
        let w = &d.w_star;
        for i in 0..1000 {
            let pred: f32 = (0..3).map(|j| b.x_f32[i * 3 + j] * w[j]).sum::<f32>()
                + d.b_star;
            assert!((pred - b.y_f32[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn regression_deterministic_per_seed_and_shard() {
        let mut a = Regression::new(3, 2, 0.1, 7);
        let mut b = Regression::new(3, 2, 0.1, 7);
        let ba = a.next_batch(0, 16);
        let bb = b.next_batch(0, 16);
        assert_eq!(ba.x_f32, bb.x_f32);
        // Different shards → different streams.
        let b1 = a.next_batch(1, 16);
        assert_ne!(ba.x_f32, b1.x_f32);
    }

    #[test]
    fn classification_blobs_are_separable() {
        let mut d = Classification::new(16, 4, 8.0, 1, 3);
        let b = d.next_batch(0, 400);
        // Nearest-mean classification should be near-perfect at sep 8.
        let mut correct = 0;
        for i in 0..400 {
            let x = &b.x_f32[i * 16..(i + 1) * 16];
            let mut best = (f32::INFINITY, 0);
            for (c, mu) in d.means.iter().enumerate() {
                let dist: f32 = x.iter().zip(mu).map(|(a, m)| (a - m) * (a - m)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 as i32 == b.y_i32[i] {
                correct += 1;
            }
        }
        assert!(correct > 380, "only {correct}/400 separable");
    }

    #[test]
    fn class_labels_in_range() {
        let mut d = Classification::mnist_standin(1, 0);
        let b = d.next_batch(0, 64);
        assert!(b.y_i32.iter().all(|&c| (0..10).contains(&c)));
        assert_eq!(b.x_f32.len(), 64 * 784);
    }

    #[test]
    fn token_stream_follows_transitions() {
        let mut d = TokenStream::new(64, 16, 3, 1, 11);
        let b = d.next_batch(0, 8);
        assert_eq!(b.x_i32.len(), 8 * 16);
        assert_eq!(b.y_i32.len(), 8 * 16);
        // y must always be a legal successor of x.
        for i in 0..b.x_i32.len() {
            let from = b.x_i32[i] as usize;
            let to = b.y_i32[i] as u32;
            assert!(
                d.successors[from].contains(&to),
                "illegal transition {from}->{to}"
            );
        }
        // Within a sequence, x[i+1] == y[i] (stream continuity).
        for s in 0..8 {
            for i in 0..15 {
                assert_eq!(b.x_i32[s * 16 + i + 1], b.y_i32[s * 16 + i]);
            }
        }
    }

    #[test]
    fn token_entropy_floor() {
        let d = TokenStream::new(512, 64, 4, 1, 0);
        assert!((d.entropy_floor() - 4.0f64.ln()).abs() < 1e-12);
        assert!(d.entropy_floor() < (512f64).ln());
    }

    #[test]
    fn for_model_covers_registry() {
        for name in ["linreg", "mlp", "cnn", "transformer"] {
            let mut d = for_model(name, 2, 0);
            assert_eq!(d.shards(), 2);
            let b = d.next_batch(1, 4);
            assert_eq!(b.batch_size, 4);
        }
    }

    #[test]
    fn shard_router_reassigns_and_reclaims() {
        let mut r = ShardRouter::new(3);
        assert_eq!(r.shards_of(1), vec![1]);
        // Revoke worker 2: its shard goes to the first survivor.
        r.revoke(2);
        assert_eq!(r.shards_of(0), vec![0, 2]);
        assert_eq!(r.shards_of(2), vec![]);
        // Worker 0 round-robins over both owned shards.
        assert_eq!(r.next_shard(0), 0);
        assert_eq!(r.next_shard(0), 2);
        assert_eq!(r.next_shard(0), 0);
        assert_eq!(r.next_shard(1), 1);
        // Rejoin: worker 2 reclaims exactly its home shard.
        r.admit(2);
        assert_eq!(r.shards_of(2), vec![2]);
        assert_eq!(r.shards_of(0), vec![0]);
        assert_eq!(r.next_shard(2), 2);
    }

    #[test]
    fn shard_router_cascaded_revocations_cover_all_shards() {
        let mut r = ShardRouter::new(3);
        r.revoke(2); // shard 2 -> worker 0
        r.revoke(0); // shards {0, 2} -> worker 1 (only survivor)
        assert_eq!(r.shards_of(1), vec![0, 1, 2]);
        // Rejoins give each worker its home shard back.
        r.admit(0);
        assert_eq!(r.shards_of(0), vec![0]);
        assert_eq!(r.shards_of(1), vec![1, 2]);
        r.admit(2);
        assert_eq!(r.shards_of(1), vec![1]);
        assert_eq!(r.shards_of(2), vec![2]);
    }

    #[test]
    fn shard_router_revoking_everyone_parks_shards() {
        let mut r = ShardRouter::new(2);
        r.revoke(0);
        r.revoke(1);
        // Nobody draws; shards wait for a rejoin.
        r.admit(0);
        assert_eq!(r.shards_of(0), vec![0]);
        // Worker 1's home shard is still parked on the dead worker 1 —
        // reachable again the moment it rejoins.
        r.admit(1);
        assert_eq!(r.shards_of(1), vec![1]);
    }

    #[test]
    fn shard_router_initially_absent_rank_via_revoke() {
        // The Session marks ranks that start the run absent by calling
        // the backend's retire hook, which lands here as a revoke.
        let mut r = ShardRouter::new(3);
        r.revoke(1);
        assert!(!r.is_live(1));
        assert_eq!(r.shards_of(0), vec![0, 1]);
        assert_eq!(r.shards_of(2), vec![2]);
    }

    #[test]
    fn extra_shards_leave_earlier_streams_unchanged() {
        // The engine's dedicated eval shard (k) relies on this: building
        // a dataset with k+1 shards must not alter shards 0..k.
        let mut a = Regression::new(3, 2, 0.1, 7);
        let mut b = Regression::new(3, 3, 0.1, 7);
        assert_eq!(a.next_batch(1, 16).x_f32, b.next_batch(1, 16).x_f32);
        let mut a = Classification::mnist_standin(2, 9);
        let mut b = Classification::mnist_standin(3, 9);
        assert_eq!(a.next_batch(0, 8).x_f32, b.next_batch(0, 8).x_f32);
        let mut a = TokenStream::new(64, 16, 3, 2, 11);
        let mut b = TokenStream::new(64, 16, 3, 3, 11);
        assert_eq!(a.next_batch(1, 8).x_i32, b.next_batch(1, 8).x_i32);
    }
}
