//! Real-execution training engine: leader + worker threads over the PJRT
//! runtime.
//!
//! This is the "it actually trains" path: every iteration executes the
//! AOT-compiled JAX/Pallas train step with real data, the leader
//! aggregates λ-weighted gradients (paper Eq. 2–3) and applies the
//! optimizer, and the dynamic controller re-buckets per-worker batch
//! sizes from observed iteration times.
//!
//! Heterogeneity injection: all simulated workers share one physical CPU,
//! so a worker with capacity c < 1 has `compute_time·(1/c − 1)` of
//! *virtual* slowdown added to its measured compute time — preserving the
//! relative iteration-time structure a heterogeneous cluster produces
//! while keeping the numerics real. Worker compute is serialized through
//! the single PJRT stream; the controller observes the virtual durations
//! (compute + injection), exactly the signal it would see on real
//! heterogeneous hardware.

use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::{ExperimentCfg, Policy};
use crate::controller::bucket::{quantize, quantize_alloc};
use crate::controller::{static_alloc, uniform_alloc, Adjustment, DynamicBatcher};
use crate::data::{Batch, Dataset};
use crate::metrics::{AdjustEvent, EvalRecord, IterRecord, RunReport};
use crate::ps::{lambdas_from_batches, FusedOptimizer};
use crate::runtime::{Runtime, StepKind};
use crate::util::pool;

/// Per-worker slowdown factors: capacity c ⇒ sleep compute·(1/c − 1).
/// c = 1.0 means full speed (no injection).
#[derive(Debug, Clone)]
pub struct Slowdowns(pub Vec<f64>);

impl Slowdowns {
    pub fn none(k: usize) -> Self {
        Slowdowns(vec![1.0; k])
    }

    /// Capacity proportional to core counts, normalized to max = 1.
    pub fn from_cores(cores: &[usize]) -> Self {
        let max = *cores.iter().max().expect("empty cores") as f64;
        Slowdowns(cores.iter().map(|&c| c as f64 / max).collect())
    }
}

/// Options for a real-execution run.
#[derive(Debug, Clone)]
pub struct TrainOpts {
    /// Registry model name (must exist in the manifest).
    pub model: String,
    pub policy: Policy,
    pub steps: u64,
    /// Evaluate every N global steps (0 = never); results land in
    /// [`RunReport::evals`]. Evals draw from dataset shard `k` (workers
    /// use shards `0..k`), so enabling them never perturbs the training
    /// streams — build the dataset with `k + 1` shards when set.
    pub eval_every: u64,
    pub seed: u64,
    /// Shard count for the PS hot path: the leader's fused
    /// aggregate+optimizer pass runs sharded across the persistent
    /// worker pool ([`FusedOptimizer::step_mt`]). Clamped to available
    /// parallelism; 1 = single-threaded.
    pub pool_threads: usize,
    /// Overlap batch generation for worker w+1 with worker w's PJRT
    /// train step (double-buffered `Dataset::next_batch` on the pool).
    pub prefetch: bool,
    /// Stop early when train loss falls below this (0 = disabled).
    pub loss_target: f64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            model: "mlp".into(),
            policy: Policy::Dynamic,
            steps: 50,
            eval_every: 0,
            seed: 0,
            pool_threads: 4,
            prefetch: true,
            loss_target: 0.0,
        }
    }
}

/// Drives data-parallel training over the real runtime.
pub struct Engine<'rt> {
    pub runtime: &'rt mut Runtime,
    pub cfg: ExperimentCfg,
    pub opts: TrainOpts,
    pub slowdowns: Slowdowns,
}

impl<'rt> Engine<'rt> {
    pub fn new(
        runtime: &'rt mut Runtime,
        cfg: ExperimentCfg,
        opts: TrainOpts,
        slowdowns: Slowdowns,
    ) -> Result<Self> {
        if slowdowns.0.len() != cfg.workers.len() {
            bail!("slowdowns/workers length mismatch");
        }
        if slowdowns.0.iter().any(|&c| c <= 0.0 || c > 1.0) {
            bail!("slowdown capacities must be in (0, 1]");
        }
        runtime.model(&opts.model)?; // validate model exists
        Ok(Engine {
            runtime,
            cfg,
            opts,
            slowdowns,
        })
    }

    /// Initial *continuous* allocation by policy (quantized to buckets).
    fn initial_alloc(&self, b0: f64) -> Vec<f64> {
        match self.opts.policy {
            Policy::Uniform => uniform_alloc(b0, self.cfg.workers.len()),
            Policy::Static | Policy::Dynamic => {
                let est: Vec<f64> = self
                    .cfg
                    .workers
                    .iter()
                    .map(|w| w.device.flops_estimate())
                    .collect();
                static_alloc(b0, &est)
            }
        }
    }

    /// Run BSP training; returns the report with the real loss curve.
    pub fn run(&mut self, dataset: &mut dyn Dataset) -> Result<RunReport> {
        let k = self.cfg.workers.len();
        if self.opts.eval_every > 0 && dataset.shards() <= k {
            bail!(
                "eval_every needs a dedicated eval shard: dataset has {} shard(s) \
                 for k = {k} workers — build it with k + 1 (workers draw from \
                 shards 0..k, evals from shard k)",
                dataset.shards()
            );
        }
        let model_name = self.opts.model.clone();
        let m = self.runtime.model(&model_name)?.clone();
        let buckets = m.buckets.clone();
        let b0 = if self.cfg.b0 > 0 {
            self.cfg.b0 as f64
        } else {
            // Middle bucket as default reference.
            buckets[buckets.len() / 2] as f64
        };

        let mut report = RunReport::new(&format!(
            "real/{}/{}",
            model_name,
            self.opts.policy.label()
        ));

        // Controller state.
        let proposal = self.initial_alloc(b0);
        let (mut cur_buckets, _) =
            quantize_alloc(&proposal, &buckets, &vec![0usize; k]);
        let mut controller = (self.opts.policy == Policy::Dynamic).then(|| {
            DynamicBatcher::new(
                self.cfg.controller.clone(),
                &cur_buckets.iter().map(|&b| b as f64).collect::<Vec<_>>(),
            )
        });

        // Parameters. The optimizer is the fused tiled aggregate+update
        // kernel (§Perf iteration 1).
        let init = self.runtime.init_params(&model_name)?;
        let mut params = init;
        let mut optimizer =
            FusedOptimizer::for_workload(&model_name, m.param_total, self.opts.steps);
        // Per-worker gradient buffers, reused across rounds (§Perf it. 2).
        let mut grads_per_worker: Vec<Vec<f32>> =
            (0..k).map(|_| vec![0.0f32; m.param_total]).collect();

        // Warm up all bucket executables so swaps are cheap.
        self.runtime.warmup(&model_name, &[StepKind::Train])?;
        // Periodic evals run at one fixed bucket (nearest to b0), so
        // only that eval executable is compiled.
        let eval_bucket = quantize(b0, &buckets);
        if self.opts.eval_every > 0 {
            self.runtime
                .ensure_compiled(&model_name, StepKind::Eval, eval_bucket)?;
        }

        // Prefetch pipelining (§Perf iteration 4): the dataset and a
        // one-slot hand-off buffer live behind mutexes so a pool worker
        // can generate worker w+1's batch while the leader drives worker
        // w's PJRT step. Batch generation order is unchanged (w, w+1,
        // ... strictly in turn), so the run is bit-identical with
        // prefetch on or off.
        let ds = Mutex::new(dataset);
        let slot: Mutex<Option<Batch>> = Mutex::new(None);
        let prefetch = self.opts.prefetch && k > 1;

        let wall0 = Instant::now();
        let mut step = 0u64;
        while step < self.opts.steps {
            // --- each worker computes its mini-batch (BSP round) ---
            // Real compute is serialized through the runtime (PJRT client
            // is single-stream here). Parameter literals are marshaled
            // once per round and shared by all K workers (§Perf it. 3).
            let mut durations = vec![0.0f64; k];
            let mut losses = vec![0.0f32; k];
            let round_start = wall0.elapsed().as_secs_f64();
            let param_lits = self.runtime.prepare_params(&model_name, &params)?;
            for w in 0..k {
                let b = cur_buckets[w];
                let batch = match slot.lock().unwrap().take() {
                    Some(batch) => batch, // prefetched during worker w−1
                    None => ds.lock().unwrap().next_batch(w, b),
                };
                let handle = if prefetch && w + 1 < k {
                    let (nw, nb) = (w + 1, cur_buckets[w + 1]);
                    let (dsr, slotr) = (&ds, &slot);
                    // SAFETY: the handle is joined inside this loop
                    // iteration — `h.wait()` below on the normal path,
                    // `Drop` on the `?` early return — before `ds` and
                    // `slot` can go out of scope; it is never leaked.
                    Some(unsafe {
                        pool::global().submit(move || {
                            let next = dsr.lock().unwrap().next_batch(nw, nb);
                            *slotr.lock().unwrap() = Some(next);
                        })
                    })
                } else {
                    None
                };
                let t0 = Instant::now();
                let loss = self.runtime.train_step_prepared(
                    &model_name,
                    b,
                    &param_lits,
                    &batch,
                    &mut grads_per_worker[w],
                )?;
                let compute = t0.elapsed().as_secs_f64();
                let c = self.slowdowns.0[w];
                let injected = compute * (1.0 / c - 1.0);
                durations[w] = compute + injected;
                losses[w] = loss;
                if let Some(h) = handle {
                    h.wait(); // batch generation ran under the PJRT step
                }
            }
            drop(param_lits);
            // Injected slowdowns are *accounted*, not slept: worker
            // compute is serialized through the single PJRT stream, so
            // sleeping would only burn wall-clock without changing what
            // the controller observes. The BSP barrier cost per round is
            // the max virtual duration.
            let barrier = durations.iter().cloned().fold(0.0, f64::max);

            for w in 0..k {
                report.iters.push(IterRecord {
                    worker: w,
                    iter: step,
                    start: round_start,
                    duration: durations[w],
                    batch: cur_buckets[w] as f64,
                    wait: barrier - durations[w],
                });
            }

            // --- leader: fused weighted aggregation + optimizer (Eq. 2–3),
            // sharded across the persistent pool (§Perf iteration 4) ---
            let lambdas =
                lambdas_from_batches(&cur_buckets.iter().map(|&b| b as f64).collect::<Vec<_>>());
            let grad_refs: Vec<&[f32]> =
                grads_per_worker.iter().map(|g| g.as_slice()).collect();
            optimizer.step_mt(&mut params, &grad_refs, &lambdas, self.opts.pool_threads);

            // Global loss = λ-weighted worker losses.
            let loss: f64 = losses
                .iter()
                .zip(&lambdas)
                .map(|(&l, &lam)| l as f64 * lam)
                .sum();
            report
                .losses
                .push((wall0.elapsed().as_secs_f64(), step, loss));

            step += 1;

            // --- periodic evaluation (StepKind::Eval executable) ---
            // Shard k is the dedicated eval stream: training shards
            // 0..k stay untouched, so eval-on vs eval-off runs produce
            // identical loss curves.
            if self.opts.eval_every > 0 && step % self.opts.eval_every == 0 {
                let batch = ds.lock().unwrap().next_batch(k, eval_bucket);
                let ev = self
                    .runtime
                    .eval_step(&model_name, eval_bucket, &params, &batch)?;
                report.evals.push(EvalRecord {
                    time: wall0.elapsed().as_secs_f64(),
                    iter: step,
                    loss: ev.loss as f64,
                    metric: ev.metric as f64,
                });
            }

            if self.opts.loss_target > 0.0 && loss < self.opts.loss_target {
                report.reached_target = true;
                break;
            }

            // --- controller ---
            if let Some(ctl) = controller.as_mut() {
                for w in 0..k {
                    ctl.observe(w, durations[w]);
                }
                if let Adjustment::Apply(proposal) = ctl.maybe_adjust() {
                    let (snapped, swaps) =
                        quantize_alloc(&proposal, &buckets, &cur_buckets);
                    if swaps.iter().any(|&s| s) {
                        report.adjustments.push(AdjustEvent {
                            time: wall0.elapsed().as_secs_f64(),
                            iter: step,
                            batches: snapped.iter().map(|&b| b as f64).collect(),
                            cost: 0.0, // executable swap: pre-compiled
                        });
                        cur_buckets = snapped.clone();
                    }
                    // Tell the controller what was actually applied.
                    ctl.set_batches(
                        &snapped.iter().map(|&b| b as f64).collect::<Vec<_>>(),
                    );
                }
            }
        }
        report.total_iters = step;
        report.total_time = wall0.elapsed().as_secs_f64();
        if self.opts.loss_target == 0.0 {
            report.reached_target = true;
        }
        Ok(report)
    }
}

/// Shared-runtime wrapper used by benches that execute from two threads.
pub struct SharedRuntime(pub Mutex<Runtime>);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slowdowns_from_cores_normalized() {
        let s = Slowdowns::from_cores(&[3, 6, 12]);
        assert_eq!(s.0, vec![0.25, 0.5, 1.0]);
    }

    #[test]
    fn default_opts_sane() {
        let o = TrainOpts::default();
        assert!(o.steps > 0);
        assert_eq!(o.policy, Policy::Dynamic);
        assert!(o.pool_threads >= 1);
        assert!(o.prefetch);
        assert_eq!(o.eval_every, 0);
    }
    // Engine integration tests (need artifacts) live in
    // rust/tests/engine_integration.rs.
}
