//! Unified training-loop API: one [`Session`] drives the paper's
//! controller over pluggable execution [`Backend`]s.
//!
//! The paper's contribution is *one* algorithm — the proportional batch
//! controller — observed under many execution regimes: BSP/ASP/SSP,
//! static and dynamic heterogeneity, simulated and real execution.  The
//! session is the single orchestrator that owns everything regime- and
//! policy-shaped:
//!
//! - policy selection and the initial allocation (uniform / static /
//!   dynamic, [`crate::controller`]),
//! - [`DynamicBatcher`] observe/adjust and bucket quantization,
//! - [`SyncState`] gating — BSP, ASP, and SSP on *both* backends,
//! - virtual-slowdown injection and availability traces
//!   ([`crate::trace::ClusterTraces`] drive real runs too),
//! - [`RunReport`] assembly.
//!
//! A [`Backend`] owns only execution: produce one worker-iteration's
//! work/loss ([`Backend::execute_wave`]) and apply a gradient update
//! ([`Backend::apply_update`]).  Two implementations ship:
//! [`SimBackend`] (virtual-time capacity model — regenerates the paper's
//! figures in milliseconds) and [`RealBackend`] (AOT-compiled PJRT train
//! steps with the fused parameter-server hot path).  New policies,
//! sync modes, and executors all extend through this one seam.
//!
//! The loop itself is event-driven over virtual time: idle workers the
//! sync gate admits are started as a *wave*, time advances to the
//! earliest completion, and the completed update is pushed through
//! [`SyncState`].  BSP falls out as the lockstep special case (waves of
//! K, one λ-weighted aggregate update per barrier); ASP/SSP apply each
//! worker's update individually with genuine staleness.  Each BSP
//! member's contribution is handed to the backend at its *completion
//! event* ([`Backend::stage_update`]) — the real backend combines it
//! into an eager reduction tree inside the straggler window (DESIGN.md
//! §11), so the barrier itself no longer pays a flat O(k·d) pass.
//!
//! Event selection is O(log k) per event ([`Scheduler::Heap`], the
//! default): a min-heap of completion times with lazy deletion plus a
//! ready-queue for wave admission, so fleet-scale clusters (k in the
//! thousands) cost k·iters·log k instead of the k²·iters the seed's
//! per-event linear scans paid.  [`Scheduler::Scan`] keeps the linear
//! path as the bench baseline; both produce identical reports
//! (property-tested), and `benches/session.rs` records the speedup.

pub mod real;
pub mod sim;

use anyhow::{anyhow, bail, Result};

use std::collections::{BTreeMap, BTreeSet, BinaryHeap, VecDeque};

use crate::ckpt::Checkpointer;
use crate::cluster::{cpu_cluster, DeviceKind, GpuModel, WorkerSpec};
use crate::config::{split_policy_spec, Policy};
use crate::controller::bucket::quantize_alloc;
use crate::controller::{
    Adjustment, BatchPolicy, ControllerCfg, DynamicBatcher, OptimalBatcher, RlBatcher,
    RlTable,
};
use crate::fault::{
    Autoscaler, AutoscalerCfg, DetectorCfg, FaultPlan, GuardCfg, GuardVerdict,
    LatePolicy, SpawnOutcome, UpdateGuard,
};
use crate::metrics::{
    AdjustEvent, DetectorAction, DetectorEvent, EpochEvent, EvalRecord, GuardAction,
    GuardEvent, IterRecord, RunReport, SpawnAction, SpawnEvent,
};
use crate::runtime::Runtime;
use crate::sync::{SyncMode, SyncState};
use crate::trace::{
    ClusterTraces, JoinSpec, MembershipEvent, MembershipKind, MembershipPlan,
    SpotSpec, SPOT_HORIZON_S,
};
use crate::util::json::Json;

pub use real::{BspAgg, RealBackend};
pub use sim::SimBackend;

/// Result of one executed worker iteration, as the backend sees it.
/// (Losses reach the report through [`Backend::apply_update`]'s return
/// value — an update, not an iteration, is what produces one.)
#[derive(Debug, Clone)]
pub struct WorkerOutcome {
    /// Seconds of *full-capacity* compute this iteration represents
    /// (simulated work, or measured wall compute on the real runtime).
    /// The session divides by the worker's slowdown capacity and
    /// integrates over its availability trace to get the virtual
    /// duration the controller observes.
    pub work: f64,
    /// Seconds outside capacity integration (fixed dispatch/comm cost).
    pub fixed: f64,
}

/// An execution substrate the [`Session`] can drive.
///
/// Implementations execute iterations and apply updates; they hold *no*
/// policy, controller, or synchronization logic of their own.
pub trait Backend {
    /// Number of workers.
    fn k(&self) -> usize;

    /// Label prefix for [`RunReport::label`] (e.g. `"resnet"`,
    /// `"real/mlp"`).
    fn label(&self) -> String;

    /// Batch-size bucket grid, if execution requires static shapes
    /// (AOT-compiled executables). `None` = continuous batch sizes.
    fn buckets(&self) -> Option<Vec<usize>>;

    /// Default reference per-worker batch b0.
    fn default_b0(&self) -> f64;

    /// Per-worker throughput estimates for the open-loop allocators
    /// (FLOPs — deliberately imperfect; the controller corrects them).
    fn flops_estimates(&self) -> Vec<f64>;

    /// Global iterations to the convergence target when the session has
    /// no explicit step budget.
    fn default_target(&self) -> u64;

    /// Execute one iteration for each worker in `wave` (in order) with
    /// `batches[w]` examples, at virtual time `now`.  Returns one
    /// [`WorkerOutcome`] per wave entry.  Backends may pipeline across
    /// the wave (the real backend prefetches batch w+1 under worker w's
    /// train step) but must keep per-worker results independent.
    fn execute_wave(
        &mut self,
        wave: &[usize],
        batches: &[f64],
        now: f64,
    ) -> Result<Vec<WorkerOutcome>>;

    /// Apply the completed updates of `workers` as one gradient
    /// application, λ-weighted by their batch sizes (paper Eq. 2–3).
    /// BSP passes all K workers at the barrier; ASP/SSP pass one.
    /// Only `batches[w]` for `w ∈ workers` is meaningful — entries for
    /// other ranks may be stale (the session passes its executed-batch
    /// buffer without per-round copies).  Returns the resulting global
    /// loss when the backend trains for real.
    fn apply_update(&mut self, workers: &[usize], batches: &[f64]) -> Result<Option<f64>>;

    /// BSP eager-aggregation hook: the session hands worker `w`'s round
    /// contribution over at its *completion event*, instead of
    /// collecting everything for one barrier pass.  Backends that
    /// aggregate incrementally (the real backend's reduction tree,
    /// DESIGN.md §11) finalize the contribution here; a revocation
    /// between execution and the barrier arrives via
    /// [`Backend::retire_worker`] and must drop it again.  As with
    /// `apply_update`, only `batches[w]` is meaningful.  Default: no-op
    /// (the simulator models updates, it does not hold gradients).
    fn stage_update(&mut self, _w: usize, _batches: &[f64]) -> Result<()> {
        Ok(())
    }

    /// Data-plane guard hook (DESIGN.md §16): the L2 norm of worker
    /// `w`'s most recently completed update payload, inspected by the
    /// session's [`UpdateGuard`] at the completion event *before* the
    /// contribution is staged into the eager combine.  `None` means the
    /// backend cannot observe payload norms, and the guard accepts the
    /// contribution unchecked.  Default: `None`.
    fn update_norm(&mut self, _w: usize) -> Option<f64> {
        None
    }

    /// Data-plane guard hook: drop worker `w`'s most recently completed
    /// update payload *without* staging it — the guard rejected it.
    /// Backends that pushed the payload into an eager structure at
    /// execution time (the real backend's reduction tree) must revoke
    /// the leaf here, exactly as [`Backend::retire_worker`] would, so a
    /// rejection is bitwise-equal to a same-round revocation.  Default:
    /// no-op (the simulator models updates, it holds no payloads).
    fn discard_update(&mut self, _w: usize) -> Result<()> {
        Ok(())
    }

    /// Fresh-equivalent progress retained by an update of the given
    /// staleness (simulation convergence model; real backends return 1.0
    /// — their convergence is real, not modeled).  Must be a pure
    /// function of `staleness`: the session memoizes small values.
    fn staleness_discount(&self, staleness: u64) -> f64;

    /// Periodic evaluation at global step `step`; returns
    /// `(loss, metric)` or `None` when the backend does not evaluate.
    fn eval(&mut self, step: u64, now: f64) -> Result<Option<(f64, f64)>>;

    /// Membership hook: worker `w` left the training group (spot
    /// revocation / starts absent).  Backends owning per-worker
    /// resources reroute them here (e.g. the real backend hands the
    /// departed rank's data shards to survivors).  Default: no-op.
    fn retire_worker(&mut self, _w: usize) -> Result<()> {
        Ok(())
    }

    /// Membership hook: worker `w` (re)joined, seeded from the current
    /// global model.  Default: no-op.
    fn admit_worker(&mut self, _w: usize) -> Result<()> {
        Ok(())
    }

    /// Fault-injection hook (DESIGN.md §12): the session hands the run's
    /// [`FaultPlan`] over before the first wave.  Backends that honour it
    /// keep a [`crate::fault::FaultState`] and perturb each outcome at
    /// dispatch (stall/slow); crashes never reach the backend — the
    /// session suppresses the completion event itself.  Default: no-op
    /// (faults silently don't fire — the builder rejects fault plans the
    /// session can't enforce, so this only matters for custom backends).
    fn set_fault_plan(&mut self, _plan: &FaultPlan) {}

    /// Checkpoint hook (DESIGN.md §15): the backend's own irreducible
    /// state as JSON — rng stream positions, fault-overlay progress —
    /// or `None` for stateless backends.  Restored by
    /// [`Backend::restore_state`] after the session has replayed
    /// membership (`retire_worker`) and re-handed the fault plan.
    fn snapshot_state(&self) -> Option<Json> {
        None
    }

    /// Inverse of [`Backend::snapshot_state`].  Default: accept nothing
    /// was captured.
    fn restore_state(&mut self, _j: &Json) -> Result<(), String> {
        Ok(())
    }

    /// Checkpoint hook for bulk binary state (the real backend's
    /// parameter vector + optimizer moments), written as a sidecar file
    /// next to the JSON state.  `None` = no sidecar.
    fn snapshot_binary(&self) -> Option<Vec<u8>> {
        None
    }

    /// Inverse of [`Backend::snapshot_binary`].  The default rejects:
    /// a sidecar in the checkpoint that the backend cannot consume
    /// means the checkpoint was taken on a different backend kind.
    fn restore_binary(&mut self, _bytes: &[u8]) -> Result<(), String> {
        Err("this backend holds no binary checkpoint state".to_string())
    }
}

/// Event-scheduling implementation of the [`Session::run`] loop
/// (DESIGN.md §10).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheduler {
    /// Indexed min-heap of completion times (lazy deletion via per-worker
    /// generations) plus a ready-queue for wave admission: O(log k) per
    /// event.  The default — required for fleet-scale (k ≫ 100) runs.
    Heap,
    /// The seed's per-event linear scans: O(k) per event.  Kept as the
    /// `benches/session.rs` baseline and as the property-test
    /// cross-check (`tests/property.rs` asserts both schedulers produce
    /// identical `RunReport`s).
    Scan,
}

impl Scheduler {
    pub fn parse(s: &str) -> Option<Scheduler> {
        match s {
            "heap" => Some(Scheduler::Heap),
            "scan" => Some(Scheduler::Scan),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Scheduler::Heap => "heap",
            Scheduler::Scan => "scan",
        }
    }
}

/// Per-worker slowdown capacities: capacity c ∈ (0, 1] ⇒ a worker's
/// full-capacity work w costs w/c of virtual time (before availability
/// traces).  c = 1.0 means full speed (no injection).
#[derive(Debug, Clone)]
pub struct Slowdowns(pub Vec<f64>);

impl Slowdowns {
    pub fn none(k: usize) -> Self {
        Slowdowns(vec![1.0; k])
    }

    /// Capacity proportional to core counts, normalized to max = 1.
    pub fn from_cores(cores: &[usize]) -> Self {
        let max = *cores.iter().max().expect("empty cores") as f64;
        Slowdowns(cores.iter().map(|&c| c as f64 / max).collect())
    }

    /// Capacity proportional to throughput estimates, normalized to
    /// max = 1 (the real-backend default: heterogeneity follows the
    /// cluster's FLOPs profile).
    pub fn from_estimates(estimates: &[f64]) -> Self {
        let max = estimates.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max > 0.0, "estimates must be positive");
        Slowdowns(estimates.iter().map(|&e| e / max).collect())
    }
}

/// Seed perturbation for spot-trace generation, so the availability
/// stream is decorrelated from the backend's iteration-noise stream.
const SPOT_SEED_TAG: u64 = 0x51D0_7C4A;

/// Builder for a [`Session`] — the single entry point for simulated and
/// real training runs (replaces the old `ExperimentCfg` + `TrainOpts` +
/// standalone-`Slowdowns` trio).
///
/// ```no_run
/// # use hetero_batch::session::Session;
/// # use hetero_batch::config::Policy;
/// # use hetero_batch::sync::SyncMode;
/// let report = Session::builder()
///     .model("resnet")
///     .cores(&[3, 16, 20])
///     .policy(Policy::Dynamic)
///     .sync(SyncMode::Ssp { bound: 2 })
///     .steps(300)
///     .build_sim()
///     .unwrap()
///     .run()
///     .unwrap();
/// ```
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    model: String,
    workers: Vec<WorkerSpec>,
    policy: Policy,
    rl_table: Option<String>,
    sync: SyncMode,
    controller: ControllerCfg,
    b0: usize,
    steps: u64,
    target_iters: u64,
    adjust_cost_s: Option<f64>,
    noise_sigma: f64,
    seed: u64,
    traces: Option<ClusterTraces>,
    slowdowns: Option<Slowdowns>,
    membership: Option<MembershipPlan>,
    spot: Option<SpotSpec>,
    faults: Option<FaultPlan>,
    detector: Option<DetectorCfg>,
    guard: Option<GuardCfg>,
    autoscale: Option<AutoscalerCfg>,
    eval_every: u64,
    pool_threads: usize,
    prefetch: bool,
    loss_target: f64,
    scheduler: Scheduler,
    report_sample: u64,
    eager_agg: bool,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            model: "resnet".into(),
            workers: cpu_cluster(&[9, 12, 18]),
            policy: Policy::Dynamic,
            rl_table: None,
            sync: SyncMode::Bsp,
            controller: ControllerCfg::default(),
            b0: 0,
            steps: 0,
            target_iters: 0,
            adjust_cost_s: None,
            noise_sigma: 0.06,
            seed: 0,
            traces: None,
            slowdowns: None,
            membership: None,
            spot: None,
            faults: None,
            detector: None,
            guard: None,
            autoscale: None,
            eval_every: 0,
            pool_threads: 4,
            prefetch: true,
            loss_target: 0.0,
            scheduler: Scheduler::Heap,
            report_sample: 1,
            eager_agg: true,
        }
    }
}

impl SessionBuilder {
    pub fn new() -> Self {
        SessionBuilder::default()
    }

    /// Simulation workload profile name, or registry model name for real
    /// execution (resnet|mnist|linreg|transformer vs linreg|mlp|cnn|…).
    pub fn model(mut self, name: &str) -> Self {
        self.model = name.to_string();
        self
    }

    pub fn workers(mut self, workers: Vec<WorkerSpec>) -> Self {
        self.workers = workers;
        self
    }

    /// Convenience: CPU cluster from per-worker core counts.
    pub fn cores(mut self, cores: &[usize]) -> Self {
        self.workers = cpu_cluster(cores);
        self
    }

    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Path to a trained RL controller table (`--policy rl:table.json`).
    /// `None` with [`Policy::Rl`] uses the committed built-in table.
    pub fn rl_table(mut self, path: &str) -> Self {
        self.rl_table = Some(path.to_string());
        self
    }

    pub fn sync(mut self, sync: SyncMode) -> Self {
        self.sync = sync;
        self
    }

    pub fn controller(mut self, cfg: ControllerCfg) -> Self {
        self.controller = cfg;
        self
    }

    /// Reference per-worker batch (0 = backend default: workload profile
    /// b0 in simulation, the middle bucket on the real runtime).
    pub fn b0(mut self, b0: usize) -> Self {
        self.b0 = b0;
        self
    }

    /// Global iteration budget (0 = run to the convergence target —
    /// simulation only; real sessions require an explicit budget).
    pub fn steps(mut self, steps: u64) -> Self {
        self.steps = steps;
        self
    }

    /// Override the simulated workload's iterations-to-target (scales
    /// run-to-target experiments down for tests/figures).
    pub fn target_iters(mut self, iters: u64) -> Self {
        self.target_iters = iters;
        self
    }

    /// Seconds charged per applied batch readjustment (default: 30 in
    /// simulation — the paper's TF kill-restart; 0 on the real runtime —
    /// executable swaps are pre-compiled).
    pub fn adjust_cost(mut self, seconds: f64) -> Self {
        self.adjust_cost_s = Some(seconds);
        self
    }

    /// Lognormal iteration-time noise sigma (simulation).
    pub fn noise(mut self, sigma: f64) -> Self {
        self.noise_sigma = sigma;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Per-worker availability traces (interference, over-commitment,
    /// spot preemptions).  Drive *both* backends: on the real runtime
    /// the measured compute is integrated over the trace, so a
    /// preemption costs real downtime in the virtual timeline.
    pub fn traces(mut self, traces: ClusterTraces) -> Self {
        self.traces = Some(traces);
        self
    }

    /// Explicit per-worker slowdown capacities (real-backend default:
    /// derived from the cluster's FLOPs estimates).
    pub fn slowdowns(mut self, slowdowns: Slowdowns) -> Self {
        self.slowdowns = Some(slowdowns);
        self
    }

    /// Explicit membership schedule (revocations / joins).  Merged with
    /// any events already accumulated (e.g. from [`Self::spot`]).
    pub fn membership(mut self, plan: MembershipPlan) -> Self {
        self.membership = Some(match self.membership.take() {
            Some(p) => p.merged(&plan),
            None => plan,
        });
        self
    }

    /// Spot-churn scenario (`--spot mttf:down[:grace]`): every worker
    /// gets an independent preemption trace seeded from the session
    /// seed, and membership revoke/rejoin events are derived from those
    /// traces with the spec's grace period.  The traces are materialized
    /// at build time, so builder-call ordering relative to
    /// `.workers()`/`.seed()` does not matter; a spot spec replaces any
    /// explicitly-set traces.
    pub fn spot(mut self, spec: SpotSpec) -> Self {
        self.spot = Some(spec);
        self
    }

    /// Scheduled mid-run joins (`--join k@t`): each listed worker starts
    /// the run absent and first appears at its join time.
    pub fn joins(mut self, joins: &[JoinSpec]) -> Self {
        if joins.is_empty() {
            return self;
        }
        let plan = MembershipPlan::default().with_joins(joins);
        self.membership(plan)
    }

    /// Fault-injection schedule (`--faults crash:W@T,stall:W@T:D,...`):
    /// unannounced crashes, mid-run stalls, slowdown spikes — none of
    /// which the membership plan knows about (DESIGN.md §12).  Crash
    /// faults require a failure [`Self::detector`]; nothing else can
    /// reclaim the crashed rank.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Fold a corruption plan (`--corrupt`, DESIGN.md §16) into the
    /// fault schedule, merging with any timing faults already set via
    /// [`Self::faults`] — the two flags compose, and the config echo
    /// round-trips through the `faults` key alone.
    pub fn corrupt(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(match self.faults.take() {
            Some(existing) => existing.merged(plan),
            None => plan,
        });
        self
    }

    /// Progress-deadline failure detector (`--detect
    /// grace=4,floor=30,late=readmit`): suspect any worker in flight
    /// past `max(floor, grace × smoothed-iteration-time)` and
    /// provisionally retire it through the revocation path.
    pub fn detector(mut self, cfg: DetectorCfg) -> Self {
        self.detector = Some(cfg);
        self
    }

    /// Data-plane update guard (`--guard
    /// norm=8,strikes=3,probation=60,late=readmit`): validate every
    /// completed contribution (finite check + a median/MAD norm gate
    /// over recently accepted updates) before it enters the aggregate;
    /// rejected updates drop through the revocation path, and repeated
    /// strikes quarantine the worker with a probation readmit
    /// (DESIGN.md §16).  Corruption faults require a guard.
    pub fn guard(mut self, cfg: GuardCfg) -> Self {
        self.guard = Some(cfg);
        self
    }

    /// Autoscaled recovery (`--autoscale pool=2,cold=30,...`): spawn
    /// replacements from a provisioning pool when the live count falls
    /// below the capacity floor (with cold start, backoff + jitter on
    /// failed spawns, and a ride-out option).
    pub fn autoscale(mut self, cfg: AutoscalerCfg) -> Self {
        self.autoscale = Some(cfg);
        self
    }

    /// Evaluate every N global steps (real backend; 0 = never).
    pub fn eval_every(mut self, every: u64) -> Self {
        self.eval_every = every;
        self
    }

    /// Shard count for the PS hot path (fused aggregate+optimizer on the
    /// persistent pool).
    pub fn pool_threads(mut self, threads: usize) -> Self {
        self.pool_threads = threads;
        self
    }

    /// Overlap batch generation with the PJRT train step.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Stop early when the training loss falls below this (0 = off).
    pub fn loss_target(mut self, target: f64) -> Self {
        self.loss_target = target;
        self
    }

    /// Event-scheduling implementation (default [`Scheduler::Heap`];
    /// [`Scheduler::Scan`] keeps the O(k)-per-event baseline for benches
    /// and cross-checks — both produce identical reports).
    pub fn scheduler(mut self, scheduler: Scheduler) -> Self {
        self.scheduler = scheduler;
        self
    }

    /// BSP gradient aggregation on the real backend (default true):
    /// eager reduction tree — each completed gradient combines into a
    /// fixed rank-indexed binary tree inside the straggler window, and
    /// live gradient memory is ⌈log₂k⌉+1 buffers instead of k
    /// (DESIGN.md §11).  `false` selects the collect-then-aggregate
    /// baseline (per-worker arena, same tree built at the barrier) —
    /// reports are bit-identical either way (the tree shape, not the
    /// schedule, fixes the summation order); the knob exists for the
    /// parity lock and as a debugging fallback (CLI `--collect-agg`).
    pub fn eager_agg(mut self, on: bool) -> Self {
        self.eager_agg = on;
        self
    }

    /// Keep every n-th BSP round (all of its member records) / every
    /// n-th async update and loss sample in the [`RunReport`] (default
    /// 1 = keep everything).  At fleet scale a full-fidelity report is
    /// O(steps·k) memory; sampling bounds it without touching the run's
    /// numerics — only the report density changes.  BSP sampling is
    /// round-aligned so kept rounds stay complete: per-worker stats and
    /// `iteration_gap` remain unbiased instead of aliasing with the
    /// round period.
    pub fn report_sample(mut self, n: u64) -> Self {
        self.report_sample = n;
        self
    }

    // ------------------------------------------------------------- JSON

    /// Parse worker list from JSON: `[{"cpu": 9}, {"gpu": "P100"}]`.
    pub fn workers_from_json(arr: &Json) -> Result<Vec<WorkerSpec>, String> {
        let items = arr.as_arr().ok_or("workers must be an array")?;
        let mut out = Vec::new();
        for (i, item) in items.iter().enumerate() {
            if let Some(c) = item.get("cpu").as_usize() {
                out.push(WorkerSpec::cpu(i, c));
            } else if let Some(g) = item.get("gpu").as_str() {
                let model = match g {
                    "P100" => GpuModel::P100,
                    "T4" => GpuModel::T4,
                    "P4" => GpuModel::P4,
                    _ => return Err(format!("unknown gpu model {g:?}")),
                };
                out.push(WorkerSpec::gpu(i, model));
            } else {
                return Err(format!(
                    "worker {i}: need {{\"cpu\": n}} or {{\"gpu\": name}}"
                ));
            }
        }
        if out.is_empty() {
            return Err("empty worker list".into());
        }
        Ok(out)
    }

    /// Load overrides from a JSON object (missing keys keep defaults).
    /// `max_iters` is accepted as an alias for `steps`.
    pub fn from_json(j: &Json) -> Result<SessionBuilder, String> {
        let mut b = SessionBuilder::default();
        if let Some(w) = j.get("workload").as_str() {
            b.model = w.to_string();
        }
        if let Some(w) = j.get("model").as_str() {
            b.model = w.to_string();
        }
        if !j.get("workers").is_null() {
            b.workers = Self::workers_from_json(j.get("workers"))?;
        }
        if let Some(p) = j.get("policy").as_str() {
            let (name, table) = split_policy_spec(p);
            b.policy = Policy::parse(name).ok_or(format!("bad policy {p:?}"))?;
            if let Some(t) = table {
                b.rl_table = Some(t.to_string());
            }
        }
        if let Some(t) = j.get("rl_table").as_str() {
            b.rl_table = Some(t.to_string());
        }
        if let Some(s) = j.get("sync").as_str() {
            b.sync = SyncMode::parse(s).ok_or(format!("bad sync {s:?}"))?;
        }
        if let Some(v) = j.get("b0").as_usize() {
            b.b0 = v;
        }
        if let Some(c) = j.get("adjust_cost_s").as_f64() {
            b.adjust_cost_s = Some(c);
        }
        if let Some(n) = j.get("noise_sigma").as_f64() {
            b.noise_sigma = n;
        }
        if let Some(m) = j.get("max_iters").as_usize() {
            b.steps = m as u64;
        }
        if let Some(m) = j.get("steps").as_usize() {
            b.steps = m as u64;
        }
        if let Some(s) = j.get("seed").as_usize() {
            b.seed = s as u64;
        }
        if let Some(s) = j.get("scheduler").as_str() {
            b.scheduler = Scheduler::parse(s).ok_or(format!("bad scheduler {s:?}"))?;
        }
        if let Some(n) = j.get("report_sample").as_usize() {
            b.report_sample = n as u64;
        }
        if let Some(v) = j.get("eager_agg").as_bool() {
            b.eager_agg = v;
        }
        if let Some(v) = j.get("loss_target").as_f64() {
            b.loss_target = v;
        }
        if let Some(n) = j.get("eval_every").as_usize() {
            b.eval_every = n as u64;
        }
        if let Some(n) = j.get("pool_threads").as_usize() {
            b.pool_threads = n;
        }
        if let Some(v) = j.get("prefetch").as_bool() {
            b.prefetch = v;
        }
        if !j.get("slowdowns").is_null() {
            let caps = j
                .get("slowdowns")
                .as_arr()
                .ok_or("slowdowns must be an array")?
                .iter()
                .map(|v| v.as_f64().ok_or("slowdowns must hold numbers".to_string()))
                .collect::<Result<Vec<_>, _>>()?;
            b.slowdowns = Some(Slowdowns(caps));
        }
        // Explicit membership schedule (the checkpoint config echo's
        // shape; CLI users normally reach this through `join`/`spot`).
        if !j.get("membership_events").is_null() {
            let evs = j
                .get("membership_events")
                .as_arr()
                .ok_or("membership_events must be an array")?
                .iter()
                .map(|e| {
                    let kind = match e.get("kind").as_str() {
                        Some("revoke") => MembershipKind::Revoke,
                        Some("join") => MembershipKind::Join,
                        other => return Err(format!("bad membership kind {other:?}")),
                    };
                    Ok(MembershipEvent {
                        time: e.get("time").as_f64().ok_or("membership event needs a time")?,
                        worker: e
                            .get("worker")
                            .as_usize()
                            .ok_or("membership event needs a worker")?,
                        kind,
                    })
                })
                .collect::<Result<Vec<_>, String>>()?;
            b = b.membership(MembershipPlan::new(evs));
        }
        let c = j.get("controller");
        if !c.is_null() {
            if let Some(d) = c.get("deadband").as_f64() {
                b.controller.deadband = d;
            }
            if let Some(a) = c.get("ewma_alpha").as_f64() {
                b.controller.ewma_alpha = a;
            }
            if let Some(m) = c.get("min_obs").as_usize() {
                b.controller.min_obs = m;
            }
            if let Some(v) = c.get("b_min").as_f64() {
                b.controller.b_min = v;
            }
            if let Some(v) = c.get("b_max").as_f64() {
                b.controller.b_max = v;
            }
            if let Some(v) = c.get("adaptive_bmax").as_bool() {
                b.controller.adaptive_bmax = v;
            }
            if let Some(v) = c.get("conserve_global").as_bool() {
                b.controller.conserve_global = v;
            }
            if let Some(v) = c.get("backoff").as_bool() {
                b.controller.backoff = v;
            }
            if let Some(v) = c.get("backoff_cap").as_usize() {
                b.controller.backoff_cap = v;
            }
            if let Some(v) = c.get("drift_reset").as_f64() {
                b.controller.drift_reset = v;
            }
        }
        // Elastic-membership scenario keys (same shapes as the CLI
        // flags; the spot scenario materializes at build time).
        if let Some(s) = j.get("spot").as_str() {
            let spec = SpotSpec::parse(s).ok_or(format!("bad spot {s:?}"))?;
            b = b.spot(spec);
        }
        if let Some(s) = j.get("join").as_str() {
            let joins =
                JoinSpec::parse_list(s).ok_or(format!("bad join {s:?}"))?;
            b = b.joins(&joins);
        }
        // Robustness keys (DESIGN.md §12), same string shapes as the
        // CLI flags.
        if let Some(s) = j.get("faults").as_str() {
            let plan =
                FaultPlan::parse(s).map_err(|e| format!("bad faults {s:?}: {e}"))?;
            b = b.faults(plan);
        }
        if let Some(s) = j.get("detect").as_str() {
            let cfg =
                DetectorCfg::parse(s).map_err(|e| format!("bad detect {s:?}: {e}"))?;
            b = b.detector(cfg);
        }
        // Corruption shorthand: same item grammar as `--corrupt` (the
        // `corrupt:` prefix implied), merged into the fault plan so the
        // echo round-trips through the `faults` key alone.
        if let Some(s) = j.get("corrupt").as_str() {
            let plan = FaultPlan::parse_corrupt(s)
                .map_err(|e| format!("bad corrupt {s:?}: {e}"))?;
            b.faults = Some(match b.faults.take() {
                Some(existing) => existing.merged(plan),
                None => plan,
            });
        }
        if let Some(s) = j.get("guard").as_str() {
            let cfg =
                GuardCfg::parse(s).map_err(|e| format!("bad guard {s:?}: {e}"))?;
            b = b.guard(cfg);
        }
        if let Some(s) = j.get("autoscale").as_str() {
            let cfg = AutoscalerCfg::parse(s)
                .map_err(|e| format!("bad autoscale {s:?}: {e}"))?;
            b = b.autoscale(cfg);
        }
        b.validate()?;
        Ok(b)
    }

    pub fn from_json_str(s: &str) -> Result<SessionBuilder, String> {
        let j = Json::parse(s).map_err(|e| e.to_string())?;
        Self::from_json(&j)
    }

    pub fn from_file(path: &str) -> Result<SessionBuilder, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_json_str(&text)
    }

    /// Serialize this builder as the JSON shape [`Self::from_json`]
    /// parses — the checkpoint's `config.json` echo (DESIGN.md §15), so
    /// `hbatch resume` can rebuild the exact session.  Errors on
    /// programmatic-only state no config key can express (explicit
    /// availability traces, a sim convergence-target override): a
    /// checkpoint whose config echo silently dropped part of the setup
    /// would resume a *different* run, which is worse than refusing.
    pub fn to_json(&self) -> Result<Json, String> {
        if self.traces.is_some() {
            return Err(
                "checkpointing needs a config-expressible session: explicit \
                 availability traces are programmatic (use a spot spec instead)"
                    .into(),
            );
        }
        if self.target_iters > 0 {
            return Err(
                "checkpointing needs a config-expressible session: the sim \
                 convergence-target override has no config key"
                    .into(),
            );
        }
        let mut j = Json::obj();
        j.set("workload", Json::Str(self.model.clone()));
        j.set(
            "workers",
            Json::Arr(
                self.workers
                    .iter()
                    .map(|w| {
                        let mut o = Json::obj();
                        match w.device {
                            DeviceKind::Cpu { cores } => {
                                o.set("cpu", Json::Num(cores as f64));
                            }
                            DeviceKind::Gpu { model } => {
                                o.set("gpu", Json::Str(model.name().to_string()));
                            }
                        }
                        o
                    })
                    .collect(),
            ),
        );
        j.set("policy", Json::Str(self.policy.label().to_string()));
        if let Some(t) = &self.rl_table {
            j.set("rl_table", Json::Str(t.clone()));
        }
        j.set("sync", Json::Str(self.sync.label()));
        j.set("b0", Json::Num(self.b0 as f64));
        if let Some(c) = self.adjust_cost_s {
            j.set("adjust_cost_s", Json::Num(c));
        }
        j.set("noise_sigma", Json::Num(self.noise_sigma));
        j.set("steps", Json::Num(self.steps as f64));
        j.set("seed", Json::Num(self.seed as f64));
        j.set("scheduler", Json::Str(self.scheduler.label().to_string()));
        j.set("report_sample", Json::Num(self.report_sample as f64));
        j.set("eager_agg", Json::Bool(self.eager_agg));
        j.set("loss_target", Json::Num(self.loss_target));
        j.set("eval_every", Json::Num(self.eval_every as f64));
        j.set("pool_threads", Json::Num(self.pool_threads as f64));
        j.set("prefetch", Json::Bool(self.prefetch));
        let mut c = Json::obj();
        c.set("deadband", Json::Num(self.controller.deadband));
        c.set("ewma_alpha", Json::Num(self.controller.ewma_alpha));
        c.set("min_obs", Json::Num(self.controller.min_obs as f64));
        c.set("b_min", Json::Num(self.controller.b_min));
        c.set("b_max", Json::Num(self.controller.b_max));
        c.set("adaptive_bmax", Json::Bool(self.controller.adaptive_bmax));
        c.set("conserve_global", Json::Bool(self.controller.conserve_global));
        c.set("backoff", Json::Bool(self.controller.backoff));
        c.set("backoff_cap", Json::Num(self.controller.backoff_cap as f64));
        c.set("drift_reset", Json::Num(self.controller.drift_reset));
        j.set("controller", c);
        if let Some(s) = &self.slowdowns {
            j.set(
                "slowdowns",
                Json::Arr(s.0.iter().map(|&c| Json::Num(c)).collect()),
            );
        }
        if let Some(spec) = &self.spot {
            j.set(
                "spot",
                Json::Str(format!("{}:{}:{}", spec.mttf_s, spec.down_s, spec.grace_s)),
            );
        }
        if let Some(plan) = &self.membership {
            if !plan.events().is_empty() {
                j.set(
                    "membership_events",
                    Json::Arr(
                        plan.events()
                            .iter()
                            .map(|e| {
                                let mut o = Json::obj();
                                o.set("time", Json::Num(e.time));
                                o.set("worker", Json::Num(e.worker as f64));
                                o.set("kind", Json::Str(e.kind.label().to_string()));
                                o
                            })
                            .collect(),
                    ),
                );
            }
        }
        if let Some(plan) = &self.faults {
            j.set("faults", Json::Str(plan.spec()));
        }
        if let Some(d) = &self.detector {
            j.set("detect", Json::Str(d.spec()));
        }
        if let Some(g) = &self.guard {
            j.set("guard", Json::Str(g.spec()));
        }
        if let Some(a) = &self.autoscale {
            j.set("autoscale", Json::Str(a.spec()));
        }
        Ok(j)
    }

    /// Ranks this config will run with — the fleet arbiter's demand.
    pub fn planned_workers(&self) -> usize {
        self.workers.len()
    }

    /// Autoscaler spawn-pool size (0 without an autoscaler).  The
    /// fleet counts these as reservable capacity beyond the ranks, so
    /// an "uncontended" fleet stays uncontended even when every job
    /// drains its pool.
    pub fn planned_spawn_pool(&self) -> usize {
        self.autoscale.as_ref().map_or(0, |a| a.pool)
    }

    // ------------------------------------------------------- validation

    pub fn validate(&self) -> Result<(), String> {
        if self.workers.is_empty() {
            return Err("no workers".into());
        }
        self.validate_for_k(self.workers.len())
    }

    fn validate_for_k(&self, k: usize) -> Result<(), String> {
        if self.controller.deadband < 0.0 || self.controller.deadband >= 1.0 {
            return Err(format!(
                "deadband {} out of [0,1)",
                self.controller.deadband
            ));
        }
        if self.controller.b_min < 1.0 || self.controller.b_min > self.controller.b_max {
            return Err("b_min must be in [1, b_max]".into());
        }
        if self.adjust_cost_s.map_or(false, |c| c < 0.0) || self.noise_sigma < 0.0 {
            return Err("costs/noise must be non-negative".into());
        }
        if self.report_sample == 0 {
            return Err("report_sample must be >= 1".into());
        }
        if let Some(tr) = &self.traces {
            if tr.traces.len() != k {
                return Err("traces/workers length mismatch".into());
            }
        }
        if let Some(s) = &self.slowdowns {
            if s.0.len() != k {
                return Err("slowdowns/workers length mismatch".into());
            }
            if s.0.iter().any(|&c| c <= 0.0 || c > 1.0) {
                return Err("slowdown capacities must be in (0, 1]".into());
            }
        }
        if let Some(plan) = &self.membership {
            if let Some(mw) = plan.max_worker() {
                if mw >= k {
                    return Err(format!(
                        "membership event for worker {mw} but only {k} workers"
                    ));
                }
            }
            if plan
                .events()
                .iter()
                .any(|e| !e.time.is_finite() || e.time < 0.0)
            {
                return Err("membership event times must be finite and non-negative".into());
            }
            if plan.initial_live(k).iter().all(|&l| !l) {
                return Err("no initially-live workers (every rank is join_at)".into());
            }
        }
        if let Some(plan) = &self.faults {
            if let Some(mw) = plan.max_worker() {
                if mw >= k {
                    return Err(format!(
                        "fault event for worker {mw} but only {k} workers"
                    ));
                }
            }
            // An unannounced crash makes its rank's iteration never
            // complete; without a detector nothing can reclaim it and a
            // BSP run hangs at the barrier until the update cap.
            if plan.has_crash() && self.detector.is_none() {
                return Err(
                    "crash faults need a failure detector (--detect); \
                     nothing else can reclaim the crashed rank"
                        .into(),
                );
            }
            // A corrupt update with nothing inspecting it flows straight
            // into the aggregate and silently poisons the model — the
            // data-plane mirror of the crash-requires-detector rule.
            if plan.has_corrupt() && self.guard.is_none() {
                return Err(
                    "corruption faults need an update guard (--guard); \
                     an unguarded corrupt update would silently poison the model"
                        .into(),
                );
            }
        }
        if let Some(path) = &self.rl_table {
            if self.policy != Policy::Rl {
                return Err(format!(
                    "rl_table {path:?} given but policy is {}",
                    self.policy.label()
                ));
            }
            RlTable::from_file(path)?;
        }
        if let Some(d) = &self.detector {
            d.validate()?;
        }
        if let Some(g) = &self.guard {
            g.validate()?;
        }
        if let Some(a) = &self.autoscale {
            a.validate()?;
            if a.floor > k {
                return Err(format!(
                    "autoscaler floor {} exceeds the cluster size {k}",
                    a.floor
                ));
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------ build

    /// Build a virtual-time simulation session ([`SimBackend`]).
    pub fn build_sim(&self) -> Result<Session<SimBackend>> {
        self.validate().map_err(|e| anyhow!(e))?;
        let backend = SimBackend::new(
            &self.model,
            self.workers.clone(),
            self.noise_sigma,
            self.target_iters,
            self.seed,
        )
        .map_err(|e| anyhow!(e))?;
        self.assemble(backend, 30.0)
    }

    /// Build a real-execution session ([`RealBackend`]) over an opened
    /// PJRT [`Runtime`].
    pub fn build_real<'rt>(&self, runtime: &'rt mut Runtime) -> Result<Session<RealBackend<'rt>>> {
        self.validate().map_err(|e| anyhow!(e))?;
        if self.steps == 0 {
            bail!("real-execution sessions need steps > 0 (run-to-target is simulation-only)");
        }
        let estimates: Vec<f64> = self
            .workers
            .iter()
            .map(|w| w.device.flops_estimate())
            .collect();
        // BSP barrier aggregation scheme (DESIGN.md §11): the eager
        // reduction tree by default, with buffers recycled (`Free`)
        // unless the session is elastic — a membership plan (explicit
        // or spot-derived) means mid-round revocations, which need the
        // retained sibling partials to rebuild from.
        let bsp_agg = if matches!(self.sync, SyncMode::Bsp) {
            if self.eager_agg {
                // Detector suspicions and autoscaled joins are
                // membership transitions too — a faulted/detected run
                // needs the retained sibling partials just like a spot
                // run does.
                let elastic = self.spot.is_some()
                    || self
                        .membership
                        .as_ref()
                        .map_or(false, |p| !p.events().is_empty())
                    || self.faults.is_some()
                    || self.detector.is_some()
                    // Guard rejections revoke a leaf mid-round exactly
                    // like a spot revocation does.
                    || self.guard.is_some()
                    || self.autoscale.is_some();
                Some(real::BspAgg::Eager(if elastic {
                    crate::ps::RetainPolicy::Retain
                } else {
                    crate::ps::RetainPolicy::Free
                }))
            } else {
                Some(real::BspAgg::Collect)
            }
        } else {
            None
        };
        let backend = RealBackend::new(
            runtime,
            &self.model,
            self.workers.len(),
            estimates.clone(),
            self.seed,
            self.steps,
            self.eval_every,
            self.b0,
            self.pool_threads,
            self.prefetch,
            bsp_agg,
        )?;
        let mut session = self.assemble(backend, 0.0)?;
        if self.slowdowns.is_none() {
            // Real-backend default: heterogeneity follows the cluster's
            // FLOPs profile (for CPU clusters this equals from_cores).
            session.slowdowns = Slowdowns::from_estimates(&estimates);
        }
        Ok(session)
    }

    /// Assemble a session over a custom [`Backend`] (tests, new
    /// executors).  Worker count comes from the backend; the builder's
    /// `workers` list is ignored.
    pub fn build_with<B: Backend>(&self, backend: B) -> Result<Session<B>> {
        if backend.k() == 0 {
            bail!("backend has no workers");
        }
        self.validate_for_k(backend.k()).map_err(|e| anyhow!(e))?;
        self.assemble(backend, 0.0)
    }

    fn assemble<B: Backend>(&self, backend: B, default_adjust_cost: f64) -> Result<Session<B>> {
        let k = backend.k();
        let b0 = if self.b0 > 0 {
            self.b0 as f64
        } else {
            backend.default_b0()
        };
        if b0 <= 0.0 {
            bail!("reference batch b0 must be positive");
        }
        // Materialize the spot-churn scenario now, when the final worker
        // count and seed are known — builder-call ordering is immaterial.
        // A spot spec supersedes explicitly-set traces.
        let (traces, membership) = match &self.spot {
            Some(spec) => {
                let traces = ClusterTraces::spot_cluster(
                    k,
                    SPOT_HORIZON_S,
                    spec.mttf_s,
                    spec.down_s,
                    self.seed ^ SPOT_SEED_TAG,
                );
                let derived = MembershipPlan::from_traces(&traces, spec.grace_s)
                    .map_err(|e| anyhow!("bad spot grace: {e}"))?;
                let membership = match &self.membership {
                    Some(p) => p.clone().merged(&derived),
                    None => derived,
                };
                (traces, membership)
            }
            None => (
                self.traces
                    .clone()
                    .unwrap_or_else(|| ClusterTraces::constant(k)),
                self.membership.clone().unwrap_or_default(),
            ),
        };
        Ok(Session {
            backend,
            policy: self.policy,
            rl_table: self.rl_table.clone(),
            sync: self.sync,
            controller: self.controller.clone(),
            b0,
            steps: self.steps,
            adjust_cost_s: self.adjust_cost_s.unwrap_or(default_adjust_cost),
            eval_every: self.eval_every,
            loss_target: self.loss_target,
            scheduler: self.scheduler,
            report_sample: self.report_sample.max(1),
            slowdowns: self
                .slowdowns
                .clone()
                .unwrap_or_else(|| Slowdowns::none(k)),
            traces,
            membership,
            seed: self.seed,
            faults: self.faults.clone(),
            detector: self.detector.clone(),
            guard: self.guard.clone(),
            autoscale: self.autoscale.clone(),
        })
    }
}

/// One training run: a policy/sync configuration driving a [`Backend`].
pub struct Session<B: Backend> {
    backend: B,
    policy: Policy,
    rl_table: Option<String>,
    sync: SyncMode,
    controller: ControllerCfg,
    b0: f64,
    steps: u64,
    adjust_cost_s: f64,
    eval_every: u64,
    loss_target: f64,
    scheduler: Scheduler,
    report_sample: u64,
    slowdowns: Slowdowns,
    traces: ClusterTraces,
    membership: MembershipPlan,
    seed: u64,
    faults: Option<FaultPlan>,
    detector: Option<DetectorCfg>,
    guard: Option<GuardCfg>,
    autoscale: Option<AutoscalerCfg>,
}

impl Session<SimBackend> {
    /// Entry point: `Session::builder().model(..)...build_sim()/..real()`.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }
}

impl<B: Backend> Session<B> {
    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Policy allocation over the live cohort at total mass `mass`
    /// (absent ranks get 0).  Used for the initial allocation *and* for
    /// open-loop rebalances at membership epochs.  This is
    /// [`crate::controller::uniform_alloc`]/[`crate::controller::static_alloc`]
    /// generalized to a live mask — keep the arithmetic in sync.
    fn policy_alloc(&self, live: &[bool], mass: f64) -> Vec<f64> {
        let k = live.len();
        let n = live.iter().filter(|&&l| l).count();
        let mut out = vec![0.0; k];
        if n == 0 {
            return out;
        }
        match self.policy {
            Policy::Uniform => {
                for (b, &l) in out.iter_mut().zip(live) {
                    if l {
                        *b = mass / n as f64;
                    }
                }
            }
            // Open-loop: proportional to the FLOPs *estimate* (not the
            // true throughput — that gap is what the closed-loop
            // policies correct).
            Policy::Static | Policy::Dynamic | Policy::Optimal | Policy::Rl => {
                let est = self.backend.flops_estimates();
                let total: f64 = est
                    .iter()
                    .zip(live)
                    .filter(|(_, &l)| l)
                    .map(|(&e, _)| e)
                    .sum();
                assert!(
                    total > 0.0,
                    "live cohort's FLOPs estimates must be positive"
                );
                for ((b, &l), &e) in out.iter_mut().zip(live).zip(&est) {
                    if l {
                        *b = mass * e / total;
                    }
                }
                // Skewed estimates can push a live share outside the
                // controller's [b_min, b_max], which the controller
                // constructors reject.  Water-fill the live cohort back
                // into bounds — but only on violation, so in-bounds
                // allocations stay bitwise identical.
                let (b_min, b_max) = (self.controller.b_min, self.controller.b_max);
                if out
                    .iter()
                    .zip(live)
                    .any(|(&b, &l)| l && (b < b_min || b > b_max))
                {
                    let mut lv: Vec<f64> = out
                        .iter()
                        .zip(live)
                        .filter(|(_, &l)| l)
                        .map(|(&b, _)| b)
                        .collect();
                    let caps = vec![b_max; lv.len()];
                    crate::controller::water_fill(&mut lv, mass, b_min, &caps);
                    let mut it = lv.into_iter();
                    for (b, &l) in out.iter_mut().zip(live) {
                        if l {
                            *b = it.next().unwrap();
                        }
                    }
                }
            }
        }
        out
    }

    /// Run to the step budget / convergence target and report.
    ///
    /// Equivalent to driving [`Self::start`] / [`Self::step`] /
    /// [`Self::finish`] to completion — the fleet layer
    /// ([`crate::fleet`]) uses that decomposed form to interleave many
    /// sessions on one merged virtual clock.  The two paths are
    /// bit-identical by construction: the step body *is* the loop body.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut rs = self.start()?;
        while self.step(&mut rs)? {}
        Ok(self.finish(rs))
    }

    /// Validate the configuration and set up a run: initial cohort,
    /// allocation, controller, sync state, and event queues.  Advance
    /// the returned [`RunState`] with [`Self::step`]; consume it with
    /// [`Self::finish`].
    pub fn start(&mut self) -> Result<RunState> {
        let k = self.backend.k();
        if self.slowdowns.0.len() != k {
            bail!("slowdowns/workers length mismatch");
        }
        if self.traces.traces.len() != k {
            bail!("traces/workers length mismatch");
        }
        if self.membership.max_worker().map_or(false, |w| w >= k) {
            bail!("membership event for a worker outside 0..{k}");
        }
        let live = self.membership.initial_live(k);
        if live.iter().all(|&l| !l) {
            bail!("no initially-live workers (every rank is join_at)");
        }
        // Tell the backend about ranks that start the run absent.
        for w in 0..k {
            if !live[w] {
                self.backend.retire_worker(w)?;
            }
        }
        // Hand the fault schedule to the backend: stall/slow faults
        // perturb outcomes at dispatch; crash faults are enforced
        // loop-side by suppressing the completion event (DESIGN.md §12).
        if let Some(plan) = &self.faults {
            self.backend.set_fault_plan(plan);
        }
        let is_bsp = matches!(self.sync, SyncMode::Bsp);
        let buckets = self.backend.buckets();
        let mut report = RunReport::new(&format!(
            "{}/{}/{}",
            self.backend.label(),
            self.policy.label(),
            self.sync.label()
        ));

        // Initial allocation over the live cohort, quantized on
        // bucketed backends.
        let n_live = live.iter().filter(|&&l| l).count();
        if matches!(self.policy, Policy::Dynamic | Policy::Optimal | Policy::Rl) {
            // Controller policies must start inside the bounds; catch an
            // infeasible total mass here with a configuration error
            // instead of a constructor panic downstream.
            let (b_min, b_max) = (self.controller.b_min, self.controller.b_max);
            let mass = self.b0 * n_live as f64;
            if mass < n_live as f64 * b_min - 1e-9 || mass > n_live as f64 * b_max + 1e-9 {
                bail!(
                    "global batch {mass} infeasible for {n_live} live workers \
                     with controller bounds [{b_min}, {b_max}]"
                );
            }
        }
        let proposal = self.policy_alloc(&live, self.b0 * n_live as f64);
        let mut cur_buckets: Option<Vec<usize>> = None;
        let batches: Vec<f64> = match &buckets {
            Some(grid) => {
                let (snapped, _) =
                    quantize_alloc_live(&proposal, grid, &vec![0usize; k], &live);
                let b = snapped.iter().map(|&x| x as f64).collect();
                cur_buckets = Some(snapped);
                b
            }
            None => proposal,
        };
        let controller: Option<Box<dyn BatchPolicy>> = match self.policy {
            Policy::Uniform | Policy::Static => None,
            Policy::Dynamic => Some(Box::new(
                DynamicBatcher::try_with_membership(self.controller.clone(), &batches, &live)
                    .map_err(|e| anyhow!(e))?,
            )),
            Policy::Optimal => Some(Box::new(
                OptimalBatcher::try_with_membership(self.controller.clone(), &batches, &live)
                    .map_err(|e| anyhow!(e))?,
            )),
            Policy::Rl => {
                let table = match &self.rl_table {
                    Some(path) => RlTable::from_file(path).map_err(|e| anyhow!(e))?,
                    None => RlTable::builtin(),
                };
                Some(Box::new(
                    RlBatcher::try_with_membership(
                        self.controller.clone(),
                        &batches,
                        &live,
                        table,
                    )
                    .map_err(|e| anyhow!(e))?,
                ))
            }
        };
        // Async progress is denominated in the *initial* global batch
        // (post-quantization), not k·b0: bucket snapping can leave the
        // batch sum off k·b0, and the budget must count global-batch
        // equivalents of the allocation actually executed.  Conserving
        // policies keep the sum at this value across adjustments *and*
        // membership epochs.
        let global_batch: f64 = batches.iter().sum();

        let target = if self.steps > 0 {
            self.steps
        } else {
            self.backend.default_target()
        };
        if target == 0 {
            bail!("no step budget and no backend convergence target");
        }
        // Hard update cap: an explicit budget caps at one update per
        // worker per global step; run-to-target gets a generous safety
        // margin so pathological configs terminate.
        let hard_updates = if self.steps > 0 {
            self.steps.saturating_mul(k as u64)
        } else {
            target.saturating_mul(k as u64).saturating_mul(40)
        };

        let mut events: VecDeque<MembershipEvent> =
            self.membership.events().iter().copied().collect();
        let mut st = LoopState {
            batches: batches.clone(),
            exec_batch: batches,
            cur_buckets,
            buckets,
            controller,
            sync: SyncState::with_live(self.sync, &live),
            live,
            epoch: 0,
            t: 0.0,
            progress: 0.0,
            updates: 0,
            global_steps: 0,
            busy: vec![false; k],
            next_done: vec![0.0; k],
            started_at: vec![0.0; k],
            round: Vec::new(),
            stopped_early: false,
            global_batch,
            is_bsp,
            heap_mode: self.scheduler == Scheduler::Heap,
            ready: BTreeSet::new(),
            blocked: BTreeMap::new(),
            done_heap: BinaryHeap::new(),
            gen: vec![0; k],
            wave_buf: Vec::with_capacity(k),
            members_buf: Vec::with_capacity(k),
            alloc_buf: Vec::with_capacity(k),
            report_sample: self.report_sample.max(1),
            iter_seen: 0,
            loss_seen: 0,
            discount_cache: vec![f64::NAN; DISCOUNT_MEMO],
            deadline: vec![f64::INFINITY; k],
            deadline_heap: BinaryHeap::new(),
            suspected: vec![false; k],
            pending_arrival: vec![f64::INFINITY; k],
            arrivals: Vec::new(),
            obs_sum: vec![0.0; k],
            obs_n: vec![0; k],
            track_obs: self.detector.is_some()
                || self.autoscale.as_ref().map_or(false, |a| a.tput > 0.0),
            n_plan_revoked: 0,
            n_suspected: 0,
            guard: self
                .guard
                .as_ref()
                .map(|cfg| UpdateGuard::new(cfg.clone(), k)),
            quarantined: vec![false; k],
            probation_until: vec![f64::INFINITY; k],
            probations: Vec::new(),
            ascaler: self
                .autoscale
                .as_ref()
                .map(|cfg| Autoscaler::new(cfg.clone(), n_live, self.seed)),
        };
        if st.heap_mode {
            // Every initially-live worker is idle at clock 0 = the live
            // minimum, so the gate admits all of them in every mode.
            for w in 0..k {
                if st.live[w] {
                    st.ready.insert(w);
                }
            }
        }

        Ok(RunState {
            st,
            events,
            report,
            target,
            hard_updates,
            done: false,
        })
    }

    /// Process one event-loop iteration: membership transitions due
    /// now, autoscaler actuation, wave dispatch, then the next
    /// completion / membership / aux event.  Returns `false` once the
    /// run is over (budget met, loss target hit, or early stop);
    /// further calls are no-ops.
    pub fn step(&mut self, rs: &mut RunState) -> Result<bool> {
        if rs.done
            || !(rs.st.progress < rs.target as f64 && rs.st.updates < rs.hard_updates)
        {
            rs.done = true;
            return Ok(false);
        }
        let k = self.backend.k();
        let RunState {
            st, events, report, done, ..
        } = rs;
        {
            // Membership transitions due now (revocations first at equal
            // timestamps — the plan is pre-sorted).
            while events.front().map_or(false, |e| e.time <= st.t) {
                let ev = events.pop_front().unwrap();
                if ev.kind == MembershipKind::Revoke && st.live[ev.worker] {
                    st.n_plan_revoked += 1;
                }
                self.apply_membership(ev, st, report)?;
                if st.stopped_early {
                    // A revocation-forced barrier can hit the loss target.
                    *done = true;
                    return Ok(false);
                }
            }
            // Autoscaler actuation: admit replacements whose cold start
            // finished, then run any due spawn attempts (DESIGN.md §12).
            self.autoscale_step(st, report)?;
            if st.sync.live_count() == 0 && events.is_empty() {
                // Autoscaler-aware bail: a pending replacement (cold
                // start in progress / retry scheduled) or a readmittable
                // late arrival can still rescue an empty fleet — wait
                // them out instead of erroring.
                let rescue = st
                    .arrivals
                    .iter()
                    .any(|&w| st.pending_arrival[w].is_finite())
                    || st
                        .probations
                        .iter()
                        .any(|&w| st.probation_until[w].is_finite())
                    || st
                        .ascaler
                        .as_ref()
                        .map_or(false, |a| a.next_event(0, None).is_some());
                if !rescue {
                    bail!(
                        "all workers are gone ({} plan-revoked, {} detector-suspected) \
                         and no rejoin, late arrival, or autoscaled replacement is pending",
                        st.n_plan_revoked,
                        st.n_suspected
                    );
                }
            }

            // Start every idle live worker the sync gate admits, as one
            // wave (ascending worker order — the backend consumes its
            // noise stream in wave order, so ordering is part of the
            // numerics).  Heap mode drains the ready-queue, which the
            // bookkeeping below keeps equal to the scan's filter set.
            st.wave_buf.clear();
            if st.heap_mode {
                st.wave_buf.extend(st.ready.iter().copied());
                st.ready.clear();
            } else {
                st.wave_buf
                    .extend((0..k).filter(|&w| st.live[w] && !st.busy[w] && st.sync.may_proceed(w)));
            }
            if !st.wave_buf.is_empty() {
                for i in 0..st.wave_buf.len() {
                    st.sync.pull(st.wave_buf[i]);
                }
                let outs = self.backend.execute_wave(&st.wave_buf, &st.batches, st.t)?;
                if outs.len() != st.wave_buf.len() {
                    bail!(
                        "backend returned {} outcomes for a wave of {}",
                        outs.len(),
                        st.wave_buf.len()
                    );
                }
                for (i, out) in outs.iter().enumerate() {
                    let w = st.wave_buf[i];
                    // Virtual-slowdown injection: capacity c scales the
                    // work, the availability trace integrates it (a
                    // preemption costs its downtime, not work/ε).
                    let c = self.slowdowns.0[w];
                    let dur = self.traces.traces[w].time_to_complete(st.t, out.work / c)
                        + out.fixed;
                    st.started_at[w] = st.t;
                    st.next_done[w] = st.t + dur;
                    // Unannounced crash: an iteration in flight at (or
                    // dispatched after) the crash instant never
                    // completes.  Only the failure detector below can
                    // reclaim the rank.
                    if let Some(faults) = &self.faults {
                        if faults.crash_time(w).map_or(false, |ct| ct < st.next_done[w]) {
                            st.next_done[w] = f64::INFINITY;
                        }
                    }
                    st.busy[w] = true;
                    // The batch this iteration actually runs with — a
                    // mid-flight membership rebalance must not relabel it.
                    st.exec_batch[w] = st.batches[w];
                    if st.heap_mode {
                        st.gen[w] += 1;
                        st.done_heap.push(DoneEntry {
                            time: st.next_done[w],
                            worker: w,
                            gen: st.gen[w],
                        });
                    }
                    // Arm the progress deadline: miss
                    // max(floor, grace × smoothed-iteration-time) and the
                    // detector suspects the worker.  With no estimate yet
                    // (cold start) the floor is the whole budget.
                    if let Some(det) = &self.detector {
                        let budget = st
                            .est_iter_time(w)
                            .map_or(det.floor_s, |e| (det.grace * e).max(det.floor_s));
                        st.deadline[w] = st.t + budget;
                        if st.heap_mode {
                            st.deadline_heap.push(DoneEntry {
                                time: st.deadline[w],
                                worker: w,
                                gen: st.gen[w],
                            });
                        }
                    }
                }
            }

            // Advance virtual time to the earlier of the next completion
            // and the next membership event (a revocation must be able to
            // cut short an in-flight iteration a preemption has stretched
            // to the VM's recovery — that is its whole point).  Ties on
            // completion time break toward the lowest worker index in
            // both scheduler modes.
            let next_completion = if st.heap_mode {
                st.peek_completion()
            } else {
                (0..k)
                    .filter(|&w| st.busy[w])
                    .min_by(|&a, &b| st.next_done[a].total_cmp(&st.next_done[b]))
            }
            // A crash-suppressed iteration never completes — it must not
            // drag virtual time to infinity.  (The min-first orderings
            // guarantee a finite completion is preferred when one
            // exists, so filtering the winner is enough.)
            .filter(|&w| st.next_done[w].is_finite());
            let next_event_t = events.front().map(|e| e.time);
            // Detector deadlines, late arrivals, and autoscaler timers
            // are a third event source.  An aux event pre-empts only
            // when *strictly* earlier than both the next completion and
            // the next membership event: a worker completing exactly at
            // its deadline survives, and plan-driven transitions outrank
            // synthesized ones at equal timestamps (the bitwise lock of
            // detector-retire == plan-revoke depends on this).
            if let Some((ta, aux)) = st.next_aux() {
                let beats_completion =
                    next_completion.map_or(true, |w| ta < st.next_done[w]);
                let beats_event = next_event_t.map_or(true, |te| ta < te);
                if beats_completion && beats_event {
                    st.t = st.t.max(ta);
                    match aux {
                        AuxEvent::Deadline(w) => {
                            if st.heap_mode {
                                st.deadline_heap.pop(); // `w`'s validated entry
                            }
                            self.suspect(w, st, report)?;
                            if st.stopped_early {
                                // A suspicion-forced barrier can hit the
                                // loss target.
                                *done = true;
                                return Ok(false);
                            }
                        }
                        AuxEvent::Arrival(w) => {
                            self.late_arrival(w, st, report)?;
                        }
                        // Probation expiry: the quarantined worker has
                        // served its sentence — readmit it through the
                        // join path with a warm-start batch.
                        AuxEvent::Probation(w) => {
                            self.probation_readmit(w, st, report)?;
                        }
                        // Provisioning timer: the loop-top autoscale
                        // step acts at the new time.
                        AuxEvent::Spawn => {}
                    }
                    return Ok(true);
                }
            }
            let w = match (next_completion, next_event_t) {
                (Some(w), Some(te)) if te < st.next_done[w] => {
                    st.t = st.t.max(te);
                    return Ok(true);
                }
                (Some(w), _) => w,
                (None, Some(te)) => {
                    // Nobody is live/running: fast-forward to the next
                    // scheduled join.
                    st.t = st.t.max(te);
                    return Ok(true);
                }
                (None, None) => bail!("session deadlock: no runnable workers"),
            };
            if st.heap_mode {
                st.done_heap.pop(); // `w`'s (validated) entry is the top
            }
            let dur = st.next_done[w] - st.started_at[w];
            st.t = st.t.max(st.next_done[w]);
            st.busy[w] = false;
            st.deadline[w] = f64::INFINITY;
            if st.track_obs {
                // Loop-side cumulative mean of observed durations: the
                // deadline/throughput estimate for runs without a
                // dynamic controller (whose smoothed estimate is
                // preferred when present).
                st.obs_sum[w] += dur;
                st.obs_n[w] += 1;
            }
            let clock = st.sync.clock(w);
            let staleness = st.sync.push_update(w);
            st.updates += 1;
            if st.heap_mode {
                // The push may have advanced the live minimum (this was
                // the laggard): admit newly-unblocked idle workers, then
                // re-classify `w` itself.
                st.drain_unblocked();
                st.note_idle(w);
            }

            if st.is_bsp {
                let mut quarantine = false;
                match self.guard_verdict(w, st) {
                    GuardVerdict::Accept => {
                        st.round.push((w, st.started_at[w], dur));
                        // Hand the member's contribution to the backend
                        // now — eager backends combine it into the
                        // round's reduction tree inside the straggler
                        // window; the barrier below only closes the
                        // round.
                        self.backend.stage_update(w, &st.exec_batch)?;
                    }
                    GuardVerdict::Reject => {
                        // Drop the contribution through the revocation
                        // path: the leaf never enters (or leaves) the
                        // eager combine, and the barrier λ-renormalizes
                        // over the surviving members (DESIGN.md §16).
                        self.backend.discard_update(w)?;
                        report.rejections.push(GuardEvent {
                            time: st.t,
                            worker: w,
                            action: GuardAction::Reject,
                        });
                    }
                    GuardVerdict::Quarantine => {
                        self.backend.discard_update(w)?;
                        // Escalate after the barrier check: if this
                        // completion closed the barrier, the round must
                        // settle over the survivors before the revoke.
                        quarantine = true;
                    }
                }
                if st.sync.at_barrier() {
                    // `push_update` above already bumped the model
                    // version for this round; a guard rejection only
                    // shrinks the member list the round closes over.
                    self.close_bsp_round(st, report, false)?;
                    if st.stopped_early {
                        *done = true;
                        return Ok(false);
                    }
                }
                if quarantine {
                    self.quarantine_worker(w, st, report)?;
                    if st.stopped_early {
                        *done = true;
                        return Ok(false);
                    }
                }
            } else {
                match self.guard_verdict(w, st) {
                    GuardVerdict::Accept => {}
                    GuardVerdict::Reject => {
                        // The iteration happened but its update is
                        // dropped whole: no apply, no progress, no
                        // controller observation — a [`GuardEvent`]
                        // stands in for the iteration record.
                        self.backend.discard_update(w)?;
                        report.rejections.push(GuardEvent {
                            time: st.t,
                            worker: w,
                            action: GuardAction::Reject,
                        });
                        return Ok(true);
                    }
                    GuardVerdict::Quarantine => {
                        self.backend.discard_update(w)?;
                        self.quarantine_worker(w, st, report)?;
                        if st.stopped_early {
                            *done = true;
                            return Ok(false);
                        }
                        return Ok(true);
                    }
                }
                if st.sample_iter() {
                    report.iters.push(IterRecord {
                        worker: w,
                        iter: clock,
                        start: st.started_at[w],
                        duration: dur,
                        batch: st.exec_batch[w],
                        wait: 0.0,
                    });
                }
                let loss = self.backend.apply_update(&[w], &st.batches)?;
                // Fresh-equivalent progress: weight by share of the
                // global batch and by the staleness discount; K fresh
                // updates of share 1/K ⇒ one global iteration.  The
                // discount is memoized for small staleness (the common
                // case — ASP/SSP staleness rarely exceeds the cohort
                // size), saving a virtual call + float math per update.
                let disc = st.discount(&self.backend, staleness);
                st.progress += (st.exec_batch[w] / st.global_batch) * disc;
                if let Some(l) = loss {
                    if st.sample_loss() {
                        report.losses.push((st.t, st.updates - 1, l));
                    }
                }
                if hit_loss_target(loss, self.loss_target) {
                    report.reached_target = true;
                    *done = true;
                    return Ok(false);
                }
                if st.updates % k as u64 == 0 {
                    st.global_steps += 1;
                    record_eval(
                        &mut self.backend,
                        report,
                        self.eval_every,
                        st.global_steps,
                        st.t,
                    )?;
                }
                if let Some(ctl) = st.controller.as_mut() {
                    // As at the barrier: an iteration that flew across a
                    // membership rebalance describes the old batch size —
                    // don't feed it into the fresh smoothing interval.
                    if st.exec_batch[w] == st.batches[w] {
                        ctl.observe(w, dur);
                        if let Adjustment::Apply(p) = ctl.maybe_adjust() {
                            apply_adjustment(
                                p,
                                &st.buckets,
                                &mut st.cur_buckets,
                                &mut st.batches,
                                &st.live,
                                ctl.as_mut(),
                                report,
                                &mut st.t,
                                st.updates,
                                self.adjust_cost_s,
                            );
                        }
                    }
                }
            }
        }
        Ok(true)
    }

    /// Assemble the final [`RunReport`] (total time/iterations and the
    /// budget-consumed convergence verdict).
    pub fn finish(&self, mut rs: RunState) -> RunReport {
        rs.report.total_time = rs.st.t;
        rs.report.total_iters = if rs.st.is_bsp {
            rs.st.global_steps
        } else {
            rs.st.updates
        };
        if !rs.report.reached_target {
            rs.report.reached_target = if self.loss_target > 0.0 {
                false
            } else {
                // An explicit budget fully consumed counts as reached:
                // under async sync, bucket quantization can leave the
                // batch sum (and thus per-update progress) slightly
                // short, and a normally completed run must not report
                // failure.
                rs.st.progress >= rs.target as f64
                    || (self.steps > 0 && rs.st.updates >= rs.hard_updates)
            };
        }
        rs.report
    }

    // ------------------------------------------ checkpoint/restore (§15)

    /// Serialize the run's full mutable closure — virtual clock, sync
    /// state, controller, rng-bearing subsystems (autoscaler, backend),
    /// event queues, heaps' flat source-of-truth, and the report so far
    /// — as one versioned JSON object (DESIGN.md §15).  Everything
    /// derivable from the configuration (buckets, scheduler mode,
    /// sampling period) is deliberately *not* persisted: restore
    /// recomputes it, so a checkpoint can only resume under the same
    /// config (which [`Checkpointer`] stores alongside as the echo).
    ///
    /// Floats ride through [`crate::ckpt::enc_f64`], so the
    /// snapshot→restore round trip is bitwise even for non-finite
    /// values, and a resumed run replays identically to an
    /// uninterrupted one.
    pub fn snapshot_run(&self, rs: &RunState) -> Json {
        use crate::ckpt::{enc_f64, enc_f64_slice, enc_u64, CKPT_VERSION};

        fn bools(v: &[bool]) -> Json {
            Json::Arr(v.iter().map(|&b| Json::Bool(b)).collect())
        }
        fn u64s(v: &[u64]) -> Json {
            Json::Arr(v.iter().map(|&x| enc_u64(x)).collect())
        }

        let st = &rs.st;
        let mut j = Json::obj();
        j.set("version", Json::Num(CKPT_VERSION as f64));
        j.set("t", enc_f64(st.t));
        j.set("progress", enc_f64(st.progress));
        j.set("global_batch", enc_f64(st.global_batch));
        j.set("epoch", enc_u64(st.epoch));
        j.set("updates", enc_u64(st.updates));
        j.set("global_steps", enc_u64(st.global_steps));
        j.set("iter_seen", enc_u64(st.iter_seen));
        j.set("loss_seen", enc_u64(st.loss_seen));
        j.set("n_plan_revoked", enc_u64(st.n_plan_revoked));
        j.set("n_suspected", enc_u64(st.n_suspected));
        j.set("target", enc_u64(rs.target));
        j.set("hard_updates", enc_u64(rs.hard_updates));
        j.set("stopped_early", Json::Bool(st.stopped_early));
        j.set("done", Json::Bool(rs.done));
        j.set("batches", enc_f64_slice(&st.batches));
        j.set("exec_batch", enc_f64_slice(&st.exec_batch));
        j.set("next_done", enc_f64_slice(&st.next_done));
        j.set("started_at", enc_f64_slice(&st.started_at));
        j.set("deadline", enc_f64_slice(&st.deadline));
        j.set("pending_arrival", enc_f64_slice(&st.pending_arrival));
        j.set("probation_until", enc_f64_slice(&st.probation_until));
        j.set("obs_sum", enc_f64_slice(&st.obs_sum));
        j.set("live", bools(&st.live));
        j.set("busy", bools(&st.busy));
        j.set("suspected", bools(&st.suspected));
        j.set("quarantined", bools(&st.quarantined));
        j.set("gen", u64s(&st.gen));
        j.set("obs_n", u64s(&st.obs_n));
        j.set(
            "arrivals",
            Json::Arr(st.arrivals.iter().map(|&w| Json::Num(w as f64)).collect()),
        );
        j.set(
            "probations",
            Json::Arr(st.probations.iter().map(|&w| Json::Num(w as f64)).collect()),
        );
        j.set(
            "cur_buckets",
            match &st.cur_buckets {
                Some(v) => Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()),
                None => Json::Null,
            },
        );
        j.set(
            "round",
            Json::Arr(
                st.round
                    .iter()
                    .map(|&(w, s, d)| {
                        Json::Arr(vec![Json::Num(w as f64), enc_f64(s), enc_f64(d)])
                    })
                    .collect(),
            ),
        );
        j.set("sync", st.sync.snapshot());
        j.set(
            "controller",
            match &st.controller {
                Some(c) => {
                    let mut cj = Json::obj();
                    cj.set("label", Json::Str(c.label().to_string()));
                    cj.set("state", c.snapshot());
                    cj
                }
                None => Json::Null,
            },
        );
        j.set(
            "ascaler",
            match &st.ascaler {
                Some(a) => a.snapshot(),
                None => Json::Null,
            },
        );
        j.set(
            "guard",
            match &st.guard {
                Some(g) => g.snapshot(),
                None => Json::Null,
            },
        );
        j.set(
            "events",
            Json::Arr(
                rs.events
                    .iter()
                    .map(|e| {
                        let mut ej = Json::obj();
                        ej.set("time", enc_f64(e.time));
                        ej.set("worker", Json::Num(e.worker as f64));
                        ej.set("kind", Json::Str(e.kind.label().to_string()));
                        ej
                    })
                    .collect(),
            ),
        );
        j.set("report", rs.report.snapshot());
        j.set("backend", self.backend.snapshot_state().unwrap_or(Json::Null));
        j
    }

    /// Rebuild a [`RunState`] from a [`Self::snapshot_run`] object (and
    /// the optional binary sidecar), on a session freshly built from
    /// the checkpoint's own config echo.  Validates the snapshot
    /// against this session at every seam — version, worker count,
    /// sync mode and live mask, controller flavor, autoscaler and
    /// bucket presence — so a checkpoint pointed at the wrong config
    /// fails loudly instead of replaying garbage.  The event heaps and
    /// the ready/blocked index are derived caches and are rebuilt from
    /// the flat per-worker state; lazily-deleted stale entries of the
    /// original heaps are simply absent, which the lazy-deletion
    /// discipline makes equivalent.
    pub fn restore_run(&mut self, state: &Json, bin: Option<&[u8]>) -> Result<RunState> {
        use crate::ckpt::{dec_f64, dec_f64_vec, dec_u64, dec_usize, CKPT_VERSION};

        fn jarr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
            j.get(key)
                .as_arr()
                .ok_or_else(|| anyhow!("checkpoint state: {key} is not an array"))
        }
        fn dec_bools(j: &Json, key: &str, k: usize) -> Result<Vec<bool>> {
            let a = jarr(j, key)?;
            if a.len() != k {
                bail!("checkpoint state: {key} has {} entries, want {k}", a.len());
            }
            a.iter()
                .enumerate()
                .map(|(i, v)| {
                    v.as_bool()
                        .ok_or_else(|| anyhow!("checkpoint state: {key}[{i}] is not a bool"))
                })
                .collect()
        }
        fn dec_f64s(j: &Json, key: &str, k: usize) -> Result<Vec<f64>> {
            let v = dec_f64_vec(j.get(key)).map_err(|e| anyhow!("checkpoint state {key}: {e}"))?;
            if v.len() != k {
                bail!("checkpoint state: {key} has {} entries, want {k}", v.len());
            }
            Ok(v)
        }
        fn dec_u64s(j: &Json, key: &str, k: usize) -> Result<Vec<u64>> {
            let a = jarr(j, key)?;
            if a.len() != k {
                bail!("checkpoint state: {key} has {} entries, want {k}", a.len());
            }
            a.iter()
                .map(|v| dec_u64(v).map_err(|e| anyhow!("checkpoint state {key}: {e}")))
                .collect()
        }
        fn num(j: &Json, key: &str) -> Result<f64> {
            dec_f64(j.get(key)).map_err(|e| anyhow!("checkpoint state {key}: {e}"))
        }
        fn int(j: &Json, key: &str) -> Result<u64> {
            dec_u64(j.get(key)).map_err(|e| anyhow!("checkpoint state {key}: {e}"))
        }
        fn flag(j: &Json, key: &str) -> Result<bool> {
            j.get(key)
                .as_bool()
                .ok_or_else(|| anyhow!("checkpoint state: {key} is not a bool"))
        }

        match state.get("version").as_i64() {
            Some(v) if v == CKPT_VERSION => {}
            Some(v) => bail!("checkpoint state version {v}; this build reads {CKPT_VERSION}"),
            None => bail!("checkpoint state carries no version"),
        }

        let k = self.backend.k();
        let live = dec_bools(state, "live", k)?;
        let busy = dec_bools(state, "busy", k)?;
        let suspected = dec_bools(state, "suspected", k)?;
        let quarantined = dec_bools(state, "quarantined", k)?;
        let batches = dec_f64s(state, "batches", k)?;
        let exec_batch = dec_f64s(state, "exec_batch", k)?;
        let next_done = dec_f64s(state, "next_done", k)?;
        let started_at = dec_f64s(state, "started_at", k)?;
        let deadline = dec_f64s(state, "deadline", k)?;
        let pending_arrival = dec_f64s(state, "pending_arrival", k)?;
        let probation_until = dec_f64s(state, "probation_until", k)?;
        let obs_sum = dec_f64s(state, "obs_sum", k)?;
        let gen = dec_u64s(state, "gen", k)?;
        let obs_n = dec_u64s(state, "obs_n", k)?;

        let arrivals: Vec<usize> = jarr(state, "arrivals")?
            .iter()
            .map(|v| dec_usize(v).map_err(|e| anyhow!("checkpoint state arrivals: {e}")))
            .collect::<Result<_>>()?;
        if let Some(&w) = arrivals.iter().find(|&&w| w >= k) {
            bail!("checkpoint state: late arrival for worker {w} outside 0..{k}");
        }

        let probations: Vec<usize> = jarr(state, "probations")?
            .iter()
            .map(|v| dec_usize(v).map_err(|e| anyhow!("checkpoint state probations: {e}")))
            .collect::<Result<_>>()?;
        if let Some(&w) = probations.iter().find(|&&w| w >= k) {
            bail!("checkpoint state: probation for worker {w} outside 0..{k}");
        }

        let mut round = Vec::new();
        for (i, item) in jarr(state, "round")?.iter().enumerate() {
            let t = item
                .as_arr()
                .ok_or_else(|| anyhow!("checkpoint state: round[{i}] is not an array"))?;
            if t.len() != 3 {
                bail!("checkpoint state: round[{i}] has {} fields, want 3", t.len());
            }
            let w = dec_usize(&t[0]).map_err(|e| anyhow!("checkpoint state round[{i}]: {e}"))?;
            if w >= k {
                bail!("checkpoint state: round member {w} outside 0..{k}");
            }
            round.push((
                w,
                dec_f64(&t[1]).map_err(|e| anyhow!("checkpoint state round[{i}]: {e}"))?,
                dec_f64(&t[2]).map_err(|e| anyhow!("checkpoint state round[{i}]: {e}"))?,
            ));
        }

        // Sync state must agree with the configured mode and live mask.
        let sync_j = state.get("sync");
        if jarr(sync_j, "clocks")?.len() != k {
            bail!("checkpoint state: sync clocks disagree with {k} workers");
        }
        let sync =
            SyncState::restore(sync_j).map_err(|e| anyhow!("checkpoint state sync: {e}"))?;
        if sync.mode() != self.sync {
            bail!(
                "checkpoint was taken under {}; config says {}",
                sync.mode().label(),
                self.sync.label()
            );
        }
        for w in 0..k {
            if sync.is_live(w) != live[w] {
                bail!("checkpoint state: sync and live mask disagree on worker {w}");
            }
        }

        // Controller presence and flavor must match the configured policy.
        let ctl_j = state.get("controller");
        let controller: Option<Box<dyn BatchPolicy>> = match self.policy {
            Policy::Uniform | Policy::Static => {
                if !ctl_j.is_null() {
                    bail!(
                        "checkpoint carries controller state but the {} policy has none",
                        self.policy.label()
                    );
                }
                None
            }
            Policy::Dynamic | Policy::Optimal | Policy::Rl => {
                let want = match self.policy {
                    Policy::Dynamic => "dynamic",
                    Policy::Optimal => "optimal",
                    _ => "rl",
                };
                let got = ctl_j.get("label").as_str().ok_or_else(|| {
                    anyhow!("checkpoint carries no controller state for the {want} policy")
                })?;
                if got != want {
                    bail!("checkpoint controller is {got:?}; config wants {want:?}");
                }
                let cfg = self.controller.clone();
                let cj = ctl_j.get("state");
                Some(match self.policy {
                    Policy::Dynamic => Box::new(
                        DynamicBatcher::restore(cfg, cj).map_err(|e| anyhow!(e))?,
                    ) as Box<dyn BatchPolicy>,
                    Policy::Optimal => {
                        Box::new(OptimalBatcher::restore(cfg, cj).map_err(|e| anyhow!(e))?)
                    }
                    _ => Box::new(RlBatcher::restore(cfg, cj).map_err(|e| anyhow!(e))?),
                })
            }
        };

        // Autoscaler: same presence agreement.
        let asc_j = state.get("ascaler");
        let ascaler = match (&self.autoscale, asc_j.is_null()) {
            (Some(cfg), false) => Some(
                Autoscaler::restore(cfg.clone(), asc_j)
                    .map_err(|e| anyhow!("checkpoint state autoscaler: {e}"))?,
            ),
            (None, true) => None,
            (Some(_), true) => {
                bail!("config enables the autoscaler but the checkpoint has no autoscaler state")
            }
            (None, false) => {
                bail!("checkpoint carries autoscaler state but the config has no autoscaler")
            }
        };

        // Update guard: same presence agreement (DESIGN.md §16).
        let guard_j = state.get("guard");
        let guard = match (&self.guard, guard_j.is_null()) {
            (Some(cfg), false) => Some(
                UpdateGuard::restore(cfg.clone(), k, guard_j)
                    .map_err(|e| anyhow!("checkpoint state guard: {e}"))?,
            ),
            (None, true) => None,
            (Some(_), true) => {
                bail!("config enables the update guard but the checkpoint has no guard state")
            }
            (None, false) => {
                bail!("checkpoint carries guard state but the config has no guard")
            }
        };

        // Buckets are a backend property; the snapshot's view must agree.
        let buckets = self.backend.buckets();
        let cur_buckets = match (&buckets, state.get("cur_buckets").is_null()) {
            (Some(_), false) => {
                let a = jarr(state, "cur_buckets")?;
                if a.len() != k {
                    bail!(
                        "checkpoint state: cur_buckets has {} entries, want {k}",
                        a.len()
                    );
                }
                Some(
                    a.iter()
                        .map(|v| {
                            dec_usize(v).map_err(|e| anyhow!("checkpoint state cur_buckets: {e}"))
                        })
                        .collect::<Result<Vec<_>>>()?,
                )
            }
            (None, true) => None,
            _ => bail!("checkpoint and backend disagree on bucketed execution"),
        };

        let mut events = VecDeque::new();
        for (i, item) in jarr(state, "events")?.iter().enumerate() {
            let time = dec_f64(item.get("time"))
                .map_err(|e| anyhow!("checkpoint state events[{i}]: {e}"))?;
            let worker = dec_usize(item.get("worker"))
                .map_err(|e| anyhow!("checkpoint state events[{i}]: {e}"))?;
            if worker >= k {
                bail!("checkpoint state: membership event for worker {worker} outside 0..{k}");
            }
            let kind = match item.get("kind").as_str() {
                Some("revoke") => MembershipKind::Revoke,
                Some("join") => MembershipKind::Join,
                other => bail!("checkpoint state: events[{i}] kind {other:?}"),
            };
            events.push_back(MembershipEvent { time, worker, kind });
        }

        let report = RunReport::restore(state.get("report"))
            .map_err(|e| anyhow!("checkpoint state report: {e}"))?;

        // Re-establish the backend in the same order a fresh start()
        // would: membership presence, fault schedule, then the
        // snapshotted stream/model state layered on top.
        for w in 0..k {
            if !live[w] {
                self.backend.retire_worker(w)?;
            }
        }
        if let Some(plan) = &self.faults {
            self.backend.set_fault_plan(plan);
        }
        let backend_j = state.get("backend");
        if !backend_j.is_null() {
            self.backend
                .restore_state(backend_j)
                .map_err(|e| anyhow!("backend restore: {e}"))?;
        }
        if let Some(bytes) = bin {
            self.backend
                .restore_binary(bytes)
                .map_err(|e| anyhow!("backend restore: {e}"))?;
        }

        let mut st = LoopState {
            batches,
            exec_batch,
            cur_buckets,
            buckets,
            controller,
            sync,
            live,
            epoch: int(state, "epoch")?,
            t: num(state, "t")?,
            progress: num(state, "progress")?,
            updates: int(state, "updates")?,
            global_steps: int(state, "global_steps")?,
            busy,
            next_done,
            started_at,
            round,
            stopped_early: flag(state, "stopped_early")?,
            global_batch: num(state, "global_batch")?,
            is_bsp: matches!(self.sync, SyncMode::Bsp),
            heap_mode: self.scheduler == Scheduler::Heap,
            ready: BTreeSet::new(),
            blocked: BTreeMap::new(),
            done_heap: BinaryHeap::new(),
            gen,
            wave_buf: Vec::with_capacity(k),
            members_buf: Vec::with_capacity(k),
            alloc_buf: Vec::with_capacity(k),
            report_sample: self.report_sample.max(1),
            iter_seen: int(state, "iter_seen")?,
            loss_seen: int(state, "loss_seen")?,
            discount_cache: vec![f64::NAN; DISCOUNT_MEMO],
            deadline,
            deadline_heap: BinaryHeap::new(),
            suspected,
            pending_arrival,
            arrivals,
            obs_sum,
            obs_n,
            track_obs: self.detector.is_some()
                || self.autoscale.as_ref().map_or(false, |a| a.tput > 0.0),
            n_plan_revoked: int(state, "n_plan_revoked")?,
            n_suspected: int(state, "n_suspected")?,
            guard,
            quarantined,
            probation_until,
            probations,
            ascaler,
        };
        if st.heap_mode {
            for w in 0..k {
                if st.busy[w] {
                    st.done_heap.push(DoneEntry {
                        time: st.next_done[w],
                        worker: w,
                        gen: st.gen[w],
                    });
                    if st.deadline[w].is_finite() {
                        st.deadline_heap.push(DoneEntry {
                            time: st.deadline[w],
                            worker: w,
                            gen: st.gen[w],
                        });
                    }
                } else if st.live[w] {
                    st.note_idle(w);
                }
            }
        }

        Ok(RunState {
            st,
            events,
            report,
            target: int(state, "target")?,
            hard_updates: int(state, "hard_updates")?,
            done: flag(state, "done")?,
        })
    }

    /// [`Self::run`] with durable checkpoints: start, commit a seq-0
    /// snapshot (so even an immediate crash has a resume point), then
    /// drive with periodic commits at update boundaries.  `stop_at`
    /// simulates a coordinator crash at that virtual time (test/fault
    /// injection): the loop stops *without* a final snapshot, exactly
    /// like a process kill.
    pub fn run_checkpointed(
        &mut self,
        config: &Json,
        ck: &mut Checkpointer,
        stop_at: Option<f64>,
    ) -> Result<CkptOutcome> {
        let rs = self.start()?;
        let state = self.snapshot_run(&rs);
        let bin = self.backend.snapshot_binary();
        ck.commit(config, &state, bin.as_deref())
            .map_err(|e| anyhow!(e))?;
        self.drive_checkpointed(rs, config, ck, stop_at)
    }

    /// Continue a [`Self::restore_run`] state under the same
    /// checkpoint discipline (the [`Checkpointer`] numbers new commits
    /// past the recovered ones).
    pub fn resume_checkpointed(
        &mut self,
        rs: RunState,
        config: &Json,
        ck: &mut Checkpointer,
        stop_at: Option<f64>,
    ) -> Result<CkptOutcome> {
        self.drive_checkpointed(rs, config, ck, stop_at)
    }

    fn drive_checkpointed(
        &mut self,
        mut rs: RunState,
        config: &Json,
        ck: &mut Checkpointer,
        stop_at: Option<f64>,
    ) -> Result<CkptOutcome> {
        let every = ck.spec().every_s;
        // Snapshot only at consistent cuts: an update or membership
        // epoch boundary (DESIGN.md §15), throttled to one per
        // `every_s` of virtual time.
        let mut last_mark = (rs.st.global_steps, rs.st.updates, rs.st.epoch);
        let mut last_snap_t = rs.st.t;
        loop {
            if let Some(at) = stop_at {
                if rs.st.t >= at && !rs.done {
                    return Ok(CkptOutcome::Stopped { t: rs.st.t });
                }
            }
            if !self.step(&mut rs)? {
                break;
            }
            let mark = (rs.st.global_steps, rs.st.updates, rs.st.epoch);
            if mark != last_mark {
                last_mark = mark;
                if rs.st.t - last_snap_t >= every {
                    let state = self.snapshot_run(&rs);
                    let bin = self.backend.snapshot_binary();
                    ck.commit(config, &state, bin.as_deref())
                        .map_err(|e| anyhow!(e))?;
                    last_snap_t = rs.st.t;
                }
            }
        }
        Ok(CkptOutcome::Completed(self.finish(rs)))
    }

    /// Close the open BSP round: barrier accounting, one λ-weighted
    /// aggregate update over the round's members (the contributions
    /// themselves were staged at each completion event — eager backends
    /// have already combined them, so the barrier applies the reduction
    /// root rather than sweeping k gradients), controller
    /// observe/adjust.  Called on a normal barrier and — with
    /// `membership_forced` — when a mid-round revocation leaves every
    /// survivor already at the barrier.
    fn close_bsp_round(
        &mut self,
        st: &mut LoopState,
        report: &mut RunReport,
        membership_forced: bool,
    ) -> Result<()> {
        if st.round.is_empty() {
            // Every member's contribution was guard-rejected: nothing
            // to apply — the round is a wash (no progress, no global
            // step), and the workers simply redispatch at the advanced
            // clock.  (`push_update` already bumped the version.)
            return Ok(());
        }
        st.round.sort_by_key(|r| r.0);
        // Barrier release time: the last member completion on a normal
        // close; on a membership-forced close the survivors stall until
        // the revocation itself (st.t), and that stall is wait too.
        let round_end = st
            .round
            .iter()
            .map(|&(_, s, d)| s + d)
            .max_by(f64::total_cmp)
            .map_or(st.t, |m| m.max(st.t));
        // Weight gradients by the batches they were *computed* with: a
        // membership rebalance between a worker's wave start and the
        // barrier must not relabel its contribution.  `exec_batch`
        // already holds exactly that for every round member, and
        // `apply_update` only reads its members' entries — no per-round
        // clone of the allocation vector needed.
        // Sampling is *round-aligned* under BSP: every n-th round keeps
        // ALL its member records (a flat every-n-th-record rule would
        // alias with the round period and drop whole workers from the
        // report whenever n shares a factor with the live count).
        let keep_round = st.global_steps % st.report_sample == 0;
        if keep_round {
            for &(rw, rs, rd) in &st.round {
                report.iters.push(IterRecord {
                    worker: rw,
                    iter: st.global_steps,
                    start: rs,
                    duration: rd,
                    batch: st.exec_batch[rw],
                    wait: round_end - rs - rd,
                });
            }
        }
        st.members_buf.clear();
        st.members_buf.extend(st.round.iter().map(|r| r.0));
        let loss = self.backend.apply_update(&st.members_buf, &st.exec_batch)?;
        st.global_steps += 1;
        st.progress += 1.0;
        if let Some(l) = loss {
            if keep_round {
                report.losses.push((st.t, st.global_steps - 1, l));
            }
        }
        record_eval(
            &mut self.backend,
            report,
            self.eval_every,
            st.global_steps,
            st.t,
        )?;
        if hit_loss_target(loss, self.loss_target) {
            report.reached_target = true;
            st.stopped_early = true;
        }
        // A membership-forced close skips the controller: the revoked
        // rank is still active inside the DynamicBatcher at this point
        // (retire runs right after, in rebalance_membership), so an
        // adjustment here would be computed over the wrong cohort — and
        // the imminent rebalance resets the smoothing interval anyway,
        // making these observations moot.
        if !st.stopped_early && !membership_forced {
            if let Some(ctl) = st.controller.as_mut() {
                for &(rw, _, rd) in &st.round {
                    // Skip members whose batch was rebalanced mid-flight
                    // (an epoch landed inside this round): their duration
                    // describes the old batch size, and the controller's
                    // smoothing interval was reset for the new one.
                    if st.exec_batch[rw] == st.batches[rw] {
                        ctl.observe(rw, rd);
                    }
                }
                if let Adjustment::Apply(p) = ctl.maybe_adjust() {
                    apply_adjustment(
                        p,
                        &st.buckets,
                        &mut st.cur_buckets,
                        &mut st.batches,
                        &st.live,
                        ctl.as_mut(),
                        report,
                        &mut st.t,
                        st.global_steps,
                        self.adjust_cost_s,
                    );
                }
            }
        }
        st.round.clear();
        Ok(())
    }

    /// Apply one membership transition (idempotent: a revoke of an
    /// already-absent worker or a join of a live one is a no-op, so
    /// trace-derived and explicit event lists compose safely).
    fn apply_membership(
        &mut self,
        ev: MembershipEvent,
        st: &mut LoopState,
        report: &mut RunReport,
    ) -> Result<()> {
        let w = ev.worker;
        match ev.kind {
            MembershipKind::Revoke => {
                if !st.live[w] {
                    return Ok(());
                }
                st.epoch += 1;
                st.live[w] = false;
                // The instance is gone: in-flight work and any
                // completed-but-unapplied round contribution die with it.
                // (A stale heap entry for an in-flight iteration is
                // filtered lazily — `busy` is false and the generation
                // won't match any future reschedule.)
                if st.heap_mode && !st.busy[w] {
                    st.remove_idle(w);
                }
                st.busy[w] = false;
                st.round.retain(|r| r.0 != w);
                st.sync.retire(w);
                if st.heap_mode {
                    // Retiring the laggard can advance the live minimum.
                    st.drain_unblocked();
                }
                self.backend.retire_worker(w)?;
                // A mid-round revocation can leave every survivor already
                // waiting at the barrier: close the round now (with
                // pre-revocation batch weights), then rebalance.
                let n_live = st.sync.live_count();
                if st.is_bsp && !st.round.is_empty() && st.round.len() == n_live {
                    st.sync.close_round();
                    self.close_bsp_round(st, report, true)?;
                }
                self.rebalance_membership(st, MembershipKind::Revoke, w);
            }
            MembershipKind::Join => {
                // Any (re)admission clears suspicion state — whether it
                // is the detector's own readmit, a plan-scheduled
                // rejoin, or an autoscaled replacement taking the rank.
                // Centralized here (before the idempotence early-return)
                // so a pending late arrival can never fire for a rank
                // that is already live again.
                if st.suspected[w] {
                    st.suspected[w] = false;
                    st.pending_arrival[w] = f64::INFINITY;
                    st.arrivals.retain(|&x| x != w);
                    st.n_suspected = st.n_suspected.saturating_sub(1);
                }
                // Likewise for quarantine (DESIGN.md §16): any
                // readmission — probation expiry, a plan-scheduled
                // rejoin, or an autoscaled replacement taking the rank —
                // wipes the slate, so a stale probation timer can never
                // fire for a rank that is already live again.
                if st.quarantined[w] {
                    st.quarantined[w] = false;
                    st.probation_until[w] = f64::INFINITY;
                    st.probations.retain(|&x| x != w);
                }
                if st.live[w] {
                    return Ok(());
                }
                st.epoch += 1;
                st.sync.admit(w);
                st.live[w] = true;
                if st.heap_mode {
                    // Seeded at the live minimum ⇒ admissible in every
                    // sync mode.
                    st.note_idle(w);
                }
                self.backend.admit_worker(w)?;
                self.rebalance_membership(st, MembershipKind::Join, w);
            }
        }
        report.epochs.push(EpochEvent {
            time: st.t,
            epoch: st.epoch,
            worker: w,
            kind: ev.kind,
            live: st.sync.live_count(),
            batches: st.batches.clone(),
        });
        Ok(())
    }

    /// Redistribute batch mass after a membership transition, conserving
    /// the global batch: the controller water-fills (revocation) or
    /// warm-starts (join); open-loop policies recompute their allocation
    /// over the live cohort.  Bucketed backends snap the result.
    fn rebalance_membership(&mut self, st: &mut LoopState, kind: MembershipKind, worker: usize) {
        // The proposal lands in a reusable scratch buffer
        // (`DynamicBatcher::batches_into`) rather than a fresh Vec per
        // transition.
        match st.controller.as_mut() {
            Some(ctl) => {
                match kind {
                    MembershipKind::Revoke => ctl.retire(worker),
                    MembershipKind::Join => ctl.admit(worker),
                }
                ctl.batches_into(&mut st.alloc_buf);
            }
            None => {
                let p = self.policy_alloc(&st.live, st.global_batch);
                st.alloc_buf.clear();
                st.alloc_buf.extend_from_slice(&p);
            }
        }
        match &st.buckets {
            Some(grid) => {
                let cur = st.cur_buckets.as_mut().expect("bucketed session state");
                let (snapped, _) = quantize_alloc_live(&st.alloc_buf, grid, cur, &st.live);
                st.batches.clear();
                st.batches.extend(snapped.iter().map(|&b| b as f64));
                *cur = snapped;
                if let Some(ctl) = st.controller.as_mut() {
                    ctl.set_batches(&st.batches);
                }
            }
            None => {
                st.batches.clear();
                st.batches.extend_from_slice(&st.alloc_buf);
            }
        }
    }

    /// Detector suspicion (DESIGN.md §12): worker `w` missed its
    /// progress deadline while in flight.  Provisionally retire it
    /// through the same path a plan revocation takes — same epoch
    /// accounting, same forced-barrier handling, same rebalance — so a
    /// detector-driven retire is bitwise identical to a plan-driven
    /// revoke at the same event time.  Under `late=readmit`, the
    /// in-flight completion (when one is still coming — crashes never
    /// complete) is remembered as a pending late arrival that reverses
    /// the suspicion.
    fn suspect(
        &mut self,
        w: usize,
        st: &mut LoopState,
        report: &mut RunReport,
    ) -> Result<()> {
        debug_assert!(st.live[w] && st.busy[w], "suspicion of a non-running worker");
        st.deadline[w] = f64::INFINITY;
        st.suspected[w] = true;
        st.n_suspected += 1;
        let readmit = self
            .detector
            .as_ref()
            .map_or(false, |d| d.late == LatePolicy::Readmit);
        if readmit && st.next_done[w].is_finite() {
            st.pending_arrival[w] = st.next_done[w];
            st.arrivals.push(w);
        }
        report.suspicions.push(DetectorEvent {
            time: st.t,
            worker: w,
            action: DetectorAction::Suspect,
        });
        self.apply_membership(
            MembershipEvent {
                time: st.t,
                worker: w,
                kind: MembershipKind::Revoke,
            },
            st,
            report,
        )
    }

    /// A suspected worker's in-flight iteration completed after all —
    /// the suspicion was false.  Under `late=readmit` the worker rejoins
    /// through the plan-join path (its late work is still discarded:
    /// the round moved on without it).  The suspicion bookkeeping is
    /// cleared inside `apply_membership`'s join arm.
    fn late_arrival(
        &mut self,
        w: usize,
        st: &mut LoopState,
        report: &mut RunReport,
    ) -> Result<()> {
        debug_assert!(
            st.suspected[w] && !st.live[w],
            "late arrival for a non-suspected worker"
        );
        report.suspicions.push(DetectorEvent {
            time: st.t,
            worker: w,
            action: DetectorAction::Readmit,
        });
        self.apply_membership(
            MembershipEvent {
                time: st.t,
                worker: w,
                kind: MembershipKind::Join,
            },
            st,
            report,
        )
    }

    /// Inspect worker `w`'s just-completed update (DESIGN.md §16).
    /// With no guard configured, or a backend that cannot observe
    /// payload norms, every contribution is accepted unchecked — and
    /// the guard state is untouched, which is what keeps an enabled but
    /// never-firing guard bitwise invisible.
    fn guard_verdict(&mut self, w: usize, st: &mut LoopState) -> GuardVerdict {
        let Some(g) = st.guard.as_mut() else {
            return GuardVerdict::Accept;
        };
        match self.backend.update_norm(w) {
            Some(norm) => g.check(w, norm),
            None => GuardVerdict::Accept,
        }
    }

    /// Guard escalation (DESIGN.md §16): worker `w` hit its strike
    /// budget — retire it through the same path a plan revocation takes
    /// (same epoch accounting, same forced-barrier handling, same
    /// rebalance), exactly as the detector's `suspect` does.  Under
    /// `late=readmit` a probation timer is armed; when it expires the
    /// worker rejoins through the plan-join path with a warm-start
    /// batch.  Under `late=drop` the rank stays vacant (an autoscaled
    /// replacement or plan join may still reclaim it).
    fn quarantine_worker(
        &mut self,
        w: usize,
        st: &mut LoopState,
        report: &mut RunReport,
    ) -> Result<()> {
        debug_assert!(st.live[w], "quarantine of an absent worker");
        st.quarantined[w] = true;
        let readmit = self
            .guard
            .as_ref()
            .map_or(false, |g| g.late == LatePolicy::Readmit);
        if readmit {
            let probation_s = self.guard.as_ref().unwrap().probation_s;
            st.probation_until[w] = st.t + probation_s;
            st.probations.push(w);
        }
        report.quarantines.push(GuardEvent {
            time: st.t,
            worker: w,
            action: GuardAction::Quarantine,
        });
        self.apply_membership(
            MembershipEvent {
                time: st.t,
                worker: w,
                kind: MembershipKind::Revoke,
            },
            st,
            report,
        )
    }

    /// A quarantined worker's probation expired: readmit it through the
    /// plan-join path.  The quarantine bookkeeping (flag, timer,
    /// probation list) is cleared inside `apply_membership`'s join arm.
    fn probation_readmit(
        &mut self,
        w: usize,
        st: &mut LoopState,
        report: &mut RunReport,
    ) -> Result<()> {
        debug_assert!(
            st.quarantined[w] && !st.live[w],
            "probation readmit for a non-quarantined worker"
        );
        report.quarantines.push(GuardEvent {
            time: st.t,
            worker: w,
            action: GuardAction::Readmit,
        });
        self.apply_membership(
            MembershipEvent {
                time: st.t,
                worker: w,
                kind: MembershipKind::Join,
            },
            st,
            report,
        )
    }

    /// Autoscaler actuation, run at the top of every loop iteration:
    /// (1) admit replacements whose cold start has finished — each takes
    /// the lowest vacant rank (never one still owed a late arrival) and
    /// joins through the plan-join path; (2) run spawn attempts that are
    /// due (fleet below the capacity floor, or smoothed throughput below
    /// the trigger), with exponential backoff + jitter on failures.
    fn autoscale_step(&mut self, st: &mut LoopState, report: &mut RunReport) -> Result<()> {
        if st.ascaler.is_none() {
            return Ok(());
        }
        let k = st.live.len();
        // 1. Materialize finished cold starts as joins.
        while let Some(_ready_at) = st.ascaler.as_mut().unwrap().take_ready(st.t) {
            let rank = (0..k).find(|&w| {
                !st.live[w]
                    && !(st.suspected[w] && st.pending_arrival[w].is_finite())
                    // A rank serving probation is owed its own readmit.
                    && !(st.quarantined[w] && st.probation_until[w].is_finite())
            });
            match rank {
                Some(w) => {
                    report.spawns.push(SpawnEvent {
                        time: st.t,
                        worker: Some(w),
                        action: SpawnAction::Ready,
                        attempt: 0,
                    });
                    self.apply_membership(
                        MembershipEvent {
                            time: st.t,
                            worker: w,
                            kind: MembershipKind::Join,
                        },
                        st,
                        report,
                    )?;
                }
                None => {
                    // Capacity arrived but every rank is live again (or
                    // owed a late arrival): paid-for but unused — the
                    // cost-vs-time curves count these.
                    report.spawns.push(SpawnEvent {
                        time: st.t,
                        worker: None,
                        action: SpawnAction::Wasted,
                        attempt: 0,
                    });
                }
            }
        }
        // 2. Run due spawn attempts.  The smoothed fleet throughput is
        // only computed when the trigger is enabled.
        let tput = if st.ascaler.as_ref().unwrap().cfg().tput > 0.0 {
            st.fleet_tput()
        } else {
            None
        };
        if let Some(tp) = tput {
            st.ascaler.as_mut().unwrap().observe_throughput(tp);
        }
        loop {
            let live = st.sync.live_count();
            let a = st.ascaler.as_mut().unwrap();
            if !a.wants_spawn(live, st.t, tput) {
                break;
            }
            let attempt = a.attempts();
            match a.try_spawn(st.t) {
                SpawnOutcome::Started { .. } => {
                    report.spawns.push(SpawnEvent {
                        time: st.t,
                        worker: None,
                        action: SpawnAction::Request,
                        attempt,
                    });
                }
                SpawnOutcome::Failed { .. } => {
                    report.spawns.push(SpawnEvent {
                        time: st.t,
                        worker: None,
                        action: SpawnAction::Fail,
                        attempt: attempt + 1,
                    });
                }
                SpawnOutcome::GaveUp => {
                    report.spawns.push(SpawnEvent {
                        time: st.t,
                        worker: None,
                        action: SpawnAction::GaveUp,
                        attempt: attempt + 1,
                    });
                }
            }
        }
        Ok(())
    }
}

/// Memoization width for [`Backend::staleness_discount`]: staleness is
/// bounded by in-flight updates, which rarely exceeds the cohort size —
/// values at or above this fall through to the backend call.
const DISCOUNT_MEMO: usize = 64;

/// Completion-heap entry, ordered *min-first* by (time, worker) so
/// `BinaryHeap` (a max-heap) pops the earliest completion with ties
/// broken toward the lowest worker index — exactly the element the
/// seed's first-minimum linear scan selected.  `gen` implements lazy
/// deletion: an entry is live only while it matches the worker's current
/// schedule generation (a revocation, or any reschedule, strands it).
struct DoneEntry {
    time: f64,
    worker: usize,
    gen: u64,
}

impl PartialEq for DoneEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for DoneEntry {}

impl PartialOrd for DoneEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for DoneEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Deliberately reversed: the max-heap's top is the min entry.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.worker.cmp(&self.worker))
    }
}

/// Resumable state of one [`Session::run`]: everything the event loop
/// carries between iterations.  Produced by [`Session::start`],
/// advanced one event at a time by [`Session::step`], consumed by
/// [`Session::finish`].  The fleet layer ([`crate::fleet`]) drives many
/// of these on one merged virtual clock; the accessors below are its
/// whole control surface, and none of them perturbs the job's own
/// event or rng streams unless invoked — an undisturbed `RunState` is
/// bit-identical to a plain `run()`.
pub struct RunState {
    st: LoopState,
    events: VecDeque<MembershipEvent>,
    report: RunReport,
    target: u64,
    hard_updates: u64,
    done: bool,
}

impl RunState {
    /// Current virtual time (seconds since this job's own t = 0).
    pub fn now(&self) -> f64 {
        self.st.t
    }

    /// Has the run finished?  ([`Session::step`] returned `false`.)
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Live-cohort size right now.
    pub fn live_count(&self) -> usize {
        self.st.sync.live_count()
    }

    /// Is rank `w` currently a cohort member?
    pub fn is_live(&self, w: usize) -> bool {
        self.st.live.get(w).copied().unwrap_or(false)
    }

    /// The report accumulated so far (totals are filled by
    /// [`Session::finish`]).
    pub fn report(&self) -> &RunReport {
        &self.report
    }

    /// Inject a membership event (fleet grant/reclaim actuation) into
    /// the pending queue, preserving the plan's deterministic
    /// (time, worker, revoke-before-join) order.  Events dated at or
    /// before the current clock fire at the next [`Session::step`];
    /// they share the plan-event code path (idempotent
    /// revoke/join), so fleet preemption *is* the PR 3 revocation path.
    pub fn inject_membership(&mut self, ev: MembershipEvent) {
        let at = self
            .events
            .iter()
            .position(|e| crate::trace::cmp_events(e, &ev) == std::cmp::Ordering::Greater)
            .unwrap_or(self.events.len());
        self.events.insert(at, ev);
    }

    /// Arbiter-client hook: cap the autoscaler's remaining private
    /// spawn pool at the shared-capacity `spare` the fleet can lend
    /// right now.  Capping only ever shrinks the pool (the fleet lends
    /// headroom, it never refills), so an uncontended fleet — spare
    /// always ≥ pool — leaves the autoscaler untouched.  No-op for
    /// sessions without an autoscaler.
    pub fn cap_spawn_pool(&mut self, spare: usize) {
        if let Some(a) = self.st.ascaler.as_mut() {
            a.cap_pool(spare);
        }
    }

    /// Spawn-pool slots still unspent (`None` without an autoscaler).
    /// The fleet samples this around each step to charge provisioning
    /// draws against the shared capacity.
    pub fn spawn_pool_left(&self) -> Option<usize> {
        self.st.ascaler.as_ref().map(|a| a.pool_left())
    }
}

/// How a checkpointed drive ([`Session::run_checkpointed`] /
/// [`Session::resume_checkpointed`]) ended.
pub enum CkptOutcome {
    /// Ran to its budget/target; the finished report.
    Completed(RunReport),
    /// The injected coordinator crash (`stop_at`) fired at virtual
    /// time `t` — state above the last durable checkpoint is lost,
    /// exactly like a process kill.
    Stopped { t: f64 },
}

/// Mutable per-run state of the [`Session::run`] event loop, factored
/// out so membership transitions and BSP round closure can live in
/// helper methods without fighting the borrow checker.
struct LoopState {
    /// Current allocation (0 for absent ranks).
    batches: Vec<f64>,
    /// Batch each worker's current/last iteration executed with.
    exec_batch: Vec<f64>,
    cur_buckets: Option<Vec<usize>>,
    buckets: Option<Vec<usize>>,
    controller: Option<Box<dyn BatchPolicy>>,
    sync: SyncState,
    live: Vec<bool>,
    epoch: u64,
    t: f64,
    progress: f64,
    updates: u64,
    global_steps: u64,
    busy: Vec<bool>,
    next_done: Vec<f64>,
    started_at: Vec<f64>,
    /// BSP round accumulator: (worker, start, duration) of the open round.
    round: Vec<(usize, f64, f64)>,
    stopped_early: bool,
    global_batch: f64,
    is_bsp: bool,

    // ----- O(log k) event scheduling (Scheduler::Heap, DESIGN.md §10)
    heap_mode: bool,
    /// Idle live workers the sync gate admits *now*; the next wave is
    /// this set, drained in ascending order.
    ready: BTreeSet<usize>,
    /// Idle live workers the gate blocks, bucketed by their clock; when
    /// the live minimum advances, whole buckets move to `ready`.
    blocked: BTreeMap<u64, Vec<usize>>,
    /// Min-heap of in-flight completion times (lazy deletion via `gen`).
    done_heap: BinaryHeap<DoneEntry>,
    /// Schedule generation per worker: bumped at every wave start, so
    /// stranded heap entries from revoked iterations never resolve.
    gen: Vec<u64>,

    // ----- reusable hot-loop buffers (no per-event allocations)
    wave_buf: Vec<usize>,
    members_buf: Vec<usize>,
    /// Membership-rebalance proposal scratch (`DynamicBatcher::batches_into`).
    alloc_buf: Vec<f64>,

    // ----- report sampling (`SessionBuilder::report_sample`)
    report_sample: u64,
    iter_seen: u64,
    loss_seen: u64,

    /// Memoized staleness discounts (NaN = not yet computed).
    discount_cache: Vec<f64>,

    // ----- failure detection & autoscaled recovery (DESIGN.md §12)
    /// Per-worker progress deadline (INF = not armed / not in flight).
    deadline: Vec<f64>,
    /// Min-heap of armed deadlines (heap mode; lazy deletion shares the
    /// completion heap's `gen` discipline — scan mode scans `deadline`).
    deadline_heap: BinaryHeap<DoneEntry>,
    /// Currently-suspected workers (provisionally retired, not yet
    /// readmitted or replaced).
    suspected: Vec<bool>,
    /// Pending late-arrival time per suspected worker (INF = none).
    pending_arrival: Vec<f64>,
    /// Workers with a pending late arrival (small; scanned linearly).
    arrivals: Vec<usize>,
    /// Loop-side cumulative duration stats — the deadline estimate for
    /// runs without a dynamic controller.
    obs_sum: Vec<f64>,
    obs_n: Vec<u64>,
    track_obs: bool,
    /// Plan-driven revocations applied (for the empty-fleet error).
    n_plan_revoked: u64,
    /// Workers currently suspected (readmits decrement).
    n_suspected: u64,

    // ----- data-plane guard & quarantine (DESIGN.md §16)
    /// Update validator (finite check + median/MAD norm gate), present
    /// iff the session was built with a [`GuardCfg`].
    guard: Option<UpdateGuard>,
    /// Workers currently quarantined (retired on strikes, not yet
    /// readmitted or replaced).
    quarantined: Vec<bool>,
    /// Probation expiry per quarantined worker (INF = none armed).
    probation_until: Vec<f64>,
    /// Workers with an armed probation timer (small; scanned linearly,
    /// like `arrivals` — `next_aux` stays O(1) when the guard is idle).
    probations: Vec<usize>,
    ascaler: Option<Autoscaler>,
}

/// The third event source of the run loop (besides completions and
/// plan-membership events): detector deadlines, late arrivals,
/// probation expiries, and autoscaler timers.  Selection order at equal
/// timestamps is Arrival < Deadline < Probation < Spawn, then lowest
/// worker — fixed so both scheduler modes agree bitwise.
enum AuxEvent {
    Arrival(usize),
    Deadline(usize),
    Probation(usize),
    Spawn,
}

/// Strict (time, kind-rank, worker) ordering for aux-event selection.
fn aux_better(t: f64, rank: u8, w: usize, cur: &Option<(f64, u8, usize, AuxEvent)>) -> bool {
    match cur {
        None => true,
        Some((ct, cr, cw, _)) => match t.total_cmp(ct) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => (rank, w) < (*cr, *cw),
        },
    }
}

impl LoopState {
    /// Largest clock the gate admits for an idle live worker.
    fn admit_threshold(&self) -> u64 {
        match self.sync.mode() {
            SyncMode::Bsp => self.sync.min_clock(),
            SyncMode::Asp => u64::MAX,
            SyncMode::Ssp { bound } => self.sync.min_clock().saturating_add(bound),
        }
    }

    /// Classify an idle live worker: ready now, or blocked on its clock.
    fn note_idle(&mut self, w: usize) {
        debug_assert!(self.live[w] && !self.busy[w]);
        let clock = self.sync.clock(w);
        if clock <= self.admit_threshold() {
            self.ready.insert(w);
        } else {
            self.blocked.entry(clock).or_default().push(w);
        }
    }

    /// Move every blocked worker the gate now admits into `ready`.  Call
    /// after any mutation that can advance the live minimum (push_update,
    /// retire) — the admission threshold is monotone non-decreasing, so
    /// `ready` members never need demotion.
    fn drain_unblocked(&mut self) {
        if self.blocked.is_empty() {
            return;
        }
        let thr = self.admit_threshold();
        while let Some(c) = self.blocked.keys().next().copied() {
            if c > thr {
                break;
            }
            for w in self.blocked.remove(&c).unwrap() {
                self.ready.insert(w);
            }
        }
    }

    /// Forget an idle worker (revocation while not in flight).
    fn remove_idle(&mut self, w: usize) {
        if self.ready.remove(&w) {
            return;
        }
        let clock = self.sync.clock(w);
        if let Some(bucket) = self.blocked.get_mut(&clock) {
            bucket.retain(|&x| x != w);
            if bucket.is_empty() {
                self.blocked.remove(&clock);
            }
        }
    }

    /// Earliest valid in-flight completion, discarding stranded entries
    /// (revoked / rescheduled workers) along the way.  Leaves the valid
    /// entry on the heap — the caller pops it only when it actually
    /// completes (a membership event may pre-empt it).
    fn peek_completion(&mut self) -> Option<usize> {
        while let Some(top) = self.done_heap.peek() {
            let w = top.worker;
            if self.busy[w] && self.gen[w] == top.gen {
                return Some(w);
            }
            self.done_heap.pop();
        }
        None
    }

    /// Keep this record? (every `report_sample`-th, starting with the first)
    fn sample_iter(&mut self) -> bool {
        let keep = self.iter_seen % self.report_sample == 0;
        self.iter_seen += 1;
        keep
    }

    fn sample_loss(&mut self) -> bool {
        let keep = self.loss_seen % self.report_sample == 0;
        self.loss_seen += 1;
        keep
    }

    /// Earliest valid armed deadline (heap mode), mirroring
    /// [`Self::peek_completion`]'s lazy-deletion discipline: an entry is
    /// stale once its worker completed (`busy` false), was revoked
    /// (`live` false), or was redispatched (generation mismatch).
    /// Leaves the valid entry on the heap — the caller pops it only when
    /// the deadline actually fires.
    fn peek_deadline(&mut self) -> Option<usize> {
        while let Some(top) = self.deadline_heap.peek() {
            let w = top.worker;
            if self.live[w] && self.busy[w] && self.gen[w] == top.gen {
                return Some(w);
            }
            self.deadline_heap.pop();
        }
        None
    }

    /// Cumulative mean observed iteration time (None until observed).
    fn obs_mean(&self, w: usize) -> Option<f64> {
        if self.obs_n[w] > 0 {
            Some(self.obs_sum[w] / self.obs_n[w] as f64)
        } else {
            None
        }
    }

    /// Best available iteration-time estimate for worker `w`: the
    /// controller's smoothed estimate when a dynamic policy runs
    /// (already maintained for joins), else the loop's cumulative mean.
    fn est_iter_time(&self, w: usize) -> Option<f64> {
        self.controller
            .as_ref()
            .and_then(|c| c.smoothed_iter_time(w))
            .or_else(|| self.obs_mean(w))
    }

    /// Smoothed fleet throughput (examples/s): Σ over live workers of
    /// batch / estimated iteration time.  None until any estimate exists.
    fn fleet_tput(&self) -> Option<f64> {
        let mut sum = 0.0;
        let mut any = false;
        for w in 0..self.live.len() {
            if !self.live[w] {
                continue;
            }
            if let Some(e) = self.est_iter_time(w) {
                if e > 0.0 {
                    sum += self.batches[w] / e;
                    any = true;
                }
            }
        }
        if any {
            Some(sum)
        } else {
            None
        }
    }

    /// Earliest pending aux event: (time, event), or None when the
    /// detector/autoscaler machinery is idle (fault-free runs without a
    /// detector or autoscaler take this path every iteration — it must
    /// stay O(1) there: no arrivals, empty deadline state, no
    /// autoscaler).
    fn next_aux(&mut self) -> Option<(f64, AuxEvent)> {
        let mut best: Option<(f64, u8, usize, AuxEvent)> = None;
        for &w in &self.arrivals {
            let t = self.pending_arrival[w];
            if t.is_finite() && aux_better(t, 0, w, &best) {
                best = Some((t, 0, w, AuxEvent::Arrival(w)));
            }
        }
        let dl = if self.heap_mode {
            self.peek_deadline()
        } else {
            (0..self.live.len())
                .filter(|&w| self.live[w] && self.busy[w] && self.deadline[w].is_finite())
                .min_by(|&a, &b| self.deadline[a].total_cmp(&self.deadline[b]))
        };
        if let Some(w) = dl {
            let t = self.deadline[w];
            if t.is_finite() && aux_better(t, 1, w, &best) {
                best = Some((t, 1, w, AuxEvent::Deadline(w)));
            }
        }
        for &w in &self.probations {
            let t = self.probation_until[w];
            if t.is_finite() && aux_better(t, 2, w, &best) {
                best = Some((t, 2, w, AuxEvent::Probation(w)));
            }
        }
        if let Some(a) = &self.ascaler {
            if let Some(t) = a.next_event(self.sync.live_count(), None) {
                if aux_better(t, 3, 0, &best) {
                    best = Some((t, 3, 0, AuxEvent::Spawn));
                }
            }
        }
        best.map(|(t, _, _, ev)| (t, ev))
    }

    /// Staleness discount, memoized for small staleness values.  Sound
    /// because [`Backend::staleness_discount`] is a pure function of the
    /// staleness for a fixed backend.
    fn discount<B: Backend>(&mut self, backend: &B, staleness: u64) -> f64 {
        if (staleness as usize) < self.discount_cache.len() {
            let slot = &mut self.discount_cache[staleness as usize];
            if slot.is_nan() {
                *slot = backend.staleness_discount(staleness);
            }
            *slot
        } else {
            backend.staleness_discount(staleness)
        }
    }
}

/// Push a periodic eval record when one is due and the backend evaluates.
fn record_eval<B: Backend>(
    backend: &mut B,
    report: &mut RunReport,
    eval_every: u64,
    step: u64,
    t: f64,
) -> Result<()> {
    if eval_every > 0 && step % eval_every == 0 {
        if let Some((loss, metric)) = backend.eval(step, t)? {
            report.evals.push(EvalRecord {
                time: t,
                iter: step,
                loss,
                metric,
            });
        }
    }
    Ok(())
}

/// Early-stop check: a real loss fell below a positive target.
fn hit_loss_target(loss: Option<f64>, target: f64) -> bool {
    target > 0.0 && loss.map_or(false, |l| l < target)
}

/// Quantize only the live entries of an allocation to the bucket grid;
/// absent ranks stay at bucket 0 / batch 0 (a 0 proposal must never
/// snap to the grid's smallest bucket).
fn quantize_alloc_live(
    proposal: &[f64],
    grid: &[usize],
    cur: &[usize],
    live: &[bool],
) -> (Vec<usize>, Vec<bool>) {
    let idx: Vec<usize> = (0..proposal.len()).filter(|&i| live[i]).collect();
    let sub_p: Vec<f64> = idx.iter().map(|&i| proposal[i]).collect();
    let sub_c: Vec<usize> = idx.iter().map(|&i| cur[i]).collect();
    let (snapped, swaps) = quantize_alloc(&sub_p, grid, &sub_c);
    let mut full_s = vec![0usize; proposal.len()];
    let mut full_w = vec![false; proposal.len()];
    for ((&i, &s), &w) in idx.iter().zip(&snapped).zip(&swaps) {
        full_s[i] = s;
        full_w[i] = w;
    }
    (full_s, full_w)
}

/// Apply a controller proposal: quantize to the bucket grid when the
/// backend has one (an executable swap; recorded only when some bucket
/// actually changes), or apply the continuous allocation directly.
#[allow(clippy::too_many_arguments)]
fn apply_adjustment(
    proposal: Vec<f64>,
    grid: &Option<Vec<usize>>,
    cur_buckets: &mut Option<Vec<usize>>,
    batches: &mut Vec<f64>,
    live: &[bool],
    ctl: &mut dyn BatchPolicy,
    report: &mut RunReport,
    t: &mut f64,
    iter: u64,
    cost: f64,
) {
    match grid {
        Some(g) => {
            let cur = cur_buckets.as_mut().expect("bucketed session state");
            let (snapped, swaps) = quantize_alloc_live(&proposal, g, cur, live);
            let snapped_f: Vec<f64> = snapped.iter().map(|&b| b as f64).collect();
            // Tell the controller what was actually applied (only `ctl`
            // reads between here and the assignment below, so ordering
            // lets `snapped_f` move instead of cloning twice).
            ctl.set_batches(&snapped_f);
            if swaps.iter().any(|&s| s) {
                *t += cost;
                report.adjustments.push(AdjustEvent {
                    time: *t,
                    iter,
                    batches: snapped_f.clone(),
                    cost,
                });
                *cur = snapped;
                *batches = snapped_f;
            }
        }
        None => {
            *t += cost;
            report.adjustments.push(AdjustEvent {
                time: *t,
                iter,
                batches: proposal.clone(),
                cost,
            });
            *batches = proposal;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceKind;

    #[test]
    fn builder_defaults_are_valid() {
        assert!(SessionBuilder::default().validate().is_ok());
    }

    #[test]
    fn builder_parses_full_config() {
        let src = r#"{
            "workload": "mnist",
            "workers": [{"cpu": 4}, {"cpu": 16}, {"gpu": "T4"}],
            "policy": "static",
            "sync": "ssp:3",
            "b0": 100,
            "adjust_cost_s": 5.0,
            "controller": {"deadband": 0.1, "b_min": 2, "b_max": 512},
            "seed": 9
        }"#;
        let b = SessionBuilder::from_json_str(src).unwrap();
        assert_eq!(b.model, "mnist");
        assert_eq!(b.workers.len(), 3);
        assert_eq!(b.workers[1].device, DeviceKind::Cpu { cores: 16 });
        assert!(matches!(b.workers[2].device, DeviceKind::Gpu { .. }));
        assert_eq!(b.policy, Policy::Static);
        assert_eq!(b.sync, SyncMode::Ssp { bound: 3 });
        assert_eq!(b.b0, 100);
        assert_eq!(b.controller.deadband, 0.1);
        assert_eq!(b.adjust_cost_s, Some(5.0));
        assert_eq!(b.seed, 9);
    }

    #[test]
    fn builder_missing_keys_keep_defaults() {
        let b = SessionBuilder::from_json_str(r#"{"workload": "linreg"}"#).unwrap();
        assert_eq!(b.model, "linreg");
        assert_eq!(b.policy, Policy::Dynamic);
        assert_eq!(b.workers.len(), 3);
        assert_eq!(b.steps, 0);
    }

    #[test]
    fn builder_max_iters_aliases_steps() {
        let b = SessionBuilder::from_json_str(r#"{"max_iters": 250}"#).unwrap();
        assert_eq!(b.steps, 250);
        let b = SessionBuilder::from_json_str(r#"{"steps": 80}"#).unwrap();
        assert_eq!(b.steps, 80);
    }

    #[test]
    fn builder_rejects_bad_configs() {
        assert!(SessionBuilder::from_json_str(r#"{"policy": "bogus"}"#).is_err());
        assert!(SessionBuilder::from_json_str(r#"{"sync": "bogus"}"#).is_err());
        assert!(
            SessionBuilder::from_json_str(r#"{"workers": [{"gpu": "H100"}]}"#).is_err()
        );
        assert!(SessionBuilder::from_json_str(r#"{"workers": []}"#).is_err());
        assert!(SessionBuilder::from_json_str(
            r#"{"controller": {"deadband": 2.0}}"#
        )
        .is_err());
    }

    #[test]
    fn builder_parses_policy_specs() {
        let b = SessionBuilder::from_json_str(r#"{"policy": "pid"}"#).unwrap();
        assert_eq!(b.policy, Policy::Dynamic);
        let b = SessionBuilder::from_json_str(r#"{"policy": "optimal"}"#).unwrap();
        assert_eq!(b.policy, Policy::Optimal);
        let b = SessionBuilder::from_json_str(r#"{"policy": "rl"}"#).unwrap();
        assert_eq!(b.policy, Policy::Rl);
        assert_eq!(b.rl_table, None);
        // `rl:path` splits into policy + table; a missing table file is
        // a validation error, not a downstream panic.
        assert!(SessionBuilder::from_json_str(
            r#"{"policy": "rl:/no/such/table.json"}"#
        )
        .is_err());
        // A table path without the rl policy is a config error.
        assert!(SessionBuilder::from_json_str(
            r#"{"policy": "dynamic", "rl_table": "t.json"}"#
        )
        .is_err());
    }

    #[test]
    fn infeasible_controller_mass_errors_instead_of_panicking() {
        // b0 above b_max: every controller policy must surface a config
        // error from start() instead of tripping a constructor assert.
        for policy in [Policy::Dynamic, Policy::Optimal, Policy::Rl] {
            let mut cfg = ControllerCfg::default();
            cfg.b_max = 32.0;
            cfg.adaptive_bmax = false;
            let mut s = SessionBuilder::default()
                .cores(&[4, 8])
                .policy(policy)
                .b0(64)
                .controller(cfg)
                .steps(5)
                .build_sim()
                .unwrap();
            assert!(s.run().is_err(), "{policy:?} should reject b0 > b_max");
        }
    }

    #[test]
    fn builder_rejects_mismatched_injection() {
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .slowdowns(Slowdowns::none(3));
        assert!(b.validate().is_err());
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .traces(ClusterTraces::constant(3));
        assert!(b.validate().is_err());
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .slowdowns(Slowdowns(vec![0.0, 1.0]));
        assert!(b.validate().is_err());
    }

    #[test]
    fn builder_rejects_bad_membership() {
        // Worker index out of range.
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .joins(&[JoinSpec { worker: 5, time: 10.0 }]);
        assert!(b.validate().is_err());
        // Every rank scheduled as join_at ⇒ nobody to start the run.
        let b = SessionBuilder::default().cores(&[4, 8]).joins(&[
            JoinSpec { worker: 0, time: 5.0 },
            JoinSpec { worker: 1, time: 9.0 },
        ]);
        assert!(b.validate().is_err());
        // Negative event time.
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .membership(MembershipPlan::new(vec![MembershipEvent {
                time: -1.0,
                worker: 0,
                kind: MembershipKind::Revoke,
            }]));
        assert!(b.validate().is_err());
    }

    #[test]
    fn builder_parses_spot_and_join_keys() {
        let b = SessionBuilder::from_json_str(
            r#"{"workload": "mnist", "seed": 3, "spot": "5000:120:30", "join": "1@40"}"#,
        )
        .unwrap();
        assert_eq!(
            b.spot,
            Some(SpotSpec { mttf_s: 5000.0, down_s: 120.0, grace_s: 30.0 })
        );
        let plan = b.membership.as_ref().unwrap();
        assert!(plan.events().iter().any(|e| e.worker == 1
            && e.kind == MembershipKind::Join
            && e.time == 40.0));
        assert!(SessionBuilder::from_json_str(r#"{"spot": "bogus"}"#).is_err());
        assert!(SessionBuilder::from_json_str(r#"{"join": "bogus"}"#).is_err());
        // join for a worker outside the cluster fails validation.
        assert!(SessionBuilder::from_json_str(r#"{"join": "9@4"}"#).is_err());
    }

    #[test]
    fn spot_scenario_is_deterministic_and_order_independent() {
        // The spot spec materializes at build time, so .seed() placement
        // relative to .spot() must not matter.
        let spec = SpotSpec { mttf_s: 4_000.0, down_s: 200.0, grace_s: 20.0 };
        let spot_first = SessionBuilder::default()
            .cores(&[4, 8, 16])
            .spot(spec)
            .seed(11)
            .build_sim()
            .unwrap();
        let seed_first = SessionBuilder::default()
            .cores(&[4, 8, 16])
            .seed(11)
            .spot(spec)
            .build_sim()
            .unwrap();
        assert_eq!(
            spot_first.membership.events(),
            seed_first.membership.events()
        );
        // And a different seed yields a different churn schedule.
        let other = SessionBuilder::default()
            .cores(&[4, 8, 16])
            .seed(12)
            .spot(spec)
            .build_sim()
            .unwrap();
        assert_ne!(
            spot_first.membership.events(),
            other.membership.events()
        );
    }

    #[test]
    fn slowdowns_from_cores_normalized() {
        let s = Slowdowns::from_cores(&[3, 6, 12]);
        assert_eq!(s.0, vec![0.25, 0.5, 1.0]);
    }

    #[test]
    fn slowdowns_from_estimates_matches_cores_for_cpu_clusters() {
        let est: Vec<f64> = cpu_cluster(&[4, 16])
            .iter()
            .map(|w| w.device.flops_estimate())
            .collect();
        let s = Slowdowns::from_estimates(&est);
        assert!((s.0[0] - 0.25).abs() < 1e-12);
        assert!((s.0[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn run_to_target_stays_legal_for_sim() {
        // steps == 0 (run to the convergence target) builds fine for the
        // simulator; build_real rejects it before touching artifacts
        // (covered in tests/engine_integration.rs).
        let b = SessionBuilder::default().steps(0);
        assert!(b.build_sim().is_ok());
    }

    #[test]
    fn scheduler_parses_and_round_trips_json() {
        assert_eq!(Scheduler::parse("heap"), Some(Scheduler::Heap));
        assert_eq!(Scheduler::parse("scan"), Some(Scheduler::Scan));
        assert_eq!(Scheduler::parse("bogus"), None);
        assert_eq!(Scheduler::Heap.label(), "heap");
        let b = SessionBuilder::from_json_str(r#"{"scheduler": "scan"}"#).unwrap();
        assert_eq!(b.scheduler, Scheduler::Scan);
        assert!(SessionBuilder::from_json_str(r#"{"scheduler": "x"}"#).is_err());
        // Default is the heap.
        assert_eq!(SessionBuilder::default().scheduler, Scheduler::Heap);
    }

    #[test]
    fn eager_agg_defaults_on_and_parses_from_json() {
        assert!(SessionBuilder::default().eager_agg);
        let b = SessionBuilder::from_json_str(r#"{"eager_agg": false}"#).unwrap();
        assert!(!b.eager_agg);
        let b = SessionBuilder::from_json_str(r#"{"eager_agg": true}"#).unwrap();
        assert!(b.eager_agg);
    }

    #[test]
    fn report_sample_parses_and_rejects_zero() {
        let b = SessionBuilder::from_json_str(r#"{"report_sample": 10}"#).unwrap();
        assert_eq!(b.report_sample, 10);
        assert!(SessionBuilder::default().report_sample(0).validate().is_err());
    }

    /// The correctness lock for the O(log k) rework: heap- and
    /// scan-scheduled runs of the same churny seeded scenario must be
    /// *bitwise* identical — same event order, same numerics, same
    /// report.  (tests/property.rs fans this out over random scenarios
    /// on the mock backend; this pins the real simulator path.)
    #[test]
    fn heap_and_scan_schedulers_are_bit_identical_on_sim() {
        use crate::trace::SpotSpec;
        for sync in [SyncMode::Bsp, SyncMode::Asp, SyncMode::Ssp { bound: 2 }] {
            let mk = |scheduler| {
                SessionBuilder::default()
                    .model("mnist")
                    .cores(&[4, 8, 27])
                    .policy(Policy::Dynamic)
                    .sync(sync)
                    .steps(200)
                    .adjust_cost(1.0)
                    .seed(5)
                    .spot(SpotSpec { mttf_s: 8.0, down_s: 2.0, grace_s: 0.3 })
                    .scheduler(scheduler)
                    .build_sim()
                    .unwrap()
                    .run()
                    .unwrap()
            };
            let (h, s) = (mk(Scheduler::Heap), mk(Scheduler::Scan));
            assert_eq!(h.total_time, s.total_time, "{sync:?}");
            assert_eq!(h.total_iters, s.total_iters, "{sync:?}");
            assert_eq!(h.iters.len(), s.iters.len(), "{sync:?}");
            for (a, b) in h.iters.iter().zip(&s.iters) {
                assert_eq!(
                    (a.worker, a.iter, a.start, a.duration, a.batch, a.wait),
                    (b.worker, b.iter, b.start, b.duration, b.batch, b.wait),
                    "{sync:?}"
                );
            }
            assert_eq!(h.adjustments.len(), s.adjustments.len(), "{sync:?}");
            for (a, b) in h.adjustments.iter().zip(&s.adjustments) {
                assert_eq!((a.time, a.iter, &a.batches), (b.time, b.iter, &b.batches));
            }
            assert_eq!(h.epochs.len(), s.epochs.len(), "{sync:?}");
            for (a, b) in h.epochs.iter().zip(&s.epochs) {
                assert_eq!(
                    (a.time, a.epoch, a.worker, a.kind, a.live, &a.batches),
                    (b.time, b.epoch, b.worker, b.kind, b.live, &b.batches),
                    "{sync:?}"
                );
            }
        }
    }

    #[test]
    fn report_sample_thins_records_without_touching_the_run() {
        let mk = |n: u64| {
            SessionBuilder::default()
                .model("mnist")
                .cores(&[4, 8, 16])
                .policy(Policy::Dynamic)
                .steps(120)
                .seed(3)
                .report_sample(n)
                .build_sim()
                .unwrap()
                .run()
                .unwrap()
        };
        let full = mk(1);
        let thin = mk(4);
        // Same trajectory: makespan, iterations, adjustments untouched.
        assert_eq!(full.total_time, thin.total_time);
        assert_eq!(full.total_iters, thin.total_iters);
        assert_eq!(full.adjustments.len(), thin.adjustments.len());
        // BSP sampling keeps every 4th *round* whole (first kept): 120
        // rounds -> 30 kept x 3 workers.
        let rounds = full.total_iters;
        let kept = (rounds + 3) / 4;
        assert_eq!(thin.iters.len() as u64, kept * 3);
        assert_eq!(
            (thin.iters[0].worker, thin.iters[0].start),
            (full.iters[0].worker, full.iters[0].start)
        );
        // Round alignment: no worker is aliased out of the report.
        for w in 0..3 {
            assert_eq!(
                thin.iters.iter().filter(|r| r.worker == w).count() as u64,
                kept,
                "worker {w} under-represented"
            );
        }
    }

    #[test]
    fn session_label_composes_backend_policy_sync() {
        let r = SessionBuilder::default()
            .model("mnist")
            .cores(&[4, 8])
            .policy(Policy::Uniform)
            .sync(SyncMode::Ssp { bound: 2 })
            .steps(20)
            .build_sim()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.label, "mnist/uniform/ssp:2");
        assert!(r.total_iters > 0);
    }

    #[test]
    fn builder_parses_fault_keys() {
        let b = SessionBuilder::from_json_str(
            r#"{
                "workload": "mnist",
                "faults": "stall:1@40:30,slow:2@10:1.5:20",
                "corrupt": "0@25:nan,1@30:scale:50:10",
                "guard": "norm=6,strikes=2,probation=45,late=drop,window=16",
                "detect": "grace=3,floor=10,late=drop",
                "autoscale": "pool=2,cold=15,ride"
            }"#,
        )
        .unwrap();
        // The corrupt shorthand merges into the fault plan.
        let plan = b.faults.as_ref().unwrap();
        assert_eq!(plan.events().len(), 4);
        assert!(plan.has_corrupt());
        let g = b.guard.as_ref().unwrap();
        assert_eq!(g.norm_k, 6.0);
        assert_eq!(g.strikes, 2);
        assert_eq!(g.probation_s, 45.0);
        assert_eq!(g.late, LatePolicy::Drop);
        assert_eq!(g.window, 16);
        let d = b.detector.as_ref().unwrap();
        assert_eq!(d.grace, 3.0);
        assert_eq!(d.floor_s, 10.0);
        assert_eq!(d.late, LatePolicy::Drop);
        let a = b.autoscale.as_ref().unwrap();
        assert_eq!(a.pool, 2);
        assert_eq!(a.cold_s, 15.0);
        assert!(a.ride_out);
        // Malformed specs fail at parse time, like --spot/--join.
        assert!(SessionBuilder::from_json_str(r#"{"faults": "bogus"}"#).is_err());
        assert!(SessionBuilder::from_json_str(r#"{"faults": "crash:x@3"}"#).is_err());
        assert!(SessionBuilder::from_json_str(r#"{"detect": "grace=abc"}"#).is_err());
        assert!(SessionBuilder::from_json_str(r#"{"autoscale": "pool=x"}"#).is_err());
        assert!(SessionBuilder::from_json_str(r#"{"corrupt": "1@5:bogus"}"#).is_err());
        assert!(SessionBuilder::from_json_str(r#"{"guard": "norm=abc"}"#).is_err());
    }

    #[test]
    fn builder_rejects_bad_fault_configs() {
        let crash = || FaultPlan::parse("crash:1@50").unwrap();
        // A crash with no detector would hang the BSP barrier forever.
        let b = SessionBuilder::default().cores(&[4, 8]).faults(crash());
        assert!(b.validate().unwrap_err().contains("detector"));
        // With a detector it is legal.
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .faults(crash())
            .detector(DetectorCfg::default());
        assert!(b.validate().is_ok());
        // Fault worker outside the cluster.
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .faults(FaultPlan::parse("stall:5@10:30").unwrap());
        assert!(b.validate().is_err());
        // Detector / autoscaler parameter validation runs at build time
        // (parse() already rejects grace=0, so construct directly).
        let b = SessionBuilder::default().cores(&[4, 8]).detector(DetectorCfg {
            grace: 0.0,
            ..DetectorCfg::default()
        });
        assert!(b.validate().is_err());
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .autoscale(AutoscalerCfg::parse("pool=1,floor=9").unwrap());
        assert!(b.validate().unwrap_err().contains("floor"));
        // An unguarded corruption would silently poison the model.
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .corrupt(FaultPlan::parse_corrupt("1@10:nan").unwrap());
        assert!(b.validate().unwrap_err().contains("guard"));
        // With a guard it is legal.
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .corrupt(FaultPlan::parse_corrupt("1@10:nan").unwrap())
            .guard(GuardCfg::default());
        assert!(b.validate().is_ok());
        // Corrupt worker outside the cluster.
        let b = SessionBuilder::default()
            .cores(&[4, 8])
            .corrupt(FaultPlan::parse_corrupt("5@10:nan").unwrap())
            .guard(GuardCfg::default());
        assert!(b.validate().is_err());
        // Guard parameter validation runs at build time (parse()
        // already rejects strikes=0, so construct directly).
        let b = SessionBuilder::default().cores(&[4, 8]).guard(GuardCfg {
            strikes: 0,
            ..GuardCfg::default()
        });
        assert!(b.validate().is_err());
    }

    /// The tentpole recovery trail: a scripted NaN gradient arrives,
    /// the guard rejects it at completion (strikes=1 ⇒ immediate
    /// quarantine), the rank drops through the revocation path, and the
    /// probation timer readmits it through the join path — the run
    /// completes at full strength.
    #[test]
    fn corrupt_worker_is_quarantined_then_readmitted() {
        let base = || {
            SessionBuilder::default()
                .model("mnist")
                .cores(&[4, 4, 8])
                .policy(Policy::Dynamic)
                .steps(60)
                .adjust_cost(1.0)
                .seed(2)
        };
        // Calibrate the onset/probation against the clean run's measured
        // makespan: a guarded run replays the clean timeline bitwise
        // until the corruption onset, so mid-run fractions of it stay
        // mid-run whatever the workload's absolute time scale.
        let t = base().build_sim().unwrap().run().unwrap().total_time;
        let r = base()
            .corrupt(FaultPlan::parse_corrupt(&format!("1@{:.4}:nan", 0.35 * t)).unwrap())
            .guard(
                GuardCfg::parse(&format!(
                    "norm=8,strikes=1,probation={:.4},late=readmit",
                    0.3 * t
                ))
                .unwrap(),
            )
            .build_sim()
            .unwrap()
            .run()
            .unwrap();
        assert!(r.total_iters >= 60, "run did not complete: {}", r.total_iters);
        // strikes=1: the single bad update escalates straight to
        // quarantine — no standalone rejection events.
        assert!(r.rejections.is_empty(), "{:?}", r.rejections);
        let acts: Vec<(usize, GuardAction)> =
            r.quarantines.iter().map(|q| (q.worker, q.action)).collect();
        assert!(acts.contains(&(1, GuardAction::Quarantine)), "{acts:?}");
        assert!(acts.contains(&(1, GuardAction::Readmit)), "{acts:?}");
        assert_eq!(r.guard_quarantines(), 1);
        // Quarantine + readmit flowed through the epoch machinery, and
        // the cluster ends at full strength (liveness).
        assert!(r.epochs.iter().any(|e| e.worker == 1
            && e.kind == MembershipKind::Revoke));
        assert!(r.epochs.iter().any(|e| e.worker == 1
            && e.kind == MembershipKind::Join));
        assert_eq!(r.epochs.last().unwrap().live, 3);
    }

    /// Quarantine with `late=drop` is permanent: no probation timer is
    /// armed and the rank never returns.
    #[test]
    fn quarantine_with_late_drop_never_readmits() {
        let base = || {
            SessionBuilder::default()
                .model("mnist")
                .cores(&[4, 4, 8])
                .policy(Policy::Dynamic)
                .steps(40)
                .adjust_cost(1.0)
                .seed(2)
        };
        let t = base().build_sim().unwrap().run().unwrap().total_time;
        let r = base()
            .corrupt(FaultPlan::parse_corrupt(&format!("1@{:.4}:inf", 0.35 * t)).unwrap())
            .guard(GuardCfg::parse("norm=8,strikes=1,probation=10,late=drop").unwrap())
            .build_sim()
            .unwrap()
            .run()
            .unwrap();
        assert!(r.total_iters >= 40);
        assert_eq!(r.guard_quarantines(), 1);
        assert!(r
            .quarantines
            .iter()
            .all(|q| q.action != GuardAction::Readmit));
        assert_eq!(r.epochs.last().unwrap().live, 2);
    }

    /// The §16 invariant at unit scope: a guard that never fires must
    /// not perturb the run — the norm probe runs either way, so
    /// guard-on and guard-off do identical work (the property suite
    /// fans this over sync modes × policies under churn).
    #[test]
    fn idle_guard_is_bitwise_invisible() {
        let mk = |guard: bool| {
            let mut b = SessionBuilder::default()
                .model("mnist")
                .cores(&[4, 8, 27])
                .policy(Policy::Dynamic)
                .steps(150)
                .adjust_cost(1.0)
                .seed(5)
                .spot(SpotSpec { mttf_s: 8.0, down_s: 2.0, grace_s: 0.3 });
            if guard {
                b = b.guard(GuardCfg::parse("norm=8,strikes=3,probation=60").unwrap());
            }
            b.build_sim().unwrap().run().unwrap()
        };
        let (on, off) = (mk(true), mk(false));
        assert!(on.rejections.is_empty());
        assert!(on.quarantines.is_empty());
        assert_eq!(on.total_time, off.total_time);
        assert_eq!(on.total_iters, off.total_iters);
        assert_eq!(on.iters.len(), off.iters.len());
        for (a, b) in on.iters.iter().zip(&off.iters) {
            assert_eq!(
                (a.worker, a.iter, a.start, a.duration, a.batch, a.wait),
                (b.worker, b.iter, b.start, b.duration, b.batch, b.wait)
            );
        }
    }

    /// The ISSUE's acceptance scenario: a worker crashes unannounced
    /// mid-BSP; the progress-deadline detector suspects it, retires it
    /// through the revocation path, and the autoscaler's replacement
    /// takes over the vacated rank — the run completes.
    #[test]
    fn crash_is_detected_and_autoscaled_replacement_recovers() {
        let r = SessionBuilder::default()
            .model("mnist")
            .cores(&[4, 4, 8])
            .policy(Policy::Dynamic)
            .steps(60)
            .adjust_cost(1.0)
            .seed(2)
            .faults(FaultPlan::parse("crash:1@1").unwrap())
            .detector(DetectorCfg::parse("grace=4,floor=5").unwrap())
            .autoscale(AutoscalerCfg::parse("pool=1,cold=1").unwrap())
            .build_sim()
            .unwrap()
            .run()
            .unwrap();
        assert!(r.total_iters >= 60, "run did not complete: {}", r.total_iters);
        // Exactly one suspicion, for the crashed rank, and no readmission
        // (a crashed worker never produces a late arrival).
        assert_eq!(r.suspicions.len(), 1);
        assert_eq!(r.suspicions[0].worker, 1);
        assert_eq!(r.suspicions[0].action, DetectorAction::Suspect);
        // The pool VM came up and took the vacated rank.
        assert!(r.spawns.iter().any(|s| s.action == SpawnAction::Request));
        assert!(r
            .spawns
            .iter()
            .any(|s| s.action == SpawnAction::Ready && s.worker == Some(1)));
        // Revocation + rejoin both flowed through the epoch machinery.
        assert!(r.epochs.iter().any(|e| e.worker == 1
            && e.kind == MembershipKind::Revoke));
        assert!(r.epochs.iter().any(|e| e.worker == 1
            && e.kind == MembershipKind::Join));
    }

    /// False suspicion is reversible: a long stall trips the deadline,
    /// the rank is provisionally retired, and when its iteration finally
    /// lands the late-arrival readmit path brings it back.
    #[test]
    fn stalled_worker_is_suspected_then_readmitted() {
        let r = SessionBuilder::default()
            .model("mnist")
            .cores(&[4, 4, 8])
            .policy(Policy::Dynamic)
            .steps(80)
            .adjust_cost(1.0)
            .seed(3)
            .faults(FaultPlan::parse("stall:2@20:400").unwrap())
            .detector(DetectorCfg::parse("grace=4,floor=5").unwrap())
            .build_sim()
            .unwrap()
            .run()
            .unwrap();
        assert!(r.total_iters >= 80);
        let acts: Vec<(usize, DetectorAction)> =
            r.suspicions.iter().map(|s| (s.worker, s.action)).collect();
        assert!(acts.contains(&(2, DetectorAction::Suspect)), "{acts:?}");
        assert!(acts.contains(&(2, DetectorAction::Readmit)), "{acts:?}");
        // Readmission is a Join epoch; the cluster ends at full strength.
        assert!(r.epochs.iter().any(|e| e.worker == 2
            && e.kind == MembershipKind::Join));
        assert_eq!(r.epochs.last().unwrap().live, 3);
    }

    /// A detector that never fires must not perturb the run: armed
    /// deadlines only act when *strictly earlier* than every completion
    /// and membership event, so a generous detector is bitwise free.
    #[test]
    fn idle_detector_is_bitwise_invisible() {
        let mk = |detect: bool| {
            let mut b = SessionBuilder::default()
                .model("mnist")
                .cores(&[4, 8, 27])
                .policy(Policy::Dynamic)
                .steps(150)
                .adjust_cost(1.0)
                .seed(5)
                .spot(SpotSpec { mttf_s: 8.0, down_s: 2.0, grace_s: 0.3 });
            if detect {
                b = b.detector(DetectorCfg::parse("grace=1e6,floor=1e7").unwrap());
            }
            b.build_sim().unwrap().run().unwrap()
        };
        let (on, off) = (mk(true), mk(false));
        assert!(on.suspicions.is_empty());
        assert_eq!(on.total_time, off.total_time);
        assert_eq!(on.total_iters, off.total_iters);
        assert_eq!(on.iters.len(), off.iters.len());
        for (a, b) in on.iters.iter().zip(&off.iters) {
            assert_eq!(
                (a.worker, a.iter, a.start, a.duration, a.batch, a.wait),
                (b.worker, b.iter, b.start, b.duration, b.batch, b.wait)
            );
        }
    }

    fn tmp_ckpt_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("hbatch_sess_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn builder_config_echo_is_a_fixed_point() {
        // to_json → from_json → to_json must reproduce the same text:
        // the echo is what a checkpoint stores, and a drifting echo
        // would silently resume a different run.
        let mk = || {
            SessionBuilder::default()
                .model("mnist")
                .cores(&[4, 8, 27])
                .policy(Policy::Dynamic)
                .sync(SyncMode::Ssp { bound: 3 })
                .steps(50)
                .adjust_cost(2.0)
                .seed(7)
                .spot(SpotSpec { mttf_s: 8.0, down_s: 2.0, grace_s: 0.3 })
                .faults(FaultPlan::parse("stall:2@10:6,slow:0@5:2.5:30").unwrap())
                // Corruption events merge into the fault plan, so the
                // echo must round-trip them through the `faults` key.
                .corrupt(FaultPlan::parse_corrupt("1@20:nan,0@30:scale:50:10").unwrap())
                .guard(GuardCfg::parse("norm=6,strikes=2,probation=40,late=drop").unwrap())
                .detector(DetectorCfg::parse("grace=4,floor=5,late=drop").unwrap())
                .autoscale(AutoscalerCfg::parse("pool=1,cold=1,jitter=0.2").unwrap())
        };
        let j = mk().to_json().unwrap();
        let j2 = SessionBuilder::from_json(&j).unwrap().to_json().unwrap();
        assert_eq!(j.to_pretty(), j2.to_pretty());
        // Programmatic-only configurations refuse to echo.
        assert!(mk().traces(ClusterTraces::constant(3)).to_json().is_err());
    }

    /// The tentpole lock: kill the coordinator mid-run, recover from
    /// the latest durable checkpoint through the stored config echo,
    /// resume — the stitched report is *bitwise* identical to an
    /// uninterrupted run, across sync modes and policies under spot
    /// churn (tests/ckpt_roundtrip.rs fans the same property over
    /// random scenarios and crash points on the mock backend).
    #[test]
    fn crash_resume_replays_bitwise_on_sim() {
        use crate::ckpt::{recover_latest, CkptSpec};
        for (i, (sync, policy)) in [
            (SyncMode::Bsp, Policy::Dynamic),
            (SyncMode::Asp, Policy::Optimal),
            (SyncMode::Ssp { bound: 2 }, Policy::Rl),
            (SyncMode::Bsp, Policy::Uniform),
        ]
        .into_iter()
        .enumerate()
        {
            let mk = || {
                SessionBuilder::default()
                    .model("mnist")
                    .cores(&[4, 8, 27])
                    .policy(policy)
                    .sync(sync)
                    .steps(120)
                    .adjust_cost(1.0)
                    .seed(5)
                    .spot(SpotSpec { mttf_s: 8.0, down_s: 2.0, grace_s: 0.3 })
            };
            let base = mk().build_sim().unwrap().run().unwrap();

            let dir = tmp_ckpt_dir(&format!("rt{i}"));
            let spec = CkptSpec { dir: dir.clone(), every_s: 0.0, keep_n: 3 };
            let config = mk().to_json().unwrap();
            let mut sess = mk().build_sim().unwrap();
            let mut ck = Checkpointer::open(spec.clone()).unwrap();
            let crash_at = base.total_time / 2.0;
            match sess
                .run_checkpointed(&config, &mut ck, Some(crash_at))
                .unwrap()
            {
                CkptOutcome::Stopped { t } => assert!(t >= crash_at),
                CkptOutcome::Completed(_) => {
                    panic!("{sync:?}/{policy:?}: run outlived its crash")
                }
            }

            let lc = recover_latest(&dir).unwrap();
            assert!(lc.seq >= 1, "no boundary snapshot before the crash");
            let mut rsess = SessionBuilder::from_json(&lc.config)
                .unwrap()
                .build_sim()
                .unwrap();
            let rs = rsess
                .restore_run(&lc.state, lc.backend_bin.as_deref())
                .unwrap();
            let mut ck2 = Checkpointer::open(spec).unwrap();
            let resumed = match rsess
                .resume_checkpointed(rs, &lc.config, &mut ck2, None)
                .unwrap()
            {
                CkptOutcome::Completed(r) => r,
                CkptOutcome::Stopped { .. } => unreachable!(),
            };
            assert_eq!(
                base.snapshot().to_pretty(),
                resumed.snapshot().to_pretty(),
                "{sync:?}/{policy:?}: resumed report diverged"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn restore_rejects_version_and_config_mismatches() {
        let mk = |sync| {
            SessionBuilder::default()
                .model("mnist")
                .cores(&[4, 8])
                .policy(Policy::Dynamic)
                .sync(sync)
                .steps(20)
                .seed(2)
        };
        let mut sess = mk(SyncMode::Bsp).build_sim().unwrap();
        let rs = sess.start().unwrap();
        let state = sess.snapshot_run(&rs);

        let mut wrong_ver = state.clone();
        wrong_ver.set("version", Json::Num(99.0));
        assert!(mk(SyncMode::Bsp)
            .build_sim()
            .unwrap()
            .restore_run(&wrong_ver, None)
            .is_err());

        // Sync mode drifted between checkpoint and resume config.
        assert!(mk(SyncMode::Asp)
            .build_sim()
            .unwrap()
            .restore_run(&state, None)
            .is_err());

        // Policy drifted: uniform has no controller state to accept.
        assert!(mk(SyncMode::Bsp)
            .policy(Policy::Uniform)
            .build_sim()
            .unwrap()
            .restore_run(&state, None)
            .is_err());

        // Guard presence must agree between config and checkpoint: a
        // guard-off snapshot cannot restore into a guard-on config
        // (the window/strike state would be fabricated) …
        assert!(mk(SyncMode::Bsp)
            .guard(GuardCfg::default())
            .build_sim()
            .unwrap()
            .restore_run(&state, None)
            .is_err());
        // … and a guard-on snapshot cannot restore guard-off.
        let mut gsess = mk(SyncMode::Bsp)
            .guard(GuardCfg::default())
            .build_sim()
            .unwrap();
        let grs = gsess.start().unwrap();
        let gstate = gsess.snapshot_run(&grs);
        assert!(mk(SyncMode::Bsp)
            .build_sim()
            .unwrap()
            .restore_run(&gstate, None)
            .is_err());
        // Agreement restores cleanly.
        assert!(mk(SyncMode::Bsp)
            .guard(GuardCfg::default())
            .build_sim()
            .unwrap()
            .restore_run(&gstate, None)
            .is_ok());
    }
}
