//! Real-execution backend: AOT-compiled PJRT train steps, λ-weighted
//! fused aggregation + optimizer on the parameter server, and batch
//! prefetch pipelining — the "it actually trains" path.
//!
//! Heterogeneity injection: all simulated workers share one physical
//! CPU, so heterogeneity and availability dynamics cannot come from the
//! hardware.  Instead the backend reports each worker's *measured* PJRT
//! compute seconds as [`WorkerOutcome::work`], and the
//! [`super::Session`] divides by the worker's slowdown capacity and
//! integrates over its availability trace — preserving the relative
//! iteration-time structure a heterogeneous (and dynamically varying)
//! cluster produces while keeping the numerics real.  Worker compute is
//! serialized through the single PJRT stream; the controller observes
//! the virtual durations, exactly the signal it would see on real
//! heterogeneous hardware.  Injected slowdowns are *accounted*, not
//! slept: sleeping would only burn wall-clock without changing what the
//! controller observes.
//!
//! Under ASP/SSP the staleness is genuine: a worker's gradients are
//! computed against the parameters it pulled when its iteration started,
//! and other workers' updates land (bumping the parameter version)
//! before its own update is applied.
//!
//! BSP aggregation (§Perf iteration 6, DESIGN.md §11) runs through the
//! eager reduction tree ([`crate::ps::ReduceTree`]): each train step
//! writes its gradients straight into a tree-leased buffer and the
//! gradient combines into the round's fixed rank-indexed tree the
//! moment the step completes — the former k-buffer `grads` arena is
//! gone for BSP runs, replaced by a [`RetainPolicy`] (`Free`:
//! ⌈log₂k⌉+1 live buffers; `Retain` for elastic sessions, where a
//! revocation rebuilds only the revoked leaf's ancestor path).  At the
//! barrier the tree root feeds [`FusedOptimizer::step_mt`] directly,
//! carrying the 1/Σb normalization as its λ weight.  The
//! collect-then-aggregate baseline ([`BspAgg::Collect`]) keeps the
//! arena and builds the *same* tree at the barrier — bit-identical
//! reports, property- and integration-tested.

use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::controller::bucket::quantize;
use crate::data::{self, Batch, Dataset, ShardRouter};
use crate::fault::{Corruption, FaultPlan, FaultState, CORRUPT_SEED_TAG};
use crate::ps::{lambdas_into, FusedOptimizer, ReduceTree, RetainPolicy};
use crate::runtime::{ModelManifest, Runtime, StepKind};
use crate::session::{Backend, WorkerOutcome};
use crate::util::pool;
use crate::util::rng::Rng;

/// How a BSP session computes the barrier aggregate (async sessions
/// always use the per-worker arena — their updates are single-gradient).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BspAgg {
    /// Eager reduction tree (the default): gradients combine at
    /// completion, no per-worker arena.  The policy picks the buffer
    /// lifetime — `Free` for static membership, `Retain` under churn.
    Eager(RetainPolicy),
    /// Collect-then-aggregate baseline: the k-buffer arena survives and
    /// the same rank-indexed tree is built at the barrier.  Exists for
    /// the eager-vs-collect bit-identity lock
    /// (`tests/engine_integration.rs`) and as a debugging fallback
    /// (CLI `--collect-agg`).
    Collect,
}

/// Where gradients live between the train step and the optimizer.
enum GradStore {
    /// Per-worker buffers (async sync, and the `Collect` baseline —
    /// which additionally carries the barrier-time tree).
    Arena {
        bufs: Vec<Vec<f32>>,
        barrier_tree: Option<ReduceTree>,
    },
    /// Eager BSP reduction tree: train steps write into leased buffers
    /// that the tree absorbs at completion.
    Tree(ReduceTree),
}

/// One barrier application of a reduction tree: finalize, feed the root
/// to the fused optimizer — with the deferred 1/Σb normalization riding
/// its λ slot (leaves carry the raw batch b_w) — and reset for the next
/// round.  Shared by the eager and collect arms of `apply_update`: the
/// eager-vs-collect bit-identity contract lives in this one place.
fn apply_tree_barrier(
    tree: &mut ReduceTree,
    optimizer: &mut FusedOptimizer,
    params: &mut [f32],
    lam_batches: &[f64],
    pool_threads: usize,
) {
    let total: f64 = lam_batches.iter().sum();
    tree.finalize();
    let root = tree.root();
    optimizer.step_mt(params, &[root], &[1.0 / total], pool_threads);
    tree.reset();
}

/// PJRT-backed execution substrate over an opened [`Runtime`].
pub struct RealBackend<'rt> {
    runtime: &'rt mut Runtime,
    model_name: String,
    model: ModelManifest,
    dataset: Box<dyn Dataset>,
    /// Elastic shard routing: a revoked worker's data shards flow to the
    /// survivors (round-robin) and return when it rejoins — streams are
    /// never reset, so no sample repeats.
    router: ShardRouter,
    params: Vec<f32>,
    optimizer: FusedOptimizer,
    /// Gradient storage (§Perf it. 2 buffer reuse; §Perf it. 6 eager
    /// reduction tree for BSP).
    grads: GradStore,
    /// Per-worker completion bookkeeping: the session's BSP flow marks a
    /// member staged at its completion event; the barrier asserts every
    /// member it applies was staged.
    staged: Vec<bool>,
    /// Last observed per-worker loss (consumed by `apply_update`).
    losses: Vec<f64>,
    /// Reusable per-update scratch: member batch sizes and their λ
    /// weights (one allocation for the whole run, not one per update).
    lam_batches: Vec<f64>,
    lambdas: Vec<f64>,
    /// (params version, marshaled literals): parameter literals are
    /// prepared once per parameter version and shared by every train
    /// step until the next update lands (§Perf it. 3 — one marshal per
    /// BSP round).
    prepared: Option<(u64, Vec<xla::Literal>)>,
    version: u64,
    k: usize,
    estimates: Vec<f64>,
    b0: f64,
    eval_bucket: usize,
    eval_enabled: bool,
    pool_threads: usize,
    prefetch: bool,
    steps: u64,
    /// Injected fault schedule (DESIGN.md §12): stall/slow faults
    /// perturb the *accounted* outcome the same way capacity traces do
    /// — the measured PJRT compute stays real, the virtual duration
    /// carries the fault.
    faults: Option<FaultState>,
    /// L2 norm of each worker's in-flight gradient, measured after any
    /// scripted corruption lands (DESIGN.md §16).  Computed
    /// unconditionally — the O(d) pass is noise against the O(d·b)
    /// train step — so guard-on and guard-off runs do identical work.
    pending_norm: Vec<f64>,
    /// Dedicated rng stream for bitflip corruption, forked off the run
    /// seed under [`CORRUPT_SEED_TAG`].  Advanced only when a bitflip
    /// actually fires, so a corruption-free plan leaves it untouched.
    corrupt_rng: Rng,
}

/// Apply one scripted corruption to a real gradient buffer, in the
/// order the plan's tie-break sorted them.  NaN/Inf poison a single
/// element — enough to blow the norm probe, and the closest model of a
/// transient hardware flip; scale rescales the whole update; bitflip
/// flips N random (element, bit) positions from the dedicated stream.
fn corrupt_grad(buf: &mut [f32], c: &Corruption, rng: &mut Rng) {
    match *c {
        Corruption::Nan => buf[0] = f32::NAN,
        Corruption::Inf => buf[0] = f32::INFINITY,
        Corruption::Scale { factor } => {
            let f = factor as f32;
            for x in buf.iter_mut() {
                *x *= f;
            }
        }
        Corruption::Bitflip { flips } => {
            for _ in 0..flips {
                let i = rng.below(buf.len() as u64) as usize;
                let bit = rng.below(32) as u32;
                buf[i] = f32::from_bits(buf[i].to_bits() ^ (1u32 << bit));
            }
        }
    }
}

/// L2 norm of a gradient buffer, accumulated in f64.  NaN/Inf elements
/// propagate into the result, which is exactly what the guard's finite
/// check wants to see.
fn l2_norm(buf: &[f32]) -> f64 {
    buf.iter()
        .map(|&x| {
            let v = x as f64;
            v * v
        })
        .sum::<f64>()
        .sqrt()
}

impl<'rt> RealBackend<'rt> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        runtime: &'rt mut Runtime,
        model_name: &str,
        k: usize,
        estimates: Vec<f64>,
        seed: u64,
        steps: u64,
        eval_every: u64,
        b0_hint: usize,
        pool_threads: usize,
        prefetch: bool,
        bsp_agg: Option<BspAgg>,
    ) -> Result<Self> {
        if k == 0 {
            bail!("no workers");
        }
        if estimates.len() != k {
            bail!("estimates/workers length mismatch");
        }
        let model = runtime.model(model_name)?.clone();
        let b0 = if b0_hint > 0 {
            b0_hint as f64
        } else {
            // Middle bucket as default reference.
            model.buckets[model.buckets.len() / 2] as f64
        };
        // Warm up all bucket executables so controller swaps are cheap
        // rebinds, never compiles.
        runtime.warmup(model_name, &[StepKind::Train])?;
        // Periodic evals run at one fixed bucket (nearest to b0), so
        // only that eval executable is compiled.
        let eval_bucket = quantize(b0, &model.buckets);
        if eval_every > 0 {
            runtime.ensure_compiled(model_name, StepKind::Eval, eval_bucket)?;
        }
        let params = runtime.init_params(model_name)?;
        let optimizer = FusedOptimizer::for_workload(model_name, model.param_total, steps);
        // Shard k is the dedicated eval stream: training shards 0..k stay
        // untouched, so eval-on vs eval-off runs produce identical loss
        // curves.
        let shards = k + usize::from(eval_every > 0);
        let dataset = data::for_model(model_name, shards, seed);
        let grads = match bsp_agg {
            Some(BspAgg::Eager(policy)) => {
                GradStore::Tree(ReduceTree::new(k, model.param_total, policy, pool_threads))
            }
            Some(BspAgg::Collect) => GradStore::Arena {
                bufs: (0..k).map(|_| vec![0.0f32; model.param_total]).collect(),
                barrier_tree: Some(ReduceTree::new(
                    k,
                    model.param_total,
                    RetainPolicy::Free,
                    pool_threads,
                )),
            },
            None => GradStore::Arena {
                bufs: (0..k).map(|_| vec![0.0f32; model.param_total]).collect(),
                barrier_tree: None,
            },
        };
        Ok(RealBackend {
            runtime,
            model_name: model_name.to_string(),
            model,
            dataset,
            router: ShardRouter::new(k),
            params,
            optimizer,
            grads,
            staged: vec![false; k],
            losses: vec![0.0; k],
            lam_batches: Vec::with_capacity(k),
            lambdas: Vec::with_capacity(k),
            prepared: None,
            version: 0,
            k,
            estimates,
            b0,
            eval_bucket,
            eval_enabled: eval_every > 0,
            pool_threads,
            prefetch,
            steps,
            faults: None,
            pending_norm: vec![0.0; k],
            corrupt_rng: Rng::new(seed ^ CORRUPT_SEED_TAG),
        })
    }

    /// Current (flattened) model parameters.
    pub fn params(&self) -> &[f32] {
        &self.params
    }
}

impl Backend for RealBackend<'_> {
    fn k(&self) -> usize {
        self.k
    }

    fn label(&self) -> String {
        format!("real/{}", self.model_name)
    }

    fn buckets(&self) -> Option<Vec<usize>> {
        Some(self.model.buckets.clone())
    }

    fn default_b0(&self) -> f64 {
        self.b0
    }

    fn flops_estimates(&self) -> Vec<f64> {
        self.estimates.clone()
    }

    fn default_target(&self) -> u64 {
        self.steps.max(1)
    }

    fn set_fault_plan(&mut self, plan: &FaultPlan) {
        self.faults = Some(plan.state());
    }

    fn execute_wave(
        &mut self,
        wave: &[usize],
        batches: &[f64],
        now: f64,
    ) -> Result<Vec<WorkerOutcome>> {
        // Marshal parameters once per version; a BSP wave of K workers
        // shares one prepared set.
        if self.prepared.as_ref().map(|(v, _)| *v) != Some(self.version) {
            let lits = self.runtime.prepare_params(&self.model_name, &self.params)?;
            self.prepared = Some((self.version, lits));
        }

        // Shard routing: resolve every wave entry's shard up front (in
        // wave order) so the round-robin cursor advances identically
        // with prefetch on or off.
        let shards: Vec<usize> = wave.iter().map(|&w| self.router.next_shard(w)).collect();

        // Prefetch pipelining (§Perf iteration 4): the dataset and a
        // one-slot hand-off buffer live behind mutexes so a pool worker
        // can generate the next wave entry's batch while the leader
        // drives the current PJRT step.  Batch generation order is
        // unchanged (wave order, strictly in turn), so a run is
        // bit-identical with prefetch on or off.
        let ds: Mutex<&mut dyn Dataset> = Mutex::new(&mut *self.dataset);
        let slot: Mutex<Option<Batch>> = Mutex::new(None);
        let prefetch = self.prefetch && wave.len() > 1;

        let mut outs = Vec::with_capacity(wave.len());
        for (i, &w) in wave.iter().enumerate() {
            let b = batches[w] as usize;
            let batch = match slot.lock().unwrap().take() {
                Some(batch) => batch, // prefetched under the previous step
                None => ds.lock().unwrap().next_batch(shards[i], b),
            };
            let handle = if prefetch && i + 1 < wave.len() {
                let nw = wave[i + 1];
                let ns = shards[i + 1];
                let nb = batches[nw] as usize;
                let (dsr, slotr) = (&ds, &slot);
                // SAFETY: the handle is joined inside this loop
                // iteration — `h.wait()` below on the normal path,
                // `Drop` on the `?` early return — before `ds` and
                // `slot` can go out of scope; it is never leaked.
                Some(unsafe {
                    pool::global().submit(move || {
                        let next = dsr.lock().unwrap().next_batch(ns, nb);
                        *slotr.lock().unwrap() = Some(next);
                    })
                })
            } else {
                None
            };
            // Eager BSP mode writes the step's gradients into a
            // tree-leased buffer; the arena modes into the worker's own.
            let mut leased: Option<Vec<f32>> = match &mut self.grads {
                GradStore::Tree(t) => Some(t.lease()),
                GradStore::Arena { .. } => None,
            };
            let t0 = Instant::now();
            let step = {
                let gout: &mut [f32] = match (&mut leased, &mut self.grads) {
                    (Some(buf), _) => buf,
                    (None, GradStore::Arena { bufs, .. }) => &mut bufs[w],
                    _ => unreachable!("leased buffer without a tree store"),
                };
                self.runtime.train_step_prepared(
                    &self.model_name,
                    b,
                    &self.prepared.as_ref().expect("prepared params").1,
                    &batch,
                    gout,
                )
            };
            let loss = match step {
                Ok(l) => l,
                Err(e) => {
                    // Hand the leased buffer back unused so the tree's
                    // live/peak accounting stays honest; the prefetch
                    // handle (if any) joins via Drop on this return.
                    if let (Some(buf), GradStore::Tree(t)) =
                        (leased.take(), &mut self.grads)
                    {
                        t.unlease(buf);
                    }
                    return Err(e);
                }
            };
            let compute = t0.elapsed().as_secs_f64();
            // Data-plane corruption (DESIGN.md §16) lands on the raw
            // gradient buffer *before* it enters the reduction tree or
            // arena, so the norm probe below sees exactly what the
            // optimizer would consume.
            {
                let gbuf: &mut [f32] = match (&mut leased, &mut self.grads) {
                    (Some(buf), _) => buf,
                    (None, GradStore::Arena { bufs, .. }) => &mut bufs[w],
                    _ => unreachable!("leased buffer without a tree store"),
                };
                if let Some(f) = self.faults.as_mut() {
                    if f.has_corrupt() {
                        for c in f.corruptions(w, now) {
                            corrupt_grad(gbuf, &c, &mut self.corrupt_rng);
                        }
                    }
                }
                self.pending_norm[w] = l2_norm(gbuf);
            }
            if let Some(buf) = leased.take() {
                // Combine at completion: the gradient enters the round's
                // reduction tree — pre-weighted by its λ numerator b_w —
                // the moment its step finishes, so the combine work
                // lands inside the wave instead of at the barrier, and
                // the buffer count stays at ⌈log₂k⌉+1 (ascending rank
                // order is the streaming order of the Free bound).
                match &mut self.grads {
                    GradStore::Tree(t) => t.push_owned(w, buf, batches[w] as f32),
                    _ => unreachable!("leased buffer without a tree store"),
                }
            }
            if let Some(h) = handle {
                h.wait(); // batch generation ran under the PJRT step
            }
            // Stashed for apply_update's λ-weighted global loss.
            self.losses[w] = loss as f64;
            let mut out = WorkerOutcome {
                work: compute,
                fixed: 0.0,
            };
            if let Some(f) = self.faults.as_mut() {
                f.perturb(w, now, &mut out);
            }
            outs.push(out);
        }
        Ok(outs)
    }

    fn apply_update(&mut self, workers: &[usize], batches: &[f64]) -> Result<Option<f64>> {
        if workers.is_empty() {
            bail!("apply_update needs at least one worker");
        }
        // λ scratch buffers are reused across updates (§Perf it. 5);
        // the λ vector weights the global loss below, and the gradients
        // on the async arena path.
        self.lam_batches.clear();
        self.lam_batches.extend(workers.iter().map(|&w| batches[w]));
        lambdas_into(&mut self.lambdas, &self.lam_batches);
        match &mut self.grads {
            GradStore::Tree(tree) => {
                // Eager BSP (§Perf it. 6): the members' gradients are
                // already combined; the barrier pays only the residual
                // cascade — O(d·log k) worst case, O(d) typical — and
                // one fused optimizer pass over the root, whose λ slot
                // carries the deferred 1/Σb normalization (leaves were
                // weighted by the raw batch b_w).
                debug_assert_eq!(tree.pushed_count(), workers.len());
                debug_assert!(workers.iter().all(|&w| tree.is_pushed(w)));
                debug_assert!(workers.iter().all(|&w| self.staged[w]));
                apply_tree_barrier(
                    tree,
                    &mut self.optimizer,
                    &mut self.params,
                    &self.lam_batches,
                    self.pool_threads,
                );
            }
            GradStore::Arena { bufs, barrier_tree: Some(tree) } => {
                // Collect-then-aggregate baseline: the same rank-indexed
                // tree, built at the barrier in ascending member order —
                // bit-identical to the eager path by the tree's
                // arrival-order invariance.
                for &w in workers {
                    tree.push(w, &bufs[w], batches[w] as f32);
                }
                apply_tree_barrier(
                    tree,
                    &mut self.optimizer,
                    &mut self.params,
                    &self.lam_batches,
                    self.pool_threads,
                );
            }
            GradStore::Arena { bufs, barrier_tree: None } => {
                // Async single-gradient update: λ-weighted fused
                // aggregation + optimizer (Eq. 2–3), sharded across the
                // persistent pool (§Perf iteration 4).
                let grad_refs: Vec<&[f32]> =
                    workers.iter().map(|&w| bufs[w].as_slice()).collect();
                self.optimizer
                    .step_mt(&mut self.params, &grad_refs, &self.lambdas, self.pool_threads);
            }
        }
        for &w in workers {
            self.staged[w] = false;
        }
        self.version += 1;
        // Global loss = λ-weighted worker losses.
        let loss: f64 = workers
            .iter()
            .zip(&self.lambdas)
            .map(|(&w, &lam)| self.losses[w] * lam)
            .sum();
        Ok(Some(loss))
    }

    fn stage_update(&mut self, w: usize, _batches: &[f64]) -> Result<()> {
        // The session's BSP round flow hands each member over at its
        // completion event.  The gradient itself entered the tree when
        // its train step finished (execute_wave); this marks the
        // contribution *final* for round accounting — the barrier
        // asserts every member it applies was staged, and a revocation
        // between execution and completion instead routes through
        // retire_worker → ReduceTree::revoke.
        if let GradStore::Tree(tree) = &self.grads {
            debug_assert!(
                tree.is_pushed(w),
                "completion event for worker {w} before its gradient was staged"
            );
        }
        self.staged[w] = true;
        Ok(())
    }

    fn update_norm(&mut self, w: usize) -> Option<f64> {
        Some(self.pending_norm[w])
    }

    fn discard_update(&mut self, w: usize) -> Result<()> {
        // A guard rejection drops the contribution exactly the way a
        // same-round revocation does (DESIGN.md §16): the eager tree
        // invalidates the rank's ancestor path and the sibling partials
        // rebuild it; an arena buffer is simply never read because the
        // worker leaves the update's member set.  Unlike retire_worker
        // this keeps the worker's shards — it stays live.
        if let GradStore::Tree(tree) = &mut self.grads {
            tree.revoke(w);
        }
        self.staged[w] = false;
        Ok(())
    }

    fn staleness_discount(&self, _staleness: u64) -> f64 {
        1.0 // convergence is real here, not modeled
    }

    fn retire_worker(&mut self, w: usize) -> Result<()> {
        self.router.revoke(w);
        self.staged[w] = false;
        if let GradStore::Tree(tree) = &mut self.grads {
            // Drop the rank's round contribution (in-flight or staged):
            // under RetainPolicy::Retain only its ancestor path is
            // invalidated and the sibling partials rebuild it.  A rank
            // that never pushed (absent from the start) is a no-op.
            tree.revoke(w);
        }
        Ok(())
    }

    fn admit_worker(&mut self, w: usize) -> Result<()> {
        self.router.admit(w);
        Ok(())
    }

    fn eval(&mut self, _step: u64, _now: f64) -> Result<Option<(f64, f64)>> {
        if !self.eval_enabled {
            return Ok(None);
        }
        let batch = self.dataset.next_batch(self.k, self.eval_bucket);
        let ev = self
            .runtime
            .eval_step(&self.model_name, self.eval_bucket, &self.params, &batch)?;
        Ok(Some((ev.loss as f64, ev.metric as f64)))
    }

    // Checkpoint sidecar (DESIGN.md §15): parameters, optimizer moments
    // and the parameter version travel in `backend.bin`.  Dataset
    // cursors and shard-router state are deliberately *not* captured —
    // a resumed real run continues with fresh data streams, so it is
    // model-state-consistent, not stream-bitwise (the bitwise resume
    // claim is proven on the sim/mock backends, whose state closure is
    // complete).

    fn snapshot_state(&self) -> Option<crate::util::json::Json> {
        use crate::ckpt::{enc_f64_slice, enc_opt_f64, enc_u128};
        use crate::util::json::Json;
        let mut j = Json::obj();
        if let Some(f) = &self.faults {
            j.set("faults", f.snapshot());
        }
        // The corrupt stream and in-flight norms ride along for state
        // completeness (a checkpoint can land between a corrupted
        // dispatch and its completion's guard check), even though real
        // resume is stream-consistent rather than bitwise — see the
        // sidecar note above.
        let (cstate, cinc, cspare) = self.corrupt_rng.state_parts();
        j.set("corrupt_rng_state", enc_u128(cstate));
        j.set("corrupt_rng_inc", enc_u128(cinc));
        j.set("corrupt_rng_spare", enc_opt_f64(cspare));
        j.set("pending_norm", enc_f64_slice(&self.pending_norm));
        Some(j)
    }

    fn restore_state(&mut self, j: &crate::util::json::Json) -> Result<(), String> {
        use crate::ckpt::{dec_f64_vec, dec_opt_f64, dec_u128};
        use crate::util::json::Json;
        if !j.get("corrupt_rng_state").is_null() {
            self.corrupt_rng = Rng::from_parts(
                dec_u128(j.get("corrupt_rng_state"))?,
                dec_u128(j.get("corrupt_rng_inc"))?,
                dec_opt_f64(j.get("corrupt_rng_spare"))?,
            );
        }
        if !j.get("pending_norm").is_null() {
            let pending = dec_f64_vec(j.get("pending_norm"))?;
            if pending.len() != self.pending_norm.len() {
                return Err(format!(
                    "backend snapshot: pending_norm has {} entries, want {}",
                    pending.len(),
                    self.pending_norm.len()
                ));
            }
            self.pending_norm = pending;
        }
        match (self.faults.as_mut(), j.get("faults")) {
            (_, Json::Null) => Ok(()),
            (Some(f), snap) => f.restore(snap),
            (None, _) => Err(
                "backend snapshot carries fault state but no plan is set \
                 (restore order: set_fault_plan before restore_state)"
                    .into(),
            ),
        }
    }

    fn snapshot_binary(&self) -> Option<Vec<u8>> {
        use crate::ckpt::{bin_new, bin_put_f32s, bin_put_u64};
        let mut buf = bin_new();
        bin_put_u64(&mut buf, self.version);
        bin_put_f32s(&mut buf, &self.params);
        let (t, moments) = self.optimizer.ckpt_moments();
        bin_put_u64(&mut buf, t);
        bin_put_u64(&mut buf, moments.len() as u64);
        for m in moments {
            bin_put_f32s(&mut buf, m);
        }
        Some(buf)
    }

    fn restore_binary(&mut self, bytes: &[u8]) -> Result<(), String> {
        use crate::ckpt::BinReader;
        let mut r = BinReader::new(bytes)?;
        let version = r.u64()?;
        let params = r.f32s()?;
        if params.len() != self.params.len() {
            return Err(format!(
                "backend.bin: {} parameters, model {} has {}",
                params.len(),
                self.model_name,
                self.params.len()
            ));
        }
        let t = r.u64()?;
        let n = r.u64()? as usize;
        let mut moments = Vec::with_capacity(n);
        for _ in 0..n {
            moments.push(r.f32s()?);
        }
        r.finish()?;
        self.optimizer.ckpt_restore(t, &moments)?;
        self.params = params;
        self.version = version;
        self.prepared = None; // re-marshal against the restored params
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // RealBackend integration tests (need built artifacts) live in
    // rust/tests/engine_integration.rs.
}
