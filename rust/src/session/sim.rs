//! Virtual-time simulation backend.
//!
//! Regenerates the paper's evaluation at testbed scale: each worker's
//! iteration work is sampled from the [`CapacityModel`] (Amdahl scaling,
//! batch-efficiency curve, lognormal noise), the [`super::Session`]
//! integrates it over availability traces and drives the batching policy
//! under test, and a convergence model converts executed updates into
//! progress toward the accuracy target.  Time is virtual — a simulated
//! 90-minute ResNet run costs milliseconds — which is what makes the
//! Fig. 6 sweeps tractable.
//!
//! Convergence model: at fixed global batch (which every policy here
//! preserves), BSP needs `iters_to_target` global iterations regardless
//! of how the batch is split — λ-weighted aggregation keeps the update
//! equivalent (paper §III-A, [17]).  Under ASP, a stale update
//! contributes [`staleness_discount`]`(s)` of a fresh one ([18], [19]),
//! so more updates are needed — the statistical-inefficiency penalty the
//! paper describes.

use anyhow::Result;

use crate::cluster::{CapacityModel, WorkerSpec, WorkloadProfile};
use crate::fault::{Corruption, FaultPlan, FaultState, CORRUPT_SEED_TAG};
use crate::session::{Backend, WorkerOutcome};
use crate::sync::staleness_discount;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Staleness discount sharpness for ASP statistical efficiency.
pub const STALENESS_GAMMA: f64 = 0.4;

/// Simulated execution substrate: capacity model + per-worker devices.
pub struct SimBackend {
    /// Public so experiments can tune the workload (e.g. shrink
    /// `model.workload.iters_to_target` for fast run-to-target tests).
    pub model: CapacityModel,
    workload: String,
    workers: Vec<WorkerSpec>,
    rng: Rng,
    faults: Option<FaultState>,
    /// Modeled L2 norm of each worker's in-flight update (DESIGN.md
    /// §16).  The simulator models updates rather than holding
    /// gradients, so a healthy contribution has unit norm by
    /// construction — deliberately batch-independent, so heterogeneous
    /// batch splits can never trip the guard — and scripted corruptions
    /// perturb it at dispatch, exactly where timing faults land.
    pending_norm: Vec<f64>,
    /// Dedicated rng stream for bitflip corruption, forked off the run
    /// seed under [`CORRUPT_SEED_TAG`].  Advanced only when a bitflip
    /// actually fires, so a corruption-free plan leaves it untouched
    /// (part of the guard-invisibility invariant).
    corrupt_rng: Rng,
}

impl SimBackend {
    pub fn new(
        workload: &str,
        workers: Vec<WorkerSpec>,
        noise_sigma: f64,
        target_iters: u64,
        seed: u64,
    ) -> Result<Self, String> {
        let profile = WorkloadProfile::by_name(workload)
            .ok_or_else(|| format!("unknown workload {workload:?}"))?;
        let mut model = CapacityModel::new(profile).with_noise(noise_sigma);
        if target_iters > 0 {
            model.workload.iters_to_target = target_iters;
        }
        let k = workers.len();
        Ok(SimBackend {
            model,
            workload: workload.to_string(),
            workers,
            rng: Rng::new(seed),
            faults: None,
            pending_norm: vec![1.0; k],
            corrupt_rng: Rng::new(seed ^ CORRUPT_SEED_TAG),
        })
    }
}

/// Apply one scripted corruption to a modeled update norm.  Bitflips
/// flip random bits of the norm's own f64 pattern (the closest modeled
/// analogue of flipping payload bits), drawing from the dedicated
/// corrupt stream only when they fire.
fn corrupt_norm(norm: f64, c: &Corruption, rng: &mut Rng) -> f64 {
    match *c {
        Corruption::Nan => f64::NAN,
        Corruption::Inf => f64::INFINITY,
        Corruption::Scale { factor } => norm * factor.abs(),
        Corruption::Bitflip { flips } => {
            let mut bits = norm.to_bits();
            for _ in 0..flips {
                bits ^= 1u64 << rng.below(64);
            }
            f64::from_bits(bits)
        }
    }
}

impl Backend for SimBackend {
    fn k(&self) -> usize {
        self.workers.len()
    }

    fn label(&self) -> String {
        self.workload.clone()
    }

    fn buckets(&self) -> Option<Vec<usize>> {
        None // continuous batch sizes (no AOT shape constraint)
    }

    fn default_b0(&self) -> f64 {
        self.model.workload.b0 as f64
    }

    fn flops_estimates(&self) -> Vec<f64> {
        self.workers
            .iter()
            .map(|w| w.device.flops_estimate())
            .collect()
    }

    fn default_target(&self) -> u64 {
        self.model.workload.iters_to_target
    }

    fn execute_wave(
        &mut self,
        wave: &[usize],
        batches: &[f64],
        now: f64,
    ) -> Result<Vec<WorkerOutcome>> {
        Ok(wave
            .iter()
            .map(|&w| {
                let mut out = WorkerOutcome {
                    work: self.model.compute_work(
                        &self.workers[w].device,
                        batches[w].max(1.0),
                        &mut self.rng,
                    ),
                    fixed: self.model.fixed_time(),
                };
                // Injected timing faults (stall/slow) perturb the
                // outcome at dispatch; crashes are session-side.
                if let Some(f) = self.faults.as_mut() {
                    f.perturb(w, now, &mut out);
                }
                // Data-plane corruption perturbs the modeled update
                // norm the guard will inspect at completion.  The
                // has_corrupt gate keeps corruption-free dispatches off
                // the event scan (and off the corrupt rng stream).
                self.pending_norm[w] = 1.0;
                if let Some(f) = self.faults.as_mut() {
                    if f.has_corrupt() {
                        for c in f.corruptions(w, now) {
                            self.pending_norm[w] =
                                corrupt_norm(self.pending_norm[w], &c, &mut self.corrupt_rng);
                        }
                    }
                }
                out
            })
            .collect())
    }

    fn update_norm(&mut self, w: usize) -> Option<f64> {
        Some(self.pending_norm[w])
    }

    fn set_fault_plan(&mut self, plan: &FaultPlan) {
        self.faults = Some(plan.state());
    }

    fn apply_update(&mut self, _workers: &[usize], _batches: &[f64]) -> Result<Option<f64>> {
        Ok(None) // progress is modeled, not trained
    }

    fn staleness_discount(&self, staleness: u64) -> f64 {
        staleness_discount(staleness, STALENESS_GAMMA)
    }

    fn eval(&mut self, _step: u64, _now: f64) -> Result<Option<(f64, f64)>> {
        Ok(None)
    }

    fn snapshot_state(&self) -> Option<Json> {
        use crate::ckpt::{enc_f64_slice, enc_opt_f64, enc_u128};
        let (state, inc, spare) = self.rng.state_parts();
        let mut j = Json::obj();
        j.set("rng_state", enc_u128(state));
        j.set("rng_inc", enc_u128(inc));
        j.set("rng_spare", enc_opt_f64(spare));
        // The corrupt stream and the in-flight modeled norms must ride
        // along: a checkpoint can land between a corrupted dispatch and
        // its completion's guard check (DESIGN.md §16).
        let (cstate, cinc, cspare) = self.corrupt_rng.state_parts();
        j.set("corrupt_rng_state", enc_u128(cstate));
        j.set("corrupt_rng_inc", enc_u128(cinc));
        j.set("corrupt_rng_spare", enc_opt_f64(cspare));
        j.set("pending_norm", enc_f64_slice(&self.pending_norm));
        if let Some(f) = &self.faults {
            j.set("faults", f.snapshot());
        }
        Some(j)
    }

    fn restore_state(&mut self, j: &Json) -> Result<(), String> {
        use crate::ckpt::{dec_f64_vec, dec_opt_f64, dec_u128};
        self.rng = Rng::from_parts(
            dec_u128(j.get("rng_state"))?,
            dec_u128(j.get("rng_inc"))?,
            dec_opt_f64(j.get("rng_spare"))?,
        );
        self.corrupt_rng = Rng::from_parts(
            dec_u128(j.get("corrupt_rng_state"))?,
            dec_u128(j.get("corrupt_rng_inc"))?,
            dec_opt_f64(j.get("corrupt_rng_spare"))?,
        );
        let pending = dec_f64_vec(j.get("pending_norm"))?;
        if pending.len() != self.pending_norm.len() {
            return Err(format!(
                "backend snapshot: pending_norm has {} entries, want {}",
                pending.len(),
                self.pending_norm.len()
            ));
        }
        self.pending_norm = pending;
        match (self.faults.as_mut(), j.get("faults")) {
            (_, Json::Null) => {}
            (Some(f), snap) => f.restore(snap)?,
            (None, _) => {
                return Err(
                    "backend snapshot carries fault state but no plan is set \
                     (restore order: set_fault_plan before restore_state)"
                        .into(),
                )
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use crate::config::Policy;
    use crate::metrics::RunReport;
    use crate::session::{Session, SessionBuilder};
    use crate::sync::SyncMode;
    use crate::trace::{
        AvailTrace, ClusterTraces, JoinSpec, MembershipKind, MembershipPlan,
        DOWN_EPS,
    };

    fn quick(workload: &str, cores: &[usize], policy: Policy) -> SessionBuilder {
        Session::builder()
            .model(workload)
            .cores(cores)
            .policy(policy)
            .steps(300)
            .adjust_cost(5.0)
    }

    fn run(b: SessionBuilder) -> RunReport {
        b.build_sim().unwrap().run().unwrap()
    }

    #[test]
    fn homogeneous_policies_equivalent() {
        // On a homogeneous cluster, variable batching ≈ uniform batching.
        let u = run(quick("mnist", &[13, 13, 13], Policy::Uniform));
        let s = run(quick("mnist", &[13, 13, 13], Policy::Static));
        let ratio = u.total_time / s.total_time;
        assert!((ratio - 1.0).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn variable_beats_uniform_on_heterogeneous_bsp() {
        // The paper's core claim, at H-level 4 (3,13,18)+: static variable
        // batching substantially beats uniform under BSP.
        let u = run(quick("resnet", &[3, 16, 20], Policy::Uniform));
        let s = run(quick("resnet", &[3, 16, 20], Policy::Static));
        let speedup = u.total_time / s.total_time;
        assert!(speedup > 1.5, "speedup={speedup}");
    }

    #[test]
    fn dynamic_converges_and_stops_adjusting() {
        let r = run(quick("resnet", &[3, 12, 24], Policy::Dynamic).steps(400));
        assert!(r.adjustments.len() >= 1, "controller never engaged");
        assert!(
            r.adjustments.len() < 25,
            "controller oscillating: {} adjustments",
            r.adjustments.len()
        );
        // All adjustments happen early (steady state after warm-up).
        let last = r.adjustments.last().unwrap();
        assert!(last.iter < 300, "late adjustment at iter {}", last.iter);
    }

    #[test]
    fn dynamic_equalizes_iteration_times() {
        let dynamic = run(quick("resnet", &[3, 12, 24], Policy::Dynamic).steps(400));
        let uniform = run(quick("resnet", &[3, 12, 24], Policy::Uniform));
        // Compare iteration gap over the steady-state tail.
        let gd = dynamic.iteration_gap(3);
        let gu = uniform.iteration_gap(3);
        assert!(gd < gu * 0.5, "gap dynamic={gd} uniform={gu}");
    }

    #[test]
    fn bsp_waits_stragglers_asp_does_not() {
        let base = quick("resnet", &[3, 16, 20], Policy::Uniform).steps(200);
        let bsp = run(base.clone());
        let asp = run(base.sync(SyncMode::Asp));
        assert!(bsp.wait_fraction() > 0.2, "bsp wait={}", bsp.wait_fraction());
        assert!(asp.wait_fraction() < 1e-9);
    }

    #[test]
    fn asp_needs_more_updates_due_to_staleness() {
        // Run to a shrunk target so the test is fast.
        let asp = run(Session::builder()
            .model("mnist")
            .cores(&[3, 16, 20])
            .policy(Policy::Uniform)
            .steps(0)
            .noise(0.02)
            .target_iters(300)
            .sync(SyncMode::Asp));
        assert!(asp.reached_target);
        // Fresh-equivalent target is 300 global iterations = 900 updates
        // at K=3; staleness means strictly more.
        assert!(
            asp.total_iters > 900,
            "updates={} (staleness discount not applied?)",
            asp.total_iters
        );
    }

    #[test]
    fn ssp_bounds_iteration_lead() {
        let r = run(quick("resnet", &[2, 18, 19], Policy::Uniform)
            .steps(100)
            .sync(SyncMode::Ssp { bound: 2 }));
        // Reconstruct clocks: per worker max iter index; lead ≤ bound+1.
        let mut max_clock = [0u64; 3];
        for rec in &r.iters {
            max_clock[rec.worker] = max_clock[rec.worker].max(rec.iter);
        }
        let lead = max_clock.iter().max().unwrap() - max_clock.iter().min().unwrap();
        assert!(lead <= 3, "lead={lead}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(quick("mnist", &[4, 8, 27], Policy::Dynamic));
        let b = run(quick("mnist", &[4, 8, 27], Policy::Dynamic));
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.adjustments.len(), b.adjustments.len());
    }

    // ---------------------------------------------------- elastic membership

    /// A 150 s outage on worker 0 starting at t=60, as traces + the
    /// membership plan derived from them (grace 15 s ⇒ revoke at t=75,
    /// rejoin at t=210).  Timescale: a simulated resnet round on ~13
    /// cores is ≈4 s, so both events land well inside a 120-step run.
    fn outage_scenario() -> (ClusterTraces, MembershipPlan) {
        let traces = ClusterTraces {
            traces: vec![
                AvailTrace::from_segments(vec![
                    (0.0, 1.0),
                    (60.0, DOWN_EPS),
                    (210.0, 1.0),
                ]),
                AvailTrace::constant(),
                AvailTrace::constant(),
            ],
        };
        let plan = MembershipPlan::from_traces(&traces, 15.0).unwrap();
        (traces, plan)
    }

    #[test]
    fn revocation_beats_riding_out_the_preemption_under_bsp() {
        // Rigid BSP must eat the whole outage at the barrier; elastic
        // membership revokes the preempted worker and keeps training.
        let (traces, plan) = outage_scenario();
        let rigid = run(quick("resnet", &[13, 13, 13], Policy::Uniform)
            .steps(120)
            .traces(traces.clone()));
        let elastic = run(quick("resnet", &[13, 13, 13], Policy::Uniform)
            .steps(120)
            .traces(traces)
            .membership(plan));
        // Two transitions: revoke at 75, rejoin at 210.
        assert_eq!(elastic.epochs.len(), 2);
        assert_eq!(elastic.epochs[0].kind, MembershipKind::Revoke);
        assert_eq!(elastic.epochs[0].worker, 0);
        assert_eq!(elastic.epochs[0].live, 2);
        assert_eq!(elastic.epochs[1].kind, MembershipKind::Join);
        assert_eq!(elastic.epochs[1].live, 3);
        // The rigid run pays ~the full 150 s outage at one barrier;
        // elastic pays only the grace period plus temporarily bigger
        // survivor batches.
        assert!(
            elastic.total_time + 50.0 < rigid.total_time,
            "elastic {} vs rigid {}",
            elastic.total_time,
            rigid.total_time
        );
        assert!(elastic.reached_target);
    }

    #[test]
    fn membership_conserves_global_batch_at_every_epoch() {
        let (traces, plan) = outage_scenario();
        for policy in [
            Policy::Uniform,
            Policy::Static,
            Policy::Dynamic,
            Policy::Optimal,
            Policy::Rl,
        ] {
            for sync in [SyncMode::Bsp, SyncMode::Asp, SyncMode::Ssp { bound: 2 }] {
                let r = run(quick("resnet", &[4, 13, 22], policy)
                    .steps(150)
                    .sync(sync)
                    .traces(traces.clone())
                    .membership(plan.clone()));
                assert!(!r.epochs.is_empty(), "{policy:?}/{sync:?}: no epochs");
                // Σb of the initial allocation (each worker's first
                // record predates the first adjustment: min_obs gates it)…
                let initial: f64 = (0..3)
                    .map(|w| r.iters.iter().find(|i| i.worker == w).unwrap().batch)
                    .sum();
                // …is conserved through every membership rebalance.
                for e in &r.epochs {
                    let sum: f64 = e.batches.iter().sum();
                    assert!(
                        (sum - initial).abs() < 1e-6,
                        "{policy:?}/{sync:?} epoch {e:?}: sum {sum} != {initial}"
                    );
                }
            }
        }
    }

    #[test]
    fn scheduled_join_brings_worker_in_late() {
        // Worker 2 is a scheduled join: absent at start, appears at
        // t=4 s (≈ round 50 at mnist's ~80 ms rounds), seeded from the
        // global model.
        let r = run(quick("mnist", &[13, 13, 13], Policy::Uniform)
            .steps(300)
            .joins(&[JoinSpec { worker: 2, time: 4.0 }]));
        assert_eq!(r.epochs.len(), 1);
        assert_eq!(r.epochs[0].kind, MembershipKind::Join);
        assert_eq!(r.epochs[0].live, 3);
        // No records for worker 2 before the join…
        assert!(r
            .iters
            .iter()
            .filter(|i| i.worker == 2)
            .all(|i| i.start >= 4.0));
        // …and plenty after.
        assert!(r.iters.iter().any(|i| i.worker == 2));
        // Two-worker rounds carried the full global batch before the
        // join; after it, three ways.
        let early = r.iters.iter().find(|i| i.worker == 0).unwrap().batch;
        let late = r.iters.iter().rev().find(|i| i.worker == 0).unwrap().batch;
        assert!(late < early, "batch should shrink at the join: {early} -> {late}");
    }

    #[test]
    fn dynamic_rebalances_after_rejoin() {
        // After the outage worker 0 rejoins; the controller must fold it
        // back in and keep conserving the global batch.
        let (traces, plan) = outage_scenario();
        let r = run(quick("resnet", &[13, 13, 13], Policy::Dynamic)
            .adjust_cost(1.0)
            .steps(200)
            .traces(traces)
            .membership(plan));
        assert_eq!(r.epochs.len(), 2);
        let rejoin = &r.epochs[1];
        assert!(rejoin.batches[0] > 0.0, "rejoiner got no batch: {rejoin:?}");
        // Worker 0 runs iterations again after rejoining.
        assert!(r
            .iters
            .iter()
            .any(|i| i.worker == 0 && i.start > rejoin.time));
    }

    #[test]
    fn deterministic_under_spot_churn() {
        use crate::trace::SpotSpec;
        let mk = || {
            // mnist rounds are ~0.1 s: an mttf of 8 s gives several
            // preemptions inside a 250-step run.
            run(quick("mnist", &[4, 8, 27], Policy::Dynamic)
                .steps(250)
                .seed(5)
                .spot(SpotSpec { mttf_s: 8.0, down_s: 2.0, grace_s: 0.3 }))
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.epochs.len(), b.epochs.len());
        assert_eq!(a.adjustments.len(), b.adjustments.len());
        for (x, y) in a.epochs.iter().zip(&b.epochs) {
            assert_eq!(x.time, y.time);
            assert_eq!(x.worker, y.worker);
            assert_eq!(x.kind, y.kind);
        }
    }

    #[test]
    fn trace_slowdown_triggers_dynamic_readjustment() {
        // Worker 0 loses half its capacity at t=200s.
        let traces = ClusterTraces {
            traces: vec![
                AvailTrace::from_segments(vec![(0.0, 1.0), (200.0, 0.5)]),
                AvailTrace::constant(),
                AvailTrace::constant(),
            ],
        };
        let r = run(quick("resnet", &[13, 13, 13], Policy::Dynamic)
            .adjust_cost(1.0)
            .traces(traces));
        // The controller must have reacted after the capacity change with
        // a smaller batch for worker 0.
        let late: Vec<_> = r.adjustments.iter().filter(|a| a.time > 200.0).collect();
        assert!(!late.is_empty(), "no reaction to interference");
        let final_b = r.final_batches().unwrap();
        assert!(
            final_b[0] < final_b[1] * 0.8,
            "worker 0 batch {final_b:?} not reduced"
        );
    }
}
