//! Typed experiment configuration.
//!
//! An experiment = workload + cluster + batching policy + sync mode +
//! controller settings + run budget.  Configs parse from JSON files (see
//! `examples/configs/`) and/or CLI flags; every field has a sane default
//! so `hbatch simulate --workload resnet --cores 9,12,18` just works.

use crate::cluster::{cpu_cluster, GpuModel, WorkerSpec};
use crate::controller::ControllerCfg;
use crate::sync::SyncMode;
use crate::util::json::Json;

/// Which batch-allocation policy to run (the paper's three contenders).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Vanilla TF: same batch everywhere.
    Uniform,
    /// Open-loop FLOPs-proportional (§III-B).
    Static,
    /// Closed-loop proportional controller (§III-C).
    Dynamic,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "uniform" => Some(Policy::Uniform),
            "static" => Some(Policy::Static),
            "dynamic" => Some(Policy::Dynamic),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Policy::Uniform => "uniform",
            Policy::Static => "static",
            Policy::Dynamic => "dynamic",
        }
    }
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentCfg {
    /// Workload profile name (resnet | mnist | linreg | transformer) for
    /// simulation; registry model name for real execution.
    pub workload: String,
    pub workers: Vec<WorkerSpec>,
    pub policy: Policy,
    pub sync: SyncMode,
    pub controller: ControllerCfg,
    /// Reference per-worker batch b0 (0 ⇒ workload default).
    pub b0: usize,
    /// Cost (seconds) of applying a batch readjustment (TF kill-restart /
    /// executable swap).
    pub adjust_cost_s: f64,
    /// Iteration-time noise sigma (lognormal).
    pub noise_sigma: f64,
    /// Stop after this many global iterations (0 ⇒ run to target).
    pub max_iters: u64,
    pub seed: u64,
}

impl Default for ExperimentCfg {
    fn default() -> Self {
        ExperimentCfg {
            workload: "resnet".into(),
            workers: cpu_cluster(&[9, 12, 18]),
            policy: Policy::Dynamic,
            sync: SyncMode::Bsp,
            controller: ControllerCfg::default(),
            b0: 0,
            adjust_cost_s: 30.0, // paper: TF terminate+restart is expensive
            noise_sigma: 0.06,
            max_iters: 0,
            seed: 0,
        }
    }
}

impl ExperimentCfg {
    /// Parse worker list from JSON: `[{"cpu": 9}, {"gpu": "P100"}]`.
    pub fn workers_from_json(arr: &Json) -> Result<Vec<WorkerSpec>, String> {
        let items = arr.as_arr().ok_or("workers must be an array")?;
        let mut out = Vec::new();
        for (i, item) in items.iter().enumerate() {
            if let Some(c) = item.get("cpu").as_usize() {
                out.push(WorkerSpec::cpu(i, c));
            } else if let Some(g) = item.get("gpu").as_str() {
                let model = match g {
                    "P100" => GpuModel::P100,
                    "T4" => GpuModel::T4,
                    "P4" => GpuModel::P4,
                    _ => return Err(format!("unknown gpu model {g:?}")),
                };
                out.push(WorkerSpec::gpu(i, model));
            } else {
                return Err(format!("worker {i}: need {{\"cpu\": n}} or {{\"gpu\": name}}"));
            }
        }
        if out.is_empty() {
            return Err("empty worker list".into());
        }
        Ok(out)
    }

    /// Load overrides from a JSON object (missing keys keep defaults).
    pub fn from_json(j: &Json) -> Result<ExperimentCfg, String> {
        let mut cfg = ExperimentCfg::default();
        if let Some(w) = j.get("workload").as_str() {
            cfg.workload = w.to_string();
        }
        if !j.get("workers").is_null() {
            cfg.workers = Self::workers_from_json(j.get("workers"))?;
        }
        if let Some(p) = j.get("policy").as_str() {
            cfg.policy = Policy::parse(p).ok_or(format!("bad policy {p:?}"))?;
        }
        if let Some(s) = j.get("sync").as_str() {
            cfg.sync = SyncMode::parse(s).ok_or(format!("bad sync {s:?}"))?;
        }
        if let Some(b) = j.get("b0").as_usize() {
            cfg.b0 = b;
        }
        if let Some(c) = j.get("adjust_cost_s").as_f64() {
            cfg.adjust_cost_s = c;
        }
        if let Some(n) = j.get("noise_sigma").as_f64() {
            cfg.noise_sigma = n;
        }
        if let Some(m) = j.get("max_iters").as_usize() {
            cfg.max_iters = m as u64;
        }
        if let Some(s) = j.get("seed").as_usize() {
            cfg.seed = s as u64;
        }
        let c = j.get("controller");
        if !c.is_null() {
            if let Some(d) = c.get("deadband").as_f64() {
                cfg.controller.deadband = d;
            }
            if let Some(a) = c.get("ewma_alpha").as_f64() {
                cfg.controller.ewma_alpha = a;
            }
            if let Some(m) = c.get("min_obs").as_usize() {
                cfg.controller.min_obs = m;
            }
            if let Some(b) = c.get("b_min").as_f64() {
                cfg.controller.b_min = b;
            }
            if let Some(b) = c.get("b_max").as_f64() {
                cfg.controller.b_max = b;
            }
            if let Some(b) = c.get("adaptive_bmax").as_bool() {
                cfg.controller.adaptive_bmax = b;
            }
            if let Some(b) = c.get("conserve_global").as_bool() {
                cfg.controller.conserve_global = b;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> Result<ExperimentCfg, String> {
        let j = Json::parse(s).map_err(|e| e.to_string())?;
        Self::from_json(&j)
    }

    pub fn from_file(path: &str) -> Result<ExperimentCfg, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_json_str(&text)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.workers.is_empty() {
            return Err("no workers".into());
        }
        if self.controller.deadband < 0.0 || self.controller.deadband >= 1.0 {
            return Err(format!("deadband {} out of [0,1)", self.controller.deadband));
        }
        if self.controller.b_min < 1.0 || self.controller.b_min > self.controller.b_max {
            return Err("b_min must be in [1, b_max]".into());
        }
        if self.adjust_cost_s < 0.0 || self.noise_sigma < 0.0 {
            return Err("costs/noise must be non-negative".into());
        }
        Ok(())
    }

    /// Effective b0: explicit or the workload profile's default.
    pub fn effective_b0(&self) -> usize {
        if self.b0 > 0 {
            return self.b0;
        }
        crate::cluster::WorkloadProfile::by_name(&self.workload)
            .map(|w| w.b0)
            .unwrap_or(64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceKind;

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("uniform"), Some(Policy::Uniform));
        assert_eq!(Policy::parse("dynamic"), Some(Policy::Dynamic));
        assert_eq!(Policy::parse("x"), None);
    }

    #[test]
    fn defaults_are_valid() {
        assert!(ExperimentCfg::default().validate().is_ok());
    }

    #[test]
    fn parse_full_config() {
        let src = r#"{
            "workload": "mnist",
            "workers": [{"cpu": 4}, {"cpu": 16}, {"gpu": "T4"}],
            "policy": "static",
            "sync": "ssp:3",
            "b0": 100,
            "adjust_cost_s": 5.0,
            "controller": {"deadband": 0.1, "b_min": 2, "b_max": 512},
            "seed": 9
        }"#;
        let cfg = ExperimentCfg::from_json_str(src).unwrap();
        assert_eq!(cfg.workload, "mnist");
        assert_eq!(cfg.workers.len(), 3);
        assert_eq!(cfg.workers[1].device, DeviceKind::Cpu { cores: 16 });
        assert!(matches!(cfg.workers[2].device, DeviceKind::Gpu { .. }));
        assert_eq!(cfg.policy, Policy::Static);
        assert_eq!(cfg.sync, SyncMode::Ssp { bound: 3 });
        assert_eq!(cfg.b0, 100);
        assert_eq!(cfg.controller.deadband, 0.1);
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn missing_keys_keep_defaults() {
        let cfg = ExperimentCfg::from_json_str(r#"{"workload": "linreg"}"#).unwrap();
        assert_eq!(cfg.workload, "linreg");
        assert_eq!(cfg.policy, Policy::Dynamic);
        assert_eq!(cfg.workers.len(), 3);
    }

    #[test]
    fn bad_configs_rejected() {
        assert!(ExperimentCfg::from_json_str(r#"{"policy": "bogus"}"#).is_err());
        assert!(ExperimentCfg::from_json_str(r#"{"sync": "bogus"}"#).is_err());
        assert!(
            ExperimentCfg::from_json_str(r#"{"workers": [{"gpu": "H100"}]}"#).is_err()
        );
        assert!(ExperimentCfg::from_json_str(r#"{"workers": []}"#).is_err());
        assert!(ExperimentCfg::from_json_str(
            r#"{"controller": {"deadband": 2.0}}"#
        )
        .is_err());
    }

    #[test]
    fn effective_b0_falls_back_to_profile() {
        let mut cfg = ExperimentCfg::default();
        cfg.workload = "mnist".into();
        assert_eq!(cfg.effective_b0(), 100);
        cfg.b0 = 7;
        assert_eq!(cfg.effective_b0(), 7);
    }
}
