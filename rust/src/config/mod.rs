//! Batching-policy selection (the paper's three contenders plus the
//! learned controllers, DESIGN.md §14).
//!
//! Run configuration lives in [`crate::session::SessionBuilder`] — one
//! builder for simulated and real sessions, JSON-loadable (see
//! `SessionBuilder::from_json`); this module keeps only the policy enum
//! it selects between.

/// Which batch-allocation policy to run: the paper's three contenders
/// plus the two learned controllers behind the `BatchPolicy` seam
/// (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Vanilla TF: same batch everywhere.
    Uniform,
    /// Open-loop FLOPs-proportional (§III-B).
    Static,
    /// Closed-loop proportional controller (§III-C). `pid` is an
    /// accepted spelling — the label (and therefore every report label
    /// and golden) stays `dynamic`.
    Dynamic,
    /// One-shot optimal allocator: fits per-worker linear iteration-time
    /// models and jumps straight to the equalizing allocation
    /// (Nie et al., PAPERS.md).
    Optimal,
    /// Tabular bandit/RL policy over slow→fast batch-mass moves
    /// (DYNAMIX, PAPERS.md); the Q-table is JSON-serializable.
    Rl,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "uniform" => Some(Policy::Uniform),
            "static" => Some(Policy::Static),
            // `pid` aliases the paper's controller: same implementation,
            // same `dynamic` label, bitwise-identical trajectories.
            "dynamic" | "pid" => Some(Policy::Dynamic),
            "optimal" => Some(Policy::Optimal),
            "rl" => Some(Policy::Rl),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Policy::Uniform => "uniform",
            Policy::Static => "static",
            Policy::Dynamic => "dynamic",
            Policy::Optimal => "optimal",
            Policy::Rl => "rl",
        }
    }
}

/// Split a CLI/JSON policy spec like `rl:table.json` into the policy
/// name and an optional argument (the RL table path).  Only the first
/// `:` splits, so paths containing `:` survive intact.
pub fn split_policy_spec(spec: &str) -> (&str, Option<&str>) {
    match spec.split_once(':') {
        Some((name, arg)) if !arg.is_empty() => (name, Some(arg)),
        Some((name, _)) => (name, None),
        None => (spec, None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_splits_on_first_colon() {
        assert_eq!(split_policy_spec("dynamic"), ("dynamic", None));
        assert_eq!(
            split_policy_spec("rl:t.json"),
            ("rl", Some("t.json"))
        );
        assert_eq!(
            split_policy_spec("rl:dir:with:colons.json"),
            ("rl", Some("dir:with:colons.json"))
        );
        assert_eq!(split_policy_spec("rl:"), ("rl", None));
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("uniform"), Some(Policy::Uniform));
        assert_eq!(Policy::parse("dynamic"), Some(Policy::Dynamic));
        assert_eq!(Policy::parse("optimal"), Some(Policy::Optimal));
        assert_eq!(Policy::parse("rl"), Some(Policy::Rl));
        assert_eq!(Policy::parse("x"), None);
    }

    #[test]
    fn pid_aliases_dynamic_with_dynamic_label() {
        // The alias must not mint a new label: report labels (and the
        // scenario goldens keyed on them) stay `dynamic`.
        assert_eq!(Policy::parse("pid"), Some(Policy::Dynamic));
        assert_eq!(Policy::parse("pid").unwrap().label(), "dynamic");
    }

    #[test]
    fn labels_round_trip() {
        for p in [
            Policy::Uniform,
            Policy::Static,
            Policy::Dynamic,
            Policy::Optimal,
            Policy::Rl,
        ] {
            assert_eq!(Policy::parse(p.label()), Some(p));
        }
    }
}
