//! Batching-policy selection (the paper's three contenders).
//!
//! Run configuration lives in [`crate::session::SessionBuilder`] — one
//! builder for simulated and real sessions, JSON-loadable (see
//! `SessionBuilder::from_json`); this module keeps only the policy enum
//! it selects between.

/// Which batch-allocation policy to run (the paper's three contenders).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Vanilla TF: same batch everywhere.
    Uniform,
    /// Open-loop FLOPs-proportional (§III-B).
    Static,
    /// Closed-loop proportional controller (§III-C).
    Dynamic,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "uniform" => Some(Policy::Uniform),
            "static" => Some(Policy::Static),
            "dynamic" => Some(Policy::Dynamic),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Policy::Uniform => "uniform",
            Policy::Static => "static",
            Policy::Dynamic => "dynamic",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("uniform"), Some(Policy::Uniform));
        assert_eq!(Policy::parse("dynamic"), Some(Policy::Dynamic));
        assert_eq!(Policy::parse("x"), None);
    }

    #[test]
    fn labels_round_trip() {
        for p in [Policy::Uniform, Policy::Static, Policy::Dynamic] {
            assert_eq!(Policy::parse(p.label()), Some(p));
        }
    }
}
