//! Virtual-time training simulator.
//!
//! Regenerates the paper's evaluation at testbed scale: each worker's
//! iteration times are sampled from the [`CapacityModel`] (Amdahl scaling,
//! batch-efficiency curve, lognormal noise, availability traces), the
//! batching policy under test allocates mini-batches, and a convergence
//! model converts executed iterations into progress toward the accuracy
//! target.  Time is virtual — a simulated 90-minute ResNet run costs
//! milliseconds — which is what makes the Fig. 6 sweeps tractable.
//!
//! Convergence model: at fixed global batch (which every policy here
//! preserves), BSP needs `iters_to_target` global iterations regardless of
//! how the batch is split — λ-weighted aggregation keeps the update
//! equivalent (paper §III-A, [17]).  Under ASP, a stale update contributes
//! `staleness_discount(s)` of a fresh one ([18], [19]), so more iterations
//! are needed — the statistical-inefficiency penalty the paper describes.

use crate::cluster::{CapacityModel, WorkloadProfile};
use crate::config::{ExperimentCfg, Policy};
use crate::controller::{static_alloc, uniform_alloc, Adjustment, DynamicBatcher};
use crate::metrics::{AdjustEvent, IterRecord, RunReport};
use crate::sync::{staleness_discount, SyncMode, SyncState};
use crate::trace::ClusterTraces;
use crate::util::rng::Rng;

/// Staleness discount sharpness for ASP statistical efficiency.
pub const STALENESS_GAMMA: f64 = 0.4;

/// Simulator harness.
pub struct Simulator {
    pub cfg: ExperimentCfg,
    pub model: CapacityModel,
    pub traces: ClusterTraces,
}

impl Simulator {
    pub fn new(cfg: ExperimentCfg) -> Self {
        let profile = WorkloadProfile::by_name(&cfg.workload)
            .unwrap_or_else(|| panic!("unknown workload {:?}", cfg.workload));
        let model = CapacityModel::new(profile).with_noise(cfg.noise_sigma);
        let traces = ClusterTraces::constant(cfg.workers.len());
        Simulator { cfg, model, traces }
    }

    pub fn with_traces(mut self, traces: ClusterTraces) -> Self {
        assert_eq!(traces.traces.len(), self.cfg.workers.len());
        self.traces = traces;
        self
    }

    /// Initial allocation for the configured policy.
    fn initial_alloc(&self) -> Vec<f64> {
        let b0 = self.cfg.effective_b0() as f64;
        match self.cfg.policy {
            Policy::Uniform => uniform_alloc(b0, self.cfg.workers.len()),
            // Open-loop: proportional to the FLOPs *estimate* (not the true
            // throughput — that gap is what Dynamic corrects).
            Policy::Static | Policy::Dynamic => {
                let est: Vec<f64> = self
                    .cfg
                    .workers
                    .iter()
                    .map(|w| w.device.flops_estimate())
                    .collect();
                static_alloc(b0, &est)
            }
        }
    }

    /// Run BSP/ASP/SSP to the accuracy target (or max_iters) and report.
    pub fn run(&self) -> RunReport {
        match self.cfg.sync {
            SyncMode::Bsp => self.run_bsp(),
            SyncMode::Asp | SyncMode::Ssp { .. } => self.run_async(),
        }
    }

    /// BSP: global iterations in lockstep; iteration time = max over
    /// workers; controller observes compute times and adjusts between
    /// iterations (charging the restart cost).
    fn run_bsp(&self) -> RunReport {
        let cfg = &self.cfg;
        let k = cfg.workers.len();
        let mut rng = Rng::new(cfg.seed);
        let mut report = RunReport::new(&format!(
            "{}/{}/bsp",
            cfg.workload,
            cfg.policy.label()
        ));

        let mut batches = self.initial_alloc();
        let mut controller = (cfg.policy == Policy::Dynamic)
            .then(|| DynamicBatcher::new(cfg.controller.clone(), &batches));

        let target_iters = self.target_iters();
        let mut t = 0.0f64;
        let mut iter: u64 = 0;
        let hard_cap = if cfg.max_iters > 0 {
            cfg.max_iters
        } else {
            target_iters * 20 // safety: pathological configs terminate
        };

        while iter < hard_cap && iter < target_iters {
            // Each worker computes its mini-batch. Capacity is integrated
            // over the availability trace so mid-iteration changes
            // (bursts, preemptions) cost what they physically cost.
            let mut times = Vec::with_capacity(k);
            for (w, spec) in cfg.workers.iter().enumerate() {
                let work = self
                    .model
                    .compute_work(&spec.device, batches[w].max(1.0), &mut rng);
                let dur = self.traces.traces[w].time_to_complete(t, work)
                    + self.model.fixed_time();
                times.push(dur);
            }
            let barrier = times.iter().cloned().fold(f64::MIN, f64::max);
            for (w, &dur) in times.iter().enumerate() {
                report.iters.push(IterRecord {
                    worker: w,
                    iter,
                    start: t,
                    duration: dur,
                    batch: batches[w],
                    wait: barrier - dur,
                });
            }
            t += barrier;
            iter += 1;

            // Dynamic policy: feed observations, maybe adjust.
            if let Some(ctl) = controller.as_mut() {
                for (w, &dur) in times.iter().enumerate() {
                    ctl.observe(w, dur);
                }
                if let Adjustment::Apply(new_b) = ctl.maybe_adjust() {
                    t += cfg.adjust_cost_s; // kill-restart analogue
                    report.adjustments.push(AdjustEvent {
                        time: t,
                        iter,
                        batches: new_b.clone(),
                        cost: cfg.adjust_cost_s,
                    });
                    batches = new_b;
                }
            }
        }
        report.total_time = t;
        report.total_iters = iter;
        report.reached_target = iter >= target_iters;
        report
    }

    /// ASP/SSP: per-worker event loop in virtual time; progress counts
    /// stale updates at a discount. SSP blocks fast workers at the bound.
    fn run_async(&self) -> RunReport {
        let cfg = &self.cfg;
        let k = cfg.workers.len();
        let mut rng = Rng::new(cfg.seed);
        let mut report = RunReport::new(&format!(
            "{}/{}/{}",
            cfg.workload,
            cfg.policy.label(),
            cfg.sync.label()
        ));

        let mut batches = self.initial_alloc();
        let mut controller = (cfg.policy == Policy::Dynamic)
            .then(|| DynamicBatcher::new(cfg.controller.clone(), &batches));
        let mut sync = SyncState::new(cfg.sync, k);

        // Effective progress needed (fresh-equivalent updates). A fresh
        // uniform-batch BSP run applies K updates per global iteration的
        // equivalent; here each worker update carries weight b_w/(K·b0).
        let target: f64 = self.target_iters() as f64;
        let b0 = cfg.effective_b0() as f64;
        let mut progress = 0.0f64;

        // Next completion time per worker.
        let mut next_done = vec![0.0f64; k];
        let mut busy = vec![false; k];
        let mut t = 0.0f64;
        let mut updates: u64 = 0;
        let hard_updates = if cfg.max_iters > 0 {
            cfg.max_iters * k as u64
        } else {
            self.target_iters() * k as u64 * 40
        };

        while progress < target && updates < hard_updates {
            // Start any idle worker allowed to proceed.
            for w in 0..k {
                if !busy[w] && sync.may_proceed(w) {
                    sync.pull(w);
                    let work = self.model.compute_work(
                        &cfg.workers[w].device,
                        batches[w].max(1.0),
                        &mut rng,
                    );
                    let dur = self.traces.traces[w].time_to_complete(t, work)
                        + self.model.fixed_time();
                    next_done[w] = t + dur;
                    busy[w] = true;
                }
            }
            // Advance to the earliest completion.
            let (w, &done) = next_done
                .iter()
                .enumerate()
                .filter(|(w, _)| busy[*w])
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .expect("deadlock: no busy workers");
            let dur = done - t.min(done);
            report.iters.push(IterRecord {
                worker: w,
                iter: sync.clock(w),
                start: done - dur,
                duration: dur,
                batch: batches[w],
                wait: 0.0,
            });
            t = done;
            busy[w] = false;
            let staleness = sync.push_update(w);
            updates += 1;
            // Fresh-equivalent progress: weight by batch share and
            // staleness discount; K updates of weight 1/K ⇒ one iteration.
            progress += (batches[w] / (k as f64 * b0))
                * staleness_discount(staleness, STALENESS_GAMMA)
                * k as f64
                / k as f64;

            if let Some(ctl) = controller.as_mut() {
                ctl.observe(w, dur);
                if let Adjustment::Apply(new_b) = ctl.maybe_adjust() {
                    t += cfg.adjust_cost_s;
                    report.adjustments.push(AdjustEvent {
                        time: t,
                        iter: updates,
                        batches: new_b.clone(),
                        cost: cfg.adjust_cost_s,
                    });
                    batches = new_b;
                }
            }
        }
        report.total_time = t;
        report.total_iters = updates;
        report.reached_target = progress >= target;
        report
    }

    /// Global iterations to the accuracy target for this workload.
    fn target_iters(&self) -> u64 {
        if self.cfg.max_iters > 0 {
            return self.cfg.max_iters;
        }
        self.model.workload.iters_to_target
    }
}

/// Convenience: run a (workload, cores, policy) CPU experiment.
pub fn run_cpu_experiment(
    workload: &str,
    cores: &[usize],
    policy: Policy,
    sync: SyncMode,
    max_iters: u64,
    seed: u64,
) -> RunReport {
    let mut cfg = ExperimentCfg::default();
    cfg.workload = workload.into();
    cfg.workers = crate::cluster::cpu_cluster(cores);
    cfg.policy = policy;
    cfg.sync = sync;
    cfg.max_iters = max_iters;
    cfg.seed = seed;
    Simulator::new(cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cpu_cluster;

    fn quick_cfg(workload: &str, cores: &[usize], policy: Policy) -> ExperimentCfg {
        let mut cfg = ExperimentCfg::default();
        cfg.workload = workload.into();
        cfg.workers = cpu_cluster(cores);
        cfg.policy = policy;
        cfg.max_iters = 300;
        cfg.adjust_cost_s = 5.0;
        cfg
    }

    #[test]
    fn homogeneous_policies_equivalent() {
        // On a homogeneous cluster, variable batching ≈ uniform batching.
        let u = Simulator::new(quick_cfg("mnist", &[13, 13, 13], Policy::Uniform)).run();
        let s = Simulator::new(quick_cfg("mnist", &[13, 13, 13], Policy::Static)).run();
        let ratio = u.total_time / s.total_time;
        assert!((ratio - 1.0).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn variable_beats_uniform_on_heterogeneous_bsp() {
        // The paper's core claim, at H-level 4 (3,13,18)+: static variable
        // batching substantially beats uniform under BSP.
        let u = Simulator::new(quick_cfg("resnet", &[3, 16, 20], Policy::Uniform)).run();
        let s = Simulator::new(quick_cfg("resnet", &[3, 16, 20], Policy::Static)).run();
        let speedup = u.total_time / s.total_time;
        assert!(speedup > 1.5, "speedup={speedup}");
    }

    #[test]
    fn dynamic_converges_and_stops_adjusting() {
        let mut cfg = quick_cfg("resnet", &[3, 12, 24], Policy::Dynamic);
        cfg.max_iters = 400;
        let r = Simulator::new(cfg).run();
        assert!(r.adjustments.len() >= 1, "controller never engaged");
        assert!(
            r.adjustments.len() < 25,
            "controller oscillating: {} adjustments",
            r.adjustments.len()
        );
        // All adjustments happen early (steady state after warm-up).
        let last = r.adjustments.last().unwrap();
        assert!(
            last.iter < 300,
            "late adjustment at iter {}",
            last.iter
        );
    }

    #[test]
    fn dynamic_equalizes_iteration_times() {
        let mut cfg = quick_cfg("resnet", &[3, 12, 24], Policy::Dynamic);
        cfg.max_iters = 400;
        let dynamic = Simulator::new(cfg).run();
        let uniform =
            Simulator::new(quick_cfg("resnet", &[3, 12, 24], Policy::Uniform)).run();
        // Compare iteration gap over the steady-state tail.
        let gd = dynamic.iteration_gap(3);
        let gu = uniform.iteration_gap(3);
        assert!(gd < gu * 0.5, "gap dynamic={gd} uniform={gu}");
    }

    #[test]
    fn bsp_waits_stragglers_asp_does_not() {
        let mut cfg = quick_cfg("resnet", &[3, 16, 20], Policy::Uniform);
        cfg.max_iters = 200;
        let bsp = Simulator::new(cfg.clone()).run();
        cfg.sync = SyncMode::Asp;
        let asp = Simulator::new(cfg).run();
        assert!(bsp.wait_fraction() > 0.2, "bsp wait={}", bsp.wait_fraction());
        assert!(asp.wait_fraction() < 1e-9);
    }

    #[test]
    fn asp_needs_more_updates_due_to_staleness() {
        let mut cfg = quick_cfg("mnist", &[3, 16, 20], Policy::Uniform);
        cfg.max_iters = 0; // run to target
        cfg.noise_sigma = 0.02;
        // Shrink the problem so the test is fast.
        let mut sim = Simulator::new(cfg);
        sim.model.workload.iters_to_target = 300;
        sim.cfg.sync = SyncMode::Asp;
        let asp = sim.run();
        assert!(asp.reached_target);
        // Fresh-equivalent target is 300 global iterations = 900 updates
        // at K=3; staleness means strictly more.
        assert!(
            asp.total_iters > 900,
            "updates={} (staleness discount not applied?)",
            asp.total_iters
        );
    }

    #[test]
    fn ssp_bounds_iteration_lead() {
        let mut cfg = quick_cfg("resnet", &[2, 18, 19], Policy::Uniform);
        cfg.sync = SyncMode::Ssp { bound: 2 };
        cfg.max_iters = 100;
        let r = Simulator::new(cfg).run();
        // Reconstruct clocks: per worker max iter index; lead ≤ bound+1.
        let mut max_clock = [0u64; 3];
        for rec in &r.iters {
            max_clock[rec.worker] = max_clock[rec.worker].max(rec.iter);
        }
        let lead = max_clock.iter().max().unwrap() - max_clock.iter().min().unwrap();
        assert!(lead <= 3, "lead={lead}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Simulator::new(quick_cfg("mnist", &[4, 8, 27], Policy::Dynamic)).run();
        let b = Simulator::new(quick_cfg("mnist", &[4, 8, 27], Policy::Dynamic)).run();
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.adjustments.len(), b.adjustments.len());
    }

    #[test]
    fn trace_slowdown_triggers_dynamic_readjustment() {
        use crate::trace::{AvailTrace, ClusterTraces};
        let mut cfg = quick_cfg("resnet", &[13, 13, 13], Policy::Dynamic);
        cfg.max_iters = 300;
        cfg.adjust_cost_s = 1.0;
        // Worker 0 loses half its capacity at t=200s.
        let traces = ClusterTraces {
            traces: vec![
                AvailTrace::from_segments(vec![(0.0, 1.0), (200.0, 0.5)]),
                AvailTrace::constant(),
                AvailTrace::constant(),
            ],
        };
        let r = Simulator::new(cfg).with_traces(traces).run();
        // The controller must have reacted after the capacity change with
        // a smaller batch for worker 0.
        let late: Vec<_> = r
            .adjustments
            .iter()
            .filter(|a| a.time > 200.0)
            .collect();
        assert!(!late.is_empty(), "no reaction to interference");
        let final_b = r.final_batches().unwrap();
        assert!(
            final_b[0] < final_b[1] * 0.8,
            "worker 0 batch {final_b:?} not reduced"
        );
    }
}
