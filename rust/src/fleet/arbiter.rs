//! Capacity arbitration between fleet jobs (DESIGN.md §13).
//!
//! The arbiter is a *pure function* from a demand vector to a grant
//! vector under a fixed total capacity: no internal state, no clock,
//! no rng.  Fleet decisions therefore replay bit-identically — the
//! scheduler calls [`CapacityArbiter::grants`] at its two decision
//! points (job admission, job completion) and actuates the diff
//! against the previous grants through the membership join/revoke
//! paths.

/// Capacity-arbitration policy between jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ArbiterPolicy {
    /// Weighted max-min fair share: water-fill capacity in proportion
    /// to job weights, capping each job at its demand.
    #[default]
    FairShare,
    /// Strict priority: higher priority fills to its full demand
    /// first; ties admit in job-id order.  Running jobs keep their
    /// floor (the fleet degrades, it never kills).
    Priority,
}

impl ArbiterPolicy {
    pub fn parse(s: &str) -> Option<ArbiterPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "fair" | "fairshare" | "fair-share" | "fair_share" => {
                Some(ArbiterPolicy::FairShare)
            }
            "priority" | "strict" | "strict-priority" => Some(ArbiterPolicy::Priority),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ArbiterPolicy::FairShare => "fair",
            ArbiterPolicy::Priority => "priority",
        }
    }
}

/// One job's standing with the arbiter.
#[derive(Debug, Clone, Copy)]
pub struct JobDemand {
    /// Fair-share weight (> 0).
    pub weight: f64,
    /// Strict-priority rank (higher wins).
    pub priority: i64,
    /// Worker slots the job can use (its session's k).
    pub ranks: usize,
    /// Slots the arbiter must not cut below: 1 for admitted jobs — a
    /// session with an empty cohort and nothing pending errors out —
    /// and 0 for a candidate still waiting at the door.
    pub floor: usize,
}

/// Grants worker slots to jobs under a fixed total capacity.
#[derive(Debug, Clone)]
pub struct CapacityArbiter {
    capacity: usize,
    policy: ArbiterPolicy,
}

impl CapacityArbiter {
    pub fn new(capacity: usize, policy: ArbiterPolicy) -> CapacityArbiter {
        CapacityArbiter { capacity, policy }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn policy(&self) -> ArbiterPolicy {
        self.policy
    }

    /// Slot grants for the demand set, deterministically.
    ///
    /// Floors are satisfied first (shedding from the highest job id if
    /// they alone exceed capacity — admission control is supposed to
    /// prevent that, but the arbiter never over-grants).  Remaining
    /// capacity goes out by policy; the uncontended case (total demand
    /// ≤ capacity) short-circuits to full grants in O(n).
    pub fn grants(&self, demands: &[JobDemand]) -> Vec<usize> {
        let want: usize = demands.iter().map(|d| d.ranks).sum();
        if want <= self.capacity {
            return demands.iter().map(|d| d.ranks).collect();
        }
        let mut grant: Vec<usize> =
            demands.iter().map(|d| d.floor.min(d.ranks)).collect();
        let floors: usize = grant.iter().sum();
        if floors >= self.capacity {
            let mut over = floors - self.capacity;
            for g in grant.iter_mut().rev() {
                let cut = (*g).min(over);
                *g -= cut;
                over -= cut;
                if over == 0 {
                    break;
                }
            }
            return grant;
        }
        let left = self.capacity - floors;
        match self.policy {
            ArbiterPolicy::Priority => self.fill_priority(demands, &mut grant, left),
            ArbiterPolicy::FairShare => self.water_fill(demands, &mut grant, left),
        }
        grant
    }

    /// Top jobs up to their demand in (priority desc, id asc) order.
    fn fill_priority(&self, demands: &[JobDemand], grant: &mut [usize], mut left: usize) {
        let mut order: Vec<usize> = (0..demands.len()).collect();
        order.sort_by(|&a, &b| {
            demands[b].priority.cmp(&demands[a].priority).then(a.cmp(&b))
        });
        for i in order {
            let top = demands[i].ranks.saturating_sub(grant[i]).min(left);
            grant[i] += top;
            left -= top;
            if left == 0 {
                break;
            }
        }
    }

    /// Weighted max-min water-fill of `left` slots above the floors:
    /// find the level λ with Σ min(headroomᵢ, λ·wᵢ) = left (sort jobs
    /// by saturation level, sweep — O(n log n)), floor the continuous
    /// shares, then hand out the rounding remainder one slot at a time
    /// by (fractional part desc, id asc).
    fn water_fill(&self, demands: &[JobDemand], grant: &mut [usize], left: usize) {
        let n = demands.len();
        let head: Vec<usize> = (0..n).map(|i| demands[i].ranks - grant[i]).collect();
        let mut active: Vec<usize> = (0..n)
            .filter(|&i| head[i] > 0 && demands[i].weight > 0.0)
            .collect();
        if active.is_empty() {
            return;
        }
        // Ascending saturation level: job i soaks up headᵢ once the
        // level reaches headᵢ/wᵢ.
        active.sort_by(|&a, &b| {
            (head[a] as f64 / demands[a].weight)
                .total_cmp(&(head[b] as f64 / demands[b].weight))
                .then(a.cmp(&b))
        });
        let mut wsum: f64 = active.iter().map(|&i| demands[i].weight).sum();
        let mut remaining = left as f64;
        let mut level = 0.0_f64;
        let mut share = vec![0.0_f64; n];
        for (pos, &i) in active.iter().enumerate() {
            let sat = head[i] as f64 / demands[i].weight;
            let cost = (sat - level) * wsum;
            if cost < remaining {
                remaining -= cost;
                level = sat;
                wsum -= demands[i].weight;
                share[i] = head[i] as f64;
            } else {
                level += remaining / wsum;
                for &j in &active[pos..] {
                    share[j] = (level * demands[j].weight).min(head[j] as f64);
                }
                break;
            }
        }
        let mut handed = 0usize;
        for i in 0..n {
            let g = (share[i].floor() as usize).min(head[i]);
            grant[i] += g;
            handed += g;
        }
        // Rounding remainder: < #active slots by construction, so one
        // deterministic pass suffices (guarded loop for float dust).
        let mut spare = left - handed.min(left);
        while spare > 0 {
            let mut order: Vec<usize> = (0..n)
                .filter(|&i| grant[i] < demands[i].ranks)
                .collect();
            if order.is_empty() {
                break;
            }
            order.sort_by(|&a, &b| {
                let fa = share[a] - share[a].floor();
                let fb = share[b] - share[b].floor();
                fb.total_cmp(&fa).then(a.cmp(&b))
            });
            for i in order {
                if spare == 0 {
                    break;
                }
                grant[i] += 1;
                spare -= 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(weight: f64, priority: i64, ranks: usize, floor: usize) -> JobDemand {
        JobDemand {
            weight,
            priority,
            ranks,
            floor,
        }
    }

    #[test]
    fn uncontended_grants_full_demand() {
        let a = CapacityArbiter::new(32, ArbiterPolicy::FairShare);
        let g = a.grants(&[d(1.0, 0, 8, 1), d(1.0, 0, 8, 1), d(2.0, 0, 16, 1)]);
        assert_eq!(g, vec![8, 8, 16]);
    }

    #[test]
    fn fair_share_splits_by_weight() {
        // 12 slots, weights 2:1, both want 12: continuous shares are
        // 8 and 4 (floors included in the share).
        let a = CapacityArbiter::new(12, ArbiterPolicy::FairShare);
        let g = a.grants(&[d(2.0, 0, 12, 1), d(1.0, 0, 12, 1)]);
        assert_eq!(g.iter().sum::<usize>(), 12);
        assert_eq!(g, vec![8, 4]);
    }

    #[test]
    fn fair_share_caps_at_demand_and_redistributes() {
        // Job 0 saturates at 2 ranks; the rest of its share spills to
        // the others.
        let a = CapacityArbiter::new(12, ArbiterPolicy::FairShare);
        let g = a.grants(&[d(1.0, 0, 2, 1), d(1.0, 0, 12, 1), d(1.0, 0, 12, 1)]);
        assert_eq!(g.iter().sum::<usize>(), 12);
        assert_eq!(g[0], 2);
        assert_eq!(g[1] + g[2], 10);
        assert!(g[1].abs_diff(g[2]) <= 1, "equal weights stay within 1: {g:?}");
    }

    #[test]
    fn priority_preempts_to_the_floor() {
        // Capacity 8: the high-priority job takes its full 6; the two
        // low-priority running jobs keep only their floors.
        let a = CapacityArbiter::new(8, ArbiterPolicy::Priority);
        let g = a.grants(&[d(1.0, 0, 4, 1), d(1.0, 0, 4, 1), d(1.0, 5, 6, 0)]);
        assert_eq!(g, vec![1, 1, 6]);
    }

    #[test]
    fn priority_ties_break_by_job_id() {
        let a = CapacityArbiter::new(6, ArbiterPolicy::Priority);
        let g = a.grants(&[d(1.0, 1, 5, 1), d(1.0, 1, 5, 1)]);
        assert_eq!(g, vec![5, 1]);
    }

    #[test]
    fn floors_over_capacity_shed_from_the_back() {
        let a = CapacityArbiter::new(2, ArbiterPolicy::FairShare);
        let g = a.grants(&[d(1.0, 0, 4, 1), d(1.0, 0, 4, 1), d(1.0, 0, 4, 1)]);
        assert_eq!(g, vec![1, 1, 0]);
    }

    #[test]
    fn grants_are_deterministic() {
        let a = CapacityArbiter::new(17, ArbiterPolicy::FairShare);
        let ds = [d(1.5, 0, 9, 1), d(0.5, 0, 7, 1), d(3.0, 0, 30, 1), d(1.0, 0, 2, 0)];
        let g1 = a.grants(&ds);
        let g2 = a.grants(&ds);
        assert_eq!(g1, g2);
        assert_eq!(g1.iter().sum::<usize>(), 17);
        for (g, dm) in g1.iter().zip(&ds) {
            assert!(*g <= dm.ranks);
            assert!(*g >= dm.floor.min(dm.ranks));
        }
    }
}
