//! Multi-tenant fleet scheduler: N independent [`Session`]s multiplexed
//! over one shared, elastic worker pool (DESIGN.md §13).
//!
//! The paper's dynamic batcher equalizes iteration times *within* one
//! job; this layer arbitrates capacity *between* jobs.  A
//! [`FleetScheduler`] owns a global virtual clock and interleaves
//! per-job event loops — each job is a [`Session`] driven through the
//! resumable [`Session::start`]/[`Session::step`] form, and a min-heap
//! over (per-job next-event time, job id) merges job A's completions,
//! deadlines, and autoscaler timers deterministically with job B's.  A
//! [`CapacityArbiter`] grants/reclaims worker slots under fair-share or
//! strict-priority policy; grant diffs are actuated through the
//! membership join/revoke paths ([`RunState::inject_membership`]), and
//! each job's [`crate::fault::Autoscaler`] becomes an arbiter client:
//! its private spawn pool is capped at the fleet's spare capacity
//! before every step ([`RunState::cap_spawn_pool`]).
//!
//! Two invariants anchor the design:
//!
//! 1. **Isolation**: with no contention (capacity ≥ total demand) the
//!    fleet never touches a job's event or rng streams, so every
//!    per-job [`RunReport`] is *bitwise identical* to the same job run
//!    standalone.  `benches/fleet.rs` self-asserts this before timing.
//! 2. **Determinism under interleaving**: every per-job rng (backend
//!    noise, spot traces, autoscaler backoff jitter) derives from the
//!    job's own seed — fleet configs that don't pin one get
//!    [`job_seed`]`(fleet_seed, job_id)` — so job outcomes are a
//!    function of (fleet config, seeds), never of scheduling order.
//!
//! Uncontended fleets take a parallel fast path (the jobs can't
//! interact, so they fan out across the process-wide thread pool with
//! a slot-ordered gather — this is what [`crate::figures::run_batch`]
//! dispatches through); contended fleets run single-threaded
//! interleaved so arbiter decisions happen at well-defined points on
//! the merged clock.

mod arbiter;

pub use arbiter::{ArbiterPolicy, CapacityArbiter, JobDemand};

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use anyhow::{anyhow, bail, Context, Error, Result};

use crate::ckpt::{
    self, dec_f64, dec_u64, enc_f64, enc_u64, recover_latest, Checkpointer, CkptSpec,
    LoadedCkpt,
};
use crate::metrics::RunReport;
use crate::session::{RunState, Session, SessionBuilder, SimBackend};
use crate::trace::{MembershipEvent, MembershipKind};
use crate::util::json::Json;
use crate::util::rng::SplitMix64;
use crate::util::stats::percentile;

/// Tag folded into per-job seed derivation (cf.
/// [`crate::fault::AUTOSCALE_SEED_TAG`] one layer down).
pub const FLEET_JOB_SEED_TAG: u64 = 0xF1EE_70B5;

/// Deterministic per-job seed stream: fleet jobs that don't pin a seed
/// run with `job_seed(fleet_seed, job_id)`, so every downstream rng —
/// backend noise, spot traces, and the autoscaler's backoff-jitter
/// stream (which forks off the session seed) — is a function of the
/// (fleet_seed, job_id) pair and never of scheduling order.
pub fn job_seed(fleet_seed: u64, job_id: u64) -> u64 {
    // SplitMix64 finalizer over the pair: adjacent job ids land in
    // decorrelated streams (a bare XOR would differ in one bit).
    let mut sm = SplitMix64(
        fleet_seed
            ^ FLEET_JOB_SEED_TAG
            ^ job_id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    sm.next()
}

/// One fleet job: a session config plus its standing with the arbiter.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    /// Fair-share weight (> 0).
    pub weight: f64,
    /// Strict-priority rank (higher wins).
    pub priority: i64,
    /// Fleet time the job is submitted.  Its own virtual clock still
    /// starts at 0; completion on the fleet clock = admission + run
    /// time (admission ≥ arrival when the job queues for capacity).
    pub arrival: f64,
    pub builder: SessionBuilder,
}

impl JobSpec {
    pub fn new(name: &str, builder: SessionBuilder) -> JobSpec {
        JobSpec {
            name: name.to_string(),
            weight: 1.0,
            priority: 0,
            arrival: 0.0,
            builder,
        }
    }
}

// ------------------------------------------------------------- builder

/// Builds a [`FleetScheduler`] from code or a JSON `jobs: [...]`
/// config (`hbatch fleet`).
#[derive(Debug, Clone, Default)]
pub struct FleetBuilder {
    capacity: Option<usize>,
    policy: ArbiterPolicy,
    seed: u64,
    interleave: Option<bool>,
    ckpt: Option<CkptSpec>,
    crash_at: Option<f64>,
    jobs: Vec<JobSpec>,
}

impl FleetBuilder {
    pub fn new() -> FleetBuilder {
        FleetBuilder::default()
    }

    /// Shared worker capacity.  Unset = uncontended: the sum of every
    /// job's ranks + spawn pool, i.e. the arbiter never has to say no.
    pub fn capacity(mut self, capacity: usize) -> Self {
        self.capacity = Some(capacity);
        self
    }

    pub fn policy(mut self, policy: ArbiterPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Fleet seed: jobs added via JSON without their own `seed` key
    /// derive theirs as [`job_seed`]`(fleet_seed, job_id)`.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Force the scheduling mode: `true` = single-threaded
    /// deterministic interleave, `false` = parallel fan-out (valid
    /// only for uncontended fleets).  Unset = interleave exactly when
    /// contended.
    pub fn interleave(mut self, interleave: bool) -> Self {
        self.interleave = Some(interleave);
        self
    }

    /// Durable whole-fleet checkpointing (DESIGN.md §15).  Every commit
    /// is one atomic fleet-level checkpoint whose state embeds each
    /// job's full session snapshot keyed by job id — a crash can never
    /// observe job A's state from a different instant than job B's.
    /// Re-running the same fleet command with the same `--checkpoint`
    /// dir resumes from the newest valid checkpoint (whole-fleet
    /// restart).
    pub fn checkpoint(mut self, spec: CkptSpec) -> Self {
        self.ckpt = Some(spec);
        self
    }

    /// Coordinator-crash injection: stop (without a final snapshot)
    /// once the fleet clock passes `t`.  Requires [`Self::checkpoint`].
    pub fn crash_at(mut self, t: f64) -> Self {
        self.crash_at = Some(t);
        self
    }

    pub fn job(mut self, spec: JobSpec) -> Self {
        self.jobs.push(spec);
        self
    }

    pub fn jobs(mut self, specs: Vec<JobSpec>) -> Self {
        self.jobs.extend(specs);
        self
    }

    /// Parse `{capacity?, policy?, seed?, jobs: [{name?, weight?,
    /// priority?, arrival?, <session keys>}, ..]}`.  Job objects take
    /// the same keys as `hbatch simulate --config` session configs.
    pub fn from_json(j: &Json) -> Result<FleetBuilder, String> {
        let mut f = FleetBuilder::new();
        if let Some(c) = j.get("capacity").as_usize() {
            f.capacity = Some(c);
        }
        if let Some(p) = j.get("policy").as_str() {
            f.policy = ArbiterPolicy::parse(p).ok_or(format!("bad policy {p:?}"))?;
        }
        if let Some(s) = j.get("seed").as_usize() {
            f.seed = s as u64;
        }
        if let Some(c) = j.get("checkpoint").as_str() {
            f.ckpt =
                Some(CkptSpec::parse(c).map_err(|e| format!("bad checkpoint: {e}"))?);
        }
        let jobs = j
            .get("jobs")
            .as_arr()
            .ok_or("fleet config needs a jobs: [...] array")?;
        for (i, job) in jobs.iter().enumerate() {
            let mut b =
                SessionBuilder::from_json(job).map_err(|e| format!("jobs[{i}]: {e}"))?;
            if job.get("seed").is_null() {
                b = b.seed(job_seed(f.seed, i as u64));
            }
            let name = job
                .get("name")
                .as_str()
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!("job{i}"));
            let mut spec = JobSpec::new(&name, b);
            if let Some(w) = job.get("weight").as_f64() {
                spec.weight = w;
            }
            if let Some(p) = job.get("priority").as_f64() {
                spec.priority = p as i64;
            }
            if let Some(a) = job.get("arrival").as_f64() {
                spec.arrival = a;
            }
            f.jobs.push(spec);
        }
        Ok(f)
    }

    pub fn from_json_str(s: &str) -> Result<FleetBuilder, String> {
        let j = Json::parse(s).map_err(|e| e.to_string())?;
        Self::from_json(&j)
    }

    pub fn from_file(path: &str) -> Result<FleetBuilder, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_json_str(&text)
    }

    pub fn build(self) -> Result<FleetScheduler, String> {
        if self.jobs.is_empty() {
            return Err("fleet has no jobs".into());
        }
        let mut demand = 0usize;
        for (i, spec) in self.jobs.iter().enumerate() {
            if !(spec.weight > 0.0 && spec.weight.is_finite()) {
                return Err(format!("jobs[{i}]: weight {} must be > 0", spec.weight));
            }
            if !(spec.arrival >= 0.0 && spec.arrival.is_finite()) {
                return Err(format!("jobs[{i}]: arrival {} must be ≥ 0", spec.arrival));
            }
            spec.builder
                .validate()
                .map_err(|e| format!("jobs[{i}] ({}): {e}", spec.name))?;
            demand += spec.builder.planned_workers() + spec.builder.planned_spawn_pool();
        }
        let capacity = self.capacity.unwrap_or(demand);
        if capacity == 0 {
            return Err("fleet capacity must be ≥ 1".into());
        }
        if capacity < demand && self.interleave == Some(false) {
            return Err(format!(
                "contended fleet (capacity {capacity} < demand {demand}) requires the \
                 interleaved scheduler"
            ));
        }
        if self.ckpt.is_some() && self.interleave == Some(false) {
            return Err(
                "checkpointed fleet requires the interleaved scheduler (snapshots are \
                 taken on the merged clock)"
                    .into(),
            );
        }
        if self.crash_at.is_some() && self.ckpt.is_none() {
            return Err(
                "crash injection needs a checkpoint spec (there is nothing to recover \
                 from otherwise)"
                    .into(),
            );
        }
        // The config echo rides in every checkpoint (and is what
        // `resume == same command` verifies against); computing it here
        // surfaces non-echoable jobs (e.g. in-memory traces) before any
        // work starts.
        let config = match self.ckpt {
            Some(_) => Some(fleet_config_echo(
                capacity, self.policy, self.seed, &self.jobs,
            )?),
            None => None,
        };
        Ok(FleetScheduler {
            arbiter: CapacityArbiter::new(capacity, self.policy),
            seed: self.seed,
            interleave: self.interleave,
            ckpt: self.ckpt,
            crash_at: self.crash_at,
            config,
            demand,
            jobs: self.jobs,
        })
    }
}

// ----------------------------------------------------------- scheduler

/// N concurrent jobs on one shared elastic pool.  Build via
/// [`FleetBuilder`]; [`Self::run`] returns a [`FleetReport`].
pub struct FleetScheduler {
    arbiter: CapacityArbiter,
    seed: u64,
    interleave: Option<bool>,
    ckpt: Option<CkptSpec>,
    crash_at: Option<f64>,
    /// Fleet config echo committed with every checkpoint (`Some` iff
    /// `ckpt` is).
    config: Option<Json>,
    /// Total demand (ranks + spawn pools) across jobs.
    demand: usize,
    jobs: Vec<JobSpec>,
}

/// The fleet-level config echo: enough to rebuild the exact same
/// `FleetBuilder` (job session configs included), plus a `backend`
/// discriminator matching the session-level convention.
fn fleet_config_echo(
    capacity: usize,
    policy: ArbiterPolicy,
    seed: u64,
    jobs: &[JobSpec],
) -> Result<Json, String> {
    let mut j = Json::obj();
    j.set("backend", Json::Str("fleet".into()));
    j.set("capacity", Json::Num(capacity as f64));
    j.set("policy", Json::Str(policy.label().into()));
    j.set("seed", enc_u64(seed));
    let mut arr = Vec::with_capacity(jobs.len());
    for (i, spec) in jobs.iter().enumerate() {
        let mut jj = spec
            .builder
            .to_json()
            .map_err(|e| format!("jobs[{i}] ({}): {e}", spec.name))?;
        jj.set("name", Json::Str(spec.name.clone()));
        jj.set("weight", enc_f64(spec.weight));
        jj.set("priority", Json::Num(spec.priority as f64));
        jj.set("arrival", enc_f64(spec.arrival));
        arr.push(jj);
    }
    j.set("jobs", Json::Arr(arr));
    Ok(j)
}

/// Min-first heap key: (fleet time of the job's next activity, job id).
/// Ties pop the lowest job id — the fleet's merge order is total.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Key {
    t: f64,
    job: usize,
}

impl Eq for Key {}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, the fleet wants min-first.
        other.t.total_cmp(&self.t).then(other.job.cmp(&self.job))
    }
}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A job currently running under the interleaved scheduler.
struct Active {
    session: Session<SimBackend>,
    rs: Option<RunState>,
    /// Fleet time of admission (job-local t = 0).
    offset: f64,
    /// Fleet time of the job's one in-heap key.  Tracked so a restored
    /// fleet rebuilds the heap with the *same* merge order the
    /// snapshot had (an admission key sits at the admission time, not
    /// at the job's first event — reconstructing from the event clock
    /// alone would reorder shared-capacity decisions).
    next_key: f64,
    /// Capacity slots currently charged to the job.
    granted: usize,
    /// Ranks the fleet revoked and may later re-grant (ascending).
    held: Vec<usize>,
    /// Spawn-pool slots drawn from shared capacity so far.
    pool_drawn: usize,
    preemptions: u64,
    regrants: u64,
}

enum JobPhase {
    /// Submitted, not yet at the arbiter (its arrival key is queued).
    Waiting,
    /// Admission refused (grant would be 0); retried at every
    /// completion.
    Parked,
    Running(Box<Active>),
    Done(Box<JobOutcome>),
}

impl FleetScheduler {
    pub fn capacity(&self) -> usize {
        self.arbiter.capacity()
    }

    /// Run every job to completion and aggregate.  Uncontended fleets
    /// fan out in parallel (slot-ordered gather — per-job results
    /// can't depend on pool interleaving because nothing is shared);
    /// contended fleets interleave deterministically on the merged
    /// virtual clock.  The two paths agree bitwise per job whenever
    /// both are legal.
    pub fn run(&mut self) -> Result<FleetReport> {
        match self.run_resumable()? {
            Some(report) => Ok(report),
            None => bail!(
                "fleet stopped by crash injection; rerun the same command (same \
                 checkpoint dir) to resume"
            ),
        }
    }

    /// Like [`Self::run`], but a configured coordinator crash
    /// ([`FleetBuilder::crash_at`]) returns `Ok(None)` instead of an
    /// error: the fleet died mid-run and the checkpoint dir holds the
    /// newest committed whole-fleet snapshot.  Running the same fleet
    /// again resumes from it.
    pub fn run_resumable(&mut self) -> Result<Option<FleetReport>> {
        let uncontended = self.arbiter.capacity() >= self.demand;
        // Checkpointing forces the interleave: snapshots are taken at
        // well-defined points on the merged clock.
        let interleaved =
            self.ckpt.is_some() || self.interleave.unwrap_or(!uncontended);
        if !uncontended && !interleaved {
            bail!("contended fleet requires the interleaved scheduler");
        }
        if interleaved {
            self.run_interleaved()
        } else {
            self.run_parallel().map(Some)
        }
    }

    // ---------------------------------------------- parallel fast path

    fn run_parallel(&self) -> Result<FleetReport> {
        let tasks: Vec<Box<dyn FnOnce() -> Result<RunReport> + Send>> = self
            .jobs
            .iter()
            .map(|spec| {
                let b = spec.builder.clone();
                Box::new(move || -> Result<RunReport> { b.build_sim()?.run() })
                    as Box<dyn FnOnce() -> Result<RunReport> + Send>
            })
            .collect();
        let results = crate::util::pool::global().run_collect(tasks);
        let mut outcomes = Vec::with_capacity(self.jobs.len());
        let mut timeline = Vec::with_capacity(2 * self.jobs.len());
        for (i, (spec, res)) in self.jobs.iter().zip(results).enumerate() {
            let report =
                res.with_context(|| format!("fleet job {i} ({})", spec.name))?;
            let ranks = spec.builder.planned_workers();
            let completion = spec.arrival + report.total_time;
            timeline.push((spec.arrival, ranks as i64));
            timeline.push((completion, -(ranks as i64)));
            outcomes.push(JobOutcome {
                name: spec.name.clone(),
                arrival: spec.arrival,
                admission: spec.arrival,
                completion,
                granted_final: ranks,
                fleet_preemptions: 0,
                fleet_regrants: 0,
                report,
            });
        }
        Ok(self.aggregate(false, outcomes, timeline))
    }

    // --------------------------------------------- interleaved scheduler

    fn run_interleaved(&self) -> Result<Option<FleetReport>> {
        let n = self.jobs.len();
        let ranks: Vec<usize> =
            self.jobs.iter().map(|s| s.builder.planned_workers()).collect();
        let mut phase: Vec<JobPhase> = (0..n).map(|_| JobPhase::Waiting).collect();
        let mut heap: BinaryHeap<Key> = BinaryHeap::new();
        let mut parked: Vec<usize> = Vec::new();
        let mut committed = 0usize;
        let mut fleet_now = 0.0_f64;
        let mut timeline: Vec<(f64, i64)> = Vec::new();

        // Checkpointed fleets resume from the newest valid snapshot if
        // the dir holds any; otherwise start fresh (and a corrupt
        // history is an error, never a silent restart from zero).
        let mut ck = match &self.ckpt {
            Some(spec) => Some(Checkpointer::open(spec.clone()).map_err(Error::msg)?),
            None => None,
        };
        let mut resumed = false;
        if let Some(spec) = &self.ckpt {
            if ckpt::has_ckpts(&spec.dir) {
                let lc = recover_latest(&spec.dir).map_err(Error::msg)?;
                eprintln!("fleet: resuming from {} (seq {})", lc.path.display(), lc.seq);
                self.restore_fleet(
                    &lc,
                    &mut phase,
                    &mut heap,
                    &mut parked,
                    &mut committed,
                    &mut fleet_now,
                    &mut timeline,
                )?;
                resumed = true;
            }
        }
        if !resumed {
            for j in 0..n {
                heap.push(Key {
                    t: self.jobs[j].arrival,
                    job: j,
                });
            }
            if let Some(ck) = ck.as_mut() {
                // Seq-0 snapshot: even a crash before the first event
                // leaves something to resume from.
                self.commit_fleet(ck, fleet_now, &phase, &timeline)?;
            }
        }
        let mut last_snap_t = fleet_now;

        while let Some(key) = heap.pop() {
            fleet_now = fleet_now.max(key.t);
            if let Some(at) = self.crash_at {
                if fleet_now >= at {
                    // Coordinator crash: die before processing the
                    // event, leaving only previously committed
                    // snapshots — exactly what a real kill would.
                    return Ok(None);
                }
            }
            let tl_mark = timeline.len();
            let j = key.job;
            if matches!(phase[j], JobPhase::Waiting) {
                // Arrival: one reconcile over the running set, the
                // backlog, and the newcomer.  Under strict priority
                // this is where a high-priority arrival preempts.
                // Reconcile at the *arrival* time, not fleet_now: a
                // completion whose final event overshot this arrival
                // may have advanced fleet_now past it, but admission
                // semantics (and parallel-path equality) pin an
                // uncontended job's offset to its arrival.
                parked.push(j);
                phase[j] = JobPhase::Parked;
                let admitted = self.reconcile(
                    key.t,
                    &ranks,
                    &mut phase,
                    &mut parked,
                    &mut committed,
                    &mut timeline,
                )?;
                for a in admitted {
                    heap.push(Key { t: key.t, job: a });
                }
            } else if matches!(phase[j], JobPhase::Running(_)) {
                let done = self.step_job(j, &mut phase, &mut committed)?;
                if done {
                    let completion =
                        self.complete(j, &mut phase, &mut committed, &mut timeline)?;
                    fleet_now = fleet_now.max(completion);
                    let admitted = self.reconcile(
                        fleet_now,
                        &ranks,
                        &mut phase,
                        &mut parked,
                        &mut committed,
                        &mut timeline,
                    )?;
                    for a in admitted {
                        heap.push(Key {
                            t: fleet_now,
                            job: a,
                        });
                    }
                } else if let JobPhase::Running(active) = &mut phase[j] {
                    active.next_key =
                        active.offset + active.rs.as_ref().expect("running").now();
                    heap.push(Key {
                        t: active.next_key,
                        job: j,
                    });
                }
            } else {
                // Parked jobs have no heap key (reconcile re-queues
                // them); Done jobs are never re-pushed.
                unreachable!("stale fleet key for job {j}");
            }
            if let Some(ck) = ck.as_mut() {
                // Membership changes (admission, completion, and —
                // preempt-to-disk — every arbiter grant change) always
                // commit, so preempted progress is durable before the
                // slots are reused; quiet events commit on the
                // `every_s` cadence.
                let membership_changed = timeline.len() > tl_mark;
                if membership_changed || fleet_now - last_snap_t >= ck.spec().every_s {
                    self.commit_fleet(ck, fleet_now, &phase, &timeline)?;
                    last_snap_t = fleet_now;
                }
            }
        }

        let mut outcomes = Vec::with_capacity(n);
        for (j, ph) in phase.into_iter().enumerate() {
            match ph {
                JobPhase::Done(out) => outcomes.push(*out),
                _ => bail!(
                    "fleet job {j} ({}) never completed (capacity {} can't admit it)",
                    self.jobs[j].name,
                    self.arbiter.capacity()
                ),
            }
        }
        Ok(Some(self.aggregate(true, outcomes, timeline)))
    }

    // ---------------------------------------------- fleet checkpointing

    /// Commit one whole-fleet checkpoint: the config echo plus every
    /// job's state keyed by job id, in a single atomic commit.
    fn commit_fleet(
        &self,
        ck: &mut Checkpointer,
        fleet_now: f64,
        phase: &[JobPhase],
        timeline: &[(f64, i64)],
    ) -> Result<()> {
        let config = self.config.as_ref().expect("checkpointed fleet has a config echo");
        let state = snapshot_fleet(fleet_now, phase, timeline);
        ck.commit(config, &state, None).map_err(Error::msg)?;
        Ok(())
    }

    /// Inverse of [`snapshot_fleet`]: rebuild phases, heap keys (fully
    /// derivable — waiting jobs key on arrival, running jobs on their
    /// next event), the parked set, and `committed`.
    #[allow(clippy::too_many_arguments)]
    fn restore_fleet(
        &self,
        lc: &LoadedCkpt,
        phase: &mut [JobPhase],
        heap: &mut BinaryHeap<Key>,
        parked: &mut Vec<usize>,
        committed: &mut usize,
        fleet_now: &mut f64,
        timeline: &mut Vec<(f64, i64)>,
    ) -> Result<()> {
        let config = self.config.as_ref().expect("checkpointed fleet has a config echo");
        if lc.config.to_pretty() != config.to_pretty() {
            bail!(
                "{} was written by a different fleet config; resume with the exact \
                 config that produced it",
                lc.path.display()
            );
        }
        let st = &lc.state;
        let v = st.get("version").as_i64().unwrap_or(-1);
        if v != ckpt::CKPT_VERSION {
            bail!("fleet state version {v}; this build reads {}", ckpt::CKPT_VERSION);
        }
        *fleet_now = dec_f64(st.get("t")).map_err(Error::msg)?;
        for e in st
            .get("timeline")
            .as_arr()
            .ok_or_else(|| anyhow!("fleet state: missing timeline"))?
        {
            let pair = e
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| anyhow!("fleet state: bad timeline entry"))?;
            timeline.push((
                dec_f64(&pair[0]).map_err(Error::msg)?,
                pair[1]
                    .as_i64()
                    .ok_or_else(|| anyhow!("fleet state: bad timeline delta"))?,
            ));
        }
        let jobs = st
            .get("jobs")
            .as_arr()
            .ok_or_else(|| anyhow!("fleet state: missing jobs"))?;
        if jobs.len() != self.jobs.len() {
            bail!(
                "fleet state has {} jobs, this config has {}",
                jobs.len(),
                self.jobs.len()
            );
        }
        for (id, jj) in jobs.iter().enumerate() {
            let usz = |key: &str| -> Result<usize> {
                jj.get(key)
                    .as_usize()
                    .ok_or_else(|| anyhow!("fleet state: job {id} missing {key}"))
            };
            match jj.get("phase").as_str() {
                Some("waiting") => {
                    heap.push(Key {
                        t: self.jobs[id].arrival,
                        job: id,
                    });
                }
                Some("parked") => parked.push(id),
                Some("running") => {
                    let spec = &self.jobs[id];
                    let mut session = spec
                        .builder
                        .build_sim()
                        .with_context(|| format!("fleet job {id} ({})", spec.name))?;
                    let rs = session
                        .restore_run(jj.get("session"), None)
                        .with_context(|| format!("fleet job {id} ({})", spec.name))?;
                    let granted = usz("granted")?;
                    let pool_drawn = usz("pool_drawn")?;
                    let held = jj
                        .get("held")
                        .as_arr()
                        .ok_or_else(|| anyhow!("fleet state: job {id} missing held"))?
                        .iter()
                        .map(|w| {
                            w.as_usize()
                                .ok_or_else(|| anyhow!("fleet state: job {id} bad held rank"))
                        })
                        .collect::<Result<Vec<usize>>>()?;
                    let active = Active {
                        offset: dec_f64(jj.get("offset")).map_err(Error::msg)?,
                        next_key: dec_f64(jj.get("next_key")).map_err(Error::msg)?,
                        granted,
                        held,
                        pool_drawn,
                        preemptions: dec_u64(jj.get("preemptions")).map_err(Error::msg)?,
                        regrants: dec_u64(jj.get("regrants")).map_err(Error::msg)?,
                        rs: Some(rs),
                        session,
                    };
                    *committed += granted + pool_drawn;
                    heap.push(Key {
                        t: active.next_key,
                        job: id,
                    });
                    phase[id] = JobPhase::Running(Box::new(active));
                }
                Some("done") => {
                    phase[id] = JobPhase::Done(Box::new(JobOutcome {
                        name: self.jobs[id].name.clone(),
                        arrival: dec_f64(jj.get("arrival")).map_err(Error::msg)?,
                        admission: dec_f64(jj.get("admission")).map_err(Error::msg)?,
                        completion: dec_f64(jj.get("completion")).map_err(Error::msg)?,
                        granted_final: usz("granted_final")?,
                        fleet_preemptions: dec_u64(jj.get("preemptions"))
                            .map_err(Error::msg)?,
                        fleet_regrants: dec_u64(jj.get("regrants")).map_err(Error::msg)?,
                        report: RunReport::restore(jj.get("report")).map_err(Error::msg)?,
                    }));
                }
                other => bail!("fleet state: job {id} has unknown phase {other:?}"),
            }
        }
        Ok(())
    }

    /// One arbiter pass at fleet time `now`: recompute grants over the
    /// running set plus the admission backlog, actuate shrinks first
    /// (freeing slots) then grows, then admit every backlog job whose
    /// grant came back ≥ 1.  Returns the newly admitted job ids.
    fn reconcile(
        &self,
        now: f64,
        ranks: &[usize],
        phase: &mut [JobPhase],
        parked: &mut Vec<usize>,
        committed: &mut usize,
        timeline: &mut Vec<(f64, i64)>,
    ) -> Result<Vec<usize>> {
        parked.sort_unstable();
        let running: Vec<usize> = (0..phase.len())
            .filter(|&i| matches!(phase[i], JobPhase::Running(_)))
            .collect();
        let mut ids = running.clone();
        ids.extend(parked.iter().copied());
        let demands: Vec<JobDemand> = ids
            .iter()
            .enumerate()
            .map(|(pos, &i)| JobDemand {
                weight: self.jobs[i].weight,
                priority: self.jobs[i].priority,
                ranks: ranks[i],
                floor: if pos < running.len() { 1 } else { 0 },
            })
            .collect();
        // Spawn draws hold real slots until their job completes, so
        // the arbiter only gets to place what's left of the fleet —
        // Σ grants + Σ draws never exceeds capacity.
        let drawn: usize = running
            .iter()
            .map(|&i| match &phase[i] {
                JobPhase::Running(a) => a.pool_drawn,
                _ => 0,
            })
            .sum();
        let effective = self.arbiter.capacity().saturating_sub(drawn);
        let grants =
            CapacityArbiter::new(effective, self.arbiter.policy()).grants(&demands);

        // Shrinks before grows: slots freed here fund the grows and
        // admissions below, so `committed` never overshoots capacity.
        for (pos, &i) in running.iter().enumerate() {
            if grants[pos] < self.granted(phase, i) {
                self.set_grant(i, grants[pos], now, phase, committed, timeline);
            }
        }
        for (pos, &i) in running.iter().enumerate() {
            if grants[pos] > self.granted(phase, i) {
                self.set_grant(i, grants[pos], now, phase, committed, timeline);
            }
        }
        let mut admitted = Vec::new();
        for (pos, &i) in ids.iter().enumerate().skip(running.len()) {
            if grants[pos] == 0 {
                continue;
            }
            self.admit(i, grants[pos], now, phase, committed, timeline)?;
            admitted.push(i);
        }
        parked.retain(|p| !admitted.contains(p));
        Ok(admitted)
    }

    fn granted(&self, phase: &[JobPhase], j: usize) -> usize {
        match &phase[j] {
            JobPhase::Running(a) => a.granted,
            _ => 0,
        }
    }

    /// Build + start job `j` with `grant` slots at fleet time `now`.
    /// Under-grants are actuated as revocations of the highest live
    /// ranks at job-local t = 0 — the job opens already degraded,
    /// through the same plan-revoke path mid-run preemption uses.
    fn admit(
        &self,
        j: usize,
        grant: usize,
        now: f64,
        phase: &mut [JobPhase],
        committed: &mut usize,
        timeline: &mut Vec<(f64, i64)>,
    ) -> Result<()> {
        let spec = &self.jobs[j];
        let mut session = spec
            .builder
            .build_sim()
            .with_context(|| format!("fleet job {j} ({})", spec.name))?;
        let rs = session
            .start()
            .with_context(|| format!("fleet job {j} ({})", spec.name))?;
        let mut active = Active {
            session,
            rs: Some(rs),
            offset: now,
            next_key: now,
            granted: self.jobs[j].builder.planned_workers(),
            held: Vec::new(),
            pool_drawn: 0,
            preemptions: 0,
            regrants: 0,
        };
        let full = active.granted;
        if grant < full {
            shrink_to(&mut active, full, grant, 0.0);
        }
        *committed += grant;
        timeline.push((now, grant as i64));
        phase[j] = JobPhase::Running(Box::new(active));
        Ok(())
    }

    /// Actuate a grant change for running job `j` at fleet time `now`.
    fn set_grant(
        &self,
        j: usize,
        new: usize,
        now: f64,
        phase: &mut [JobPhase],
        committed: &mut usize,
        timeline: &mut Vec<(f64, i64)>,
    ) {
        let ranks = self.jobs[j].builder.planned_workers();
        let JobPhase::Running(active) = &mut phase[j] else {
            return;
        };
        let old = active.granted;
        if new == old {
            return;
        }
        let local_t = {
            let rs = active.rs.as_ref().expect("running");
            (now - active.offset).max(rs.now())
        };
        if new < old {
            shrink_to(active, ranks, new, local_t);
            *committed -= old - new;
            timeline.push((now, -((old - new) as i64)));
        } else {
            grow_to(active, new, local_t);
            *committed += new - old;
            timeline.push((now, (new - old) as i64));
        }
    }

    /// Drive job `j` one event forward.  The autoscaler's pool is
    /// capped at the fleet's spare capacity first (arbiter-client
    /// contract), and any spawn draw during the step is charged to the
    /// shared pool after.
    fn step_job(
        &self,
        j: usize,
        phase: &mut [JobPhase],
        committed: &mut usize,
    ) -> Result<bool> {
        let spare = self.arbiter.capacity().saturating_sub(*committed);
        let JobPhase::Running(active) = &mut phase[j] else {
            unreachable!("stepping a non-running job");
        };
        let rs = active.rs.as_mut().expect("running");
        rs.cap_spawn_pool(spare);
        let before = rs.spawn_pool_left().unwrap_or(0);
        let alive = active
            .session
            .step(rs)
            .with_context(|| format!("fleet job {j} ({})", self.jobs[j].name))?;
        let drawn = before.saturating_sub(rs.spawn_pool_left().unwrap_or(0));
        active.pool_drawn += drawn;
        *committed += drawn;
        Ok(!alive)
    }

    /// Finish job `j`, release every slot it held (grant + spawn
    /// draws), and record the outcome.  Returns the completion time on
    /// the fleet clock.
    fn complete(
        &self,
        j: usize,
        phase: &mut [JobPhase],
        committed: &mut usize,
        timeline: &mut Vec<(f64, i64)>,
    ) -> Result<f64> {
        let JobPhase::Running(active) = std::mem::replace(&mut phase[j], JobPhase::Waiting)
        else {
            unreachable!("completing a non-running job");
        };
        let mut active = *active;
        let report = active.session.finish(active.rs.take().expect("running"));
        let completion = active.offset + report.total_time;
        *committed -= active.granted + active.pool_drawn;
        timeline.push((completion, -(active.granted as i64)));
        phase[j] = JobPhase::Done(Box::new(JobOutcome {
            name: self.jobs[j].name.clone(),
            arrival: self.jobs[j].arrival,
            admission: active.offset,
            completion,
            granted_final: active.granted,
            fleet_preemptions: active.preemptions,
            fleet_regrants: active.regrants,
            report,
        }));
        Ok(completion)
    }

    // -------------------------------------------------------- aggregate

    fn aggregate(
        &self,
        interleaved: bool,
        outcomes: Vec<JobOutcome>,
        mut timeline: Vec<(f64, i64)>,
    ) -> FleetReport {
        let mut completions: Vec<f64> =
            outcomes.iter().map(|o| o.completion).collect();
        let makespan = completions.iter().cloned().fold(0.0, f64::max);
        let completion_p50 = percentile(&mut completions, 0.50);
        let completion_p99 = percentile(&mut completions, 0.99);
        // Slot-seconds granted, integrated over the fleet timeline,
        // over capacity × makespan.  Spawn-pool draws are accounted as
        // spare-capacity pressure during the run but not counted here:
        // utilization measures how much of the fleet the arbiter kept
        // *assigned*.
        timeline.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut area = 0.0;
        let mut level = 0i64;
        let mut last_t = 0.0;
        for (t, delta) in timeline {
            area += level as f64 * (t - last_t);
            level += delta;
            last_t = t;
        }
        let utilization = if makespan > 0.0 {
            area / (self.arbiter.capacity() as f64 * makespan)
        } else {
            0.0
        };
        let total_wasted_spawns =
            outcomes.iter().map(|o| o.report.wasted_spawns()).sum();
        let total_rejections =
            outcomes.iter().map(|o| o.report.guard_rejections()).sum();
        let total_quarantines =
            outcomes.iter().map(|o| o.report.guard_quarantines()).sum();
        FleetReport {
            policy: self.arbiter.policy(),
            capacity: self.arbiter.capacity(),
            seed: self.seed,
            interleaved,
            makespan,
            completion_p50,
            completion_p99,
            utilization,
            total_wasted_spawns,
            total_rejections,
            total_quarantines,
            jobs: outcomes,
        }
    }
}

/// Revoke `old_granted − new` slots from a running job: the highest
/// currently-live ranks go first, injected as plan-style revocations at
/// job-local time `local_t`.  Slots whose ranks are already dead
/// (detector-retired, crashed) free without actuation.
fn shrink_to(active: &mut Active, ranks: usize, new: usize, local_t: f64) {
    let rs = active.rs.as_mut().expect("running");
    let mut cut = active.granted - new;
    for w in (0..ranks).rev() {
        if cut == 0 {
            break;
        }
        if active.held.contains(&w) {
            continue;
        }
        if rs.is_live(w) {
            rs.inject_membership(MembershipEvent {
                time: local_t,
                worker: w,
                kind: MembershipKind::Revoke,
            });
            active.held.push(w);
            active.preemptions += 1;
        }
        // Live → revoked above; dead (detector-retired, crashed,
        // trace-revoked) → the slot frees without an event and the
        // rank is not eligible for fleet regrant.
        cut -= 1;
    }
    active.granted = new;
    active.held.sort_unstable();
}

/// One whole-fleet snapshot: fleet clock, the utilization timeline so
/// far, and every job's phase — running jobs embed their full session
/// snapshot ([`Session::snapshot_run`]), done jobs their final report —
/// keyed by job id.  Everything else (heap keys, the parked set,
/// `committed`) is derivable and deliberately not stored.
fn snapshot_fleet(fleet_now: f64, phase: &[JobPhase], timeline: &[(f64, i64)]) -> Json {
    let mut st = Json::obj();
    st.set("version", Json::Num(ckpt::CKPT_VERSION as f64));
    st.set("t", enc_f64(fleet_now));
    st.set(
        "timeline",
        Json::Arr(
            timeline
                .iter()
                .map(|&(t, d)| Json::Arr(vec![enc_f64(t), Json::Num(d as f64)]))
                .collect(),
        ),
    );
    let jobs = phase
        .iter()
        .enumerate()
        .map(|(id, ph)| {
            let mut j = Json::obj();
            j.set("job_id", Json::Num(id as f64));
            match ph {
                JobPhase::Waiting => {
                    j.set("phase", Json::Str("waiting".into()));
                }
                JobPhase::Parked => {
                    j.set("phase", Json::Str("parked".into()));
                }
                JobPhase::Running(a) => {
                    j.set("phase", Json::Str("running".into()));
                    j.set("offset", enc_f64(a.offset));
                    j.set("next_key", enc_f64(a.next_key));
                    j.set("granted", Json::Num(a.granted as f64));
                    j.set(
                        "held",
                        Json::Arr(a.held.iter().map(|&w| Json::Num(w as f64)).collect()),
                    );
                    j.set("pool_drawn", Json::Num(a.pool_drawn as f64));
                    j.set("preemptions", enc_u64(a.preemptions));
                    j.set("regrants", enc_u64(a.regrants));
                    j.set(
                        "session",
                        a.session.snapshot_run(a.rs.as_ref().expect("running")),
                    );
                }
                JobPhase::Done(out) => {
                    j.set("phase", Json::Str("done".into()));
                    j.set("arrival", enc_f64(out.arrival));
                    j.set("admission", enc_f64(out.admission));
                    j.set("completion", enc_f64(out.completion));
                    j.set("granted_final", Json::Num(out.granted_final as f64));
                    j.set("preemptions", enc_u64(out.fleet_preemptions));
                    j.set("regrants", enc_u64(out.fleet_regrants));
                    j.set("report", out.report.snapshot());
                }
            }
            j
        })
        .collect();
    st.set("jobs", Json::Arr(jobs));
    st
}

/// Re-grant up to `new − granted` previously revoked ranks (lowest
/// first), injected as plan-style joins at job-local time `local_t`.
fn grow_to(active: &mut Active, new: usize, local_t: f64) {
    let rs = active.rs.as_mut().expect("running");
    let mut add = new - active.granted;
    while add > 0 && !active.held.is_empty() {
        let w = active.held.remove(0);
        rs.inject_membership(MembershipEvent {
            time: local_t,
            worker: w,
            kind: MembershipKind::Join,
        });
        active.regrants += 1;
        add -= 1;
    }
    active.granted = new;
}

// -------------------------------------------------------------- report

/// One job's fate under the fleet.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub name: String,
    /// Submission time (fleet clock).
    pub arrival: f64,
    /// Admission time (≥ arrival when the job queued for capacity).
    pub admission: f64,
    /// Completion time (fleet clock).
    pub completion: f64,
    /// Slots held at completion.
    pub granted_final: usize,
    /// Ranks the fleet revoked over the job's lifetime (including an
    /// under-granted admission).
    pub fleet_preemptions: u64,
    /// Ranks the fleet re-granted after capacity freed up.
    pub fleet_regrants: u64,
    pub report: RunReport,
}

/// Aggregate result of a fleet run (`hbatch fleet` prints its JSON).
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub policy: ArbiterPolicy,
    pub capacity: usize,
    pub seed: u64,
    /// Whether the deterministic interleaved scheduler ran (vs the
    /// uncontended parallel fast path).
    pub interleaved: bool,
    pub jobs: Vec<JobOutcome>,
    /// Latest completion on the fleet clock.
    pub makespan: f64,
    pub completion_p50: f64,
    pub completion_p99: f64,
    /// Granted slot-seconds / (capacity × makespan).
    pub utilization: f64,
    /// Σ per-job wasted autoscaler spawns (`RunReport::spawns`).
    pub total_wasted_spawns: u64,
    /// Σ per-job update-guard rejections (DESIGN.md §16).
    pub total_rejections: u64,
    /// Σ per-job guard quarantines (readmissions not counted).
    pub total_quarantines: u64,
}

impl FleetReport {
    /// Per-job reports in job-id (input) order — the slot-ordered
    /// gather figure sweeps rely on.
    pub fn into_reports(self) -> Vec<RunReport> {
        self.jobs.into_iter().map(|j| j.report).collect()
    }

    /// Summary JSON (per-job scalars, no per-iteration records).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("policy", Json::Str(self.policy.label().into()));
        j.set("capacity", Json::Num(self.capacity as f64));
        j.set("seed", Json::Num(self.seed as f64));
        j.set("interleaved", Json::Bool(self.interleaved));
        j.set("n_jobs", Json::Num(self.jobs.len() as f64));
        j.set("makespan", Json::Num(self.makespan));
        j.set("completion_p50", Json::Num(self.completion_p50));
        j.set("completion_p99", Json::Num(self.completion_p99));
        j.set("utilization", Json::Num(self.utilization));
        j.set(
            "total_wasted_spawns",
            Json::Num(self.total_wasted_spawns as f64),
        );
        j.set(
            "total_rejections",
            Json::Num(self.total_rejections as f64),
        );
        j.set(
            "total_quarantines",
            Json::Num(self.total_quarantines as f64),
        );
        let jobs = self
            .jobs
            .iter()
            .map(|o| {
                let mut jj = Json::obj();
                jj.set("name", Json::Str(o.name.clone()));
                jj.set("arrival", Json::Num(o.arrival));
                jj.set("admission", Json::Num(o.admission));
                jj.set("completion", Json::Num(o.completion));
                jj.set("total_time", Json::Num(o.report.total_time));
                jj.set("total_iters", Json::Num(o.report.total_iters as f64));
                jj.set("reached_target", Json::Bool(o.report.reached_target));
                jj.set("granted_final", Json::Num(o.granted_final as f64));
                jj.set("fleet_preemptions", Json::Num(o.fleet_preemptions as f64));
                jj.set("fleet_regrants", Json::Num(o.fleet_regrants as f64));
                jj.set(
                    "spawn_requests",
                    Json::Num(o.report.spawn_requests() as f64),
                );
                jj.set("wasted_spawns", Json::Num(o.report.wasted_spawns() as f64));
                jj.set(
                    "rejections",
                    Json::Num(o.report.guard_rejections() as f64),
                );
                jj.set(
                    "quarantines",
                    Json::Num(o.report.guard_quarantines() as f64),
                );
                jj
            })
            .collect();
        j.set("jobs", Json::Arr(jobs));
        j
    }
}

/// Thin adapter for embarrassingly-parallel sweeps
/// ([`crate::figures::run_batch`]): an uncontended fleet over
/// `builders` — capacity = total demand, so the arbiter never
/// intervenes and every report is bitwise the standalone run's —
/// returning reports in input (slot) order.  Builders keep their own
/// seeds; no fleet reseeding happens on this path.
pub fn run_uncontended(builders: Vec<SessionBuilder>) -> Vec<RunReport> {
    let specs = builders
        .into_iter()
        .enumerate()
        .map(|(i, b)| JobSpec::new(&format!("job{i}"), b))
        .collect();
    FleetBuilder::new()
        .jobs(specs)
        .build()
        .expect("fleet config")
        .run()
        .expect("fleet run")
        .into_reports()
}
