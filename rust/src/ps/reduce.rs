//! Eager binary reduction-tree aggregation (§Perf iteration 6,
//! DESIGN.md §11).
//!
//! The flat λ-weighted aggregation (`super::aggregate_into`) realizes
//! paper Eq. 2 as one O(k·d) sweep over every worker's full-model
//! gradient *at the BSP barrier* — the last O(k) hot-path scan left
//! after the O(log k) event-loop rework, and the reason the real
//! backend pinned k parameter-sized gradient buffers per round.  This
//! module replaces it with a **rank-indexed binary reduction tree**:
//!
//! - The tree *shape* is a pure function of the worker-rank leaf slots
//!   (leaf `w` sits at position `w`; internal node `(l, i)` covers the
//!   leaf range `[i·2^l, (i+1)·2^l)`), so the summation order — and
//!   therefore every f32 rounding — is **bit-identical under any
//!   arrival-order permutation** of the leaves.  Eager and
//!   collect-at-the-barrier schedules produce the same bits.
//! - Leaves are pushed **pre-weighted by the λ numerator** (the batch
//!   size b_k; [`aggregate_tree_into`] pushes λ_k itself).  Under
//!   elastic membership Σb is only known once the round closes, so the
//!   common 1/Σb normalization is applied **once at the root** (fed to
//!   the fused optimizer as its λ weight) — which is exactly what makes
//!   a mid-round revocation a pure ancestor-path rebuild instead of a
//!   reweighting of every surviving leaf.
//! - Internal nodes combine **eagerly**: a node reduces the moment both
//!   children are ready, so combine work lands inside the straggler
//!   slack the paper says heterogeneity creates, not at the barrier.
//!   The barrier-critical path is the last arrival's root walk —
//!   O(d·log k) worst case, O(d) typical — instead of the flat O(d·k).
//! - Combines are cache-blocked ([`COMBINE_TILE`] = 32 KiB per child
//!   tile, both children accumulated per tile so a node combine stays
//!   in L2) and pool-sharded over [`crate::util::pool`] for parameter
//!   vectors past [`crate::ps::MT_MIN_LEN`].
//!
//! Buffer lifetime is governed by [`RetainPolicy`]:
//!
//! - [`RetainPolicy::Free`] (static membership): combining moves the
//!   left child's buffer into the parent and recycles the right child's
//!   onto a freelist.  With leaves arriving in ascending rank order —
//!   the real backend's wave order — at most one partial per tree level
//!   is ever pending, so peak live gradient memory is **⌈log₂k⌉+1
//!   buffers** (asserted by a unit test) instead of the arena's k.
//! - [`RetainPolicy::Retain`] (elastic runs): every node keeps its
//!   buffer, trading memory (≤ 2k−1 buffers) for churn speed — a
//!   revocation invalidates only the revoked leaf's ancestor path, and
//!   the surviving *sibling partials* rebuild it in O(d·log k).
//!
//! The flat `aggregate_into` survives as the bench baseline
//! (`benches/hotpath.rs` `tree_vs_flat` series) and as the ≤1e-6
//! cross-check oracle (`rust/tests/property.rs`).

use crate::ps::{effective_threads, validate_agg};
use crate::util::pool;

/// Combine-kernel tile: 8 K f32 = 32 KiB per child stream, so the two
/// child tiles plus the destination stay L2-resident while a node
/// reduces (same blocking constant as the fused optimizer kernels).
const COMBINE_TILE: usize = 8192;

/// What happens to child buffers once a node has combined them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetainPolicy {
    /// Recycle aggressively: the left child's buffer *becomes* the
    /// parent's, the right child's returns to the freelist.  Peak live
    /// memory with in-rank-order arrival is ⌈log₂k⌉+1 buffers.  A
    /// leaf that has already been absorbed cannot be revoked — use
    /// [`RetainPolicy::Retain`] for sessions with a `MembershipPlan`.
    Free,
    /// Keep every node's buffer so a mid-round revocation rebuilds only
    /// the revoked leaf's ancestor path from the surviving sibling
    /// partials (O(d·log k) per revocation, ≤ 2k−1 live buffers).
    Retain,
}

/// One tree node.  `buf` is `None` for pending nodes, for passthrough
/// nodes (single present child — resolved via [`ReduceTree::effective_idx`]
/// under `Retain`; under `Free` the buffer migrates up instead), and
/// for nodes whose subtree holds no pushed leaf.
struct Node {
    buf: Option<Vec<f32>>,
    /// Pushed (and not revoked) leaves currently under this node.
    arrived: u32,
    /// Content reflects the current state of the node's children.
    combined: bool,
}

impl Node {
    fn new() -> Self {
        Node { buf: None, arrived: 0, combined: false }
    }
}

/// Rank-indexed eager binary reduction tree over `k` gradient leaves of
/// dimension `d`.  See the module docs for shape, weighting, and the
/// arrival-order-invariance guarantee.
pub struct ReduceTree {
    d: usize,
    policy: RetainPolicy,
    /// Shard-count *request* for pool-dispatched combines (clamped like
    /// every other PS path: single-threaded below `MT_MIN_LEN`).
    shards: usize,
    /// `levels[0]` = the k leaf slots; `levels[l+1].len() =
    /// ⌈levels[l].len()/2⌉`; the last level is the root.
    levels: Vec<Vec<Node>>,
    pushed: Vec<bool>,
    free: Vec<Vec<f32>>,
    /// Buffers currently held by nodes or leased out (not on the freelist).
    in_use: usize,
    peak: usize,
}

impl ReduceTree {
    pub fn new(k: usize, d: usize, policy: RetainPolicy, shards: usize) -> Self {
        assert!(k >= 1, "reduction tree needs at least one leaf");
        let mut levels = vec![(0..k).map(|_| Node::new()).collect::<Vec<_>>()];
        let mut n = k;
        while n > 1 {
            n = (n + 1) / 2;
            levels.push((0..n).map(|_| Node::new()).collect());
        }
        ReduceTree {
            d,
            policy,
            shards,
            levels,
            pushed: vec![false; k],
            free: Vec::new(),
            in_use: 0,
            peak: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.pushed.len()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn policy(&self) -> RetainPolicy {
        self.policy
    }

    /// Tree depth ⌈log₂k⌉ — the `Free`-mode peak-buffer bound is
    /// `depth() + 1`.
    pub fn depth(&self) -> usize {
        self.levels.len() - 1
    }

    pub fn is_pushed(&self, leaf: usize) -> bool {
        self.pushed[leaf]
    }

    pub fn pushed_count(&self) -> usize {
        self.pushed.iter().filter(|&&p| p).count()
    }

    /// Buffers currently held (nodes + leased out).
    pub fn live_buffers(&self) -> usize {
        self.in_use
    }

    /// High-water mark of live buffers over the tree's lifetime.
    pub fn peak_buffers(&self) -> usize {
        self.peak
    }

    /// Peak live gradient memory in bytes — the `benches/hotpath.rs`
    /// `peak_live_gradient_bytes` series.
    pub fn peak_live_bytes(&self) -> usize {
        self.peak * self.d * std::mem::size_of::<f32>()
    }

    /// Number of leaf slots under node `(l, i)`.
    fn span(&self, l: usize, i: usize) -> usize {
        (1usize << l).min(self.k() - (i << l))
    }

    fn eff_shards(&self) -> usize {
        effective_threads(self.shards, self.d)
    }

    /// Borrow a d-sized buffer from the freelist (or allocate one).
    /// Hand it back through [`ReduceTree::push_owned`] — the real
    /// backend's train step writes gradients straight into a leased
    /// buffer, so no per-worker arena exists between step and combine.
    pub fn lease(&mut self) -> Vec<f32> {
        self.in_use += 1;
        self.peak = self.peak.max(self.in_use);
        self.free.pop().unwrap_or_else(|| vec![0.0; self.d])
    }

    fn recycle(&mut self, buf: Vec<f32>) {
        debug_assert_eq!(buf.len(), self.d);
        self.in_use -= 1;
        self.free.push(buf);
    }

    /// Return a [`ReduceTree::lease`]d buffer *without* pushing it (the
    /// producing step failed) — keeps the live/peak buffer accounting
    /// honest on error paths.
    pub fn unlease(&mut self, buf: Vec<f32>) {
        assert_eq!(buf.len(), self.d, "unlease of a foreign buffer");
        self.recycle(buf);
    }

    /// Install `weight · grad` at leaf slot `leaf` and eagerly combine
    /// every ancestor whose subtree just became complete.
    pub fn push(&mut self, leaf: usize, grad: &[f32], weight: f32) {
        assert_eq!(grad.len(), self.d, "gradient length mismatch");
        let mut buf = self.lease();
        let shards = self.eff_shards();
        scale_from_sharded(&mut buf, grad, weight, shards);
        self.install(leaf, buf);
    }

    /// [`ReduceTree::push`] for a buffer obtained from
    /// [`ReduceTree::lease`] and already holding the raw gradient:
    /// scales it in place (no copy) and installs it.
    pub fn push_owned(&mut self, leaf: usize, mut buf: Vec<f32>, weight: f32) {
        assert_eq!(buf.len(), self.d, "gradient length mismatch");
        if weight != 1.0 {
            let shards = self.eff_shards();
            scale_sharded(&mut buf, weight, shards);
        }
        self.install(leaf, buf);
    }

    fn install(&mut self, leaf: usize, buf: Vec<f32>) {
        assert!(leaf < self.k(), "leaf {leaf} out of range");
        assert!(!self.pushed[leaf], "leaf {leaf} already pushed");
        self.pushed[leaf] = true;
        let n = &mut self.levels[0][leaf];
        n.buf = Some(buf);
        n.arrived = 1;
        n.combined = true;
        // Bubble up: every ancestor's arrival count grows; a full one
        // combines (its children are complete by induction — the
        // on-path child was handled earlier in this walk, the sibling
        // at its own completion).
        let mut i = leaf;
        for l in 1..self.levels.len() {
            i /= 2;
            self.levels[l][i].arrived += 1;
            debug_assert!(self.levels[l][i].arrived as usize <= self.span(l, i));
            if self.levels[l][i].arrived as usize == self.span(l, i)
                && !self.levels[l][i].combined
            {
                self.combine(l, i);
            }
        }
    }

    /// Drop leaf `leaf`'s contribution (spot revocation; no-op when the
    /// leaf was never pushed).  Under `Retain` this invalidates exactly
    /// the ancestor path — the surviving sibling partials recombine it
    /// on the next push or at [`ReduceTree::finalize`].
    pub fn revoke(&mut self, leaf: usize) {
        if leaf >= self.k() || !self.pushed[leaf] {
            return;
        }
        assert!(
            self.policy == RetainPolicy::Retain || self.levels[0][leaf].buf.is_some(),
            "RetainPolicy::Free cannot revoke an already-combined leaf — \
             elastic sessions must build the tree with RetainPolicy::Retain"
        );
        self.pushed[leaf] = false;
        let n = &mut self.levels[0][leaf];
        n.arrived = 0;
        n.combined = false;
        let b = n.buf.take();
        if let Some(b) = b {
            self.recycle(b);
        }
        let mut i = leaf;
        for l in 1..self.levels.len() {
            i /= 2;
            self.levels[l][i].arrived -= 1;
            if self.levels[l][i].combined {
                self.levels[l][i].combined = false;
                let b = self.levels[l][i].buf.take();
                if let Some(b) = b {
                    self.recycle(b);
                }
            }
        }
    }

    /// Combine node `(l, i)` from its (complete) children.
    fn combine(&mut self, l: usize, i: usize) {
        let (c0, c1) = (2 * i, 2 * i + 1);
        let has_r = c1 < self.levels[l - 1].len();
        debug_assert!(self.levels[l - 1][c0].combined || self.levels[l - 1][c0].arrived == 0);
        debug_assert!(
            !has_r || self.levels[l - 1][c1].combined || self.levels[l - 1][c1].arrived == 0
        );
        self.levels[l][i].combined = true;
        let shards = self.eff_shards();
        match self.policy {
            RetainPolicy::Free => {
                // Buffers migrate upward: the left child's becomes the
                // parent's, the right child's is accumulated in and
                // recycled.  (At a finalize over absent leaves either
                // side may be empty.)
                let lb = self.levels[l - 1][c0].buf.take();
                let rb = if has_r { self.levels[l - 1][c1].buf.take() } else { None };
                let merged = match (lb, rb) {
                    (Some(mut a), Some(b)) => {
                        accumulate_tiled(&mut a, &b, shards);
                        self.recycle(b);
                        Some(a)
                    }
                    (Some(a), None) => Some(a),
                    (None, Some(b)) => Some(b),
                    (None, None) => None,
                };
                self.levels[l][i].buf = merged;
            }
            RetainPolicy::Retain => {
                // Children keep their buffers (future revocations
                // rebuild from them).  Two present children sum into a
                // fresh buffer; a single present child makes this a
                // passthrough node resolved lazily via effective_idx.
                let li = self.effective_idx(l - 1, c0);
                let ri = if has_r { self.effective_idx(l - 1, c1) } else { None };
                if let (Some(a), Some(b)) = (li, ri) {
                    let mut buf = self.lease();
                    {
                        let av = self.levels[a.0][a.1].buf.as_deref().expect("effective");
                        let bv = self.levels[b.0][b.1].buf.as_deref().expect("effective");
                        sum_tiled(&mut buf, av, bv, shards);
                    }
                    self.levels[l][i].buf = Some(buf);
                }
            }
        }
    }

    /// Node actually holding `(l, i)`'s content — itself, or (for
    /// passthrough chains) the single descendant that owns a buffer;
    /// `None` when the subtree holds no pushed leaf.
    fn effective_idx(&self, l: usize, i: usize) -> Option<(usize, usize)> {
        if self.levels[l][i].arrived == 0 {
            return None;
        }
        if self.levels[l][i].buf.is_some() {
            return Some((l, i));
        }
        if l == 0 {
            return None;
        }
        let c0 = self.effective_idx(l - 1, 2 * i);
        if c0.is_some() {
            return c0;
        }
        if 2 * i + 1 < self.levels[l - 1].len() {
            return self.effective_idx(l - 1, 2 * i + 1);
        }
        None
    }

    /// Combine whatever the eager cascade could not (absent leaves,
    /// revocation-invalidated paths) and return the root aggregate.
    /// With every leaf pushed this is O(1) — the cascade already
    /// finished at the last arrival.  Finalize is terminal for the
    /// round: call [`ReduceTree::reset`] before pushing again.
    pub fn finalize(&mut self) -> &[f32] {
        assert!(
            self.pushed.iter().any(|&p| p),
            "finalize of an empty reduction tree"
        );
        // Fast path: a combined root means the eager cascade already
        // finished (combines only fire over consistent children, and a
        // revocation un-combines the whole ancestor path up to the
        // root), so there is nothing left to sweep.
        let top = self.levels.len() - 1;
        if self.levels[top][0].combined {
            return self.root();
        }
        for l in 1..self.levels.len() {
            for i in 0..self.levels[l].len() {
                if !self.levels[l][i].combined {
                    self.combine(l, i);
                }
            }
        }
        self.root()
    }

    /// The finalized root aggregate (call [`ReduceTree::finalize`] first).
    pub fn root(&self) -> &[f32] {
        let top = self.levels.len() - 1;
        let (l, i) = self
            .effective_idx(top, 0)
            .expect("root of a finalized non-empty tree");
        self.levels[l][i].buf.as_deref().expect("effective root buffer")
    }

    /// Clear for the next round; all buffers return to the freelist, so
    /// steady-state rounds allocate nothing.
    pub fn reset(&mut self) {
        for l in 0..self.levels.len() {
            for i in 0..self.levels[l].len() {
                self.levels[l][i].arrived = 0;
                self.levels[l][i].combined = false;
                if let Some(b) = self.levels[l][i].buf.take() {
                    debug_assert_eq!(b.len(), self.d);
                    self.in_use -= 1;
                    self.free.push(b);
                }
            }
        }
        for p in &mut self.pushed {
            *p = false;
        }
    }
}

/// Flat-equivalent entry point: aggregate λ-weighted gradients through
/// a [`RetainPolicy::Free`] reduction tree into `out`.  Numerically
/// within 1e-6 of [`crate::ps::aggregate_into`] (property-tested); the
/// tree's pairwise order is the one that is arrival-order invariant.
pub fn aggregate_tree_into(out: &mut [f32], grads: &[&[f32]], lambdas: &[f64], shards: usize) {
    validate_agg(out, grads, lambdas);
    let mut tree = ReduceTree::new(grads.len(), out.len(), RetainPolicy::Free, shards);
    for (i, (g, &l)) in grads.iter().zip(lambdas).enumerate() {
        tree.push(i, g, l as f32);
    }
    out.copy_from_slice(tree.finalize());
}

// ------------------------------------------------------------ kernels
//
// All three are cache-blocked over COMBINE_TILE elements (child tiles +
// destination tile stay L2-resident) and pool-sharded when the caller
// requests shards > 1 — same dispatch discipline as the fused
// optimizer kernels.

/// out[j] += src[j]
fn accumulate_tiled(out: &mut [f32], src: &[f32], shards: usize) {
    debug_assert_eq!(out.len(), src.len());
    if shards <= 1 {
        return accumulate_chunk(out, src, 0);
    }
    pool::global().run_sharded(out, shards, |_, start, chunk| {
        accumulate_chunk(chunk, src, start);
    });
}

fn accumulate_chunk(out: &mut [f32], src: &[f32], base: usize) {
    let mut start = 0;
    while start < out.len() {
        let len = COMBINE_TILE.min(out.len() - start);
        let s = &src[base + start..base + start + len];
        for (o, &x) in out[start..start + len].iter_mut().zip(s) {
            *o += x;
        }
        start += len;
    }
}

/// out[j] = a[j] + b[j] (both children accumulated per tile)
fn sum_tiled(out: &mut [f32], a: &[f32], b: &[f32], shards: usize) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    if shards <= 1 {
        return sum_chunk(out, a, b, 0);
    }
    pool::global().run_sharded(out, shards, |_, start, chunk| {
        sum_chunk(chunk, a, b, start);
    });
}

fn sum_chunk(out: &mut [f32], a: &[f32], b: &[f32], base: usize) {
    let mut start = 0;
    while start < out.len() {
        let len = COMBINE_TILE.min(out.len() - start);
        let at = &a[base + start..base + start + len];
        let bt = &b[base + start..base + start + len];
        for ((o, &x), &y) in out[start..start + len].iter_mut().zip(at).zip(bt) {
            *o = x + y;
        }
        start += len;
    }
}

/// out[j] = w · src[j] (a 2-stream copy — sharded but not tiled; there
/// is no reuse for blocking to exploit)
fn scale_from_sharded(out: &mut [f32], src: &[f32], w: f32, shards: usize) {
    debug_assert_eq!(out.len(), src.len());
    if shards <= 1 {
        for (o, &x) in out.iter_mut().zip(src) {
            *o = w * x;
        }
        return;
    }
    pool::global().run_sharded(out, shards, |_, start, chunk| {
        for (o, &x) in chunk.iter_mut().zip(&src[start..start + chunk.len()]) {
            *o = w * x;
        }
    });
}

/// buf[j] *= w
fn scale_sharded(buf: &mut [f32], w: f32, shards: usize) {
    if shards <= 1 {
        for x in buf.iter_mut() {
            *x *= w;
        }
        return;
    }
    pool::global().run_sharded(buf, shards, |_, _, chunk| {
        for x in chunk.iter_mut() {
            *x *= w;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::{aggregate_into, lambdas_from_batches};
    use crate::util::rng::Rng;

    fn problem(k: usize, d: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let grads: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec_f32(d)).collect();
        let batches: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 256.0)).collect();
        (grads, lambdas_from_batches(&batches))
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol, "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn tree_matches_flat_across_shapes() {
        // Odd / non-power-of-two shapes included (passthrough chains).
        for &k in &[1usize, 2, 3, 5, 7, 8, 13, 64] {
            let (grads, lambdas) = problem(k, 3001, k as u64);
            let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            let mut flat = vec![0.0f32; 3001];
            aggregate_into(&mut flat, &refs, &lambdas);
            let mut tree = vec![0.0f32; 3001];
            aggregate_tree_into(&mut tree, &refs, &lambdas, 1);
            assert_close(&flat, &tree, 1e-6);
        }
    }

    #[test]
    fn sharded_combines_are_bit_identical_to_single_threaded() {
        // Shard boundaries cut only between disjoint elementwise ranges,
        // so the pool-dispatched combines must match exactly.
        let d = 3 * COMBINE_TILE + 137;
        let (grads, lambdas) = problem(6, d, 9);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut st = vec![0.0f32; d];
        aggregate_tree_into(&mut st, &refs, &lambdas, 1);
        for shards in [2usize, 3, 8] {
            let mut mt = vec![0.0f32; d];
            aggregate_tree_into(&mut mt, &refs, &lambdas, shards);
            assert!(
                st.iter().zip(&mt).all(|(a, b)| a.to_bits() == b.to_bits()),
                "sharded combine diverged at shards={shards}"
            );
        }
    }

    #[test]
    fn arrival_order_is_bitwise_invariant() {
        for policy in [RetainPolicy::Free, RetainPolicy::Retain] {
            let (grads, lambdas) = problem(11, 500, 3);
            let run = |order: &[usize]| -> Vec<u32> {
                let mut t = ReduceTree::new(11, 500, policy, 1);
                for &i in order {
                    t.push(i, &grads[i], lambdas[i] as f32);
                }
                t.finalize().iter().map(|x| x.to_bits()).collect()
            };
            let asc: Vec<usize> = (0..11).collect();
            let desc: Vec<usize> = (0..11).rev().collect();
            let shuffled = vec![4usize, 9, 0, 7, 2, 10, 5, 1, 8, 3, 6];
            let base = run(&asc);
            assert_eq!(base, run(&desc), "{policy:?}");
            assert_eq!(base, run(&shuffled), "{policy:?}");
        }
    }

    #[test]
    fn free_peak_buffers_bounded_by_depth_plus_one() {
        // The RetainPolicy::Free memory guarantee: with leaves arriving
        // in ascending rank order (the real backend's wave order) the
        // live-buffer high-water mark is ⌈log₂k⌉ + 1.
        for k in 1usize..=64 {
            let mut t = ReduceTree::new(k, 64, RetainPolicy::Free, 1);
            let g = vec![1.0f32; 64];
            for round in 0..2 {
                for i in 0..k {
                    t.push(i, &g, 0.5);
                }
                let root0 = t.finalize()[0];
                assert!((root0 - 0.5 * k as f32).abs() < 1e-3);
                assert!(
                    t.peak_buffers() <= t.depth() + 1,
                    "k={k} round={round}: peak {} > ⌈log₂k⌉+1 = {}",
                    t.peak_buffers(),
                    t.depth() + 1
                );
                t.reset();
                assert_eq!(t.live_buffers(), 0, "k={k}: buffers leaked past reset");
            }
            assert_eq!(
                t.peak_live_bytes(),
                t.peak_buffers() * 64 * 4,
                "byte accounting"
            );
        }
    }

    #[test]
    fn retain_revoke_rebuilds_to_match_fresh_tree_bitwise() {
        let k = 13;
        let (grads, lambdas) = problem(k, 700, 17);
        for victim in [0usize, 5, 12] {
            let mut t = ReduceTree::new(k, 700, RetainPolicy::Retain, 1);
            for i in 0..k {
                t.push(i, &grads[i], lambdas[i] as f32);
            }
            t.revoke(victim);
            let rebuilt: Vec<u32> = t.finalize().iter().map(|x| x.to_bits()).collect();
            let mut fresh = ReduceTree::new(k, 700, RetainPolicy::Retain, 1);
            for i in 0..k {
                if i != victim {
                    fresh.push(i, &grads[i], lambdas[i] as f32);
                }
            }
            let want: Vec<u32> = fresh.finalize().iter().map(|x| x.to_bits()).collect();
            assert_eq!(rebuilt, want, "victim {victim}");
        }
    }

    #[test]
    fn revoke_then_repush_rejoins_the_round() {
        let k = 6;
        let (grads, lambdas) = problem(k, 300, 23);
        let mut t = ReduceTree::new(k, 300, RetainPolicy::Retain, 1);
        for i in 0..k {
            t.push(i, &grads[i], lambdas[i] as f32);
        }
        t.revoke(2);
        assert!(!t.is_pushed(2));
        t.push(2, &grads[2], lambdas[2] as f32);
        let got: Vec<u32> = t.finalize().iter().map(|x| x.to_bits()).collect();
        let mut fresh = ReduceTree::new(k, 300, RetainPolicy::Retain, 1);
        for i in 0..k {
            fresh.push(i, &grads[i], lambdas[i] as f32);
        }
        let want: Vec<u32> = fresh.finalize().iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn revoke_of_unpushed_leaf_is_noop() {
        let mut t = ReduceTree::new(4, 10, RetainPolicy::Retain, 1);
        t.revoke(3); // nothing pushed yet
        t.push(0, &[1.0; 10], 1.0);
        t.revoke(2);
        assert_eq!(t.pushed_count(), 1);
        assert_eq!(t.finalize()[0], 1.0);
    }

    #[test]
    fn partial_round_finalizes_over_present_leaves_only() {
        // Absent ranks (never-arriving members) resolve as empty
        // passthroughs — the root covers exactly the pushed set.
        let (grads, lambdas) = problem(8, 200, 31);
        let refs: Vec<&[f32]> = [1usize, 4, 6]
            .iter()
            .map(|&i| grads[i].as_slice())
            .collect();
        let lam: Vec<f64> = vec![lambdas[1], lambdas[4], lambdas[6]];
        let mut t = ReduceTree::new(8, 200, RetainPolicy::Free, 1);
        for (j, &i) in [1usize, 4, 6].iter().enumerate() {
            t.push(i, refs[j], lam[j] as f32);
        }
        let root = t.finalize().to_vec();
        // Oracle: same three gradients through a compact 3-leaf tree.
        let mut want = vec![0.0f32; 200];
        aggregate_tree_into(&mut want, &refs, &lam, 1);
        // Shapes differ (slots 1/4/6 of 8 vs 0/1/2 of 3), so compare to
        // the flat oracle at 1e-6 rather than bitwise.
        assert_close(&root, &want, 1e-6);
    }

    #[test]
    fn push_owned_skips_the_copy_and_matches_push() {
        let (grads, lambdas) = problem(3, 400, 41);
        let mut a = ReduceTree::new(3, 400, RetainPolicy::Free, 1);
        let mut b = ReduceTree::new(3, 400, RetainPolicy::Free, 1);
        for i in 0..3 {
            a.push(i, &grads[i], lambdas[i] as f32);
            let mut buf = b.lease();
            buf.copy_from_slice(&grads[i]);
            b.push_owned(i, buf, lambdas[i] as f32);
        }
        let av: Vec<u32> = a.finalize().iter().map(|x| x.to_bits()).collect();
        let bv: Vec<u32> = b.finalize().iter().map(|x| x.to_bits()).collect();
        assert_eq!(av, bv);
    }

    #[test]
    fn unlease_keeps_buffer_accounting_honest() {
        // A leased buffer whose producing step fails goes back via
        // unlease — live count returns to zero and the buffer is reused
        // by the next lease instead of counting against the peak.
        let mut t = ReduceTree::new(4, 16, RetainPolicy::Free, 1);
        let buf = t.lease();
        assert_eq!(t.live_buffers(), 1);
        t.unlease(buf);
        assert_eq!(t.live_buffers(), 0);
        for i in 0..4 {
            t.push(i, &[1.0; 16], 0.25);
        }
        assert_eq!(t.finalize()[0], 1.0);
        assert!(t.peak_buffers() <= t.depth() + 1);
    }

    #[test]
    #[should_panic]
    fn empty_finalize_panics() {
        let mut t = ReduceTree::new(4, 8, RetainPolicy::Free, 1);
        t.finalize();
    }

    #[test]
    #[should_panic]
    fn double_push_panics() {
        let mut t = ReduceTree::new(2, 8, RetainPolicy::Free, 1);
        t.push(0, &[1.0; 8], 1.0);
        t.push(0, &[1.0; 8], 1.0);
    }

    #[test]
    #[should_panic]
    fn free_cannot_revoke_absorbed_leaf() {
        let mut t = ReduceTree::new(2, 8, RetainPolicy::Free, 1);
        t.push(0, &[1.0; 8], 1.0);
        t.push(1, &[1.0; 8], 1.0); // leaf 0's buffer migrated to the root
        t.revoke(0);
    }
}
