//! Fused aggregation + optimizer kernels (§Perf iteration 1).
//!
//! The naive PS pipeline makes two full passes over parameter-sized
//! memory per iteration: (1) λ-weighted aggregation writes the averaged
//! gradient, (2) the optimizer reads it back and updates params/state.
//! Both are memory-bandwidth-bound.  Fusion here is *tiled*: gradients
//! are aggregated into an L1-resident tile with the vectorized
//! `aggregate_into` kernel, and the optimizer update consumes the tile
//! while it is still in cache — the aggregated gradient never makes a
//! round trip through DRAM.  (A naive per-element fusion with indexed
//! access defeats auto-vectorization and is *slower* than the unfused
//! pipeline — measured in `benches/hotpath.rs`, kept in the §Perf log.)
//!
//! Numerics are identical to `aggregate_into` + `Optimizer::step` (same
//! operation order per element), verified by unit tests.
//!
//! §Perf iteration 4 adds sharded variants (`fused_agg_*_mt`): params
//! and optimizer state split into contiguous shards across the
//! persistent pool ([`crate::util::pool`]), one tiled fused pass per
//! shard. Elementwise numerics are unchanged — equivalence across shard
//! counts and multi-step state evolution is property-tested in
//! `rust/tests/property.rs`.

use crate::ps::optimizer::{Adam, LrSchedule, Momentum, Optimizer, Sgd};
use crate::ps::{aggregate_into, effective_threads};
use crate::util::pool;

/// Tile length: 8 K f32 = 32 KiB — fits L1d alongside the param tile.
const TILE: usize = 8192;

/// Run `update(params_tile, agg_tile, tile_start)` over λ-aggregated
/// gradient tiles.
fn tiled<F: FnMut(&mut [f32], &[f32], usize)>(
    params: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    update: F,
) {
    tiled_at(params, grads, lambdas, 0, update)
}

/// Tiled pass over one contiguous shard: `params` is the shard, `base`
/// its offset into the full parameter vector (gradients are indexed
/// globally, `update`'s tile start is shard-local). The sharded kernels
/// run one of these per pool worker; `tiled` is the base == 0 case.
fn tiled_at<F: FnMut(&mut [f32], &[f32], usize)>(
    params: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    base: usize,
    mut update: F,
) {
    let mut buf = [0.0f32; TILE];
    // Slice headers reused across tiles — §Perf iteration 4; the seed
    // allocated this Vec once per 8K-element tile, on the hot path.
    let mut slices: Vec<&[f32]> = Vec::with_capacity(grads.len());
    let n = params.len();
    let mut start = 0;
    while start < n {
        let len = TILE.min(n - start);
        slices.clear();
        slices.extend(grads.iter().map(|g| &g[base + start..base + start + len]));
        aggregate_into(&mut buf[..len], &slices, lambdas);
        update(&mut params[start..start + len], &buf[..len], start);
        start += len;
    }
}

/// Shard count for an explicit `shards` request (sharded kernels honor
/// the request so tests can exercise every split; only degenerate
/// values are clamped).
fn clamp_shards(shards: usize, len: usize) -> usize {
    shards.max(1).min(len.max(1))
}

/// Aggregate λ-weighted gradients and apply an SGD step in one pass.
pub fn fused_agg_sgd(
    params: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    opt: &mut Sgd,
) {
    validate(params, grads, lambdas);
    let lr = opt.schedule.at(opt.iterations()) as f32;
    tiled(params, grads, lambdas, |p_tile, g_tile, _| {
        for (p, &g) in p_tile.iter_mut().zip(g_tile) {
            *p -= lr * g;
        }
    });
    opt.bump();
}

/// Fused aggregation + momentum step.
pub fn fused_agg_momentum(
    params: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    opt: &mut Momentum,
) {
    validate(params, grads, lambdas);
    assert_eq!(params.len(), opt.velocity().len());
    let lr = opt.schedule.at(opt.iterations()) as f32;
    let mu = opt.mu as f32;
    let v = opt.velocity_mut();
    tiled(params, grads, lambdas, |p_tile, g_tile, start| {
        let v_tile = &mut v[start..start + p_tile.len()];
        for ((p, vel), &g) in p_tile.iter_mut().zip(v_tile.iter_mut()).zip(g_tile) {
            *vel = mu * *vel + g;
            *p -= lr * *vel;
        }
    });
    opt.bump();
}

/// Fused aggregation + Adam step (bias-corrected).
pub fn fused_agg_adam(
    params: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    opt: &mut Adam,
) {
    validate(params, grads, lambdas);
    assert_eq!(params.len(), opt.m().len());
    let t = opt.iterations() + 1;
    let lr = opt.schedule.at(t - 1);
    let (b1, b2, eps) = (opt.beta1, opt.beta2, opt.eps);
    let bc1 = 1.0 - b1.powi(t as i32);
    let bc2 = 1.0 - b2.powi(t as i32);
    let step = (lr * bc2.sqrt() / bc1) as f32;
    let (b1, b2, eps) = (b1 as f32, b2 as f32, eps as f32);
    let (m, v) = opt.state_mut();
    tiled(params, grads, lambdas, |p_tile, g_tile, start| {
        let m_tile = &mut m[start..start + p_tile.len()];
        let v_tile = &mut v[start..start + p_tile.len()];
        for (((p, mi), vi), &g) in p_tile
            .iter_mut()
            .zip(m_tile.iter_mut())
            .zip(v_tile.iter_mut())
            .zip(g_tile)
        {
            *mi = b1 * *mi + (1.0 - b1) * g;
            *vi = b2 * *vi + (1.0 - b2) * g * g;
            *p -= step * *mi / (vi.sqrt() + eps);
        }
    });
    opt.bump_to(t);
}

// ---------------------------------------------------------------------
// Sharded variants (§Perf iteration 4): params + optimizer state are
// split into contiguous shards across the persistent pool, each shard
// running its own tiled fused pass. Per-element operation order is
// identical to the single-threaded kernels (aggregation visits workers
// in the same order for every element), so numerics match exactly.

/// Sharded fused aggregation + SGD across the worker pool.
pub fn fused_agg_sgd_mt(
    params: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    opt: &mut Sgd,
    shards: usize,
) {
    validate(params, grads, lambdas);
    let shards = clamp_shards(shards, params.len());
    if shards == 1 {
        return fused_agg_sgd(params, grads, lambdas, opt);
    }
    let lr = opt.schedule.at(opt.iterations()) as f32;
    pool::global().run_sharded(params, shards, |_, base, shard| {
        tiled_at(shard, grads, lambdas, base, |p_tile, g_tile, _| {
            for (p, &g) in p_tile.iter_mut().zip(g_tile) {
                *p -= lr * g;
            }
        });
    });
    opt.bump();
}

/// Sharded fused aggregation + momentum: velocity is sharded alongside
/// the parameters (same chunking), so each task owns a disjoint
/// (params, velocity) pair.
pub fn fused_agg_momentum_mt(
    params: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    opt: &mut Momentum,
    shards: usize,
) {
    validate(params, grads, lambdas);
    assert_eq!(params.len(), opt.velocity().len());
    let shards = clamp_shards(shards, params.len());
    if shards == 1 {
        return fused_agg_momentum(params, grads, lambdas, opt);
    }
    let lr = opt.schedule.at(opt.iterations()) as f32;
    let mu = opt.mu as f32;
    let chunk = (params.len() + shards - 1) / shards;
    let v = opt.velocity_mut();
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = params
        .chunks_mut(chunk)
        .zip(v.chunks_mut(chunk))
        .enumerate()
        .map(|(i, (p_shard, v_shard))| {
            let base = i * chunk;
            Box::new(move || {
                tiled_at(p_shard, grads, lambdas, base, |p_tile, g_tile, start| {
                    let v_tile = &mut v_shard[start..start + p_tile.len()];
                    for ((p, vel), &g) in
                        p_tile.iter_mut().zip(v_tile.iter_mut()).zip(g_tile)
                    {
                        *vel = mu * *vel + g;
                        *p -= lr * *vel;
                    }
                });
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool::global().run_tasks(tasks);
    opt.bump();
}

/// Sharded fused aggregation + Adam: m and v shard with the parameters.
pub fn fused_agg_adam_mt(
    params: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    opt: &mut Adam,
    shards: usize,
) {
    validate(params, grads, lambdas);
    assert_eq!(params.len(), opt.m().len());
    let shards = clamp_shards(shards, params.len());
    if shards == 1 {
        return fused_agg_adam(params, grads, lambdas, opt);
    }
    let t = opt.iterations() + 1;
    let lr = opt.schedule.at(t - 1);
    let (b1, b2, eps) = (opt.beta1, opt.beta2, opt.eps);
    let bc1 = 1.0 - b1.powi(t as i32);
    let bc2 = 1.0 - b2.powi(t as i32);
    let step = (lr * bc2.sqrt() / bc1) as f32;
    let (b1, b2, eps) = (b1 as f32, b2 as f32, eps as f32);
    let chunk = (params.len() + shards - 1) / shards;
    let (m, v) = opt.state_mut();
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = params
        .chunks_mut(chunk)
        .zip(m.chunks_mut(chunk))
        .zip(v.chunks_mut(chunk))
        .enumerate()
        .map(|(i, ((p_shard, m_shard), v_shard))| {
            let base = i * chunk;
            Box::new(move || {
                tiled_at(p_shard, grads, lambdas, base, |p_tile, g_tile, start| {
                    let m_tile = &mut m_shard[start..start + p_tile.len()];
                    let v_tile = &mut v_shard[start..start + p_tile.len()];
                    for (((p, mi), vi), &g) in p_tile
                        .iter_mut()
                        .zip(m_tile.iter_mut())
                        .zip(v_tile.iter_mut())
                        .zip(g_tile)
                    {
                        *mi = b1 * *mi + (1.0 - b1) * g;
                        *vi = b2 * *vi + (1.0 - b2) * g * g;
                        *p -= step * *mi / (vi.sqrt() + eps);
                    }
                });
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool::global().run_tasks(tasks);
    opt.bump_to(t);
}

/// Dispatch over the optimizer kinds used by the engine.
pub enum FusedOptimizer {
    Sgd(Sgd),
    Momentum(Momentum),
    Adam(Adam),
}

impl FusedOptimizer {
    pub fn for_workload(name: &str, dim: usize, total_iters: u64) -> Self {
        match name {
            "resnet" | "cnn" => FusedOptimizer::Momentum(Momentum::new(
                LrSchedule::resnet_paper(total_iters),
                0.9,
                dim,
            )),
            "mnist" | "mlp" => FusedOptimizer::Adam(Adam::paper_mnist(dim)),
            "transformer" | "transformer_e2e" => {
                FusedOptimizer::Adam(Adam::new(LrSchedule::Constant(3e-4), dim))
            }
            _ => FusedOptimizer::Sgd(Sgd::new(LrSchedule::Constant(0.05))),
        }
    }

    /// One fused aggregate+update pass, single-threaded.
    pub fn step(&mut self, params: &mut [f32], grads: &[&[f32]], lambdas: &[f64]) {
        match self {
            FusedOptimizer::Sgd(o) => fused_agg_sgd(params, grads, lambdas, o),
            FusedOptimizer::Momentum(o) => fused_agg_momentum(params, grads, lambdas, o),
            FusedOptimizer::Adam(o) => fused_agg_adam(params, grads, lambdas, o),
        }
    }

    /// One fused aggregate+update pass, sharded across the persistent
    /// pool. `threads` is a request: it is clamped to available
    /// parallelism and the pass stays single-threaded below
    /// [`crate::ps::MT_MIN_LEN`] elements. Numerics are identical to
    /// [`FusedOptimizer::step`] either way.
    pub fn step_mt(
        &mut self,
        params: &mut [f32],
        grads: &[&[f32]],
        lambdas: &[f64],
        threads: usize,
    ) {
        let shards = effective_threads(threads, params.len());
        match self {
            FusedOptimizer::Sgd(o) => fused_agg_sgd_mt(params, grads, lambdas, o, shards),
            FusedOptimizer::Momentum(o) => {
                fused_agg_momentum_mt(params, grads, lambdas, o, shards)
            }
            FusedOptimizer::Adam(o) => fused_agg_adam_mt(params, grads, lambdas, o, shards),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FusedOptimizer::Sgd(_) => "sgd",
            FusedOptimizer::Momentum(_) => "momentum",
            FusedOptimizer::Adam(_) => "adam",
        }
    }

    /// Checkpoint state: the iteration counter and moment vectors —
    /// none for SGD, `[v]` for momentum, `[m, v]` for Adam.  Schedules
    /// and hyperparameters are run config, not state.
    pub fn ckpt_moments(&self) -> (u64, Vec<&[f32]>) {
        match self {
            FusedOptimizer::Sgd(o) => (o.iterations(), vec![]),
            FusedOptimizer::Momentum(o) => (o.iterations(), vec![o.velocity()]),
            FusedOptimizer::Adam(o) => (o.iterations(), vec![o.m(), o.v()]),
        }
    }

    /// Restore a [`FusedOptimizer::ckpt_moments`] snapshot into an
    /// optimizer freshly built with the run's config.
    pub fn ckpt_restore(&mut self, t: u64, moments: &[Vec<f32>]) -> Result<(), String> {
        let want = match self {
            FusedOptimizer::Sgd(_) => 0,
            FusedOptimizer::Momentum(_) => 1,
            FusedOptimizer::Adam(_) => 2,
        };
        if moments.len() != want {
            return Err(format!(
                "{} optimizer restore: {} moment vectors, expected {want}",
                self.name(),
                moments.len()
            ));
        }
        let copy = |dst: &mut [f32], src: &[f32], what: &str| -> Result<(), String> {
            if dst.len() != src.len() {
                return Err(format!(
                    "optimizer restore: {what} has {} elements, model has {}",
                    src.len(),
                    dst.len()
                ));
            }
            dst.copy_from_slice(src);
            Ok(())
        };
        match self {
            FusedOptimizer::Sgd(o) => o.set_iterations(t),
            FusedOptimizer::Momentum(o) => {
                copy(o.velocity_mut(), &moments[0], "velocity")?;
                o.set_iterations(t);
            }
            FusedOptimizer::Adam(o) => {
                let (m, v) = o.state_mut();
                copy(m, &moments[0], "adam m")?;
                copy(v, &moments[1], "adam v")?;
                o.bump_to(t);
            }
        }
        Ok(())
    }
}

/// Argument validation, shared with every other aggregation entry point
/// ([`crate::ps::validate_agg`] — the params vector is the length target).
fn validate(params: &[f32], grads: &[&[f32]], lambdas: &[f64]) {
    crate::ps::validate_agg(params, grads, lambdas);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::optimizer::Optimizer;
    use crate::ps::{aggregate_into, lambdas_from_batches};
    use crate::util::rng::Rng;

    fn setup(d: usize) -> (Vec<f32>, Vec<Vec<f32>>, Vec<f64>) {
        let mut rng = Rng::new(5);
        let params = rng.normal_vec_f32(d);
        let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec_f32(d)).collect();
        let lambdas = lambdas_from_batches(&[16.0, 32.0, 80.0]);
        (params, grads, lambdas)
    }

    fn assert_close(a: &[f32], b: &[f32]) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-5, "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn fused_sgd_matches_unfused() {
        let (params, grads, lambdas) = setup(10_000);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();

        let mut p1 = params.clone();
        let mut agg = vec![0.0; p1.len()];
        let mut o1 = Sgd::new(LrSchedule::Constant(0.1));
        aggregate_into(&mut agg, &refs, &lambdas);
        o1.step(&mut p1, &agg);

        let mut p2 = params;
        let mut o2 = Sgd::new(LrSchedule::Constant(0.1));
        fused_agg_sgd(&mut p2, &refs, &lambdas, &mut o2);
        assert_close(&p1, &p2);
        assert_eq!(o1.iterations(), o2.iterations());
    }

    #[test]
    fn fused_momentum_matches_unfused_over_steps() {
        let (params, grads, lambdas) = setup(5_000);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut p1 = params.clone();
        let mut p2 = params;
        let mut o1 = Momentum::new(LrSchedule::Constant(0.05), 0.9, p1.len());
        let mut o2 = Momentum::new(LrSchedule::Constant(0.05), 0.9, p2.len());
        let mut agg = vec![0.0; p1.len()];
        for _ in 0..3 {
            aggregate_into(&mut agg, &refs, &lambdas);
            o1.step(&mut p1, &agg);
            fused_agg_momentum(&mut p2, &refs, &lambdas, &mut o2);
        }
        assert_close(&p1, &p2);
    }

    #[test]
    fn fused_adam_matches_unfused_over_steps() {
        let (params, grads, lambdas) = setup(5_000);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut p1 = params.clone();
        let mut p2 = params;
        let mut o1 = Adam::new(LrSchedule::Constant(0.001), p1.len());
        let mut o2 = Adam::new(LrSchedule::Constant(0.001), p2.len());
        let mut agg = vec![0.0; p1.len()];
        for _ in 0..4 {
            aggregate_into(&mut agg, &refs, &lambdas);
            o1.step(&mut p1, &agg);
            fused_agg_adam(&mut p2, &refs, &lambdas, &mut o2);
        }
        assert_close(&p1, &p2);
    }

    #[test]
    fn sharded_kernels_match_single_threaded_over_steps() {
        // Dim deliberately a non-multiple of both TILE and any shard
        // count; state (velocity, m/v) must evolve identically.
        let d = 2 * super::TILE + 1234;
        let (params, grads, lambdas) = setup(d);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        for shards in [2usize, 3, 5, 8] {
            // SGD
            let (mut p_st, mut p_mt) = (params.clone(), params.clone());
            let mut o_st = Sgd::new(LrSchedule::Constant(0.05));
            let mut o_mt = Sgd::new(LrSchedule::Constant(0.05));
            for _ in 0..3 {
                fused_agg_sgd(&mut p_st, &refs, &lambdas, &mut o_st);
                fused_agg_sgd_mt(&mut p_mt, &refs, &lambdas, &mut o_mt, shards);
            }
            assert_close(&p_st, &p_mt);
            assert_eq!(o_st.iterations(), o_mt.iterations());
            // Momentum
            let (mut p_st, mut p_mt) = (params.clone(), params.clone());
            let mut o_st = Momentum::new(LrSchedule::Constant(0.05), 0.9, d);
            let mut o_mt = Momentum::new(LrSchedule::Constant(0.05), 0.9, d);
            for _ in 0..3 {
                fused_agg_momentum(&mut p_st, &refs, &lambdas, &mut o_st);
                fused_agg_momentum_mt(&mut p_mt, &refs, &lambdas, &mut o_mt, shards);
            }
            assert_close(&p_st, &p_mt);
            assert_close(o_st.velocity(), o_mt.velocity());
            // Adam
            let (mut p_st, mut p_mt) = (params.clone(), params.clone());
            let mut o_st = Adam::new(LrSchedule::Constant(0.001), d);
            let mut o_mt = Adam::new(LrSchedule::Constant(0.001), d);
            for _ in 0..3 {
                fused_agg_adam(&mut p_st, &refs, &lambdas, &mut o_st);
                fused_agg_adam_mt(&mut p_mt, &refs, &lambdas, &mut o_mt, shards);
            }
            assert_close(&p_st, &p_mt);
            assert_close(o_st.m(), o_mt.m());
            assert_eq!(o_st.iterations(), o_mt.iterations());
        }
    }

    #[test]
    fn step_mt_heuristic_falls_back_below_cutoff() {
        // Small model: step_mt must take the single-threaded path and
        // still produce the exact step() result.
        let (params, grads, lambdas) = setup(4_000);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut p1 = params.clone();
        let mut p2 = params;
        let mut f1 = FusedOptimizer::Adam(Adam::new(LrSchedule::Constant(0.001), p1.len()));
        let mut f2 = FusedOptimizer::Adam(Adam::new(LrSchedule::Constant(0.001), p2.len()));
        f1.step(&mut p1, &refs, &lambdas);
        f2.step_mt(&mut p2, &refs, &lambdas, 8);
        assert_close(&p1, &p2);
    }

    #[test]
    fn dispatcher_selects_paper_optimizers() {
        assert_eq!(FusedOptimizer::for_workload("cnn", 4, 100).name(), "momentum");
        assert_eq!(FusedOptimizer::for_workload("mlp", 4, 100).name(), "adam");
        assert_eq!(FusedOptimizer::for_workload("linreg", 4, 100).name(), "sgd");
    }
}
