//! Parameter server: λ-weighted gradient aggregation (paper Eq. 2–3) and
//! optimizers.
//!
//! The PS applies the paper's update rule
//!
//! ```text
//! g_t      = Σ_k λ_k ∇f(x_{b_k,t}),   λ_k = b_k / Σ_i b_i
//! x_{t+1}  = x_t − η · g_t
//! ```
//!
//! With uniform batches λ_k = 1/K and this reduces to the conventional
//! averaged update; the λ weighting is what keeps variable batching
//! statistically equivalent to uniform batching at the same global batch.
//!
//! Aggregation runs on the Rust hot path (memory-bound axpy over the
//! flattened parameter vector, optionally multi-threaded); the same
//! computation also exists as an AOT Pallas kernel (`grad_agg_k*.hlo.txt`)
//! — `benches/agg.rs` compares the two.
//!
//! §Perf iteration 6: BSP rounds no longer realize Eq. 2 as one flat
//! O(k·d) barrier sweep — [`reduce::ReduceTree`] combines each worker's
//! gradient into a fixed rank-indexed binary tree the moment it
//! completes (DESIGN.md §11).  The flat paths below remain the async
//! update path, the bench baseline, and the tree's numeric oracle.

pub mod fused;
pub mod optimizer;
pub mod reduce;
pub mod store;

pub use fused::FusedOptimizer;
pub use optimizer::{Adam, LrSchedule, Momentum, Optimizer, Sgd};
pub use reduce::{aggregate_tree_into, ReduceTree, RetainPolicy};
pub use store::ParamStore;

/// Shared argument validation for every aggregation entry point (flat,
/// pool-sharded, the spawn baseline, the fused kernels, the reduction
/// tree): one gradient per λ, at least one gradient, every gradient the
/// target's length.  (Previously triplicated across
/// `aggregate_into{,_mt,_spawn}` and duplicated again in `fused`.)
pub(crate) fn validate_agg(target: &[f32], grads: &[&[f32]], lambdas: &[f64]) {
    assert_eq!(grads.len(), lambdas.len());
    assert!(!grads.is_empty(), "no gradients");
    for g in grads {
        assert_eq!(g.len(), target.len(), "gradient length mismatch");
    }
}

/// λ_k = b_k / Σ b_i (Eq. 2's weights).
pub fn lambdas_from_batches(batches: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(batches.len());
    lambdas_into(&mut out, batches);
    out
}

/// [`lambdas_from_batches`] into a caller-owned buffer (cleared first) —
/// the per-update path reuses one allocation across the whole run.
pub fn lambdas_into(out: &mut Vec<f64>, batches: &[f64]) {
    assert!(!batches.is_empty());
    let total: f64 = batches.iter().sum();
    assert!(total > 0.0, "batches sum to zero");
    out.clear();
    out.extend(batches.iter().map(|&b| b / total));
}

/// out[j] = Σ_k λ[k]·grads[k][j] — single-threaded reference, summing
/// workers *sequentially* (k−1 dependent adds per element).  The BSP
/// hot path now aggregates through the eager reduction tree instead
/// ([`reduce`]); this flat sweep remains the async single-update path,
/// the `tree_vs_flat` bench baseline, and the ≤1e-6 numeric oracle the
/// tree is property-tested against.
pub fn aggregate_into(out: &mut [f32], grads: &[&[f32]], lambdas: &[f64]) {
    validate_agg(out, grads, lambdas);
    // First worker writes, the rest accumulate — avoids a zero-fill pass.
    let l0 = lambdas[0] as f32;
    for (o, &g) in out.iter_mut().zip(grads[0]) {
        *o = l0 * g;
    }
    for (g, &l) in grads[1..].iter().zip(&lambdas[1..]) {
        let lf = l as f32;
        for (o, &gv) in out.iter_mut().zip(*g) {
            *o += lf * gv;
        }
    }
}

/// Below this many elements the multi-threaded PS paths fall back to a
/// single-threaded pass: thread dispatch costs more than the memory
/// pass saves. Shared by [`aggregate_into_mt`], the spawn baseline, and
/// the sharded fused kernels in [`fused`].
pub const MT_MIN_LEN: usize = 1 << 16;

/// Thread/shard count actually used for a parameter-sized pass: 1 below
/// [`MT_MIN_LEN`], otherwise `requested` clamped to the machine's
/// available parallelism (the seed clamped only by vector length, which
/// allowed absurd thread counts for mid-sized vectors).
pub fn effective_threads(requested: usize, len: usize) -> usize {
    if len < MT_MIN_LEN {
        return 1;
    }
    let cap = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    requested.max(1).min(cap)
}

/// Multi-threaded aggregation: shards the parameter vector across the
/// persistent worker pool ([`crate::util::pool::global`]). Used for
/// large models (e2e transformer has ~12M params ⇒ ~48 MB of gradients
/// per worker). §Perf iteration 4: the seed spawned fresh OS threads on
/// every call ([`aggregate_into_spawn`], kept as the bench baseline).
pub fn aggregate_into_mt(
    out: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    threads: usize,
) {
    validate_agg(out, grads, lambdas);
    let threads = effective_threads(threads, out.len());
    if threads == 1 {
        return aggregate_into(out, grads, lambdas);
    }
    crate::util::pool::global().run_sharded(out, threads, |_, start, shard| {
        let slices: Vec<&[f32]> =
            grads.iter().map(|g| &g[start..start + shard.len()]).collect();
        aggregate_into(shard, &slices, lambdas);
    });
}

/// Spawn-per-call multi-threaded aggregation — the seed implementation,
/// kept only as the `pool_vs_spawn` baseline in `benches/hotpath.rs`.
/// Production callers use [`aggregate_into_mt`].
pub fn aggregate_into_spawn(
    out: &mut [f32],
    grads: &[&[f32]],
    lambdas: &[f64],
    threads: usize,
) {
    validate_agg(out, grads, lambdas);
    let threads = effective_threads(threads, out.len());
    if threads == 1 {
        return aggregate_into(out, grads, lambdas);
    }
    let chunk = (out.len() + threads - 1) / threads;
    std::thread::scope(|scope| {
        for (t, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let start = t * chunk;
            let end = start + out_chunk.len();
            scope.spawn(move || {
                let slices: Vec<&[f32]> =
                    grads.iter().map(|g| &g[start..end]).collect();
                aggregate_into(out_chunk, &slices, lambdas);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol, "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn lambdas_normalize() {
        let l = lambdas_from_batches(&[32.0, 64.0, 96.0]);
        assert!((l.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((l[0] - 32.0 / 192.0).abs() < 1e-12);
    }

    #[test]
    fn uniform_lambda_is_plain_average() {
        let g0 = vec![1.0f32, 2.0, 3.0];
        let g1 = vec![3.0f32, 4.0, 5.0];
        let mut out = vec![0.0; 3];
        aggregate_into(&mut out, &[&g0, &g1], &[0.5, 0.5]);
        assert_close(&out, &[2.0, 3.0, 4.0], 1e-7);
    }

    #[test]
    fn weighting_matches_manual() {
        let g0 = vec![1.0f32, -2.0];
        let g1 = vec![10.0f32, 20.0];
        let mut out = vec![0.0; 2];
        aggregate_into(&mut out, &[&g0, &g1], &[0.25, 0.75]);
        assert_close(&out, &[7.75, 14.5], 1e-6);
    }

    #[test]
    fn single_worker_identity() {
        let g = vec![5.0f32; 17];
        let mut out = vec![0.0; 17];
        aggregate_into(&mut out, &[&g], &[1.0]);
        assert_close(&out, &g, 0.0);
    }

    #[test]
    fn mt_matches_st_various_sizes_and_threads() {
        let mut rng = Rng::new(0);
        for &n in &[1usize, 100, 65_537, 1 << 18] {
            let grads: Vec<Vec<f32>> =
                (0..4).map(|_| rng.normal_vec_f32(n)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            let lam = lambdas_from_batches(&[1.0, 2.0, 3.0, 4.0]);
            let mut st = vec![0.0; n];
            aggregate_into(&mut st, &refs, &lam);
            for threads in [1, 2, 3, 8] {
                let mut mt = vec![0.0; n];
                aggregate_into_mt(&mut mt, &refs, &lam, threads);
                assert_close(&mt, &st, 1e-6);
                let mut sp = vec![0.0; n];
                aggregate_into_spawn(&mut sp, &refs, &lam, threads);
                assert_close(&sp, &st, 1e-6);
            }
        }
    }

    #[test]
    fn effective_threads_clamps_sanely() {
        // Below the cutoff: always single-threaded.
        assert_eq!(effective_threads(8, MT_MIN_LEN - 1), 1);
        assert_eq!(effective_threads(0, MT_MIN_LEN - 1), 1);
        // At/above the cutoff: at least 1, never above the machine.
        let cap = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        assert_eq!(effective_threads(0, MT_MIN_LEN), 1);
        assert!(effective_threads(usize::MAX, 1 << 24) <= cap);
        assert!(effective_threads(2, 1 << 24) >= 1);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let g = vec![1.0f32; 4];
        let mut out = vec![0.0; 5];
        aggregate_into(&mut out, &[&g], &[1.0]);
    }

    #[test]
    #[should_panic]
    fn zero_batches_panic() {
        lambdas_from_batches(&[0.0, 0.0]);
    }
}
