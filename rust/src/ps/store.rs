//! Parameter storage: the flattened model state the PS owns.
//!
//! Parameters live as one contiguous `Vec<f32>` (the AOT manifest fixes
//! the tensor order and shapes); per-tensor views are carved out of it by
//! offset.  The store also owns reusable gradient/aggregation buffers so
//! the training hot loop performs no allocation.

/// Shape/offset of one tensor inside the flat vector.
#[derive(Debug, Clone)]
pub struct TensorLayout {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// Flat parameter store with named tensor views.
#[derive(Debug, Clone)]
pub struct ParamStore {
    data: Vec<f32>,
    layout: Vec<TensorLayout>,
}

impl ParamStore {
    /// Build from (name, shape) pairs, zero-initialized.
    pub fn new(tensors: &[(String, Vec<usize>)]) -> Self {
        let mut layout = Vec::with_capacity(tensors.len());
        let mut offset = 0;
        for (name, shape) in tensors {
            let len = shape.iter().product::<usize>().max(1);
            layout.push(TensorLayout {
                name: name.clone(),
                shape: shape.clone(),
                offset,
                len,
            });
            offset += len;
        }
        ParamStore {
            data: vec![0.0; offset],
            layout,
        }
    }

    /// Load values from a flat f32 blob (the `<model>_init.bin` artifact).
    pub fn load_flat(&mut self, values: &[f32]) {
        assert_eq!(
            values.len(),
            self.data.len(),
            "init blob length {} != param total {}",
            values.len(),
            self.data.len()
        );
        self.data.copy_from_slice(values);
    }

    pub fn total_len(&self) -> usize {
        self.data.len()
    }

    pub fn num_tensors(&self) -> usize {
        self.layout.len()
    }

    pub fn layout(&self) -> &[TensorLayout] {
        &self.layout
    }

    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// View of tensor `i` in manifest order.
    pub fn tensor(&self, i: usize) -> &[f32] {
        let t = &self.layout[i];
        &self.data[t.offset..t.offset + t.len]
    }

    pub fn tensor_by_name(&self, name: &str) -> Option<&[f32]> {
        let t = self.layout.iter().find(|t| t.name == name)?;
        Some(&self.data[t.offset..t.offset + t.len])
    }

    /// L2 norm of the whole parameter vector (divergence monitoring).
    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// True if any parameter is NaN/Inf (blow-up detection).
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore::new(&[
            ("w".into(), vec![2, 3]),
            ("b".into(), vec![3]),
            ("scalar".into(), vec![]),
        ])
    }

    #[test]
    fn layout_offsets() {
        let s = store();
        assert_eq!(s.total_len(), 6 + 3 + 1);
        assert_eq!(s.num_tensors(), 3);
        assert_eq!(s.layout()[1].offset, 6);
        assert_eq!(s.layout()[2].len, 1); // scalar occupies one slot
    }

    #[test]
    fn load_and_view() {
        let mut s = store();
        let vals: Vec<f32> = (0..10).map(|i| i as f32).collect();
        s.load_flat(&vals);
        assert_eq!(s.tensor(0), &vals[0..6]);
        assert_eq!(s.tensor_by_name("b").unwrap(), &vals[6..9]);
        assert_eq!(s.tensor_by_name("scalar").unwrap(), &[9.0]);
        assert!(s.tensor_by_name("nope").is_none());
    }

    #[test]
    #[should_panic]
    fn load_wrong_length_panics() {
        store().load_flat(&[0.0; 3]);
    }

    #[test]
    fn norm_and_finiteness() {
        let mut s = ParamStore::new(&[("x".into(), vec![4])]);
        s.load_flat(&[3.0, 4.0, 0.0, 0.0]);
        assert!((s.l2_norm() - 5.0).abs() < 1e-9);
        assert!(!s.has_non_finite());
        s.flat_mut()[0] = f32::NAN;
        assert!(s.has_non_finite());
    }
}
