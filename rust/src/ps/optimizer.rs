//! Optimizers applied by the parameter server after aggregation.
//!
//! The paper's workloads use: ResNet — momentum with the step schedule
//! [0.1, 0.01, 0.001, 0.0002]; MNIST CNN — Adam(1e-4); LR — plain SGD.

/// Learning-rate schedule: piecewise-constant over *global iterations*
/// (the paper's ResNet schedule), or constant.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    Constant(f64),
    /// (boundary_iteration, lr) pairs: lr of the segment *starting* there.
    /// First boundary must be 0.
    Piecewise(Vec<(u64, f64)>),
}

impl LrSchedule {
    /// The paper's ResNet schedule over a total iteration budget: four
    /// equal segments at [0.1, 0.01, 0.001, 0.0002].
    pub fn resnet_paper(total_iters: u64) -> Self {
        let seg = (total_iters / 4).max(1);
        LrSchedule::Piecewise(vec![
            (0, 0.1),
            (seg, 0.01),
            (2 * seg, 0.001),
            (3 * seg, 0.0002),
        ])
    }

    pub fn at(&self, iter: u64) -> f64 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::Piecewise(segs) => {
                assert!(!segs.is_empty() && segs[0].0 == 0, "bad schedule");
                let mut lr = segs[0].1;
                for &(start, l) in segs {
                    if iter >= start {
                        lr = l;
                    } else {
                        break;
                    }
                }
                lr
            }
        }
    }
}

/// A stateful optimizer over the flattened parameter vector.
pub trait Optimizer: Send {
    /// In-place update of `params` given aggregated gradient `grad`.
    fn step(&mut self, params: &mut [f32], grad: &[f32]);
    /// Current iteration count (applied steps).
    fn iterations(&self) -> u64;
    fn name(&self) -> &'static str;
}

/// Plain SGD: x ← x − η·g.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub schedule: LrSchedule,
    t: u64,
}

impl Sgd {
    pub fn new(schedule: LrSchedule) -> Self {
        Sgd { schedule, t: 0 }
    }

    /// Advance the iteration counter (used by the fused kernels, which
    /// apply the update themselves).
    pub(crate) fn bump(&mut self) {
        self.t += 1;
    }

    /// Set the iteration counter (checkpoint restore).
    pub(crate) fn set_iterations(&mut self, t: u64) {
        self.t = t;
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        let lr = self.schedule.at(self.t) as f32;
        for (p, &g) in params.iter_mut().zip(grad) {
            *p -= lr * g;
        }
        self.t += 1;
    }

    fn iterations(&self) -> u64 {
        self.t
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Heavy-ball momentum (TF MomentumOptimizer semantics):
/// v ← μ·v + g;  x ← x − η·v.
#[derive(Debug, Clone)]
pub struct Momentum {
    pub schedule: LrSchedule,
    pub mu: f64,
    v: Vec<f32>,
    t: u64,
}

impl Momentum {
    pub fn new(schedule: LrSchedule, mu: f64, dim: usize) -> Self {
        Momentum {
            schedule,
            mu,
            v: vec![0.0; dim],
            t: 0,
        }
    }

    pub fn velocity(&self) -> &[f32] {
        &self.v
    }

    pub(crate) fn velocity_mut(&mut self) -> &mut [f32] {
        &mut self.v
    }

    pub(crate) fn bump(&mut self) {
        self.t += 1;
    }

    /// Set the iteration counter (checkpoint restore).
    pub(crate) fn set_iterations(&mut self, t: u64) {
        self.t = t;
    }
}

impl Optimizer for Momentum {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.v.len(), "dim mismatch with state");
        let lr = self.schedule.at(self.t) as f32;
        let mu = self.mu as f32;
        for ((p, v), &g) in params.iter_mut().zip(self.v.iter_mut()).zip(grad) {
            *v = mu * *v + g;
            *p -= lr * *v;
        }
        self.t += 1;
    }

    fn iterations(&self) -> u64 {
        self.t
    }

    fn name(&self) -> &'static str {
        "momentum"
    }
}

/// Adam (Kingma & Ba '15) with bias correction.
#[derive(Debug, Clone)]
pub struct Adam {
    pub schedule: LrSchedule,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(schedule: LrSchedule, dim: usize) -> Self {
        Adam {
            schedule,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }

    /// Paper's MNIST setting: Adam with lr 1e-4.
    pub fn paper_mnist(dim: usize) -> Self {
        Adam::new(LrSchedule::Constant(1e-4), dim)
    }

    pub fn m(&self) -> &[f32] {
        &self.m
    }

    pub fn v(&self) -> &[f32] {
        &self.v
    }

    pub(crate) fn state_mut(&mut self) -> (&mut [f32], &mut [f32]) {
        (&mut self.m, &mut self.v)
    }

    pub(crate) fn bump_to(&mut self, t: u64) {
        self.t = t;
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len(), "dim mismatch with state");
        self.t += 1;
        let lr = self.schedule.at(self.t - 1);
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let step = (lr * bc2.sqrt() / bc1) as f32;
        let (b1, b2) = (b1 as f32, b2 as f32);
        let eps = self.eps as f32;
        for ((p, (m, v)), &g) in params
            .iter_mut()
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
            .zip(grad)
        {
            *m = b1 * *m + (1.0 - b1) * g;
            *v = b2 * *v + (1.0 - b2) * g * g;
            *p -= step * *m / (v.sqrt() + eps);
        }
    }

    fn iterations(&self) -> u64 {
        self.t
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

/// Build the optimizer a workload uses in the paper.
pub fn for_workload(name: &str, dim: usize, total_iters: u64) -> Box<dyn Optimizer> {
    match name {
        "resnet" | "cnn" => Box::new(Momentum::new(
            LrSchedule::resnet_paper(total_iters),
            0.9,
            dim,
        )),
        "mnist" | "mlp" => Box::new(Adam::paper_mnist(dim)),
        "transformer" | "transformer_e2e" => {
            Box::new(Adam::new(LrSchedule::Constant(3e-4), dim))
        }
        _ => Box::new(Sgd::new(LrSchedule::Constant(0.05))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_constant() {
        let s = LrSchedule::Constant(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(1_000_000), 0.1);
    }

    #[test]
    fn schedule_piecewise_resnet() {
        let s = LrSchedule::resnet_paper(40_000);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(9_999), 0.1);
        assert_eq!(s.at(10_000), 0.01);
        assert_eq!(s.at(20_000), 0.001);
        assert_eq!(s.at(39_999), 0.0002);
    }

    #[test]
    fn sgd_exact_step() {
        let mut opt = Sgd::new(LrSchedule::Constant(0.5));
        let mut p = vec![1.0f32, -2.0];
        opt.step(&mut p, &[2.0, 2.0]);
        assert_eq!(p, vec![0.0, -3.0]);
        assert_eq!(opt.iterations(), 1);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = Momentum::new(LrSchedule::Constant(1.0), 0.5, 1);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0]); // v=1,   p=-1
        opt.step(&mut p, &[1.0]); // v=1.5, p=-2.5
        opt.step(&mut p, &[1.0]); // v=1.75 p=-4.25
        assert!((p[0] + 4.25).abs() < 1e-6, "p={p:?}");
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // With bias correction the first Adam step ≈ lr·sign(g).
        let mut opt = Adam::new(LrSchedule::Constant(0.001), 2);
        let mut p = vec![0.0f32, 0.0];
        opt.step(&mut p, &[0.5, -3.0]);
        assert!((p[0] + 0.001).abs() < 1e-5, "{p:?}");
        assert!((p[1] - 0.001).abs() < 1e-5, "{p:?}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // min ½x² — gradient x; Adam should get close to 0 from 5.
        let mut opt = Adam::new(LrSchedule::Constant(0.1), 1);
        let mut p = vec![5.0f32];
        for _ in 0..500 {
            let g = p[0];
            opt.step(&mut p, &[g]);
        }
        assert!(p[0].abs() < 0.05, "p={p:?}");
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut opt = Sgd::new(LrSchedule::Constant(0.1));
        let mut p = vec![5.0f32];
        for _ in 0..200 {
            let g = p[0];
            opt.step(&mut p, &[g]);
        }
        assert!(p[0].abs() < 1e-3);
    }

    #[test]
    fn workload_mapping() {
        assert_eq!(for_workload("resnet", 4, 100).name(), "momentum");
        assert_eq!(for_workload("mnist", 4, 100).name(), "adam");
        assert_eq!(for_workload("linreg", 4, 100).name(), "sgd");
    }

    #[test]
    #[should_panic]
    fn momentum_dim_mismatch_panics() {
        let mut opt = Momentum::new(LrSchedule::Constant(0.1), 0.9, 3);
        let mut p = vec![0.0f32; 2];
        opt.step(&mut p, &[1.0, 1.0]);
    }
}
