//! Measurement: iteration records, per-worker timelines, batch-size
//! traces, and the training report the figure harnesses consume.

use crate::trace::MembershipKind;
use crate::util::json::Json;
use crate::util::stats::{percentile, Running};

/// One completed worker iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct IterRecord {
    pub worker: usize,
    pub iter: u64,
    /// Virtual or wall time when the iteration started (seconds).
    pub start: f64,
    pub duration: f64,
    pub batch: f64,
    /// Seconds spent waiting at the barrier after computing (BSP).
    pub wait: f64,
}

/// One periodic evaluation (`StepKind::Eval`) during a real run.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalRecord {
    /// Wall time of the eval (seconds since run start).
    pub time: f64,
    /// Global step the eval ran after.
    pub iter: u64,
    pub loss: f64,
    /// Task metric: accuracy (classification/LM) or MSE (regression).
    pub metric: f64,
}

/// A batch readjustment event.
#[derive(Debug, Clone, PartialEq)]
pub struct AdjustEvent {
    pub time: f64,
    pub iter: u64,
    pub batches: Vec<f64>,
    /// Cost charged for applying it (restart / executable swap).
    pub cost: f64,
}

/// One membership-epoch transition (a worker revoked or (re)joined).
#[derive(Debug, Clone, PartialEq)]
pub struct EpochEvent {
    /// Virtual/wall time of the transition.
    pub time: f64,
    /// Epoch number after the transition (epoch 0 is the initial
    /// membership; the first transition opens epoch 1).
    pub epoch: u64,
    pub worker: usize,
    pub kind: MembershipKind,
    /// Live workers after the transition.
    pub live: usize,
    /// Batch allocation after the rebalance (0 for absent ranks).
    pub batches: Vec<f64>,
}

/// What the failure detector decided about a worker (DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DetectorAction {
    /// Missed its progress deadline: provisionally retired.
    Suspect,
    /// Late completion arrived under `late=readmit`: rejoined.
    Readmit,
}

impl DetectorAction {
    pub fn label(&self) -> &'static str {
        match self {
            DetectorAction::Suspect => "suspect",
            DetectorAction::Readmit => "readmit",
        }
    }
}

/// One failure-detector decision.
#[derive(Debug, Clone, PartialEq)]
pub struct DetectorEvent {
    pub time: f64,
    pub worker: usize,
    pub action: DetectorAction,
}

/// One autoscaler provisioning step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpawnAction {
    /// Spawn request accepted; cold start begins.
    Request,
    /// Spawn attempt failed; backoff scheduled.
    Fail,
    /// Cold start finished; replacement joined the fleet.
    Ready,
    /// Retry budget exhausted; autoscaler stopped trying.
    GaveUp,
    /// Replacement became ready but no rank needed it (e.g. the
    /// suspected worker was readmitted first): capacity paid for
    /// nothing — the cost-vs-time curves count these.
    Wasted,
}

impl SpawnAction {
    pub fn label(&self) -> &'static str {
        match self {
            SpawnAction::Request => "request",
            SpawnAction::Fail => "fail",
            SpawnAction::Ready => "ready",
            SpawnAction::GaveUp => "gave_up",
            SpawnAction::Wasted => "wasted",
        }
    }
}

/// One autoscaler event (provisioning requests, failures, joins).
#[derive(Debug, Clone, PartialEq)]
pub struct SpawnEvent {
    pub time: f64,
    /// Rank the event concerns (None for pool-level events like a
    /// failed attempt or give-up).
    pub worker: Option<usize>,
    pub action: SpawnAction,
    /// Consecutive failed attempts at the time of the event.
    pub attempt: u32,
}

/// What the data-plane update guard decided (DESIGN.md §16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GuardAction {
    /// A staged contribution failed the finite/norm gate and was
    /// dropped from its round.
    Reject,
    /// Strike budget spent: the worker was retired through the
    /// revocation path and its probation timer armed.
    Quarantine,
    /// Probation expired: the worker rejoined through the join path.
    Readmit,
}

impl GuardAction {
    pub fn label(&self) -> &'static str {
        match self {
            GuardAction::Reject => "reject",
            GuardAction::Quarantine => "quarantine",
            GuardAction::Readmit => "readmit",
        }
    }
}

/// One update-guard decision (rejection or quarantine-lifecycle step).
#[derive(Debug, Clone, PartialEq)]
pub struct GuardEvent {
    pub time: f64,
    pub worker: usize,
    pub action: GuardAction,
}

/// Complete record of one training run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunReport {
    pub label: String,
    pub iters: Vec<IterRecord>,
    pub adjustments: Vec<AdjustEvent>,
    /// Membership-epoch transitions (spot revocations / mid-run joins).
    pub epochs: Vec<EpochEvent>,
    /// Failure-detector decisions (suspicions and readmissions).
    pub suspicions: Vec<DetectorEvent>,
    /// Autoscaler provisioning events.
    pub spawns: Vec<SpawnEvent>,
    /// Update-guard rejections (contributions dropped from a round).
    pub rejections: Vec<GuardEvent>,
    /// Update-guard quarantine lifecycle (quarantines and probation
    /// readmissions).
    pub quarantines: Vec<GuardEvent>,
    /// (time, global_iter, loss) samples — real-execution runs only.
    pub losses: Vec<(f64, u64, f64)>,
    /// Periodic eval results (`SessionBuilder::eval_every`) — real runs only.
    pub evals: Vec<EvalRecord>,
    /// Total time to completion/target (seconds, virtual or wall).
    pub total_time: f64,
    /// Global iterations executed.
    pub total_iters: u64,
    /// True if the run reached its accuracy/loss target.
    pub reached_target: bool,
}

impl RunReport {
    pub fn new(label: &str) -> Self {
        RunReport {
            label: label.to_string(),
            ..Default::default()
        }
    }

    /// Field-by-field bitwise equality — the fleet isolation
    /// invariant's comparator (a fleet-run job must match the same job
    /// run standalone *exactly*, not approximately).  Plain `==` over
    /// every record; f64 fields compare by value, and no report field
    /// is ever NaN.
    pub fn bitwise_eq(&self, other: &RunReport) -> bool {
        self == other
    }

    /// Autoscaler spawn requests accepted over the run (cold starts
    /// begun) — the fleet's per-job provisioning-demand accounting.
    pub fn spawn_requests(&self) -> u64 {
        self.spawns
            .iter()
            .filter(|s| s.action == SpawnAction::Request)
            .count() as u64
    }

    /// Replacements that became ready but were never needed: capacity
    /// paid for nothing.  Summed fleet-wide in the `FleetReport`.
    pub fn wasted_spawns(&self) -> u64 {
        self.spawns
            .iter()
            .filter(|s| s.action == SpawnAction::Wasted)
            .count() as u64
    }

    /// Contributions the update guard dropped from their rounds.
    /// Summed fleet-wide in the `FleetReport`.
    pub fn guard_rejections(&self) -> u64 {
        self.rejections.len() as u64
    }

    /// Workers the guard quarantined (readmissions not counted).
    /// Summed fleet-wide in the `FleetReport`.
    pub fn guard_quarantines(&self) -> u64 {
        self.quarantines
            .iter()
            .filter(|q| q.action == GuardAction::Quarantine)
            .count() as u64
    }

    /// Per-worker iteration-time statistics.
    pub fn worker_time_stats(&self, k: usize) -> Vec<Running> {
        let mut out = vec![Running::new(); k];
        for r in &self.iters {
            out[r.worker].push(r.duration);
        }
        out
    }

    /// Per-worker iteration durations (for histograms).
    pub fn worker_durations(&self, worker: usize) -> Vec<f64> {
        self.iters
            .iter()
            .filter(|r| r.worker == worker)
            .map(|r| r.duration)
            .collect()
    }

    /// Fraction of total worker-time spent waiting at barriers — the
    /// parallel-efficiency loss stragglers cause under BSP.
    pub fn wait_fraction(&self) -> f64 {
        let busy: f64 = self.iters.iter().map(|r| r.duration).sum();
        let wait: f64 = self.iters.iter().map(|r| r.wait).sum();
        if busy + wait == 0.0 {
            0.0
        } else {
            wait / (busy + wait)
        }
    }

    /// p95 of the spread (max−min)/mean of concurrent iteration times —
    /// the "iteration gap" dynamic batching tries to close.
    pub fn iteration_gap(&self, k: usize) -> f64 {
        // Group by iter index.
        let max_iter = self.iters.iter().map(|r| r.iter).max().unwrap_or(0);
        let mut gaps = Vec::new();
        let mut per_iter: Vec<Vec<f64>> = vec![Vec::new(); (max_iter + 1) as usize];
        for r in &self.iters {
            per_iter[r.iter as usize].push(r.duration);
        }
        for times in per_iter.iter().filter(|t| t.len() == k) {
            let mx = times.iter().cloned().fold(f64::MIN, f64::max);
            let mn = times.iter().cloned().fold(f64::MAX, f64::min);
            let mean = times.iter().sum::<f64>() / times.len() as f64;
            gaps.push((mx - mn) / mean);
        }
        if gaps.is_empty() {
            0.0
        } else {
            percentile(&mut gaps, 0.95)
        }
    }

    /// Final batch allocation: the latest of the last controller
    /// adjustment and the last membership rebalance (None when neither
    /// happened).
    pub fn final_batches(&self) -> Option<&[f64]> {
        match (self.adjustments.last(), self.epochs.last()) {
            (Some(a), Some(e)) => Some(if e.time >= a.time {
                e.batches.as_slice()
            } else {
                a.batches.as_slice()
            }),
            (Some(a), None) => Some(a.batches.as_slice()),
            (None, Some(e)) => Some(e.batches.as_slice()),
            (None, None) => None,
        }
    }

    pub fn to_json(&self, k: usize) -> Json {
        let mut o = Json::obj();
        o.set("label", Json::Str(self.label.clone()));
        o.set("total_time_s", Json::Num(self.total_time));
        o.set("total_iters", Json::Num(self.total_iters as f64));
        o.set("reached_target", Json::Bool(self.reached_target));
        o.set("wait_fraction", Json::Num(self.wait_fraction()));
        o.set("n_adjustments", Json::Num(self.adjustments.len() as f64));
        o.set("n_epochs", Json::Num(self.epochs.len() as f64));
        if !self.epochs.is_empty() {
            let evs: Vec<Json> = self
                .epochs
                .iter()
                .map(|e| {
                    let mut eo = Json::obj();
                    eo.set("time_s", Json::Num(e.time));
                    eo.set("epoch", Json::Num(e.epoch as f64));
                    eo.set("worker", Json::Num(e.worker as f64));
                    eo.set("kind", Json::Str(e.kind.label().into()));
                    eo.set("live", Json::Num(e.live as f64));
                    eo.set(
                        "batches",
                        Json::Arr(e.batches.iter().map(|&b| Json::Num(b)).collect()),
                    );
                    eo
                })
                .collect();
            o.set("epochs", Json::Arr(evs));
        }
        if !self.suspicions.is_empty() {
            let evs: Vec<Json> = self
                .suspicions
                .iter()
                .map(|e| {
                    let mut eo = Json::obj();
                    eo.set("time_s", Json::Num(e.time));
                    eo.set("worker", Json::Num(e.worker as f64));
                    eo.set("action", Json::Str(e.action.label().into()));
                    eo
                })
                .collect();
            o.set("suspicions", Json::Arr(evs));
        }
        if !self.spawns.is_empty() {
            let evs: Vec<Json> = self
                .spawns
                .iter()
                .map(|e| {
                    let mut eo = Json::obj();
                    eo.set("time_s", Json::Num(e.time));
                    if let Some(w) = e.worker {
                        eo.set("worker", Json::Num(w as f64));
                    }
                    eo.set("action", Json::Str(e.action.label().into()));
                    eo.set("attempt", Json::Num(e.attempt as f64));
                    eo
                })
                .collect();
            o.set("spawns", Json::Arr(evs));
        }
        let guard_evs = |evs: &[GuardEvent]| -> Json {
            Json::Arr(
                evs.iter()
                    .map(|e| {
                        let mut eo = Json::obj();
                        eo.set("time_s", Json::Num(e.time));
                        eo.set("worker", Json::Num(e.worker as f64));
                        eo.set("action", Json::Str(e.action.label().into()));
                        eo
                    })
                    .collect(),
            )
        };
        if !self.rejections.is_empty() {
            o.set("rejections", guard_evs(&self.rejections));
        }
        if !self.quarantines.is_empty() {
            o.set("quarantines", guard_evs(&self.quarantines));
        }
        let stats = self.worker_time_stats(k);
        let mut workers = Vec::new();
        for (w, s) in stats.iter().enumerate() {
            let mut wo = Json::obj();
            wo.set("worker", Json::Num(w as f64));
            wo.set("mean_iter_s", Json::Num(s.mean()));
            wo.set("std_iter_s", Json::Num(s.std()));
            wo.set("n", Json::Num(s.n() as f64));
            workers.push(wo);
        }
        o.set("workers", Json::Arr(workers));
        if !self.losses.is_empty() {
            let pts: Vec<Json> = self
                .losses
                .iter()
                .map(|&(t, i, l)| {
                    Json::Arr(vec![Json::Num(t), Json::Num(i as f64), Json::Num(l)])
                })
                .collect();
            o.set("loss_curve", Json::Arr(pts));
        }
        if !self.evals.is_empty() {
            let pts: Vec<Json> = self
                .evals
                .iter()
                .map(|e| {
                    let mut eo = Json::obj();
                    eo.set("time_s", Json::Num(e.time));
                    eo.set("iter", Json::Num(e.iter as f64));
                    eo.set("loss", Json::Num(e.loss));
                    eo.set("metric", Json::Num(e.metric));
                    eo
                })
                .collect();
            o.set("evals", Json::Arr(pts));
        }
        o
    }

    /// Exact checkpoint serializer (DESIGN.md §15).  Unlike
    /// [`RunReport::to_json`] — a lossy human-facing summary — this
    /// round-trips *every* field through the `ckpt` codec so a resumed
    /// run's report-so-far is bitwise-identical.  Records use compact
    /// positional arrays: iteration logs dominate checkpoint size.
    pub fn snapshot(&self) -> Json {
        use crate::ckpt::{enc_f64, enc_u64};
        let mut o = Json::obj();
        o.set("label", Json::Str(self.label.clone()));
        o.set(
            "iters",
            Json::Arr(
                self.iters
                    .iter()
                    .map(|r| {
                        Json::Arr(vec![
                            Json::Num(r.worker as f64),
                            enc_u64(r.iter),
                            enc_f64(r.start),
                            enc_f64(r.duration),
                            enc_f64(r.batch),
                            enc_f64(r.wait),
                        ])
                    })
                    .collect(),
            ),
        );
        o.set(
            "adjustments",
            Json::Arr(
                self.adjustments
                    .iter()
                    .map(|a| {
                        Json::Arr(vec![
                            enc_f64(a.time),
                            enc_u64(a.iter),
                            Json::Arr(a.batches.iter().map(|&b| enc_f64(b)).collect()),
                            enc_f64(a.cost),
                        ])
                    })
                    .collect(),
            ),
        );
        o.set(
            "epochs",
            Json::Arr(
                self.epochs
                    .iter()
                    .map(|e| {
                        Json::Arr(vec![
                            enc_f64(e.time),
                            enc_u64(e.epoch),
                            Json::Num(e.worker as f64),
                            Json::Str(e.kind.label().into()),
                            Json::Num(e.live as f64),
                            Json::Arr(e.batches.iter().map(|&b| enc_f64(b)).collect()),
                        ])
                    })
                    .collect(),
            ),
        );
        o.set(
            "suspicions",
            Json::Arr(
                self.suspicions
                    .iter()
                    .map(|e| {
                        Json::Arr(vec![
                            enc_f64(e.time),
                            Json::Num(e.worker as f64),
                            Json::Str(e.action.label().into()),
                        ])
                    })
                    .collect(),
            ),
        );
        o.set(
            "spawns",
            Json::Arr(
                self.spawns
                    .iter()
                    .map(|e| {
                        Json::Arr(vec![
                            enc_f64(e.time),
                            match e.worker {
                                Some(w) => Json::Num(w as f64),
                                None => Json::Null,
                            },
                            Json::Str(e.action.label().into()),
                            Json::Num(e.attempt as f64),
                        ])
                    })
                    .collect(),
            ),
        );
        let guard_evs = |evs: &[GuardEvent]| -> Json {
            Json::Arr(
                evs.iter()
                    .map(|e| {
                        Json::Arr(vec![
                            enc_f64(e.time),
                            Json::Num(e.worker as f64),
                            Json::Str(e.action.label().into()),
                        ])
                    })
                    .collect(),
            )
        };
        o.set("rejections", guard_evs(&self.rejections));
        o.set("quarantines", guard_evs(&self.quarantines));
        o.set(
            "losses",
            Json::Arr(
                self.losses
                    .iter()
                    .map(|&(t, i, l)| Json::Arr(vec![enc_f64(t), enc_u64(i), enc_f64(l)]))
                    .collect(),
            ),
        );
        o.set(
            "evals",
            Json::Arr(
                self.evals
                    .iter()
                    .map(|e| {
                        Json::Arr(vec![
                            enc_f64(e.time),
                            enc_u64(e.iter),
                            enc_f64(e.loss),
                            enc_f64(e.metric),
                        ])
                    })
                    .collect(),
            ),
        );
        o.set("total_time", enc_f64(self.total_time));
        o.set("total_iters", enc_u64(self.total_iters));
        o.set("reached_target", Json::Bool(self.reached_target));
        o
    }

    /// Rebuild from a [`RunReport::snapshot`].
    pub fn restore(j: &Json) -> Result<RunReport, String> {
        use crate::ckpt::{dec_f64, dec_u64, dec_usize};
        fn arr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json], String> {
            j.get(key)
                .as_arr()
                .ok_or(format!("report snapshot: missing {key:?} array"))
        }
        fn f64s(j: &Json, what: &str) -> Result<Vec<f64>, String> {
            j.as_arr()
                .ok_or(format!("report snapshot: {what} is not an array"))?
                .iter()
                .map(dec_f64)
                .collect()
        }
        let mut r = RunReport::new(
            j.get("label")
                .as_str()
                .ok_or("report snapshot: missing label")?,
        );
        for it in arr(j, "iters")? {
            r.iters.push(IterRecord {
                worker: dec_usize(it.idx(0))?,
                iter: dec_u64(it.idx(1))?,
                start: dec_f64(it.idx(2))?,
                duration: dec_f64(it.idx(3))?,
                batch: dec_f64(it.idx(4))?,
                wait: dec_f64(it.idx(5))?,
            });
        }
        for a in arr(j, "adjustments")? {
            r.adjustments.push(AdjustEvent {
                time: dec_f64(a.idx(0))?,
                iter: dec_u64(a.idx(1))?,
                batches: f64s(a.idx(2), "adjustment batches")?,
                cost: dec_f64(a.idx(3))?,
            });
        }
        for e in arr(j, "epochs")? {
            let kind = match e.idx(3).as_str() {
                Some("revoke") => MembershipKind::Revoke,
                Some("join") => MembershipKind::Join,
                other => {
                    return Err(format!("report snapshot: bad epoch kind {other:?}"))
                }
            };
            r.epochs.push(EpochEvent {
                time: dec_f64(e.idx(0))?,
                epoch: dec_u64(e.idx(1))?,
                worker: dec_usize(e.idx(2))?,
                kind,
                live: dec_usize(e.idx(4))?,
                batches: f64s(e.idx(5), "epoch batches")?,
            });
        }
        for s in arr(j, "suspicions")? {
            let action = match s.idx(2).as_str() {
                Some("suspect") => DetectorAction::Suspect,
                Some("readmit") => DetectorAction::Readmit,
                other => {
                    return Err(format!("report snapshot: bad detector action {other:?}"))
                }
            };
            r.suspicions.push(DetectorEvent {
                time: dec_f64(s.idx(0))?,
                worker: dec_usize(s.idx(1))?,
                action,
            });
        }
        for s in arr(j, "spawns")? {
            let worker = match s.idx(1) {
                Json::Null => None,
                w => Some(dec_usize(w)?),
            };
            let action = match s.idx(2).as_str() {
                Some("request") => SpawnAction::Request,
                Some("fail") => SpawnAction::Fail,
                Some("ready") => SpawnAction::Ready,
                Some("gave_up") => SpawnAction::GaveUp,
                Some("wasted") => SpawnAction::Wasted,
                other => {
                    return Err(format!("report snapshot: bad spawn action {other:?}"))
                }
            };
            r.spawns.push(SpawnEvent {
                time: dec_f64(s.idx(0))?,
                worker,
                action,
                attempt: dec_usize(s.idx(3))? as u32,
            });
        }
        let guard_evs = |key: &str| -> Result<Vec<GuardEvent>, String> {
            let mut out = Vec::new();
            for g in arr(j, key)? {
                let action = match g.idx(2).as_str() {
                    Some("reject") => GuardAction::Reject,
                    Some("quarantine") => GuardAction::Quarantine,
                    Some("readmit") => GuardAction::Readmit,
                    other => {
                        return Err(format!("report snapshot: bad guard action {other:?}"))
                    }
                };
                out.push(GuardEvent {
                    time: dec_f64(g.idx(0))?,
                    worker: dec_usize(g.idx(1))?,
                    action,
                });
            }
            Ok(out)
        };
        r.rejections = guard_evs("rejections")?;
        r.quarantines = guard_evs("quarantines")?;
        for l in arr(j, "losses")? {
            r.losses
                .push((dec_f64(l.idx(0))?, dec_u64(l.idx(1))?, dec_f64(l.idx(2))?));
        }
        for e in arr(j, "evals")? {
            r.evals.push(EvalRecord {
                time: dec_f64(e.idx(0))?,
                iter: dec_u64(e.idx(1))?,
                loss: dec_f64(e.idx(2))?,
                metric: dec_f64(e.idx(3))?,
            });
        }
        r.total_time = dec_f64(j.get("total_time"))?;
        r.total_iters = dec_u64(j.get("total_iters"))?;
        r.reached_target = j
            .get("reached_target")
            .as_bool()
            .ok_or("report snapshot: reached_target is not a bool")?;
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(worker: usize, iter: u64, dur: f64, wait: f64) -> IterRecord {
        IterRecord {
            worker,
            iter,
            start: 0.0,
            duration: dur,
            batch: 32.0,
            wait,
        }
    }

    #[test]
    fn wait_fraction_zero_when_balanced() {
        let mut r = RunReport::new("t");
        r.iters.push(rec(0, 0, 1.0, 0.0));
        r.iters.push(rec(1, 0, 1.0, 0.0));
        assert_eq!(r.wait_fraction(), 0.0);
    }

    #[test]
    fn wait_fraction_counts_straggler_cost() {
        let mut r = RunReport::new("t");
        r.iters.push(rec(0, 0, 1.0, 3.0)); // fast worker waits 3s
        r.iters.push(rec(1, 0, 4.0, 0.0)); // straggler
        assert!((r.wait_fraction() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn iteration_gap_measures_spread() {
        let mut r = RunReport::new("t");
        for i in 0..10 {
            r.iters.push(rec(0, i, 1.0, 0.0));
            r.iters.push(rec(1, i, 3.0, 0.0));
        }
        // (3-1)/2 = 1.0 on every iteration.
        assert!((r.iteration_gap(2) - 1.0).abs() < 1e-9);
        let mut balanced = RunReport::new("b");
        for i in 0..10 {
            balanced.iters.push(rec(0, i, 2.0, 0.0));
            balanced.iters.push(rec(1, i, 2.0, 0.0));
        }
        assert!(balanced.iteration_gap(2) < 1e-9);
    }

    #[test]
    fn per_worker_stats() {
        let mut r = RunReport::new("t");
        r.iters.push(rec(0, 0, 1.0, 0.0));
        r.iters.push(rec(0, 1, 2.0, 0.0));
        r.iters.push(rec(1, 0, 5.0, 0.0));
        let stats = r.worker_time_stats(2);
        assert_eq!(stats[0].n(), 2);
        assert!((stats[0].mean() - 1.5).abs() < 1e-12);
        assert_eq!(stats[1].n(), 1);
        assert_eq!(r.worker_durations(1), vec![5.0]);
    }

    #[test]
    fn final_batches_prefers_latest_of_adjust_and_epoch() {
        let mut r = RunReport::new("t");
        assert!(r.final_batches().is_none());
        r.adjustments.push(AdjustEvent {
            time: 10.0,
            iter: 3,
            batches: vec![20.0, 44.0],
            cost: 0.0,
        });
        assert_eq!(r.final_batches().unwrap(), &[20.0, 44.0]);
        r.epochs.push(EpochEvent {
            time: 15.0,
            epoch: 1,
            worker: 0,
            kind: MembershipKind::Revoke,
            live: 1,
            batches: vec![0.0, 64.0],
        });
        assert_eq!(r.final_batches().unwrap(), &[0.0, 64.0]);
    }

    #[test]
    fn epochs_serialize_to_json() {
        let mut r = RunReport::new("t");
        let j = r.to_json(1);
        assert_eq!(j.get("n_epochs").as_i64(), Some(0));
        assert!(j.get("epochs").is_null());
        r.epochs.push(EpochEvent {
            time: 2.5,
            epoch: 1,
            worker: 2,
            kind: MembershipKind::Join,
            live: 3,
            batches: vec![32.0, 32.0, 32.0],
        });
        let j = Json::parse(&r.to_json(3).to_string()).unwrap();
        let e = j.get("epochs").idx(0);
        assert_eq!(e.get("kind").as_str(), Some("join"));
        assert_eq!(e.get("worker").as_i64(), Some(2));
        assert_eq!(e.get("live").as_i64(), Some(3));
        assert_eq!(e.get("batches").idx(1).as_f64(), Some(32.0));
    }

    #[test]
    fn detector_and_spawn_events_serialize_to_json() {
        let mut r = RunReport::new("t");
        let j = r.to_json(1);
        assert!(j.get("suspicions").is_null());
        assert!(j.get("spawns").is_null());
        r.suspicions.push(DetectorEvent {
            time: 3.0,
            worker: 1,
            action: DetectorAction::Suspect,
        });
        r.spawns.push(SpawnEvent {
            time: 4.0,
            worker: None,
            action: SpawnAction::Fail,
            attempt: 2,
        });
        r.spawns.push(SpawnEvent {
            time: 9.0,
            worker: Some(1),
            action: SpawnAction::Ready,
            attempt: 0,
        });
        let j = Json::parse(&r.to_json(2).to_string()).unwrap();
        let s = j.get("suspicions").idx(0);
        assert_eq!(s.get("action").as_str(), Some("suspect"));
        assert_eq!(s.get("worker").as_i64(), Some(1));
        let f = j.get("spawns").idx(0);
        assert_eq!(f.get("action").as_str(), Some("fail"));
        assert!(f.get("worker").is_null());
        assert_eq!(f.get("attempt").as_i64(), Some(2));
        assert_eq!(j.get("spawns").idx(1).get("action").as_str(), Some("ready"));
    }

    #[test]
    fn guard_events_serialize_to_json_and_count() {
        let mut r = RunReport::new("t");
        let j = r.to_json(1);
        assert!(j.get("rejections").is_null());
        assert!(j.get("quarantines").is_null());
        r.rejections.push(GuardEvent {
            time: 3.0,
            worker: 1,
            action: GuardAction::Reject,
        });
        r.quarantines.push(GuardEvent {
            time: 4.0,
            worker: 1,
            action: GuardAction::Quarantine,
        });
        r.quarantines.push(GuardEvent {
            time: 9.0,
            worker: 1,
            action: GuardAction::Readmit,
        });
        assert_eq!(r.guard_rejections(), 1);
        assert_eq!(r.guard_quarantines(), 1); // readmit not counted
        let j = Json::parse(&r.to_json(2).to_string()).unwrap();
        let rej = j.get("rejections").idx(0);
        assert_eq!(rej.get("action").as_str(), Some("reject"));
        assert_eq!(rej.get("worker").as_i64(), Some(1));
        assert_eq!(j.get("quarantines").idx(0).get("action").as_str(), Some("quarantine"));
        assert_eq!(j.get("quarantines").idx(1).get("action").as_str(), Some("readmit"));
    }

    #[test]
    fn ckpt_snapshot_round_trips_every_field_bitwise() {
        let mut r = RunReport::new("ckpt");
        // Awkward values on purpose: non-terminating binary fractions,
        // a u64 above 2^53, and every optional/enum variant.
        r.iters.push(IterRecord {
            worker: 3,
            iter: (1u64 << 53) + 7,
            start: 0.1 + 0.2,
            duration: 1.0 / 3.0,
            batch: 42.7,
            wait: f64::MIN_POSITIVE,
        });
        r.adjustments.push(AdjustEvent {
            time: 9.999999999999998,
            iter: 4,
            batches: vec![21.350000000000001, 42.65],
            cost: 0.0,
        });
        r.epochs.push(EpochEvent {
            time: 2.5,
            epoch: 1,
            worker: 0,
            kind: MembershipKind::Revoke,
            live: 2,
            batches: vec![0.0, 64.0],
        });
        r.epochs.push(EpochEvent {
            time: 3.5,
            epoch: 2,
            worker: 0,
            kind: MembershipKind::Join,
            live: 3,
            batches: vec![21.0, 43.0],
        });
        r.suspicions.push(DetectorEvent {
            time: 1.0,
            worker: 1,
            action: DetectorAction::Suspect,
        });
        r.suspicions.push(DetectorEvent {
            time: 2.0,
            worker: 1,
            action: DetectorAction::Readmit,
        });
        for (i, action) in [
            SpawnAction::Request,
            SpawnAction::Fail,
            SpawnAction::Ready,
            SpawnAction::GaveUp,
            SpawnAction::Wasted,
        ]
        .into_iter()
        .enumerate()
        {
            r.spawns.push(SpawnEvent {
                time: i as f64 + 0.25,
                worker: if i % 2 == 0 { Some(i) } else { None },
                action,
                attempt: i as u32,
            });
        }
        r.rejections.push(GuardEvent {
            time: 0.75,
            worker: 2,
            action: GuardAction::Reject,
        });
        for (i, action) in [GuardAction::Quarantine, GuardAction::Readmit]
            .into_iter()
            .enumerate()
        {
            r.quarantines.push(GuardEvent {
                time: 1.25 + i as f64,
                worker: 2,
                action,
            });
        }
        r.losses.push((1.5, 10, 0.123456789012345678));
        r.evals.push(EvalRecord {
            time: 2.0,
            iter: 5,
            loss: 0.4,
            metric: 0.9,
        });
        r.total_time = 123.45600000000002;
        r.total_iters = 9_007_199_254_740_993; // 2^53 + 1
        r.reached_target = true;
        // Through actual serialized text, not just the Json tree.
        let text = r.snapshot().to_pretty();
        let back = RunReport::restore(&Json::parse(&text).unwrap()).unwrap();
        assert!(r.bitwise_eq(&back), "report changed across the codec");
        // An empty report round-trips too (the satellite's no-loss case).
        let empty = RunReport::new("empty");
        let back =
            RunReport::restore(&Json::parse(&empty.snapshot().to_pretty()).unwrap()).unwrap();
        assert!(empty.bitwise_eq(&back));
    }

    #[test]
    fn json_roundtrip_has_fields() {
        let mut r = RunReport::new("run1");
        r.total_time = 12.5;
        r.total_iters = 10;
        r.reached_target = true;
        r.losses.push((1.0, 1, 0.5));
        r.iters.push(rec(0, 0, 1.0, 0.0));
        r.evals.push(EvalRecord {
            time: 2.0,
            iter: 5,
            loss: 0.4,
            metric: 0.9,
        });
        let j = r.to_json(1);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("label").as_str(), Some("run1"));
        assert_eq!(parsed.get("total_time_s").as_f64(), Some(12.5));
        assert_eq!(parsed.get("reached_target").as_bool(), Some(true));
        assert_eq!(parsed.get("loss_curve").idx(0).idx(2).as_f64(), Some(0.5));
        assert_eq!(parsed.get("evals").idx(0).get("metric").as_f64(), Some(0.9));
    }
}
