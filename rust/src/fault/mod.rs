//! Fault injection, failure detection, and autoscaled recovery
//! (DESIGN.md §12).
//!
//! Every churn scenario before this module was *oracle-driven*: a
//! [`crate::trace::MembershipPlan`] tells the session about revocations
//! in advance.  Real spot fleets only learn a worker is gone when it
//! stops making progress.  This module supplies the three pieces that
//! close that gap:
//!
//! - [`FaultPlan`] — scripted failures injected into a run *without*
//!   telling the membership machinery: unannounced crashes, mid-run
//!   stalls, transient slowdown spikes, and (DESIGN.md §16) data-plane
//!   corruption of the update payload itself.  Timing faults (stall,
//!   slow) and corruptions are applied by the backend via
//!   [`crate::session::Backend::set_fault_plan`];
//!   a crash is the *absence* of an outcome, so the session enforces it
//!   by suppressing the completion event — only the detector below can
//!   reclaim the rank.
//! - [`GuardCfg`] / [`UpdateGuard`] — the data-plane guard (DESIGN.md
//!   §16): validates every staged worker contribution *before* the leaf
//!   enters the eager combine — a finite check plus a robust norm gate
//!   (median + MAD over a window of recently accepted update norms).  A
//!   rejection drops that worker's round contribution through the
//!   drop-contribution/λ-renormalization path; repeated strikes
//!   escalate to quarantine through the detector-retire path, with a
//!   probation timer readmitting through the join path.
//! - [`DetectorCfg`] — the progress-deadline failure detector the
//!   session event loop arms at every dispatch: a worker that misses
//!   `max(floor, grace × smoothed-iteration-time)` is *suspected* and
//!   provisionally retired through the plan-revocation path.  A false
//!   suspicion is survivable: under [`LatePolicy::Readmit`] the late
//!   completion readmits the worker like a scheduled join.
//! - [`Autoscaler`] / [`AutoscalerCfg`] — the recovery policy: watches
//!   the live count (and optionally the smoothed fleet throughput)
//!   and spawns replacements from a finite provisioning pool with a
//!   cold-start delay, exponential backoff + jitter on failed spawn
//!   attempts, and a ride-out option that records the degradation
//!   instead of paying for capacity.
//!
//! All three are deterministic under the session seed: the only
//! randomness is the autoscaler's spawn-failure/jitter stream, forked
//! from the session seed with its own tag so it never perturbs the
//! backend's iteration-noise stream.

use crate::session::WorkerOutcome;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Seed perturbation for the autoscaler's spawn-failure/backoff-jitter
/// stream (decorrelated from backend noise and spot traces, like
/// `SPOT_SEED_TAG`).
pub const AUTOSCALE_SEED_TAG: u64 = 0xA5CA_1E75;

/// Seed perturbation for the bit-flip corruption stream (decorrelated
/// from backend iteration noise and the autoscaler stream).  The stream
/// is consumed only when a bitflip fault actually fires, so plans
/// without bitflips leave it untouched — part of the "guard-on with no
/// corruption is bitwise invisible" invariant (DESIGN.md §16).
pub const CORRUPT_SEED_TAG: u64 = 0xC022_0BAD;

// ------------------------------------------------------------- faults

/// One failure mode (the injection taxonomy, DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The instance dies unannounced: any iteration in flight at (or
    /// dispatched after) the fault time never completes, and no
    /// membership event warns the session.  Requires a configured
    /// failure detector — nothing else can reclaim the rank.
    Crash,
    /// The first iteration dispatched at or after the fault time is
    /// pinned for `stall_s` seconds mid-flight, then resumes and
    /// completes (one-shot).  A generous detector rides it out; a tight
    /// one falsely suspects the worker and must survive its return.
    Stall { stall_s: f64 },
    /// Transient slowdown spike: iterations dispatched inside
    /// `[time, time + dur_s)` cost `factor ×` their normal work.
    Slow { factor: f64, dur_s: f64 },
    /// Data-plane corruption (DESIGN.md §16): the update payload of the
    /// first iteration dispatched at or after the fault time is filled
    /// with NaNs (one-shot).  Timing is untouched — only the gradient
    /// contribution is poisoned, so nothing but an [`UpdateGuard`] can
    /// notice it.
    CorruptNan,
    /// Like [`FaultKind::CorruptNan`] with a ∞ fill (one-shot).
    CorruptInf,
    /// Flip `flips` bits of the update payload (one-shot); positions
    /// come from the dedicated [`CORRUPT_SEED_TAG`] rng stream, so they
    /// are deterministic under the session seed.
    CorruptBitflip { flips: u32 },
    /// Mis-scaled update: payloads of iterations dispatched inside
    /// `[time, time + dur_s)` are multiplied by `factor`; `dur_s = 0`
    /// degenerates to one-shot (first dispatch at/after onset, like the
    /// stall).
    CorruptScale { factor: f64, dur_s: f64 },
}

impl FaultKind {
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Stall { .. } => "stall",
            FaultKind::Slow { .. } => "slow",
            FaultKind::CorruptNan => "corrupt:nan",
            FaultKind::CorruptInf => "corrupt:inf",
            FaultKind::CorruptBitflip { .. } => "corrupt:bitflip",
            FaultKind::CorruptScale { .. } => "corrupt:scale",
        }
    }

    /// Deterministic same-worker/same-timestamp tie-break rank (see
    /// [`FaultPlan::new`]): crash < stall < slow < corrupt:nan <
    /// corrupt:inf < corrupt:bitflip < corrupt:scale.
    fn rank(&self) -> u8 {
        match self {
            FaultKind::Crash => 0,
            FaultKind::Stall { .. } => 1,
            FaultKind::Slow { .. } => 2,
            FaultKind::CorruptNan => 3,
            FaultKind::CorruptInf => 4,
            FaultKind::CorruptBitflip { .. } => 5,
            FaultKind::CorruptScale { .. } => 6,
        }
    }

    fn is_corrupt(&self) -> bool {
        matches!(
            self,
            FaultKind::CorruptNan
                | FaultKind::CorruptInf
                | FaultKind::CorruptBitflip { .. }
                | FaultKind::CorruptScale { .. }
        )
    }
}

/// One payload perturbation a backend must apply to the update a worker
/// is about to contribute (the resolved, dispatch-time view of the
/// `corrupt:*` [`FaultKind`]s — see [`FaultState::corruptions`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Corruption {
    Nan,
    Inf,
    Bitflip { flips: u32 },
    Scale { factor: f64 },
}

/// One scripted fault: `kind` hits `worker` at virtual time `time`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub time: f64,
    pub worker: usize,
    pub kind: FaultKind,
}

/// A validated, time-sorted fault schedule (`--faults` /
/// `"faults"` config key).
///
/// Spec shape, mirroring `--spot`/`--join`: a comma-separated list of
/// `crash:W@T` | `stall:W@T:D` | `slow:W@T:F:D` | `corrupt:W@T:nan` |
/// `corrupt:W@T:inf` | `corrupt:W@T:bitflip:N` | `corrupt:W@T:scale:F[:D]`
/// items, e.g. `crash:1@40,stall:2@10:6,corrupt:0@5:nan`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Build from explicit events (tests, scenario harnesses),
    /// validated like the parsed shape.
    ///
    /// Ordering is fully deterministic: events sort by time, then
    /// worker, then [`FaultKind`] rank (crash < stall < slow <
    /// corrupt:nan < corrupt:inf < corrupt:bitflip < corrupt:scale);
    /// the sort is stable, so two events that still tie keep their spec
    /// order.  Any spec permutation of the same events therefore
    /// replays identically.
    pub fn new(mut events: Vec<FaultEvent>) -> Result<FaultPlan, String> {
        for ev in &events {
            validate_event(ev)?;
        }
        events.sort_by(|a, b| {
            a.time
                .total_cmp(&b.time)
                .then(a.worker.cmp(&b.worker))
                .then(a.kind.rank().cmp(&b.kind.rank()))
        });
        Ok(FaultPlan { events })
    }

    /// Parse the CLI/config spec (see type docs for the shape).
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for item in s.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            events.push(parse_item(item)?);
        }
        if events.is_empty() {
            return Err("empty fault list".into());
        }
        FaultPlan::new(events)
    }

    /// Parse the `--corrupt` shorthand: the same item grammar as
    /// [`Self::parse`] with the `corrupt:` prefix implied, e.g.
    /// `0@5:nan,1@10:scale:50:20`.
    pub fn parse_corrupt(s: &str) -> Result<FaultPlan, String> {
        let prefixed: Vec<String> = s
            .split(',')
            .map(str::trim)
            .filter(|item| !item.is_empty())
            .map(|item| format!("corrupt:{item}"))
            .collect();
        if prefixed.is_empty() {
            return Err("empty corruption list".into());
        }
        FaultPlan::parse(&prefixed.join(","))
    }

    /// Combine two plans into one schedule (`--faults` + `--corrupt`),
    /// re-sorted under the deterministic tie-break of [`Self::new`].
    pub fn merged(self, other: FaultPlan) -> FaultPlan {
        let mut events = self.events;
        events.extend(other.events);
        FaultPlan::new(events).expect("merging two validated plans cannot fail")
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn has_crash(&self) -> bool {
        self.events.iter().any(|e| matches!(e.kind, FaultKind::Crash))
    }

    /// Does the plan script any data-plane corruption?  (Corruption
    /// with no [`UpdateGuard`] would silently poison the model, so the
    /// session builder refuses the combination — mirroring the
    /// crash-requires-detector rule.)
    pub fn has_corrupt(&self) -> bool {
        self.events.iter().any(|e| e.kind.is_corrupt())
    }

    pub fn max_worker(&self) -> Option<usize> {
        self.events.iter().map(|e| e.worker).max()
    }

    /// Earliest crash time of `worker`, if it is scripted to crash.
    pub fn crash_time(&self, worker: usize) -> Option<f64> {
        self.events
            .iter()
            .filter(|e| e.worker == worker && matches!(e.kind, FaultKind::Crash))
            .map(|e| e.time)
            .min_by(f64::total_cmp)
    }

    /// Per-run mutable applicator (tracks one-shot stall and corruption
    /// consumption).
    pub fn state(&self) -> FaultState {
        FaultState {
            stall_done: vec![false; self.events.len()],
            corrupt_done: vec![false; self.events.len()],
            plan: self.clone(),
        }
    }

    /// Re-serialize as the `--faults` spec shape ([`Self::parse`]'s
    /// inverse — `f64` Display is shortest-roundtrip, so
    /// `parse(spec()) == self`).  Used by the checkpoint config echo.
    pub fn spec(&self) -> String {
        self.events
            .iter()
            .map(|e| match e.kind {
                FaultKind::Crash => format!("crash:{}@{}", e.worker, e.time),
                FaultKind::Stall { stall_s } => {
                    format!("stall:{}@{}:{}", e.worker, e.time, stall_s)
                }
                FaultKind::Slow { factor, dur_s } => {
                    format!("slow:{}@{}:{}:{}", e.worker, e.time, factor, dur_s)
                }
                FaultKind::CorruptNan => format!("corrupt:{}@{}:nan", e.worker, e.time),
                FaultKind::CorruptInf => format!("corrupt:{}@{}:inf", e.worker, e.time),
                FaultKind::CorruptBitflip { flips } => {
                    format!("corrupt:{}@{}:bitflip:{}", e.worker, e.time, flips)
                }
                FaultKind::CorruptScale { factor, dur_s } if dur_s == 0.0 => {
                    format!("corrupt:{}@{}:scale:{}", e.worker, e.time, factor)
                }
                FaultKind::CorruptScale { factor, dur_s } => {
                    format!("corrupt:{}@{}:scale:{}:{}", e.worker, e.time, factor, dur_s)
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

fn validate_event(ev: &FaultEvent) -> Result<(), String> {
    if !ev.time.is_finite() || ev.time < 0.0 {
        return Err(format!("fault time {} must be finite and non-negative", ev.time));
    }
    match ev.kind {
        FaultKind::Crash => {}
        FaultKind::Stall { stall_s } => {
            if !stall_s.is_finite() || stall_s <= 0.0 {
                return Err(format!("stall duration {stall_s} must be finite and positive"));
            }
        }
        FaultKind::Slow { factor, dur_s } => {
            if !factor.is_finite() || factor <= 0.0 {
                return Err(format!("slowdown factor {factor} must be finite and positive"));
            }
            if !dur_s.is_finite() || dur_s <= 0.0 {
                return Err(format!("slowdown duration {dur_s} must be finite and positive"));
            }
        }
        FaultKind::CorruptNan | FaultKind::CorruptInf => {}
        FaultKind::CorruptBitflip { flips } => {
            if flips == 0 {
                return Err("bit-flip count must be at least 1".into());
            }
        }
        FaultKind::CorruptScale { factor, dur_s } => {
            if !factor.is_finite() {
                return Err(format!("corruption scale factor {factor} must be finite"));
            }
            if !dur_s.is_finite() || dur_s < 0.0 {
                return Err(format!(
                    "corruption duration {dur_s} must be finite and non-negative"
                ));
            }
        }
    }
    Ok(())
}

fn parse_item(item: &str) -> Result<FaultEvent, String> {
    let (kind, rest) = item
        .split_once(':')
        .ok_or_else(|| format!("bad fault {item:?}: want kind:worker@t[:...]"))?;
    let (worker, tail) = rest
        .split_once('@')
        .ok_or_else(|| format!("bad fault {item:?}: want kind:worker@t[:...]"))?;
    let worker: usize = worker
        .parse()
        .map_err(|_| format!("bad fault {item:?}: bad worker {worker:?}"))?;
    let parts: Vec<&str> = tail.split(':').collect();
    let num = |s: &str| -> Result<f64, String> {
        s.parse::<f64>()
            .map_err(|_| format!("bad fault {item:?}: bad number {s:?}"))
    };
    let time = num(parts[0])?;
    let kind = match (kind, parts.len()) {
        ("crash", 1) => FaultKind::Crash,
        ("stall", 2) => FaultKind::Stall { stall_s: num(parts[1])? },
        ("slow", 3) => FaultKind::Slow {
            factor: num(parts[1])?,
            dur_s: num(parts[2])?,
        },
        ("corrupt", 2) if parts[1] == "nan" => FaultKind::CorruptNan,
        ("corrupt", 2) if parts[1] == "inf" => FaultKind::CorruptInf,
        ("corrupt", 3) if parts[1] == "bitflip" => FaultKind::CorruptBitflip {
            flips: parts[2]
                .parse()
                .map_err(|_| format!("bad fault {item:?}: bad flip count {:?}", parts[2]))?,
        },
        ("corrupt", 3) if parts[1] == "scale" => FaultKind::CorruptScale {
            factor: num(parts[2])?,
            dur_s: 0.0,
        },
        ("corrupt", 4) if parts[1] == "scale" => FaultKind::CorruptScale {
            factor: num(parts[2])?,
            dur_s: num(parts[3])?,
        },
        ("crash", _) => return Err(format!("bad fault {item:?}: crash takes no parameters")),
        ("stall", _) => return Err(format!("bad fault {item:?}: want stall:W@T:D")),
        ("slow", _) => return Err(format!("bad fault {item:?}: want slow:W@T:F:D")),
        ("corrupt", _) => {
            return Err(format!(
                "bad fault {item:?}: want corrupt:W@T:nan|inf|bitflip:N|scale:F[:D]"
            ))
        }
        (other, _) => return Err(format!("bad fault {item:?}: unknown kind {other:?}")),
    };
    let ev = FaultEvent { time, worker, kind };
    validate_event(&ev)?;
    Ok(ev)
}

/// Per-run fault applicator: what a [`crate::session::Backend`] holds
/// after [`crate::session::Backend::set_fault_plan`].  Timing faults
/// perturb a wave outcome at *dispatch granularity* — a stall attaches
/// to the first iteration dispatched at or after its onset, a slowdown
/// to every iteration dispatched inside its window.  Crashes are
/// deliberately not applied here (the session suppresses the completion
/// event instead), so backends need no notion of "no outcome".
#[derive(Debug, Clone)]
pub struct FaultState {
    plan: FaultPlan,
    /// One-shot stalls already consumed (parallel to `plan.events`).
    stall_done: Vec<bool>,
    /// One-shot corruptions already consumed (parallel to `plan.events`;
    /// windowed `corrupt:scale` with `dur_s > 0` never sets its flag).
    corrupt_done: Vec<bool>,
}

impl FaultState {
    /// Perturb the outcome of an iteration worker `w` starts at `now`.
    /// Corruption kinds never touch timing — they only show up through
    /// [`FaultState::corruptions`].
    pub fn perturb(&mut self, w: usize, now: f64, out: &mut WorkerOutcome) {
        for (i, ev) in self.plan.events.iter().enumerate() {
            if ev.worker != w {
                continue;
            }
            match ev.kind {
                FaultKind::Crash => {}
                FaultKind::Stall { stall_s } => {
                    if now >= ev.time && !self.stall_done[i] {
                        self.stall_done[i] = true;
                        out.fixed += stall_s;
                    }
                }
                FaultKind::Slow { factor, dur_s } => {
                    if now >= ev.time && now < ev.time + dur_s {
                        out.work *= factor;
                    }
                }
                FaultKind::CorruptNan
                | FaultKind::CorruptInf
                | FaultKind::CorruptBitflip { .. }
                | FaultKind::CorruptScale { .. } => {}
            }
        }
    }

    /// Does the plan script any payload corruption at all?  Backends
    /// use this to skip the [`FaultState::corruptions`] scan (and its
    /// allocation) on the dispatch hot path of corruption-free plans.
    pub fn has_corrupt(&self) -> bool {
        self.plan.has_corrupt()
    }

    /// Payload corruptions to apply to the update of the iteration
    /// worker `w` starts at `now`, in deterministic plan order.
    /// One-shot kinds (nan/inf/bitflip, and scale with `dur_s = 0`) are
    /// consumed at the first dispatch at/after their onset; windowed
    /// scale applies to every dispatch inside `[time, time + dur_s)`.
    pub fn corruptions(&mut self, w: usize, now: f64) -> Vec<Corruption> {
        let mut out = Vec::new();
        for (i, ev) in self.plan.events.iter().enumerate() {
            if ev.worker != w {
                continue;
            }
            let mut one_shot = |done: &mut Vec<bool>, c: Corruption, out: &mut Vec<Corruption>| {
                if now >= ev.time && !done[i] {
                    done[i] = true;
                    out.push(c);
                }
            };
            match ev.kind {
                FaultKind::CorruptNan => one_shot(&mut self.corrupt_done, Corruption::Nan, &mut out),
                FaultKind::CorruptInf => one_shot(&mut self.corrupt_done, Corruption::Inf, &mut out),
                FaultKind::CorruptBitflip { flips } => {
                    one_shot(&mut self.corrupt_done, Corruption::Bitflip { flips }, &mut out)
                }
                FaultKind::CorruptScale { factor, dur_s } => {
                    if dur_s == 0.0 {
                        one_shot(&mut self.corrupt_done, Corruption::Scale { factor }, &mut out);
                    } else if now >= ev.time && now < ev.time + dur_s {
                        out.push(Corruption::Scale { factor });
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// Checkpoint snapshot (DESIGN.md §15): only the one-shot
    /// consumption overlays — the plan itself is run config and is
    /// re-applied via [`crate::session::Backend::set_fault_plan`].
    pub fn snapshot(&self) -> Json {
        let mut j = Json::obj();
        j.set(
            "stall_done",
            Json::Arr(self.stall_done.iter().map(|&b| Json::Bool(b)).collect()),
        );
        j.set(
            "corrupt_done",
            Json::Arr(self.corrupt_done.iter().map(|&b| Json::Bool(b)).collect()),
        );
        j
    }

    /// Overlay a [`FaultState::snapshot`] onto a freshly-built state
    /// (the plan must already match — lengths are checked).
    pub fn restore(&mut self, j: &Json) -> Result<(), String> {
        let dec = |key: &str, into: &mut Vec<bool>| -> Result<(), String> {
            let arr = j
                .get(key)
                .as_arr()
                .ok_or(format!("fault snapshot has no {key} array"))?;
            if arr.len() != into.len() {
                return Err(format!(
                    "fault snapshot: {} {key} flags for a {}-event plan",
                    arr.len(),
                    into.len()
                ));
            }
            for (i, v) in arr.iter().enumerate() {
                into[i] = v
                    .as_bool()
                    .ok_or(format!("fault snapshot: {key}[{i}] is not a bool"))?;
            }
            Ok(())
        };
        dec("stall_done", &mut self.stall_done)?;
        dec("corrupt_done", &mut self.corrupt_done)?;
        Ok(())
    }
}

// ------------------------------------------------- coordinator crash

/// Coordinator-crash scenario (DESIGN.md §15): the *coordinator* — not
/// a worker — dies at virtual time `at_s`, taking every in-memory
/// structure with it; recovery restarts the binary and resumes from the
/// latest durable checkpoint.  Worker faults above perturb outcomes
/// inside a live run; this one truncates the run itself, so it is
/// enforced by the checkpointed session loop
/// ([`crate::session::Session::run_checkpointed`]) stopping once the
/// virtual clock passes `at_s`, and exercised end-to-end by the
/// crash→resume tests and the `hbatch resume` CLI path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoordinatorCrash {
    /// Virtual time at which the coordinator dies.
    pub at_s: f64,
}

impl CoordinatorCrash {
    /// Parse the `--crash-at <t>` spec: a single finite, non-negative
    /// virtual time in seconds.
    pub fn parse(s: &str) -> Result<CoordinatorCrash, String> {
        let at_s: f64 = s
            .trim()
            .parse()
            .map_err(|_| format!("bad crash time {s:?}: want a number of seconds"))?;
        if !at_s.is_finite() || at_s < 0.0 {
            return Err(format!(
                "crash time {at_s} must be finite and non-negative"
            ));
        }
        Ok(CoordinatorCrash { at_s })
    }
}

// ----------------------------------------------------------- detector

/// What to do when a suspected worker's in-flight iteration completes
/// after all — i.e. the suspicion was false.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LatePolicy {
    /// Un-suspect and readmit the worker (its late work is still
    /// discarded; it rejoins exactly like a scheduled join).  Default.
    Readmit,
    /// Ignore the late arrival; the worker stays retired.
    Drop,
}

impl LatePolicy {
    pub fn label(&self) -> &'static str {
        match self {
            LatePolicy::Readmit => "readmit",
            LatePolicy::Drop => "drop",
        }
    }
}

/// Progress-deadline failure detector (`--detect` / `"detect"` key).
///
/// At every dispatch the session arms a deadline of
/// `max(floor, grace × est)` where `est` is the worker's smoothed
/// per-iteration time — the controller's estimate
/// ([`crate::controller::DynamicBatcher::smoothed_iter_time`]) when a
/// dynamic policy runs, else the loop's own cumulative mean; with no
/// estimate yet (cold start) the deadline is just `floor`.  A worker
/// still in flight past its deadline is suspected and provisionally
/// retired.
///
/// Spec shape: comma-separated `key=value` pairs, e.g.
/// `grace=4,floor=30,late=readmit`.
#[derive(Debug, Clone, PartialEq)]
pub struct DetectorCfg {
    /// Deadline multiplier over the smoothed iteration-time estimate.
    pub grace: f64,
    /// Deadline floor in seconds — also the whole deadline while no
    /// estimate exists, so it should comfortably exceed a cold-start
    /// iteration.
    pub floor_s: f64,
    /// False-suspicion resolution policy.
    pub late: LatePolicy,
}

impl Default for DetectorCfg {
    fn default() -> Self {
        DetectorCfg {
            grace: 4.0,
            floor_s: 30.0,
            late: LatePolicy::Readmit,
        }
    }
}

impl DetectorCfg {
    pub fn parse(s: &str) -> Result<DetectorCfg, String> {
        let mut cfg = DetectorCfg::default();
        for (key, val) in parse_kv(s)? {
            match key {
                "grace" => cfg.grace = parse_num(key, val)?,
                "floor" => cfg.floor_s = parse_num(key, val)?,
                "late" => {
                    cfg.late = match val {
                        "readmit" => LatePolicy::Readmit,
                        "drop" => LatePolicy::Drop,
                        other => return Err(format!("late={other:?} (want readmit|drop)")),
                    }
                }
                other => return Err(format!("unknown detector key {other:?}")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.grace.is_finite() || self.grace <= 0.0 {
            return Err(format!("detector grace {} must be finite and positive", self.grace));
        }
        if !self.floor_s.is_finite() || self.floor_s <= 0.0 {
            return Err(format!(
                "detector floor {} must be finite and positive",
                self.floor_s
            ));
        }
        Ok(())
    }

    /// Re-serialize as the `--detect` spec shape ([`Self::parse`]'s
    /// inverse).  Used by the checkpoint config echo.
    pub fn spec(&self) -> String {
        format!(
            "grace={},floor={},late={}",
            self.grace,
            self.floor_s,
            self.late.label()
        )
    }
}

// -------------------------------------------------------------- guard

/// Minimum accepted-norm samples before the robust gate arms (below
/// this only the finite check applies — a cold-start window has no
/// meaningful median yet).
const GUARD_MIN_SAMPLES: usize = 5;

/// Data-plane update guard config (`--guard` / `"guard"` key,
/// DESIGN.md §16).
///
/// Spec shape: comma-separated `key=value` pairs, e.g.
/// `norm=8,strikes=3,probation=60,late=readmit,window=32`.
#[derive(Debug, Clone, PartialEq)]
pub struct GuardCfg {
    /// Robust-gate width: reject when the update norm deviates from the
    /// window median by more than `norm_k ×` the MAD-derived scale.
    pub norm_k: f64,
    /// Consecutive rejections of one worker before it is quarantined.
    pub strikes: u32,
    /// Probation length: a quarantined worker is readmitted through the
    /// join path this many seconds after its quarantine.
    pub probation_s: f64,
    /// What to do with an in-flight completion that lands *after* its
    /// worker was quarantined (mirrors the detector's late policy:
    /// readmit-on-probation-expiry vs stay retired).
    pub late: LatePolicy,
    /// Size of the recently-accepted-norms window the gate reasons over.
    pub window: usize,
}

impl Default for GuardCfg {
    fn default() -> Self {
        GuardCfg {
            norm_k: 8.0,
            strikes: 3,
            probation_s: 60.0,
            late: LatePolicy::Readmit,
            window: 32,
        }
    }
}

impl GuardCfg {
    pub fn parse(s: &str) -> Result<GuardCfg, String> {
        let mut cfg = GuardCfg::default();
        for (key, val) in parse_kv(s)? {
            match key {
                "norm" => cfg.norm_k = parse_num(key, val)?,
                "strikes" => cfg.strikes = parse_int(key, val)? as u32,
                "probation" => cfg.probation_s = parse_num(key, val)?,
                "window" => cfg.window = parse_int(key, val)?,
                "late" => {
                    cfg.late = match val {
                        "readmit" => LatePolicy::Readmit,
                        "drop" => LatePolicy::Drop,
                        other => return Err(format!("late={other:?} (want readmit|drop)")),
                    }
                }
                other => return Err(format!("unknown guard key {other:?}")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.norm_k.is_finite() || self.norm_k <= 0.0 {
            return Err(format!("guard norm {} must be finite and positive", self.norm_k));
        }
        if self.strikes == 0 {
            return Err("guard strikes must be at least 1".into());
        }
        if !self.probation_s.is_finite() || self.probation_s <= 0.0 {
            return Err(format!(
                "guard probation {} must be finite and positive",
                self.probation_s
            ));
        }
        if self.window < GUARD_MIN_SAMPLES {
            return Err(format!(
                "guard window {} must be at least {GUARD_MIN_SAMPLES}",
                self.window
            ));
        }
        Ok(())
    }

    /// Re-serialize as the `--guard` spec shape ([`Self::parse`]'s
    /// inverse).  Used by the checkpoint config echo.
    pub fn spec(&self) -> String {
        format!(
            "norm={},strikes={},probation={},late={},window={}",
            self.norm_k,
            self.strikes,
            self.probation_s,
            self.late.label(),
            self.window
        )
    }
}

/// What [`UpdateGuard::check`] decided about one staged contribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GuardVerdict {
    /// Contribution is healthy; it may enter the combine.
    Accept,
    /// Contribution rejected (dropped from the round); the worker keeps
    /// running.
    Reject,
    /// Contribution rejected *and* the worker's strike budget is spent:
    /// retire it through the revocation path and arm probation.
    Quarantine,
}

/// Runtime update guard (DESIGN.md §16): validates every staged worker
/// contribution before the leaf enters the eager combine.  A finite
/// check always applies; once [`GUARD_MIN_SAMPLES`] norms have been
/// accepted, a robust band of `norm_k ×` the MAD-derived scale around
/// the window median applies too.  Accepted norms enter a bounded
/// cross-worker window and reset that worker's strike counter;
/// rejections increment it, and `strikes` consecutive rejections
/// escalate to [`GuardVerdict::Quarantine`].
///
/// The guard only *observes* accepted runs: with no corruption it never
/// rejects, consumes no rng, and leaves the run bitwise identical to a
/// guard-off run (property-locked).
#[derive(Debug, Clone)]
pub struct UpdateGuard {
    cfg: GuardCfg,
    /// Recently accepted update norms (cross-worker, insertion order,
    /// bounded at `cfg.window`).
    accepted: std::collections::VecDeque<f64>,
    /// Consecutive rejections per worker rank.
    strikes: Vec<u32>,
}

impl UpdateGuard {
    pub fn new(cfg: GuardCfg, k: usize) -> UpdateGuard {
        UpdateGuard {
            accepted: std::collections::VecDeque::with_capacity(cfg.window),
            strikes: vec![0; k],
            cfg,
        }
    }

    pub fn cfg(&self) -> &GuardCfg {
        &self.cfg
    }

    /// Current strike count of `w` (for tests/accounting).
    pub fn strikes(&self, w: usize) -> u32 {
        self.strikes[w]
    }

    /// Judge the staged contribution of worker `w` with update norm
    /// `norm`.
    pub fn check(&mut self, w: usize, norm: f64) -> GuardVerdict {
        if norm.is_finite() && !self.out_of_band(norm) {
            self.accepted.push_back(norm);
            if self.accepted.len() > self.cfg.window {
                self.accepted.pop_front();
            }
            self.strikes[w] = 0;
            return GuardVerdict::Accept;
        }
        self.strikes[w] += 1;
        if self.strikes[w] >= self.cfg.strikes {
            // Counter resets here so a probation readmit starts fresh.
            self.strikes[w] = 0;
            GuardVerdict::Quarantine
        } else {
            GuardVerdict::Reject
        }
    }

    /// Robust norm gate: |norm − median| > norm_k × scale, where scale
    /// is the MAD (consistency-scaled for a normal population) floored
    /// at 5% of the median magnitude so a degenerate zero-spread window
    /// (e.g. the sim's modeled constant norms) keeps a usable band.
    fn out_of_band(&self, norm: f64) -> bool {
        if self.accepted.len() < GUARD_MIN_SAMPLES {
            return false;
        }
        let mut v: Vec<f64> = self.accepted.iter().copied().collect();
        let med = median(&mut v);
        for x in v.iter_mut() {
            *x = (*x - med).abs();
        }
        let mad = median(&mut v);
        let scale = (1.4826 * mad).max(0.05 * med.abs()).max(1e-12);
        (norm - med).abs() > self.cfg.norm_k * scale
    }

    /// Checkpoint snapshot (DESIGN.md §15): the accepted-norm window (in
    /// order) and the per-worker strike counters.  The `GuardCfg` is run
    /// config and travels in the checkpoint's config echo.
    pub fn snapshot(&self) -> Json {
        use crate::ckpt::enc_f64;
        let mut j = Json::obj();
        j.set(
            "accepted",
            Json::Arr(self.accepted.iter().map(|&x| enc_f64(x)).collect()),
        );
        j.set(
            "strikes",
            Json::Arr(self.strikes.iter().map(|&s| Json::Num(s as f64)).collect()),
        );
        j
    }

    /// Rebuild from an [`UpdateGuard::snapshot`] under `cfg` (from the
    /// checkpoint's config echo) for a `k`-rank cluster.
    pub fn restore(cfg: GuardCfg, k: usize, j: &Json) -> Result<UpdateGuard, String> {
        use crate::ckpt::{dec_f64, dec_usize};
        let accepted = j
            .get("accepted")
            .as_arr()
            .ok_or("guard snapshot has no accepted array")?
            .iter()
            .map(dec_f64)
            .collect::<Result<std::collections::VecDeque<_>, _>>()?;
        let strikes = j
            .get("strikes")
            .as_arr()
            .ok_or("guard snapshot has no strikes array")?
            .iter()
            .map(|v| dec_usize(v).map(|s| s as u32))
            .collect::<Result<Vec<_>, _>>()?;
        if strikes.len() != k {
            return Err(format!(
                "guard snapshot: {} strike counters for a {k}-rank cluster",
                strikes.len()
            ));
        }
        if accepted.len() > cfg.window {
            return Err(format!(
                "guard snapshot: {} accepted norms overflow window {}",
                accepted.len(),
                cfg.window
            ));
        }
        Ok(UpdateGuard { cfg, accepted, strikes })
    }
}

/// Median of `v` (sorted in place; empty ⇒ 0).
fn median(v: &mut [f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

// --------------------------------------------------------- autoscaler

/// Autoscaled-recovery policy (`--autoscale` / `"autoscale"` key).
///
/// Spec shape: comma-separated `key=value` pairs with a bare `ride`
/// token for the flag, e.g.
/// `pool=2,cold=30,floor=0,backoff=5,cap=300,jitter=0.2,fail=0.1,retries=8,tput=0.5`.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalerCfg {
    /// Replacement instances available in the provisioning pool.
    pub pool: usize,
    /// Cold-start delay: seconds between a successful spawn request and
    /// the replacement joining the fleet.
    pub cold_s: f64,
    /// Capacity floor: spawn while `live + cold-starting < floor`.
    /// 0 = the run's initially-live count.
    pub floor: usize,
    /// Base retry backoff after a failed spawn attempt.
    pub backoff_s: f64,
    /// Backoff cap (the exponential stops doubling here).
    pub cap_s: f64,
    /// ± jitter fraction applied to each backoff interval.
    pub jitter: f64,
    /// Per-attempt spawn failure probability (models provider stockouts;
    /// drawn from the dedicated `AUTOSCALE_SEED_TAG` rng stream).
    pub fail_p: f64,
    /// Give up after this many *consecutive* failed attempts.
    pub retries: u32,
    /// Ride-out mode: never spawn; keep the autoscaler's accounting so
    /// the degradation is measurable against the spawning variant.
    pub ride_out: bool,
    /// Optional throughput trigger: also spawn when the smoothed fleet
    /// throughput falls below this fraction of the best seen (0 = off).
    pub tput: f64,
}

impl Default for AutoscalerCfg {
    fn default() -> Self {
        AutoscalerCfg {
            pool: 1,
            cold_s: 30.0,
            floor: 0,
            backoff_s: 5.0,
            cap_s: 300.0,
            jitter: 0.0,
            fail_p: 0.0,
            retries: 8,
            ride_out: false,
            tput: 0.0,
        }
    }
}

impl AutoscalerCfg {
    pub fn parse(s: &str) -> Result<AutoscalerCfg, String> {
        let mut cfg = AutoscalerCfg::default();
        for (key, val) in parse_kv(s)? {
            match key {
                "pool" => cfg.pool = parse_int(key, val)?,
                "cold" => cfg.cold_s = parse_num(key, val)?,
                "floor" => cfg.floor = parse_int(key, val)?,
                "backoff" => cfg.backoff_s = parse_num(key, val)?,
                "cap" => cfg.cap_s = parse_num(key, val)?,
                "jitter" => cfg.jitter = parse_num(key, val)?,
                "fail" => cfg.fail_p = parse_num(key, val)?,
                "retries" => cfg.retries = parse_int(key, val)? as u32,
                "ride" => {
                    cfg.ride_out = match val {
                        "" | "1" | "true" => true,
                        "0" | "false" => false,
                        other => return Err(format!("ride={other:?} (want a bare `ride` or 0/1)")),
                    }
                }
                "tput" => cfg.tput = parse_num(key, val)?,
                other => return Err(format!("unknown autoscaler key {other:?}")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.cold_s.is_finite() || self.cold_s < 0.0 {
            return Err(format!("cold-start {} must be finite and non-negative", self.cold_s));
        }
        if !self.backoff_s.is_finite() || self.backoff_s < 0.0 {
            return Err(format!("backoff {} must be finite and non-negative", self.backoff_s));
        }
        if !self.cap_s.is_finite() || self.cap_s < self.backoff_s {
            return Err(format!(
                "backoff cap {} must be finite and >= the base backoff {}",
                self.cap_s, self.backoff_s
            ));
        }
        if !self.jitter.is_finite() || self.jitter < 0.0 || self.jitter >= 1.0 {
            return Err(format!("jitter {} out of [0, 1)", self.jitter));
        }
        if !self.fail_p.is_finite() || self.fail_p < 0.0 || self.fail_p > 1.0 {
            return Err(format!("spawn failure probability {} out of [0, 1]", self.fail_p));
        }
        if !self.tput.is_finite() || self.tput < 0.0 || self.tput >= 1.0 {
            return Err(format!("throughput trigger {} out of [0, 1)", self.tput));
        }
        Ok(())
    }

    /// Re-serialize as the `--autoscale` spec shape ([`Self::parse`]'s
    /// inverse).  Used by the checkpoint config echo.
    pub fn spec(&self) -> String {
        format!(
            "pool={},cold={},floor={},backoff={},cap={},jitter={},fail={},retries={},ride={},tput={}",
            self.pool,
            self.cold_s,
            self.floor,
            self.backoff_s,
            self.cap_s,
            self.jitter,
            self.fail_p,
            self.retries,
            if self.ride_out { 1 } else { 0 },
            self.tput
        )
    }
}

/// Outcome of one provisioning attempt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SpawnOutcome {
    /// Request accepted; the replacement joins at `ready_at`.
    Started { ready_at: f64 },
    /// Attempt failed; the next one waits until `retry_at`.
    Failed { retry_at: f64 },
    /// Retry budget exhausted; the autoscaler stops trying.
    GaveUp,
}

/// Runtime autoscaler state: the detection→degradation→recovery loop's
/// actuator.  The session owns one per run (when configured), asks it
/// for decisions, and applies the resulting joins itself so replacement
/// admission shares the plan-join code path exactly.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscalerCfg,
    /// Resolved capacity floor (cfg.floor, or the initial live count).
    floor: usize,
    pool_left: usize,
    /// Ready times of replacements still in cold start.
    pending: Vec<f64>,
    /// Consecutive failed spawn attempts.
    attempts: u32,
    /// Earliest time the next attempt may run (backoff gate).
    retry_at: f64,
    gave_up: bool,
    /// Best smoothed fleet throughput seen (throughput-trigger baseline).
    best_tput: f64,
    rng: Rng,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerCfg, initial_live: usize, seed: u64) -> Autoscaler {
        let floor = if cfg.floor == 0 { initial_live } else { cfg.floor };
        Autoscaler {
            pool_left: cfg.pool,
            floor,
            cfg,
            pending: Vec::new(),
            attempts: 0,
            retry_at: 0.0,
            gave_up: false,
            best_tput: 0.0,
            rng: Rng::new(seed ^ AUTOSCALE_SEED_TAG),
        }
    }

    pub fn cfg(&self) -> &AutoscalerCfg {
        &self.cfg
    }

    pub fn floor(&self) -> usize {
        self.floor
    }

    pub fn pool_left(&self) -> usize {
        self.pool_left
    }

    /// Arbiter-client hook (fleet runs, DESIGN.md §13): cap the
    /// remaining private spawn pool at the shared-capacity `spare` the
    /// fleet can lend right now.  Capping only ever shrinks — the
    /// arbiter lends headroom, it never refills a drained pool — so an
    /// uncontended fleet (spare always ≥ pool) leaves the autoscaler
    /// bit-identical to a standalone run.
    pub fn cap_pool(&mut self, spare: usize) {
        self.pool_left = self.pool_left.min(spare);
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    /// Consecutive failed attempts so far (for event records).
    pub fn attempts(&self) -> u32 {
        self.attempts
    }

    /// Track the smoothed fleet throughput (the trigger baseline is the
    /// best value seen, so a post-crash dip reads as a deficit).
    pub fn observe_throughput(&mut self, tput: f64) {
        if tput > self.best_tput {
            self.best_tput = tput;
        }
    }

    /// Is the fleet below the autoscaler's target, counting replacements
    /// already cold-starting?
    fn below_target(&self, live: usize, tput: Option<f64>) -> bool {
        if live + self.pending.len() < self.floor {
            return true;
        }
        if self.cfg.tput > 0.0 && self.pending.is_empty() {
            if let Some(t) = tput {
                if self.best_tput > 0.0 && t < self.cfg.tput * self.best_tput {
                    return true;
                }
            }
        }
        false
    }

    /// Should a spawn attempt run now?
    pub fn wants_spawn(&self, live: usize, now: f64, tput: Option<f64>) -> bool {
        !self.cfg.ride_out
            && !self.gave_up
            && self.pool_left > 0
            && now >= self.retry_at
            && self.below_target(live, tput)
    }

    /// One provisioning attempt at `now`.  Only call when
    /// [`Self::wants_spawn`] holds.
    pub fn try_spawn(&mut self, now: f64) -> SpawnOutcome {
        debug_assert!(self.pool_left > 0 && !self.gave_up);
        if self.cfg.fail_p > 0.0 && self.rng.bool(self.cfg.fail_p) {
            self.attempts += 1;
            if self.attempts > self.cfg.retries {
                self.gave_up = true;
                return SpawnOutcome::GaveUp;
            }
            // Exponential backoff with ± jitter, capped.
            let exp = (self.attempts - 1).min(30);
            let base = (self.cfg.backoff_s * f64::powi(2.0, exp as i32)).min(self.cfg.cap_s);
            let jit = if self.cfg.jitter > 0.0 {
                1.0 + self.cfg.jitter * (2.0 * self.rng.f64() - 1.0)
            } else {
                1.0
            };
            self.retry_at = now + (base * jit).max(0.0);
            SpawnOutcome::Failed {
                retry_at: self.retry_at,
            }
        } else {
            self.attempts = 0;
            self.pool_left -= 1;
            let ready_at = now + self.cfg.cold_s;
            self.pending.push(ready_at);
            SpawnOutcome::Started { ready_at }
        }
    }

    /// Remove and return the earliest replacement whose cold start has
    /// finished by `now`.
    pub fn take_ready(&mut self, now: f64) -> Option<f64> {
        let mut best: Option<usize> = None;
        for (i, &t) in self.pending.iter().enumerate() {
            if t <= now && best.map_or(true, |b| t < self.pending[b]) {
                best = Some(i);
            }
        }
        best.map(|i| self.pending.swap_remove(i))
    }

    /// Checkpoint snapshot (DESIGN.md §15): the full mutable state,
    /// including the rng stream position so post-resume jitter draws
    /// continue the original sequence.  `pending` keeps its insertion
    /// order — [`Autoscaler::take_ready`] uses `swap_remove`, so the
    /// order is bitwise-significant.  The `AutoscalerCfg` is run config
    /// and travels in the checkpoint's config echo instead.
    pub fn snapshot(&self) -> Json {
        use crate::ckpt::{enc_f64, enc_opt_f64, enc_u128};
        let (state, inc, spare) = self.rng.state_parts();
        let mut j = Json::obj();
        j.set("floor", Json::Num(self.floor as f64));
        j.set("pool_left", Json::Num(self.pool_left as f64));
        j.set(
            "pending",
            Json::Arr(self.pending.iter().map(|&t| enc_f64(t)).collect()),
        );
        j.set("attempts", Json::Num(self.attempts as f64));
        j.set("retry_at", enc_f64(self.retry_at));
        j.set("gave_up", Json::Bool(self.gave_up));
        j.set("best_tput", enc_f64(self.best_tput));
        j.set("rng_state", enc_u128(state));
        j.set("rng_inc", enc_u128(inc));
        j.set("rng_spare", enc_opt_f64(spare));
        j
    }

    /// Rebuild from an [`Autoscaler::snapshot`] under `cfg` (from the
    /// checkpoint's config echo).
    pub fn restore(cfg: AutoscalerCfg, j: &Json) -> Result<Autoscaler, String> {
        use crate::ckpt::{dec_f64, dec_opt_f64, dec_u128, dec_usize};
        let pending = j
            .get("pending")
            .as_arr()
            .ok_or("autoscaler snapshot has no pending array")?
            .iter()
            .map(dec_f64)
            .collect::<Result<Vec<_>, _>>()?;
        let attempts = dec_usize(j.get("attempts"))? as u32;
        let rng = Rng::from_parts(
            dec_u128(j.get("rng_state"))?,
            dec_u128(j.get("rng_inc"))?,
            dec_opt_f64(j.get("rng_spare"))?,
        );
        Ok(Autoscaler {
            cfg,
            floor: dec_usize(j.get("floor"))?,
            pool_left: dec_usize(j.get("pool_left"))?,
            pending,
            attempts,
            retry_at: dec_f64(j.get("retry_at"))?,
            gave_up: j
                .get("gave_up")
                .as_bool()
                .ok_or("autoscaler snapshot: gave_up is not a bool")?,
            best_tput: dec_f64(j.get("best_tput"))?,
            rng,
        })
    }

    /// Next time the autoscaler needs the event loop's attention: a
    /// pending replacement finishing cold start, or a backed-off retry
    /// while the fleet is below target.  None = nothing scheduled.
    pub fn next_event(&self, live: usize, tput: Option<f64>) -> Option<f64> {
        let mut next: Option<f64> = None;
        for &p in &self.pending {
            next = Some(next.map_or(p, |x: f64| x.min(p)));
        }
        if !self.cfg.ride_out
            && !self.gave_up
            && self.pool_left > 0
            && self.below_target(live, tput)
        {
            next = Some(next.map_or(self.retry_at, |x| x.min(self.retry_at)));
        }
        next
    }
}

// ------------------------------------------------------------ parsing

/// Split a `k1=v1,k2=v2,flag` spec into (key, value) pairs (a bare
/// token yields an empty value).
fn parse_kv(s: &str) -> Result<Vec<(&str, &str)>, String> {
    let mut out = Vec::new();
    for item in s.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        match item.split_once('=') {
            Some((k, v)) => out.push((k.trim(), v.trim())),
            None => out.push((item, "")),
        }
    }
    if out.is_empty() {
        return Err("empty spec".into());
    }
    Ok(out)
}

fn parse_num(key: &str, val: &str) -> Result<f64, String> {
    val.parse::<f64>()
        .map_err(|_| format!("{key}={val:?} is not a number"))
}

fn parse_int(key: &str, val: &str) -> Result<usize, String> {
    val.parse::<usize>()
        .map_err(|_| format!("{key}={val:?} is not an integer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_parses_all_kinds_and_sorts() {
        let p = FaultPlan::parse("stall:2@10:6,crash:1@40,slow:0@5:2.5:30").unwrap();
        assert_eq!(p.events().len(), 3);
        // Sorted by time.
        assert_eq!(p.events()[0].worker, 0);
        assert!(matches!(p.events()[0].kind, FaultKind::Slow { .. }));
        assert_eq!(p.events()[1].worker, 2);
        assert_eq!(p.events()[2].worker, 1);
        assert!(p.has_crash());
        assert_eq!(p.crash_time(1), Some(40.0));
        assert_eq!(p.crash_time(0), None);
        assert_eq!(p.max_worker(), Some(2));
    }

    #[test]
    fn spec_strings_roundtrip_through_parse() {
        let p = FaultPlan::parse("stall:2@10:6,crash:1@40,slow:0@5:2.5:30").unwrap();
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);

        let d = DetectorCfg::parse("grace=3.5,floor=0.25,late=drop").unwrap();
        assert_eq!(DetectorCfg::parse(&d.spec()).unwrap(), d);

        let a = AutoscalerCfg::parse(
            "pool=2,cold=30,floor=3,backoff=5,cap=300,jitter=0.2,fail=0.1,retries=4,ride,tput=0.5",
        )
        .unwrap();
        assert_eq!(AutoscalerCfg::parse(&a.spec()).unwrap(), a);
        // Defaults roundtrip too.
        let a0 = AutoscalerCfg::default();
        assert_eq!(AutoscalerCfg::parse(&a0.spec()).unwrap(), a0);
    }

    #[test]
    fn fault_plan_rejects_bad_shapes() {
        for bad in [
            "",
            "crash:1",
            "crash:1@",
            "crash:x@5",
            "crash:1@-3",
            "crash:1@nan",
            "crash:1@5:9",
            "stall:1@5",
            "stall:1@5:0",
            "stall:1@5:-2",
            "slow:1@5:2",
            "slow:1@5:0:10",
            "slow:1@5:2:0",
            "melt:1@5",
            "1@5",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn stall_is_one_shot_and_slow_is_windowed() {
        let p = FaultPlan::parse("stall:0@10:5,slow:1@10:2:10").unwrap();
        let mut st = p.state();
        let mut out = WorkerOutcome { work: 1.0, fixed: 0.5 };
        // Before onset: untouched.
        st.perturb(0, 9.0, &mut out);
        assert_eq!(out.fixed, 0.5);
        // First dispatch at/after onset: stalled once.
        st.perturb(0, 12.0, &mut out);
        assert_eq!(out.fixed, 5.5);
        st.perturb(0, 13.0, &mut out);
        assert_eq!(out.fixed, 5.5); // consumed
        // Slowdown applies inside the window only, to the right worker.
        let mut o1 = WorkerOutcome { work: 2.0, fixed: 0.0 };
        st.perturb(1, 15.0, &mut o1);
        assert_eq!(o1.work, 4.0);
        st.perturb(1, 20.0, &mut o1); // window [10, 20) closed
        assert_eq!(o1.work, 4.0);
        let mut o0 = WorkerOutcome { work: 2.0, fixed: 0.0 };
        st.perturb(0, 15.0, &mut o0); // other worker: no slowdown
        assert_eq!(o0.work, 2.0);
    }

    #[test]
    fn crash_does_not_perturb_outcomes() {
        let p = FaultPlan::parse("crash:0@10").unwrap();
        let mut st = p.state();
        let mut out = WorkerOutcome { work: 1.0, fixed: 0.0 };
        st.perturb(0, 20.0, &mut out);
        assert_eq!(out.work, 1.0);
        assert_eq!(out.fixed, 0.0);
    }

    #[test]
    fn detector_cfg_parses_and_validates() {
        let d = DetectorCfg::parse("grace=6,floor=12,late=drop").unwrap();
        assert_eq!(d.grace, 6.0);
        assert_eq!(d.floor_s, 12.0);
        assert_eq!(d.late, LatePolicy::Drop);
        // Defaults fill missing keys.
        let d = DetectorCfg::parse("grace=2").unwrap();
        assert_eq!(d.floor_s, DetectorCfg::default().floor_s);
        assert_eq!(d.late, LatePolicy::Readmit);
        for bad in ["", "grace=0", "grace=-1", "floor=0", "late=maybe", "bogus=1"] {
            assert!(DetectorCfg::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn autoscaler_cfg_parses_and_validates() {
        let a = AutoscalerCfg::parse("pool=2,cold=12,floor=3,backoff=4,cap=64,jitter=0.2,fail=0.1,retries=5,tput=0.5").unwrap();
        assert_eq!(a.pool, 2);
        assert_eq!(a.cold_s, 12.0);
        assert_eq!(a.floor, 3);
        assert_eq!(a.backoff_s, 4.0);
        assert_eq!(a.cap_s, 64.0);
        assert_eq!(a.jitter, 0.2);
        assert_eq!(a.fail_p, 0.1);
        assert_eq!(a.retries, 5);
        assert!(!a.ride_out);
        assert_eq!(a.tput, 0.5);
        let a = AutoscalerCfg::parse("pool=1,cold=5,ride").unwrap();
        assert!(a.ride_out);
        for bad in [
            "",
            "pool=x",
            "cold=-1",
            "jitter=1.5",
            "fail=2",
            "tput=1",
            "cap=1,backoff=5",
            "nonsense=3",
        ] {
            assert!(AutoscalerCfg::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn autoscaler_spawns_to_the_floor_with_cold_start() {
        let cfg = AutoscalerCfg::parse("pool=2,cold=10").unwrap();
        let mut a = Autoscaler::new(cfg, 3, 42);
        assert_eq!(a.floor(), 3); // floor=0 resolves to the initial live count
        // Fleet healthy: nothing to do.
        assert!(!a.wants_spawn(3, 0.0, None));
        assert_eq!(a.next_event(3, None), None);
        // One worker gone: spawn, cold start 10s.
        assert!(a.wants_spawn(2, 5.0, None));
        match a.try_spawn(5.0) {
            SpawnOutcome::Started { ready_at } => assert_eq!(ready_at, 15.0),
            other => panic!("expected Started, got {other:?}"),
        }
        assert_eq!(a.pool_left(), 1);
        // The cold-starting replacement counts toward the target.
        assert!(!a.wants_spawn(2, 6.0, None));
        assert_eq!(a.next_event(2, None), Some(15.0));
        // Not ready early; ready at its time.
        assert_eq!(a.take_ready(14.9), None);
        assert_eq!(a.take_ready(15.0), Some(15.0));
        assert_eq!(a.pending_count(), 0);
    }

    #[test]
    fn autoscaler_cap_pool_is_an_arbiter_clamp() {
        let cfg = AutoscalerCfg::parse("pool=4,cold=10").unwrap();
        let mut a = Autoscaler::new(cfg, 3, 42);
        assert_eq!(a.pool_left(), 4);
        // A generous spare is a no-op (uncontended fleets stay bitwise
        // identical to standalone runs).
        a.cap_pool(9);
        assert_eq!(a.pool_left(), 4);
        // A tight spare clamps; a later looser spare never refills.
        a.cap_pool(1);
        assert_eq!(a.pool_left(), 1);
        a.cap_pool(3);
        assert_eq!(a.pool_left(), 1);
        // A clamped-out pool can no longer spawn.
        a.cap_pool(0);
        assert_eq!(a.pool_left(), 0);
        assert!(!a.wants_spawn(2, 5.0, None));
    }

    #[test]
    fn autoscaler_backs_off_exponentially_and_gives_up() {
        let cfg = AutoscalerCfg::parse("pool=1,cold=1,backoff=4,cap=16,fail=1,retries=3").unwrap();
        let mut a = Autoscaler::new(cfg, 2, 7);
        let mut now = 0.0;
        let mut gaps = Vec::new();
        loop {
            assert!(a.wants_spawn(1, now, None) || a.attempts() > 0);
            match a.try_spawn(now) {
                SpawnOutcome::Failed { retry_at } => {
                    gaps.push(retry_at - now);
                    now = retry_at;
                }
                SpawnOutcome::GaveUp => break,
                SpawnOutcome::Started { .. } => panic!("fail=1 cannot succeed"),
            }
        }
        // 4, 8, 16 (capped) then give-up on the 4th attempt.
        assert_eq!(gaps, vec![4.0, 8.0, 16.0]);
        assert!(!a.wants_spawn(1, now, None));
        assert_eq!(a.next_event(1, None), None);
    }

    #[test]
    fn autoscaler_jitter_stays_within_bounds_and_is_seeded() {
        let cfg = AutoscalerCfg::parse("pool=1,cold=1,backoff=10,cap=10,fail=1,retries=6,jitter=0.5").unwrap();
        let gaps = |seed: u64| -> Vec<f64> {
            let mut a = Autoscaler::new(cfg.clone(), 2, seed);
            let mut now = 0.0;
            let mut out = Vec::new();
            loop {
                match a.try_spawn(now) {
                    SpawnOutcome::Failed { retry_at } => {
                        out.push(retry_at - now);
                        now = retry_at;
                    }
                    SpawnOutcome::GaveUp => break,
                    SpawnOutcome::Started { .. } => unreachable!(),
                }
            }
            out
        };
        let a = gaps(1);
        for &g in &a {
            assert!(g >= 5.0 && g <= 15.0, "jittered gap {g} outside ±50%");
        }
        // Deterministic under the seed; decorrelated across seeds.
        assert_eq!(a, gaps(1));
        assert_ne!(a, gaps(2));
    }

    #[test]
    fn autoscaler_ride_out_never_spawns() {
        let cfg = AutoscalerCfg::parse("pool=4,cold=1,ride").unwrap();
        let a = Autoscaler::new(cfg, 3, 0);
        assert!(!a.wants_spawn(0, 100.0, None));
        assert_eq!(a.next_event(0, None), None);
    }

    #[test]
    fn autoscaler_snapshot_restore_resumes_jitter_stream_bitwise() {
        let cfg = AutoscalerCfg::parse(
            "pool=4,cold=1,backoff=10,cap=100,fail=0.5,retries=20,jitter=0.5",
        )
        .unwrap();
        let mut a = Autoscaler::new(cfg.clone(), 2, 99);
        // Burn some of the rng stream and mutate every field.
        a.observe_throughput(50.0);
        for _ in 0..3 {
            let _ = a.try_spawn(1.0);
        }
        let text = a.snapshot().to_pretty();
        let j = Json::parse(&text).unwrap();
        let mut b = Autoscaler::restore(cfg, &j).unwrap();
        assert_eq!(a.floor(), b.floor());
        assert_eq!(a.pool_left(), b.pool_left());
        assert_eq!(a.pending_count(), b.pending_count());
        assert_eq!(a.attempts(), b.attempts());
        // The continued runs must agree bitwise, including jitter draws.
        let mut now = 20.0;
        for _ in 0..6 {
            assert_eq!(
                a.wants_spawn(0, now, Some(10.0)),
                b.wants_spawn(0, now, Some(10.0))
            );
            let (ra, rb) = (a.try_spawn(now), b.try_spawn(now));
            assert_eq!(ra, rb);
            if let SpawnOutcome::Failed { retry_at } = ra {
                now = retry_at;
            }
            if a.pool_left() == 0 || a.attempts() > 18 {
                break;
            }
        }
        assert_eq!(a.take_ready(now + 100.0), b.take_ready(now + 100.0));
    }

    #[test]
    fn fault_state_snapshot_restores_stall_overlay() {
        let p = FaultPlan::parse("stall:0@10:5,stall:1@20:5").unwrap();
        let mut st = p.state();
        let mut out = WorkerOutcome { work: 1.0, fixed: 0.0 };
        st.perturb(0, 12.0, &mut out); // consume the first stall
        let snap = st.snapshot();
        let mut st2 = p.state();
        st2.restore(&snap).unwrap();
        // Consumed stall stays consumed; the other still fires.
        let mut o = WorkerOutcome { work: 1.0, fixed: 0.0 };
        st2.perturb(0, 13.0, &mut o);
        assert_eq!(o.fixed, 0.0);
        st2.perturb(1, 25.0, &mut o);
        assert_eq!(o.fixed, 5.0);
        // Length mismatch is rejected.
        let other = FaultPlan::parse("stall:0@10:5").unwrap();
        assert!(other.state().restore(&snap).is_err());
    }

    #[test]
    fn coordinator_crash_parses_and_validates() {
        assert_eq!(CoordinatorCrash::parse("42.5").unwrap().at_s, 42.5);
        assert_eq!(CoordinatorCrash::parse(" 0 ").unwrap().at_s, 0.0);
        for bad in ["", "x", "-1", "nan", "inf"] {
            assert!(CoordinatorCrash::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn corrupt_faults_parse_sort_and_roundtrip() {
        let p = FaultPlan::parse(
            "corrupt:1@40:nan,corrupt:0@5:bitflip:3,corrupt:2@10:scale:100,corrupt:2@20:scale:0.5:15,corrupt:1@30:inf",
        )
        .unwrap();
        assert_eq!(p.events().len(), 5);
        assert!(p.has_corrupt());
        assert!(!p.has_crash());
        // Time-sorted.
        assert_eq!(p.events()[0].kind, FaultKind::CorruptBitflip { flips: 3 });
        assert_eq!(p.events()[1].kind, FaultKind::CorruptScale { factor: 100.0, dur_s: 0.0 });
        assert_eq!(p.events()[2].kind, FaultKind::CorruptScale { factor: 0.5, dur_s: 15.0 });
        assert_eq!(p.events()[3].kind, FaultKind::CorruptInf);
        assert_eq!(p.events()[4].kind, FaultKind::CorruptNan);
        // Spec roundtrip (including the one-shot vs windowed scale shapes).
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);
        // Timing plans report no corruption.
        assert!(!FaultPlan::parse("crash:1@40,stall:2@10:6").unwrap().has_corrupt());
    }

    #[test]
    fn corrupt_faults_reject_bad_shapes() {
        for bad in [
            "corrupt:1@5",
            "corrupt:1@5:melt",
            "corrupt:1@5:nan:3",
            "corrupt:1@5:bitflip",
            "corrupt:1@5:bitflip:0",
            "corrupt:1@5:bitflip:x",
            "corrupt:1@5:scale",
            "corrupt:1@5:scale:inf",
            "corrupt:1@5:scale:2:-1",
            "corrupt:1@5:scale:2:3:4",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn same_time_same_worker_events_tie_break_deterministically() {
        // Same worker, same timestamp: kind rank orders them (crash <
        // stall < slow < corrupt:*), regardless of spec order — so any
        // permutation of the same spec replays identically.
        let a = FaultPlan::parse("corrupt:1@5:nan,slow:1@5:2:10,stall:1@5:2,crash:1@5").unwrap();
        let b = FaultPlan::parse("crash:1@5,stall:1@5:2,slow:1@5:2:10,corrupt:1@5:nan").unwrap();
        assert_eq!(a, b);
        let kinds: Vec<&str> = a.events().iter().map(|e| e.kind.label()).collect();
        assert_eq!(kinds, vec!["crash", "stall", "slow", "corrupt:nan"]);
        // Identical rank at the same instant keeps spec order (stable
        // sort), and both orders replay the same perturbation sequence.
        let s1 = FaultPlan::parse("slow:0@5:2:10,slow:0@5:3:10").unwrap();
        let s2 = FaultPlan::parse("slow:0@5:3:10,slow:0@5:2:10").unwrap();
        let apply = |p: &FaultPlan| {
            let mut st = p.state();
            let mut out = WorkerOutcome { work: 1.0, fixed: 0.0 };
            st.perturb(0, 6.0, &mut out);
            out.work
        };
        assert_eq!(apply(&s1), 6.0);
        assert_eq!(apply(&s1), apply(&s1));
        // Both factors apply either way (multiplication commutes here,
        // but the *event order* inside the plan is what's pinned).
        assert_eq!(s1.events()[0].kind, FaultKind::Slow { factor: 2.0, dur_s: 10.0 });
        assert_eq!(s2.events()[0].kind, FaultKind::Slow { factor: 3.0, dur_s: 10.0 });
    }

    #[test]
    fn corruptions_are_one_shot_or_windowed() {
        let p = FaultPlan::parse(
            "corrupt:0@10:nan,corrupt:1@10:scale:4:10,corrupt:2@10:scale:9",
        )
        .unwrap();
        let mut st = p.state();
        assert!(st.has_corrupt());
        // Before onset: nothing.
        assert!(st.corruptions(0, 9.0).is_empty());
        // One-shot nan fires once at the first dispatch at/after onset.
        assert_eq!(st.corruptions(0, 12.0), vec![Corruption::Nan]);
        assert!(st.corruptions(0, 13.0).is_empty());
        // Windowed scale fires for every dispatch inside the window.
        assert_eq!(st.corruptions(1, 12.0), vec![Corruption::Scale { factor: 4.0 }]);
        assert_eq!(st.corruptions(1, 19.9), vec![Corruption::Scale { factor: 4.0 }]);
        assert!(st.corruptions(1, 20.0).is_empty());
        // dur = 0 scale degenerates to one-shot.
        assert_eq!(st.corruptions(2, 15.0), vec![Corruption::Scale { factor: 9.0 }]);
        assert!(st.corruptions(2, 16.0).is_empty());
        // Other workers untouched; timing unperturbed by corruption.
        let mut out = WorkerOutcome { work: 1.0, fixed: 0.0 };
        st.perturb(0, 12.0, &mut out);
        assert_eq!((out.work, out.fixed), (1.0, 0.0));
    }

    #[test]
    fn fault_state_snapshot_restores_corrupt_overlay() {
        let p = FaultPlan::parse("corrupt:0@10:nan,corrupt:1@20:inf").unwrap();
        let mut st = p.state();
        assert_eq!(st.corruptions(0, 12.0), vec![Corruption::Nan]);
        let snap = st.snapshot();
        let mut st2 = p.state();
        st2.restore(&snap).unwrap();
        // Consumed corruption stays consumed; the other still fires.
        assert!(st2.corruptions(0, 13.0).is_empty());
        assert_eq!(st2.corruptions(1, 25.0), vec![Corruption::Inf]);
    }

    #[test]
    fn guard_cfg_parses_validates_and_roundtrips() {
        let g = GuardCfg::parse("norm=4.5,strikes=2,probation=15,late=drop,window=8").unwrap();
        assert_eq!(g.norm_k, 4.5);
        assert_eq!(g.strikes, 2);
        assert_eq!(g.probation_s, 15.0);
        assert_eq!(g.late, LatePolicy::Drop);
        assert_eq!(g.window, 8);
        assert_eq!(GuardCfg::parse(&g.spec()).unwrap(), g);
        // Defaults fill missing keys and roundtrip.
        let d = GuardCfg::parse("norm=6").unwrap();
        assert_eq!(d.strikes, GuardCfg::default().strikes);
        assert_eq!(d.late, LatePolicy::Readmit);
        let d0 = GuardCfg::default();
        assert_eq!(GuardCfg::parse(&d0.spec()).unwrap(), d0);
        for bad in [
            "",
            "norm=0",
            "norm=-2",
            "strikes=0",
            "probation=0",
            "probation=-5",
            "window=2",
            "late=maybe",
            "bogus=1",
        ] {
            assert!(GuardCfg::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn guard_rejects_nonfinite_and_out_of_band_norms() {
        let cfg = GuardCfg::parse("norm=8,strikes=3,probation=10").unwrap();
        let mut g = UpdateGuard::new(cfg, 3);
        // Non-finite is rejected even on a cold window.
        assert_eq!(g.check(0, f64::NAN), GuardVerdict::Reject);
        assert_eq!(g.strikes(0), 1);
        assert_eq!(g.check(0, f64::INFINITY), GuardVerdict::Reject);
        // Below GUARD_MIN_SAMPLES the norm gate is disarmed: anything
        // finite is accepted and resets the strike counter.
        assert_eq!(g.check(0, 1e9), GuardVerdict::Accept);
        assert_eq!(g.strikes(0), 0);
        // Build a healthy window around norm ≈ 1.
        let mut g = UpdateGuard::new(GuardCfg::default(), 3);
        for i in 0..10 {
            let n = 1.0 + 0.01 * (i % 3) as f64;
            assert_eq!(g.check(i % 3, n), GuardVerdict::Accept);
        }
        // In-band drift accepted; a 100× mis-scale is out of band.
        assert_eq!(g.check(1, 1.02), GuardVerdict::Accept);
        assert_eq!(g.check(1, 100.0), GuardVerdict::Reject);
        assert_eq!(g.check(1, 100.0), GuardVerdict::Reject);
        // Third consecutive strike escalates and resets the counter.
        assert_eq!(g.check(1, 100.0), GuardVerdict::Quarantine);
        assert_eq!(g.strikes(1), 0);
        // Rejected norms never entered the window: healthy values from
        // other workers still pass.
        assert_eq!(g.check(2, 1.01), GuardVerdict::Accept);
    }

    #[test]
    fn guard_zero_spread_window_keeps_a_usable_band() {
        // The sim backend models constant unit norms: MAD = 0.  The 5%
        // median floor keeps the band open so identical norms pass and
        // gross corruption still fails.
        let mut g = UpdateGuard::new(GuardCfg::default(), 2);
        for _ in 0..8 {
            assert_eq!(g.check(0, 1.0), GuardVerdict::Accept);
        }
        assert_eq!(g.check(1, 1.0), GuardVerdict::Accept);
        // norm_k=8 × 5% band: 1.3 is in (|1.3-1| ≤ 0.4), 2.0 is out.
        assert_eq!(g.check(1, 1.3), GuardVerdict::Accept);
        assert_eq!(g.check(1, 2.0), GuardVerdict::Reject);
    }

    #[test]
    fn guard_snapshot_restore_is_exact() {
        let cfg = GuardCfg::parse("norm=8,strikes=3,probation=10,window=6").unwrap();
        let mut g = UpdateGuard::new(cfg.clone(), 3);
        for i in 0..9 {
            let _ = g.check(i % 3, 1.0 + 0.01 * i as f64);
        }
        let _ = g.check(2, f64::NAN); // leave a strike in place
        assert_eq!(g.strikes(2), 1);
        let snap = g.snapshot();
        let j = Json::parse(&snap.to_pretty()).unwrap();
        let mut r = UpdateGuard::restore(cfg.clone(), 3, &j).unwrap();
        assert_eq!(r.strikes(2), 1);
        // The continued verdict streams agree.
        for (w, n) in [(0, 1.05), (2, f64::NAN), (1, 50.0), (2, 1.0)] {
            assert_eq!(g.check(w, n), r.check(w, n), "divergence at {w}/{n}");
        }
        // Mismatched rank count is rejected.
        assert!(UpdateGuard::restore(cfg, 2, &j).is_err());
    }

    #[test]
    fn throughput_trigger_fires_on_dip_below_best() {
        let cfg = AutoscalerCfg::parse("pool=1,cold=1,floor=1,tput=0.5").unwrap();
        let mut a = Autoscaler::new(cfg, 2, 0);
        a.observe_throughput(100.0);
        // Live count satisfies the floor, throughput fine: no spawn.
        assert!(!a.wants_spawn(2, 0.0, Some(80.0)));
        // Throughput collapses below 50% of best: spawn even above floor.
        assert!(a.wants_spawn(2, 0.0, Some(40.0)));
        let _ = a.try_spawn(0.0);
        // With a replacement pending the trigger quiesces.
        assert!(!a.wants_spawn(2, 0.5, Some(40.0)));
    }
}
