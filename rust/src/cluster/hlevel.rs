//! H-level cluster generation (paper §IV-A).
//!
//! `H-level = max cores / min cores` with the *total* core count held
//! constant, so experiments isolate heterogeneity from capacity.  The
//! paper's examples on a 39-core/3-worker cluster: H=2 → (9, 12, 18),
//! H=10 → (2, 17, 20), H=6 → e.g. (3, 13, 18)... this module searches the
//! integer splits and returns the one whose middle workers are closest to
//! the geometric mean of min and max (matching the paper's shapes).

/// Split `total` cores across `k` workers with max/min == `h` (as close as
/// integers allow), total preserved exactly. Returns ascending core counts.
pub fn hlevel_split(total: usize, k: usize, h: f64) -> Option<Vec<usize>> {
    assert!(k >= 2, "need at least two workers");
    assert!(h >= 1.0, "H-level must be >= 1");
    let mut best: Option<(f64, Vec<usize>)> = None;
    // Try every min core count; derive max = round(h*min); fill middles.
    for min_c in 1..=(total / k) {
        let max_c = (h * min_c as f64).round() as usize;
        if max_c < min_c || min_c + max_c > total {
            continue;
        }
        let actual_h = max_c as f64 / min_c as f64;
        // Keep only splits with the right ratio (within rounding).
        if (actual_h - h).abs() > 0.5 && (actual_h / h - 1.0).abs() > 0.1 {
            continue;
        }
        let remaining = total - min_c - max_c;
        let mids = k - 2;
        if mids == 0 {
            if remaining != 0 {
                continue;
            }
            let split = vec![min_c, max_c];
            score_candidate(&mut best, h, split);
            continue;
        }
        // Distribute `remaining` across middles, each in [min_c, max_c].
        if remaining < mids * min_c || remaining > mids * max_c {
            continue;
        }
        let base = remaining / mids;
        let mut extra = remaining - base * mids;
        let mut mid_vals = vec![base; mids];
        for v in mid_vals.iter_mut() {
            if extra == 0 {
                break;
            }
            let bump = (max_c - *v).min(extra);
            *v += bump;
            extra -= bump;
        }
        if extra > 0 || mid_vals.iter().any(|&v| v < min_c || v > max_c) {
            continue;
        }
        let mut split = vec![min_c];
        split.extend(mid_vals);
        split.push(max_c);
        split.sort_unstable();
        score_candidate(&mut best, h, split);
    }
    best.map(|(_, v)| v)
}

fn score_candidate(best: &mut Option<(f64, Vec<usize>)>, h: f64, split: Vec<usize>) {
    let min_c = *split.first().unwrap() as f64;
    let max_c = *split.last().unwrap() as f64;
    let actual_h = max_c / min_c;
    // Primary: match H exactly. Secondary: middles near the arithmetic
    // mean of min and max — this reproduces both paper examples,
    // (9, 12, 18) at H=2 and (2, 17, 20) at H=10.
    let am = (min_c + max_c) / 2.0;
    let mid_err: f64 = split[1..split.len() - 1]
        .iter()
        .map(|&v| ((v as f64 - am) / am).powi(2))
        .sum();
    let score = (actual_h - h).abs() * 100.0 + mid_err;
    if best.as_ref().map_or(true, |(s, _)| score < *s) {
        *best = Some((score, split));
    }
}

/// The paper's H-level sweep values (Fig. 6 x-axis).
pub const PAPER_HLEVELS: [f64; 6] = [1.0, 2.0, 4.0, 6.0, 8.0, 10.0];

/// The paper's local-cluster total: 39 cores across 3 workers.
pub const PAPER_TOTAL_CORES: usize = 39;
pub const PAPER_WORKERS: usize = 3;

#[cfg(test)]
mod tests {
    use super::*;

    fn check(total: usize, k: usize, h: f64) -> Vec<usize> {
        let split = hlevel_split(total, k, h)
            .unwrap_or_else(|| panic!("no split for total={total} k={k} h={h}"));
        assert_eq!(split.iter().sum::<usize>(), total, "{split:?}");
        assert_eq!(split.len(), k);
        let actual = *split.last().unwrap() as f64 / split[0] as f64;
        assert!(
            (actual - h).abs() / h < 0.35,
            "h={h} actual={actual} split={split:?}"
        );
        split
    }

    #[test]
    fn paper_h2_is_9_12_18() {
        // §IV-A: "a H-level of 2 would yield a (9, 12, 18)".
        let split = check(39, 3, 2.0);
        assert_eq!(split, vec![9, 12, 18]);
    }

    #[test]
    fn paper_h10_has_tiny_worker() {
        // §IV-A: "H-level 10 is a (2,17,20) configuration" — exact middle
        // placement may differ, but min=2, max=20 are forced.
        let split = check(39, 3, 10.0);
        assert_eq!(split[0], 2);
        assert_eq!(*split.last().unwrap(), 20);
    }

    #[test]
    fn homogeneous_h1() {
        let split = check(39, 3, 1.0);
        assert_eq!(split, vec![13, 13, 13]);
    }

    #[test]
    fn all_paper_hlevels_have_splits() {
        for &h in &PAPER_HLEVELS {
            check(PAPER_TOTAL_CORES, PAPER_WORKERS, h);
        }
    }

    #[test]
    fn two_worker_splits() {
        let split = check(20, 2, 4.0);
        assert_eq!(split, vec![4, 16]);
    }

    #[test]
    fn impossible_split_returns_none() {
        // total too small for k workers at h.
        assert!(hlevel_split(3, 3, 10.0).is_none());
    }

    #[test]
    fn splits_are_ascending() {
        for &h in &[2.0, 4.0, 6.0] {
            let s = check(64, 4, h);
            for w in s.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }
}
