//! The worker capacity model: iteration time as a function of device,
//! batch size, workload, and current availability.
//!
//! This is the simulation substrate standing in for the paper's physical
//! testbed (DESIGN.md §1).  It reproduces the three behaviours the
//! paper's evaluation depends on:
//!
//! 1. **Amdahl intra-worker scaling** (§III-C): observed throughput on
//!    large workers is *below* core-count-proportional — exactly the
//!    open-loop estimation error the dynamic controller corrects.
//! 2. **Throughput-vs-batch curves** (Fig. 5): throughput ramps up with
//!    batch size (fixed per-iteration overhead amortizes), then declines —
//!    a sharp cliff on GPUs when device memory is exhausted, a gradual
//!    roll-off on CPUs.
//! 3. **Stochastic iteration noise**: lognormal multiplicative jitter, the
//!    shape reported for shared-cloud iteration times.

use crate::cluster::{DeviceKind, WorkerSpec};
use crate::util::rng::Rng;

/// Per-workload calibration. FLOP counts are per training sample
/// (fwd+bwd); rates were chosen so relative magnitudes across workloads
/// match the paper's description (ResNet compute-bound … LR comm-bound).
#[derive(Debug, Clone)]
pub struct WorkloadProfile {
    pub name: &'static str,
    /// fwd+bwd FLOPs per sample.
    pub flops_per_sample: f64,
    /// Fraction of per-sample work that parallelizes across cores (Amdahl).
    pub parallel_frac: f64,
    /// Model-update communication+sync time per iteration, seconds.
    /// Independent of batch size — this is why LR sees little benefit.
    pub comm_time_s: f64,
    /// Device memory consumed per sample in the batch, GiB (activations).
    pub mem_per_sample_gib: f64,
    /// Fixed per-iteration host-side overhead, seconds.
    pub overhead_s: f64,
    /// Iterations to reach the paper's target accuracy at reference global
    /// batch; the convergence model in `simulator` uses this.
    pub iters_to_target: u64,
    /// Reference per-worker batch size b0 (paper's uniform default).
    pub b0: usize,
}

impl WorkloadProfile {
    /// ResNet-50/CIFAR-10 class: heavily compute-bound.
    pub fn resnet() -> Self {
        WorkloadProfile {
            name: "resnet",
            flops_per_sample: 8.2e9, // ~2.7 GFLOPs fwd ⇒ ~8 GFLOPs fwd+bwd
            parallel_frac: 0.99,
            comm_time_s: 0.03, // 25M params, push/pull overlapped with bwd
            mem_per_sample_gib: 0.045,
            overhead_s: 0.02,
            iters_to_target: 30_000,
            b0: 128,
        }
    }

    /// MNIST CNN class: moderate compute.
    pub fn mnist() -> Self {
        WorkloadProfile {
            name: "mnist",
            // TF official MNIST CNN: two 5x5 conv layers dominate;
            // ~25 MFLOPs fwd => ~75 MFLOPs fwd+bwd per sample.
            flops_per_sample: 7.5e7,
            parallel_frac: 0.95,
            comm_time_s: 0.012,
            mem_per_sample_gib: 0.002,
            overhead_s: 0.008,
            iters_to_target: 20_000,
            b0: 100,
        }
    }

    /// Linear regression class: communication/synchronization-bound.
    pub fn linreg() -> Self {
        WorkloadProfile {
            name: "linreg",
            // The regression math is ~kFLOPs, but per-sample cost is
            // dominated by the input pipeline / op dispatch (~3 MFLOP
            // equivalent) — matching the paper's "least benefit, ~15%"
            // shape for LR).
            flops_per_sample: 3.0e6,
            parallel_frac: 0.85,
            comm_time_s: 0.035,
            mem_per_sample_gib: 1e-6,
            overhead_s: 0.008,
            iters_to_target: 8_000,
            b0: 256,
        }
    }

    /// Transformer-LM class (e2e example).
    pub fn transformer() -> Self {
        WorkloadProfile {
            name: "transformer",
            flops_per_sample: 9.0e9, // ~12M params × 128 tokens × 6
            parallel_frac: 0.98,
            comm_time_s: 0.15,
            mem_per_sample_gib: 0.02,
            overhead_s: 0.04,
            iters_to_target: 12_000,
            b0: 16,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "resnet" | "cnn" => Some(Self::resnet()),
            "mnist" | "mlp" => Some(Self::mnist()),
            "linreg" => Some(Self::linreg()),
            "transformer" => Some(Self::transformer()),
            _ => None,
        }
    }
}

/// Capacity model instance: (worker, workload) → iteration-time samples.
#[derive(Debug, Clone)]
pub struct CapacityModel {
    pub workload: WorkloadProfile,
    /// Lognormal sigma of iteration-time noise (0 disables).
    pub noise_sigma: f64,
    /// Effective FLOPs a single Xeon core sustains on training math.
    /// Achievable, not peak: ~23% of the AVX-512 roofline — TF CPU training
    /// efficiency is far below GPU efficiency, which is why the *true*
    /// GPU:CPU throughput ratio (~8x) exceeds the FLOPs-estimate ratio
    /// (4.3x) the static allocator uses. That gap is the controller's job.
    pub cpu_flops_per_core: f64,
    /// Fraction of GPU peak half-precision FLOPs actually achieved.
    pub gpu_efficiency: f64,
}

impl CapacityModel {
    pub fn new(workload: WorkloadProfile) -> Self {
        CapacityModel {
            workload,
            noise_sigma: 0.06,
            cpu_flops_per_core: 3.1e10,
            gpu_efficiency: 0.45,
        }
    }

    pub fn with_noise(mut self, sigma: f64) -> Self {
        self.noise_sigma = sigma;
        self
    }

    /// Amdahl speedup of `cores` over 1 core for this workload.
    fn amdahl(&self, cores: f64) -> f64 {
        let p = self.workload.parallel_frac;
        1.0 / ((1.0 - p) + p / cores)
    }

    /// Peak sustainable throughput (samples/s) of a device at large batch,
    /// before the batch-efficiency curve is applied.
    pub fn peak_throughput(&self, device: &DeviceKind) -> f64 {
        match device {
            DeviceKind::Cpu { cores } => {
                // One core's sample rate, scaled by Amdahl (NOT linear in
                // cores — this is the open-loop estimation error).
                let one_core = self.cpu_flops_per_core / self.workload.flops_per_sample;
                one_core * self.amdahl(*cores as f64)
            }
            DeviceKind::Gpu { model } => {
                model.half_precision_tflops() * 1e12 * self.gpu_efficiency
                    / self.workload.flops_per_sample
            }
        }
    }

    /// Batch at which device memory is exhausted (Fig. 5's knee).
    pub fn mem_knee(&self, device: &DeviceKind) -> f64 {
        let mem_gib = match device {
            // Host RAM is large (256 GB on the paper's servers) but CPU
            // caches thrash earlier; model an effective working-set knee.
            DeviceKind::Cpu { cores } => 8.0 + *cores as f64 * 1.2,
            DeviceKind::Gpu { model } => model.mem_gib(),
        };
        // ~70% of memory goes to activations at the knee.
        0.7 * mem_gib / self.workload.mem_per_sample_gib.max(1e-12)
    }

    /// Batch-size efficiency in (0, 1]: ramp-up then decline (Fig. 5).
    pub fn batch_efficiency(&self, device: &DeviceKind, batch: f64) -> f64 {
        assert!(batch > 0.0);
        // Ramp: fixed per-iteration launch/dispatch amortizes; half
        // efficiency at b_half.
        let b_half = match device {
            // Intra-sample parallelism (convs etc.) keeps small batches
            // efficient on CPUs; ramp saturates well below core count.
            DeviceKind::Cpu { cores } => (*cores as f64 / 8.0).max(1.0),
            DeviceKind::Gpu { .. } => 12.0,
        };
        let ramp = batch / (batch + b_half);
        let knee = self.mem_knee(device);
        let decline = if batch <= knee {
            1.0
        } else {
            match device {
                // GPU: sharp cliff — throughput collapses past memory.
                DeviceKind::Gpu { .. } => (knee / batch).powf(3.0),
                // CPU: gradual decline from cache/RAM pressure.
                DeviceKind::Cpu { .. } => (knee / batch).powf(0.8),
            }
        };
        ramp * decline
    }

    /// Deterministic throughput (samples/s) at a batch size (Fig. 5 y-axis).
    pub fn throughput(&self, device: &DeviceKind, batch: f64) -> f64 {
        // Solve samples/time where time = overhead + batch/(peak·eff).
        let eff = self.batch_efficiency(device, batch);
        let compute = batch / (self.peak_throughput(device) * eff);
        batch / (self.workload.overhead_s + compute)
    }

    /// Deterministic iteration time (compute + comm + overhead), seconds.
    /// `avail` is the current capacity multiplier in (0, 1] from traces.
    pub fn iter_time_det(&self, device: &DeviceKind, batch: f64, avail: f64) -> f64 {
        assert!(avail > 0.0 && avail <= 1.0, "avail={avail}");
        let eff = self.batch_efficiency(device, batch);
        let compute = batch / (self.peak_throughput(device) * eff * avail);
        self.workload.overhead_s + compute + self.workload.comm_time_s
    }

    /// Full-capacity compute *work* (seconds) for one iteration of size
    /// `batch`, with optional lognormal noise. Feed this into
    /// [`crate::trace::AvailTrace::time_to_complete`] for trace-integrated
    /// timing; comm+overhead are added on top (they don't scale with the
    /// worker's compute capacity).
    pub fn compute_work(&self, device: &DeviceKind, batch: f64, rng: &mut Rng) -> f64 {
        let eff = self.batch_efficiency(device, batch);
        let det = batch / (self.peak_throughput(device) * eff);
        if self.noise_sigma == 0.0 {
            det
        } else {
            det * rng.lognormal(1.0, self.noise_sigma)
        }
    }

    /// Fixed per-iteration time that does not scale with capacity.
    pub fn fixed_time(&self) -> f64 {
        self.workload.overhead_s + self.workload.comm_time_s
    }

    /// Sampled iteration time with lognormal noise.
    pub fn iter_time(
        &self,
        device: &DeviceKind,
        batch: f64,
        avail: f64,
        rng: &mut Rng,
    ) -> f64 {
        let det = self.iter_time_det(device, batch, avail);
        if self.noise_sigma == 0.0 {
            det
        } else {
            det * rng.lognormal(1.0, self.noise_sigma)
        }
    }
}

/// Convenience: specs → per-worker deterministic throughputs at batch b.
pub fn throughputs(model: &CapacityModel, specs: &[WorkerSpec], batch: f64) -> Vec<f64> {
    specs
        .iter()
        .map(|s| model.throughput(&s.device, batch))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuModel;

    fn cpu(cores: usize) -> DeviceKind {
        DeviceKind::Cpu { cores }
    }

    #[test]
    fn amdahl_sublinear() {
        let m = CapacityModel::new(WorkloadProfile::resnet());
        let x12 = m.peak_throughput(&cpu(12));
        let x3 = m.peak_throughput(&cpu(3));
        let ratio = x12 / x3;
        // 4x cores must give >1x but <4x throughput.
        assert!(ratio > 2.0 && ratio < 4.0, "ratio={ratio}");
    }

    #[test]
    fn linreg_scales_worse_than_resnet() {
        let r = CapacityModel::new(WorkloadProfile::resnet());
        let l = CapacityModel::new(WorkloadProfile::linreg());
        let rr = r.peak_throughput(&cpu(16)) / r.peak_throughput(&cpu(2));
        let lr = l.peak_throughput(&cpu(16)) / l.peak_throughput(&cpu(2));
        assert!(rr > lr, "resnet {rr} vs linreg {lr}");
    }

    #[test]
    fn throughput_curve_rises_then_falls_gpu() {
        // Fig. 5a: GPU throughput rises with batch then collapses.
        let m = CapacityModel::new(WorkloadProfile::resnet());
        let g = DeviceKind::Gpu {
            model: GpuModel::P100,
        };
        let knee = m.mem_knee(&g);
        let low = m.throughput(&g, 2.0);
        let mid = m.throughput(&g, knee * 0.8);
        let high = m.throughput(&g, knee * 3.0);
        assert!(mid > low, "ramp: {low} -> {mid}");
        assert!(high < mid * 0.3, "cliff: {mid} -> {high}");
    }

    #[test]
    fn throughput_curve_gradual_on_cpu() {
        // Fig. 5b: CPU decline past the knee is gradual, not a cliff.
        let m = CapacityModel::new(WorkloadProfile::mnist());
        let c = cpu(16);
        let knee = m.mem_knee(&c);
        let mid = m.throughput(&c, knee * 0.9);
        let past = m.throughput(&c, knee * 3.0);
        assert!(past < mid, "must decline");
        assert!(past > mid * 0.2, "but gradually: {mid} -> {past}");
    }

    #[test]
    fn iter_time_monotone_in_batch() {
        let m = CapacityModel::new(WorkloadProfile::resnet());
        let c = cpu(8);
        let mut prev = 0.0;
        for b in [1.0, 4.0, 16.0, 64.0, 256.0] {
            let t = m.iter_time_det(&c, b, 1.0);
            assert!(t > prev, "t({b})={t} <= t(prev)={prev}");
            prev = t;
        }
    }

    #[test]
    fn reduced_availability_slows_compute_only() {
        let m = CapacityModel::new(WorkloadProfile::resnet());
        let c = cpu(8);
        let full = m.iter_time_det(&c, 64.0, 1.0);
        let half = m.iter_time_det(&c, 64.0, 0.5);
        assert!(half > full);
        // Comm+overhead don't scale, so it's less than 2x overall.
        assert!(half < 2.0 * full);
        let compute_full = full - m.workload.comm_time_s - m.workload.overhead_s;
        let compute_half = half - m.workload.comm_time_s - m.workload.overhead_s;
        assert!((compute_half / compute_full - 2.0).abs() < 1e-9);
    }

    #[test]
    fn noise_is_multiplicative_and_median_preserving() {
        let m = CapacityModel::new(WorkloadProfile::mnist()).with_noise(0.1);
        let c = cpu(4);
        let det = m.iter_time_det(&c, 32.0, 1.0);
        let mut rng = Rng::new(0);
        let mut v: Vec<f64> = (0..20_001)
            .map(|_| m.iter_time(&c, 32.0, 1.0, &mut rng))
            .collect();
        v.sort_by(f64::total_cmp);
        let med = v[v.len() / 2];
        assert!((med / det - 1.0).abs() < 0.02, "median drift {med} vs {det}");
    }

    #[test]
    fn gpu_much_faster_than_small_cpu_on_resnet() {
        let m = CapacityModel::new(WorkloadProfile::resnet());
        let g = DeviceKind::Gpu {
            model: GpuModel::P100,
        };
        let ratio = m.peak_throughput(&g) / m.peak_throughput(&cpu(48));
        // The paper's 4.3x is the FLOPs-*estimate* ratio; achieved
        // training throughput favors the GPU more (CPU efficiency is
        // poor), which the paper's own >4x speedup result requires.
        assert!(ratio > 4.0 && ratio < 12.0, "ratio={ratio}");
    }

    #[test]
    #[should_panic]
    fn zero_avail_rejected() {
        let m = CapacityModel::new(WorkloadProfile::mnist());
        m.iter_time_det(&cpu(4), 8.0, 0.0);
    }
}
