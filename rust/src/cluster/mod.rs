//! Heterogeneous cluster modeling: worker specs, device capacity, and the
//! H-level cluster generators used throughout the paper's evaluation.
//!
//! The paper defines heterogeneity level for CPU clusters as
//! `H-level = max cores / min cores` at *fixed total capacity* (§IV-A),
//! e.g. 39 total cores split (9, 12, 18) at H=2 or (2, 17, 20) at H=10.

pub mod capacity;
pub mod hlevel;

pub use capacity::{CapacityModel, WorkloadProfile};
pub use hlevel::hlevel_split;

/// What computes on a worker.
#[derive(Debug, Clone, PartialEq)]
pub enum DeviceKind {
    /// CPU worker with a core count (containers/VMs of different sizes).
    Cpu { cores: usize },
    /// GPU worker identified by its model profile.
    Gpu { model: GpuModel },
}

/// GPU models used in the paper's evaluation, with half-precision TFLOPs.
/// The paper's static allocator assigns batch proportional to these (§III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuModel {
    /// Nvidia Tesla P100-PCIe-16GB (local cluster GPU).
    P100,
    /// Nvidia Tesla T4 (cloud cluster).
    T4,
    /// Nvidia Tesla P4 (cloud cluster).
    P4,
}

impl GpuModel {
    /// Half-precision peak TFLOPs (marketing numbers — the paper's
    /// open-loop allocator uses exactly these, and its §III-C point is
    /// that they are *imperfect* predictors the controller must correct).
    pub fn half_precision_tflops(self) -> f64 {
        match self {
            GpuModel::P100 => 18.7,
            GpuModel::T4 => 65.0,
            GpuModel::P4 => 5.5,
        }
    }

    /// Device memory in GiB (bounds the batch size — Fig. 5's GPU cliff).
    pub fn mem_gib(self) -> f64 {
        match self {
            GpuModel::P100 => 16.0,
            GpuModel::T4 => 16.0,
            GpuModel::P4 => 8.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GpuModel::P100 => "P100",
            GpuModel::T4 => "T4",
            GpuModel::P4 => "P4",
        }
    }
}

impl DeviceKind {
    /// Half-precision FLOPs estimate used by the *static* (open-loop)
    /// variable-batching policy.  CPU: the paper's 48-core Xeon Platinum
    /// 2.10GHz ≈ 4.3 half-precision TFLOPs (it reports the P100:Xeon split
    /// as 0.813:0.187 ⇒ Xeon ≈ 18.7·0.187/0.813 ≈ 4.3).
    pub fn flops_estimate(&self) -> f64 {
        const XEON_TFLOPS_PER_CORE: f64 = 4.3 / 48.0;
        match self {
            DeviceKind::Cpu { cores } => *cores as f64 * XEON_TFLOPS_PER_CORE,
            DeviceKind::Gpu { model } => model.half_precision_tflops(),
        }
    }

    pub fn label(&self) -> String {
        match self {
            DeviceKind::Cpu { cores } => format!("cpu{cores}"),
            DeviceKind::Gpu { model } => model.name().to_string(),
        }
    }
}

/// One worker of the training cluster.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    pub id: usize,
    pub device: DeviceKind,
}

impl WorkerSpec {
    pub fn cpu(id: usize, cores: usize) -> Self {
        WorkerSpec {
            id,
            device: DeviceKind::Cpu { cores },
        }
    }

    pub fn gpu(id: usize, model: GpuModel) -> Self {
        WorkerSpec {
            id,
            device: DeviceKind::Gpu { model },
        }
    }
}

/// Build a CPU cluster from a core-count list.
pub fn cpu_cluster(cores: &[usize]) -> Vec<WorkerSpec> {
    cores
        .iter()
        .enumerate()
        .map(|(i, &c)| WorkerSpec::cpu(i, c))
        .collect()
}

/// The paper's mixed local cluster: one P100 + one 48-core Xeon (§IV-B).
pub fn mixed_gpu_cpu_cluster() -> Vec<WorkerSpec> {
    vec![
        WorkerSpec::gpu(0, GpuModel::P100),
        WorkerSpec::cpu(1, 48),
    ]
}

/// The paper's cloud GPU cluster: 2×T4 + 2×P4 (§IV-B).
pub fn cloud_gpu_cluster() -> Vec<WorkerSpec> {
    vec![
        WorkerSpec::gpu(0, GpuModel::T4),
        WorkerSpec::gpu(1, GpuModel::T4),
        WorkerSpec::gpu(2, GpuModel::P4),
        WorkerSpec::gpu(3, GpuModel::P4),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_scale_with_cores() {
        let small = DeviceKind::Cpu { cores: 4 }.flops_estimate();
        let big = DeviceKind::Cpu { cores: 16 }.flops_estimate();
        assert!((big / small - 4.0).abs() < 1e-9);
    }

    #[test]
    fn paper_gpu_cpu_flops_split_matches() {
        // §IV-B: "the ratios of the FLOPs ... between the GPU and CPU was
        // 0.813:0.187" for P100 vs 48-core Xeon.
        let gpu = DeviceKind::Gpu {
            model: GpuModel::P100,
        }
        .flops_estimate();
        let cpu = DeviceKind::Cpu { cores: 48 }.flops_estimate();
        let share = gpu / (gpu + cpu);
        assert!((share - 0.813).abs() < 0.01, "share={share}");
    }

    #[test]
    fn cluster_builders() {
        let c = cpu_cluster(&[3, 5, 12]);
        assert_eq!(c.len(), 3);
        assert_eq!(c[2].device, DeviceKind::Cpu { cores: 12 });
        assert_eq!(cloud_gpu_cluster().len(), 4);
        assert_eq!(mixed_gpu_cpu_cluster()[0].device.label(), "P100");
    }

    #[test]
    fn gpu_ordering_t4_fastest() {
        assert!(
            GpuModel::T4.half_precision_tflops()
                > GpuModel::P100.half_precision_tflops()
        );
        assert!(
            GpuModel::P100.half_precision_tflops()
                > GpuModel::P4.half_precision_tflops()
        );
    }
}
