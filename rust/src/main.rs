//! `hbatch` — leader CLI for the hetero-batch training system.
//!
//! Subcommands:
//!   simulate          virtual-time experiment (policy × cluster × workload)
//!   train             real-execution training over the PJRT runtime
//!   resume            continue a crashed run from its latest durable checkpoint
//!   fleet             N concurrent jobs on one shared elastic worker pool
//!   figure <id>       regenerate a paper figure (1|2|3|4a|4b|5|6|7a|7cloud|asp|buckets|revocation|policies)
//!   throughput-scan   print the Fig. 5 curve for a device
//!   info              artifact/manifest inventory
//!
//! Both `simulate` and `train` assemble the same [`SessionBuilder`]; the
//! only difference is which backend they build (`build_sim` vs
//! `build_real`), so every flag — including `--sync bsp|asp|ssp:<bound>`
//! — means the same thing in both worlds.

use std::path::Path;

use hetero_batch::ckpt::{recover_latest, Checkpointer, CkptSpec};
use hetero_batch::cluster::{cpu_cluster, hlevel_split};
use hetero_batch::config::{split_policy_spec, Policy};
use hetero_batch::fault::{
    AutoscalerCfg, CoordinatorCrash, DetectorCfg, FaultPlan, GuardCfg,
};
use hetero_batch::figures;
use hetero_batch::fleet::{job_seed, ArbiterPolicy, FleetBuilder, JobSpec};
use hetero_batch::runtime::Runtime;
use hetero_batch::session::{
    CkptOutcome, Scheduler, Session, SessionBuilder, Slowdowns,
};
use hetero_batch::sync::SyncMode;
use hetero_batch::trace::{JoinSpec, SpotSpec};
use hetero_batch::util::cli::Args;
use hetero_batch::util::fs::atomic_write;
use hetero_batch::util::json::Json;

/// Parse the shared elastic-membership flags (`--spot mttf:down[:grace]`
/// and `--join k@t[,k@t...]`) and fold them into the builder.  Both
/// subcommands validate these *before* any artifact is opened, with the
/// same error text (`bad --spot` / `bad --join`, matching `bad --sync`).
fn apply_membership_flags(
    builder: SessionBuilder,
    a: &Args,
) -> Result<SessionBuilder, String> {
    let mut builder = builder;
    let spot = a.get("spot");
    if !spot.is_empty() {
        let spec = SpotSpec::parse(&spot).ok_or("bad --spot")?;
        builder = builder.spot(spec);
    }
    let join = a.get("join");
    if !join.is_empty() {
        let joins = JoinSpec::parse_list(&join).ok_or("bad --join")?;
        builder = builder.joins(&joins);
    }
    Ok(builder)
}

/// Parse the shared fault-tolerance flags (`--faults`, `--detect`,
/// `--autoscale`; DESIGN.md §12) and fold them into the builder.  Like
/// the membership flags, both subcommands validate these before any
/// artifact is opened, with matching error text.
fn apply_fault_flags(builder: SessionBuilder, a: &Args) -> Result<SessionBuilder, String> {
    let mut builder = builder;
    let faults = a.get("faults");
    if !faults.is_empty() {
        let plan = FaultPlan::parse(&faults).map_err(|e| format!("bad --faults: {e}"))?;
        builder = builder.faults(plan);
    }
    let detect = a.get("detect");
    if !detect.is_empty() {
        let cfg = DetectorCfg::parse(&detect).map_err(|e| format!("bad --detect: {e}"))?;
        builder = builder.detector(cfg);
    }
    let autoscale = a.get("autoscale");
    if !autoscale.is_empty() {
        let cfg =
            AutoscalerCfg::parse(&autoscale).map_err(|e| format!("bad --autoscale: {e}"))?;
        builder = builder.autoscale(cfg);
    }
    Ok(builder)
}

/// Parse the data-plane fault-tolerance flags (`--corrupt` and
/// `--guard`; DESIGN.md §16) and fold them into the builder.  Shared by
/// simulate, train, and the fleet's synthetic jobs, with matching
/// error text; fleet config-file jobs use the `corrupt`/`guard`
/// session keys instead.
fn apply_guard_flags(builder: SessionBuilder, a: &Args) -> Result<SessionBuilder, String> {
    let mut builder = builder;
    let corrupt = a.get("corrupt");
    if !corrupt.is_empty() {
        let plan =
            FaultPlan::parse_corrupt(&corrupt).map_err(|e| format!("bad --corrupt: {e}"))?;
        builder = builder.corrupt(plan);
    }
    let guard = a.get("guard");
    if !guard.is_empty() {
        let cfg = GuardCfg::parse(&guard).map_err(|e| format!("bad --guard: {e}"))?;
        builder = builder.guard(cfg);
    }
    Ok(builder)
}

/// Parse the shared checkpoint flags (`--checkpoint dir[:every_s][:keep_n]`
/// and the `--crash-at <t>` coordinator-crash injection; DESIGN.md §15).
/// Validated before any artifact is opened, matching the other shared
/// flags' error-text convention.
fn parse_ckpt_flags(a: &Args) -> Result<(Option<CkptSpec>, Option<f64>), String> {
    let ckpt = a.get("checkpoint");
    let spec = if ckpt.is_empty() {
        None
    } else {
        Some(CkptSpec::parse(&ckpt).map_err(|e| format!("bad --checkpoint: {e}"))?)
    };
    let crash = a.get("crash-at");
    let crash_at = if crash.is_empty() {
        None
    } else {
        let c =
            CoordinatorCrash::parse(&crash).map_err(|e| format!("bad --crash-at: {e}"))?;
        Some(c.at_s)
    };
    if crash_at.is_some() && spec.is_none() {
        return Err(
            "bad --crash-at: the coordinator-crash scenario needs --checkpoint \
             (there is nothing to recover from otherwise)"
                .into(),
        );
    }
    Ok((spec, crash_at))
}

/// Parse the shared `--policy` flag, including the `rl:<table.json>`
/// form, and fold policy + table path into the builder.  Both
/// subcommands validate the spec (and, via `validate()`, the table
/// file) before any artifact is opened.
fn apply_policy_flag(
    builder: SessionBuilder,
    spec: &str,
) -> Result<SessionBuilder, String> {
    let (name, table) = split_policy_spec(spec);
    let policy = Policy::parse(name).ok_or("bad --policy")?;
    let mut builder = builder.policy(policy);
    if let Some(t) = table {
        builder = builder.rl_table(t);
    }
    Ok(builder)
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match raw.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    let result = match cmd {
        "simulate" => cmd_simulate(&rest),
        "train" => cmd_train(&rest),
        "resume" => cmd_resume(&rest),
        "fleet" => cmd_fleet(&rest),
        "figure" => cmd_figure(&rest),
        "throughput-scan" => cmd_scan(&rest),
        "info" => cmd_info(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{}", usage())),
    };
    if let Err(e) = result {
        eprintln!("{e}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "hbatch — dynamic batching for heterogeneous distributed training\n\
     commands:\n\
     \x20 simulate          virtual-time experiment (fast, reproduces paper figures)\n\
     \x20 train             real training over AOT-compiled XLA artifacts\n\
     \x20 resume            continue a crashed run from its latest durable checkpoint\n\
     \x20 fleet             N concurrent jobs on one shared elastic worker pool\n\
     \x20 figure <id>       regenerate a paper figure: 1 2 3 4a 4b 5 6 7a 7cloud asp buckets revocation policies all\n\
     \x20 throughput-scan   throughput-vs-batch curve for a device\n\
     \x20 info              show artifact manifest\n\
     run `hbatch <cmd> --help` for options"
        .into()
}

fn cmd_simulate(rest: &[String]) -> Result<(), String> {
    let a = Args::new("hbatch simulate", "virtual-time training experiment")
        .opt("workload", "resnet", "resnet|mnist|linreg|transformer")
        .opt("cores", "9,12,18", "per-worker CPU cores")
        .opt("hlevel", "0", "generate cores from H-level (overrides --cores)")
        .opt("policy", "dynamic", "uniform|static|dynamic|pid|optimal|rl[:table.json]")
        .opt("sync", "bsp", "bsp|asp|ssp:<bound>")
        .opt("iters", "600", "global iterations (0 = run to target)")
        .opt("b0", "0", "reference per-worker batch (0 = workload default)")
        .opt("adjust-cost", "30", "seconds charged per batch readjustment")
        .opt("noise", "0.06", "lognormal iteration-time noise sigma")
        .opt("seed", "0", "rng seed")
        .opt("spot", "", "spot churn mttf:down[:grace] (s): revoke/rejoin workers")
        .opt("join", "", "scheduled joins k@t[,k@t..]: worker k first appears at t")
        .opt("faults", "", "fault schedule crash:W@T | stall:W@T:D | slow:W@T:F:D, comma-joined")
        .opt("corrupt", "", "gradient corruption W@T:nan|inf|bitflip:N|scale:F[:dur], comma-joined (needs --guard)")
        .opt("guard", "", "update guard norm=K,strikes=S,probation=D,late=readmit|drop[,window=N]")
        .opt("detect", "", "failure detector grace=G,floor=S,late=readmit|drop")
        .opt("autoscale", "", "autoscaler pool=N,cold=S[,floor=K,backoff=S,cap=S,jitter=J,fail=P,retries=N,ride,tput=F]")
        .opt("scheduler", "heap", "event scheduling: heap (O(log k)) | scan (O(k) baseline)")
        .opt("report-sample", "1", "keep every n-th round/update record (bounds report memory at large k)")
        .opt("checkpoint", "", "durable checkpoints dir[:every_s][:keep_n]; resume with `hbatch resume`")
        .opt("crash-at", "", "coordinator-crash injection: die (no final snapshot) once virtual time passes t")
        .opt("config", "", "JSON config file (explicit CLI flags override)")
        .parse(rest)?;

    let builder = if a.get("config").is_empty() {
        Session::builder()
    } else {
        SessionBuilder::from_file(&a.get("config"))?
    };
    let h = a.get_f64("hlevel");
    let cores = if h >= 1.0 {
        hlevel_split(39, 3, h).ok_or(format!("no H-level {h} split"))?
    } else {
        a.get_usize_list("cores")
    };
    if cores.is_empty() {
        return Err("--cores must list at least one worker".into());
    }
    let k = cores.len();
    let builder = builder
        .model(&a.get("workload"))
        .workers(cpu_cluster(&cores))
        .sync(SyncMode::parse(&a.get("sync")).ok_or("bad --sync")?)
        .steps(a.get_u64("iters"))
        .b0(a.get_usize("b0"))
        .adjust_cost(a.get_f64("adjust-cost"))
        .noise(a.get_f64("noise"))
        .seed(a.get_u64("seed"));
    let builder = apply_policy_flag(builder, &a.get("policy"))?;
    // Applied only when explicitly passed, so the declared defaults
    // never clobber a --config file's `scheduler`/`report_sample` keys.
    let mut builder = builder;
    if a.provided("scheduler") {
        builder =
            builder.scheduler(Scheduler::parse(&a.get("scheduler")).ok_or("bad --scheduler")?);
    }
    if a.provided("report-sample") {
        builder = builder.report_sample(a.get_u64("report-sample"));
    }
    let builder = apply_membership_flags(builder, &a)?;
    let builder = apply_fault_flags(builder, &a)?;
    let builder = apply_guard_flags(builder, &a)?;
    let (ckpt, crash_at) = parse_ckpt_flags(&a)?;
    builder.validate()?;

    let Some(spec) = ckpt else {
        let r = builder
            .build_sim()
            .map_err(|e| e.to_string())?
            .run()
            .map_err(|e| e.to_string())?;
        println!("{}", r.to_json(k).to_pretty());
        return Ok(());
    };
    // Checkpointed run: the config echo (plus a backend discriminator
    // for `resume`) rides inside every committed checkpoint.
    let mut config = builder.to_json()?;
    config.set("backend", Json::Str("sim".into()));
    let mut ck = Checkpointer::open(spec)?;
    let mut sess = builder.build_sim().map_err(|e| e.to_string())?;
    match sess
        .run_checkpointed(&config, &mut ck, crash_at)
        .map_err(|e| e.to_string())?
    {
        CkptOutcome::Completed(r) => println!("{}", r.to_json(k).to_pretty()),
        CkptOutcome::Stopped { t } => println!(
            "coordinator crashed at t={t:.3}s; resume with `hbatch resume --from {}`",
            ck.spec().dir.display()
        ),
    }
    Ok(())
}

fn cmd_resume(rest: &[String]) -> Result<(), String> {
    let a = Args::new(
        "hbatch resume",
        "continue a crashed run from its latest durable checkpoint",
    )
    .opt("from", "", "checkpoint directory (as given to --checkpoint)")
    .opt(
        "checkpoint",
        "",
        "keep checkpointing: dir[:every_s][:keep_n] (default: --from with default cadence)",
    )
    .parse(rest)?;

    let from = a.get("from");
    if from.is_empty() {
        return Err("bad --from: which checkpoint directory?".into());
    }
    let spec = if a.get("checkpoint").is_empty() {
        CkptSpec::parse(&from).map_err(|e| format!("bad --from: {e}"))?
    } else {
        CkptSpec::parse(&a.get("checkpoint")).map_err(|e| format!("bad --checkpoint: {e}"))?
    };

    let lc = recover_latest(Path::new(&from))?;
    match lc.config.get("backend").as_str() {
        // Pre-discriminator checkpoints can only have come from simulate.
        Some("sim") | None => {}
        Some("real") => {
            return Err(format!(
                "checkpoint {from:?} came from `hbatch train` (real backend); resume \
                 is sim-only for now — the real sidecar restores model/optimizer \
                 state consistently, but the runtime's execution streams cannot yet \
                 be replayed deterministically (the ROADMAP's \"Real-backend \
                 bit-identical resume\" gap), so a resumed run would not be \
                 bit-identical. Restart with `hbatch train`."
            ))
        }
        Some(other) => {
            return Err(format!("checkpoint config names unknown backend {other:?}"))
        }
    }

    let builder = SessionBuilder::from_json(&lc.config)?;
    let mut sess = builder.build_sim().map_err(|e| e.to_string())?;
    let k = sess.backend().k();
    let rs = sess
        .restore_run(&lc.state, lc.backend_bin.as_deref())
        .map_err(|e| e.to_string())?;
    eprintln!("resuming from {} (seq {})", lc.path.display(), lc.seq);
    let mut ck = Checkpointer::open(spec)?;
    match sess
        .resume_checkpointed(rs, &lc.config, &mut ck, None)
        .map_err(|e| e.to_string())?
    {
        CkptOutcome::Completed(r) => println!("{}", r.to_json(k).to_pretty()),
        CkptOutcome::Stopped { .. } => unreachable!("resume runs without crash injection"),
    }
    Ok(())
}

fn cmd_fleet(rest: &[String]) -> Result<(), String> {
    let a = Args::new(
        "hbatch fleet",
        "N concurrent training jobs arbitrated over one shared elastic worker pool",
    )
    .opt("config", "", "fleet JSON {capacity?, policy?, seed?, jobs: [{<session keys>, name?, weight?, priority?, arrival?}, ..]}")
    .opt("jobs", "4", "synthetic fleet: number of jobs (ignored with --config)")
    .opt("workload", "mnist", "synthetic fleet: workload per job")
    .opt("cores", "4,8", "synthetic fleet: per-worker cores per job")
    .opt("iters", "60", "synthetic fleet: iterations per job")
    .opt("arrival-gap", "0", "synthetic fleet: seconds between consecutive arrivals")
    .opt("corrupt", "", "synthetic fleet: per-job gradient corruption W@T:nan|inf|bitflip:N|scale:F[:dur] (needs --guard)")
    .opt("guard", "", "synthetic fleet: per-job update guard norm=K,strikes=S,probation=D,late=readmit|drop[,window=N]")
    .opt("capacity", "0", "shared worker capacity (0 = uncontended: total demand)")
    .opt("policy", "fair", "capacity arbitration: fair|priority")
    .opt("seed", "0", "fleet seed: jobs without their own get job_seed(seed, id)")
    .opt("checkpoint", "", "durable whole-fleet checkpoints dir[:every_s][:keep_n]; rerun the same command to resume")
    .opt("crash-at", "", "coordinator-crash injection: die (no final snapshot) once the fleet clock passes t")
    .flag("interleave", "force the deterministic interleaved scheduler even when uncontended")
    .parse(rest)?;

    let mut f = if a.get("config").is_empty() {
        let n = a.get_usize("jobs").max(1);
        let cores = a.get_usize_list("cores");
        if cores.is_empty() {
            return Err("--cores must list at least one worker".into());
        }
        let seed = a.get_u64("seed");
        let gap = a.get_f64("arrival-gap");
        let mut f = FleetBuilder::new().seed(seed);
        for i in 0..n {
            let b = Session::builder()
                .model(&a.get("workload"))
                .workers(cpu_cluster(&cores))
                .steps(a.get_u64("iters"))
                .seed(job_seed(seed, i as u64));
            let b = apply_guard_flags(b, &a)?;
            let mut spec = JobSpec::new(&format!("job{i}"), b);
            spec.arrival = gap * i as f64;
            f = f.job(spec);
        }
        f
    } else {
        FleetBuilder::from_file(&a.get("config"))?
    };
    if a.get_usize("capacity") > 0 {
        f = f.capacity(a.get_usize("capacity"));
    }
    if a.provided("policy") {
        f = f.policy(ArbiterPolicy::parse(&a.get("policy")).ok_or("bad --policy")?);
    }
    if a.get_flag("interleave") {
        f = f.interleave(true);
    }
    let (ckpt, crash_at) = parse_ckpt_flags(&a)?;
    if let Some(spec) = ckpt {
        f = f.checkpoint(spec);
    }
    if let Some(t) = crash_at {
        f = f.crash_at(t);
    }
    match f.build()?.run_resumable().map_err(|e| e.to_string())? {
        Some(report) => println!("{}", report.to_json().to_pretty()),
        None => println!(
            "fleet coordinator crashed at t={:.3}s; rerun the same command to resume",
            crash_at.expect("crash injection requires --crash-at")
        ),
    }
    Ok(())
}

fn cmd_train(rest: &[String]) -> Result<(), String> {
    let a = Args::new("hbatch train", "real-execution training (PJRT runtime)")
        .opt("model", "mlp", "manifest model: linreg|mlp|cnn|transformer")
        .opt("policy", "dynamic", "uniform|static|dynamic|pid|optimal|rl[:table.json]")
        .opt("sync", "bsp", "bsp|asp|ssp:<bound>")
        .opt("steps", "50", "global training steps")
        .opt("cores", "4,8,16", "simulated worker core counts (heterogeneity)")
        .opt("seed", "0", "rng seed")
        .opt("spot", "", "spot churn mttf:down[:grace] (s): revoke/rejoin workers")
        .opt("join", "", "scheduled joins k@t[,k@t..]: worker k first appears at t")
        .opt("faults", "", "fault schedule crash:W@T | stall:W@T:D | slow:W@T:F:D, comma-joined")
        .opt("corrupt", "", "gradient corruption W@T:nan|inf|bitflip:N|scale:F[:dur], comma-joined (needs --guard)")
        .opt("guard", "", "update guard norm=K,strikes=S,probation=D,late=readmit|drop[,window=N]")
        .opt("detect", "", "failure detector grace=G,floor=S,late=readmit|drop")
        .opt("autoscale", "", "autoscaler pool=N,cold=S[,floor=K,backoff=S,cap=S,jitter=J,fail=P,retries=N,ride,tput=F]")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("loss-target", "0", "stop early at this train loss (0 = off)")
        .opt("eval-every", "0", "run an eval step every N global steps (0 = never)")
        .opt("pool-threads", "4", "PS hot-path shards on the worker pool (1 = single-threaded)")
        .flag("no-prefetch", "disable batch-generation/train-step overlap")
        .flag("collect-agg", "BSP: collect gradients and aggregate at the barrier (baseline; default is the eager reduction tree)")
        .opt("scheduler", "heap", "event scheduling: heap (O(log k)) | scan (O(k) baseline)")
        .opt("report-sample", "1", "keep every n-th round/update record (bounds report memory at large k)")
        .opt("checkpoint", "", "durable checkpoints dir[:every_s][:keep_n] (model+optimizer in a binary sidecar)")
        .opt("crash-at", "", "coordinator-crash injection: die (no final snapshot) once virtual time passes t")
        .opt("report", "", "write full JSON report to this path")
        .parse(rest)?;

    // Parse and validate every flag before opening the runtime, so a bad
    // `--sync`/`--policy` fails fast with the same error text as
    // `simulate` — even without built artifacts.
    let sync = SyncMode::parse(&a.get("sync")).ok_or("bad --sync")?;
    let cores = a.get_usize_list("cores");
    if cores.is_empty() {
        return Err("--cores must list at least one worker".into());
    }
    let k = cores.len();
    let builder = apply_policy_flag(Session::builder(), &a.get("policy"))?;
    let builder = builder
        .model(&a.get("model"))
        .workers(cpu_cluster(&cores))
        .sync(sync)
        .steps(a.get_u64("steps"))
        .eval_every(a.get_u64("eval-every"))
        .seed(a.get_u64("seed"))
        .pool_threads(a.get_usize("pool-threads"))
        .prefetch(!a.get_flag("no-prefetch"))
        .eager_agg(!a.get_flag("collect-agg"))
        .loss_target(a.get_f64("loss-target"))
        .report_sample(a.get_u64("report-sample"))
        .scheduler(Scheduler::parse(&a.get("scheduler")).ok_or("bad --scheduler")?)
        .slowdowns(Slowdowns::from_cores(&cores));
    let builder = apply_membership_flags(builder, &a)?;
    let builder = apply_fault_flags(builder, &a)?;
    let builder = apply_guard_flags(builder, &a)?;
    let (ckpt, crash_at) = parse_ckpt_flags(&a)?;
    builder.validate()?;

    let mut runtime = Runtime::open(a.get("artifacts")).map_err(|e| e.to_string())?;
    let report = match ckpt {
        None => builder
            .build_real(&mut runtime)
            .map_err(|e| e.to_string())?
            .run()
            .map_err(|e| e.to_string())?,
        Some(spec) => {
            let mut config = builder.to_json()?;
            config.set("backend", Json::Str("real".into()));
            let mut ck = Checkpointer::open(spec)?;
            let mut sess = builder.build_real(&mut runtime).map_err(|e| e.to_string())?;
            match sess
                .run_checkpointed(&config, &mut ck, crash_at)
                .map_err(|e| e.to_string())?
            {
                CkptOutcome::Completed(r) => r,
                CkptOutcome::Stopped { t } => {
                    println!(
                        "coordinator crashed at t={t:.3}s; checkpoints (model + \
                         optimizer sidecar) are in {}",
                        ck.spec().dir.display()
                    );
                    return Ok(());
                }
            }
        }
    };

    // Compact progress print.
    println!("run: {}", report.label);
    println!(
        "steps: {}  wall: {:.1}s",
        report.total_iters, report.total_time
    );
    match (report.losses.first(), report.losses.last()) {
        (Some((_, _, first)), Some((_, _, last))) => {
            println!("loss: {first:.4} -> {last:.4}");
        }
        _ => println!("loss: no losses recorded"),
    }
    println!("adjustments: {}", report.adjustments.len());
    if !report.epochs.is_empty() {
        println!("membership epochs: {}", report.epochs.len());
    }
    if let Some(e) = report.evals.last() {
        println!(
            "evals: {} (last @ step {}: loss {:.4}, metric {:.4})",
            report.evals.len(),
            e.iter,
            e.loss,
            e.metric
        );
    }
    if let Some(b) = report.final_batches() {
        println!("final batches: {b:?}");
    }
    if !a.get("report").is_empty() {
        let path = a.get("report");
        atomic_write(Path::new(&path), report.to_json(k).to_pretty().as_bytes())
            .map_err(|e| e.to_string())?;
        println!("report written to {path}");
    }
    Ok(())
}

fn cmd_figure(rest: &[String]) -> Result<(), String> {
    let a = Args::new("hbatch figure", "regenerate a paper figure")
        .opt("seed", "0", "rng seed")
        .opt("out-dir", "figures_out", "CSV output directory")
        .parse(rest)?;
    let seed = a.get_u64("seed");
    let which = a
        .positionals()
        .first()
        .ok_or("which figure? 1 2 3 4a 4b 5 6 7a 7cloud asp buckets revocation policies all")?
        .clone();
    let out_dir = a.get("out-dir");
    let ids: Vec<&str> = if which == "all" {
        vec![
            "1", "2", "3", "4a", "4b", "5", "6", "7a", "7cloud", "asp", "buckets",
            "revocation", "policies",
        ]
    } else {
        vec![which.as_str()]
    };
    for id in ids {
        let (name, table) = match id {
            "1" => ("fig1_hetero_penalty", figures::fig1(seed)),
            "2" => ("fig2_timeline", figures::fig2(seed)),
            "3" => ("fig3_iter_time_hist", figures::fig3(seed).0),
            "4a" => ("fig4a_convergence", figures::fig4(true, seed)),
            "4b" => ("fig4b_oscillation", figures::fig4(false, seed)),
            "5" => ("fig5_throughput_vs_batch", figures::fig5()),
            "6" => ("fig6_bsp_hlevel", figures::fig6(seed)),
            "7a" => ("fig7a_gpu_cpu", figures::fig7a(seed)),
            "7cloud" => ("fig7_cloud_t4_p4", figures::fig7_cloud(seed)),
            "asp" => ("fig_asp", figures::fig_asp(seed)),
            "buckets" => ("fig_buckets_ablation", figures::fig_buckets(seed)),
            "revocation" => ("fig_revocation_timeline", figures::fig_revocation(seed)),
            "policies" => ("fig_policy_head2head", figures::fig_policies(seed)),
            other => return Err(format!("unknown figure {other:?}")),
        };
        println!("=== {name} ===");
        print!("{}", table.to_string());
        let path = format!("{out_dir}/{name}.csv");
        table.save(&path).map_err(|e| e.to_string())?;
        println!("-> {path}\n");
    }
    Ok(())
}

fn cmd_scan(rest: &[String]) -> Result<(), String> {
    let a = Args::new("hbatch throughput-scan", "throughput vs batch curve")
        .opt("workload", "resnet", "workload profile")
        .opt("device", "cpu:16", "cpu:<cores> | gpu:P100|T4|P4")
        .parse(rest)?;
    use hetero_batch::cluster::{CapacityModel, DeviceKind, GpuModel, WorkloadProfile};
    let profile = WorkloadProfile::by_name(&a.get("workload")).ok_or("bad workload")?;
    let model = CapacityModel::new(profile).with_noise(0.0);
    let dev = a.get("device");
    let device = if let Some(c) = dev.strip_prefix("cpu:") {
        DeviceKind::Cpu {
            cores: c.parse().map_err(|_| "bad core count")?,
        }
    } else if let Some(g) = dev.strip_prefix("gpu:") {
        DeviceKind::Gpu {
            model: match g {
                "P100" => GpuModel::P100,
                "T4" => GpuModel::T4,
                "P4" => GpuModel::P4,
                _ => return Err("bad gpu model".into()),
            },
        }
    } else {
        return Err("device must be cpu:<n> or gpu:<model>".into());
    };
    println!("batch,throughput_sps,iter_time_s");
    let mut b = 1.0;
    while b <= 8192.0 {
        println!(
            "{b},{:.2},{:.4}",
            model.throughput(&device, b),
            model.iter_time_det(&device, b, 1.0)
        );
        b *= 2.0;
    }
    Ok(())
}

fn cmd_info(rest: &[String]) -> Result<(), String> {
    let a = Args::new("hbatch info", "artifact inventory")
        .opt("artifacts", "artifacts", "artifacts directory")
        .parse(rest)?;
    let rt = Runtime::open(a.get("artifacts")).map_err(|e| e.to_string())?;
    for (name, m) in &rt.manifest.models {
        println!(
            "{name}: {} params ({} tensors), task={}, buckets={:?}",
            m.param_total,
            m.params.len(),
            m.task,
            m.buckets
        );
    }
    println!(
        "grad_agg kernels for K = {:?}, chunk {}",
        rt.manifest.agg.keys().collect::<Vec<_>>(),
        rt.manifest.agg_chunk
    );
    Ok(())
}
