//! # hetero-batch
//!
//! Reproduction of *"Taming Resource Heterogeneity In Distributed ML
//! Training With Dynamic Batching"* (Tyagi & Sharma, IEEE ACSOS 2020) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The paper's contribution — a proportional controller that assigns each
//! worker of a heterogeneous data-parallel cluster a mini-batch size
//! proportional to its throughput, so that iteration times equalize and
//! BSP stragglers disappear — lives in [`controller`].  Everything it
//! needs to run as a real system is built here too:
//!
//! - [`session`]: the unified training-loop API — one [`Session`] loop
//!   owns policy selection, controller observe/adjust, bucket
//!   quantization, BSP/ASP/SSP gating, slowdown/trace injection, and
//!   report assembly, over pluggable execution [`session::Backend`]s:
//!   [`session::SimBackend`] (virtual-time capacity model regenerating
//!   the paper's figures at testbed scale) and [`session::RealBackend`]
//!   (leader + workers over the PJRT runtime — the "it actually trains"
//!   path).  Build either via [`SessionBuilder`].
//! - [`runtime`]: PJRT client executing AOT-compiled JAX/Pallas train
//!   steps (HLO text artifacts, one per batch-size bucket).
//! - [`ps`]: the parameter server — λ-weighted gradient aggregation
//!   (paper Eq. 2–3) and optimizers (SGD / momentum / Adam).
//! - [`sync`]: BSP / ASP / SSP synchronization accounting, shared by
//!   both backends through the session loop.
//! - [`cluster`] + [`trace`]: heterogeneous worker capacity models
//!   (Amdahl scaling, throughput-vs-batch curves — paper Fig. 5) and
//!   time-varying availability traces (interference, spot preemptions)
//!   that drive simulated *and* real runs.
//! - [`fault`]: fault injection (crash / stall / slowdown), the
//!   progress-deadline failure detector config, and the autoscaled
//!   recovery policy that together close the unannounced-churn loop
//!   (DESIGN.md §12).
//! - [`fleet`]: the multi-tenant layer — N concurrent [`Session`]s on
//!   one shared elastic pool, deterministically interleaved on a merged
//!   virtual clock, with a [`fleet::CapacityArbiter`] granting and
//!   reclaiming slots under fair-share or strict-priority policy
//!   (DESIGN.md §13).
//! - [`ckpt`]: crash-consistent checkpoint/restore — versioned JSON
//!   snapshots of the full run closure with an atomic
//!   write→fsync→rename commit protocol, giving bit-identical resume
//!   after a coordinator crash (DESIGN.md §15).
//! - [`data`], [`metrics`], [`config`], [`figures`], [`util`]:
//!   synthetic datasets, measurement, policy selection, figure
//!   harnesses, and std-only substrates (JSON, RNG, CLI, stats, bench,
//!   proptest — this build is fully offline, so no external crates
//!   besides `xla` and `anyhow`).
//!
//! See `DESIGN.md` (repo root) for the paper→repo mapping and the
//! experiment index, and `EXPERIMENTS.md` for the recorded
//! reproductions and the §Perf iteration log.

pub mod ckpt;
pub mod cluster;
pub mod config;
pub mod controller;
pub mod data;
pub mod fault;
pub mod figures;
pub mod fleet;
pub mod metrics;
pub mod ps;
pub mod runtime;
pub mod session;
pub mod sync;
pub mod trace;
pub mod util;

pub use ckpt::{recover_latest, validate_ckpt, Checkpointer, CkptSpec, LoadedCkpt};
pub use config::Policy;
pub use controller::{BatchPolicy, DynamicBatcher, OptimalBatcher, RlBatcher, RlTable};
pub use fault::{Autoscaler, AutoscalerCfg, DetectorCfg, FaultPlan, LatePolicy};
pub use fleet::{
    job_seed, ArbiterPolicy, CapacityArbiter, FleetBuilder, FleetReport,
    FleetScheduler, JobSpec,
};
pub use session::{
    Backend, BspAgg, CkptOutcome, RealBackend, RunState, Scheduler, Session,
    SessionBuilder, SimBackend, Slowdowns, WorkerOutcome,
};
